"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall-time of the
jitted op where timing is meaningful; derived = the figure's headline metric).

  fig2_node0        paper Fig 2: centralized vs swarm vs local on Node 0 (10%)
  fig3_node3        paper Fig 3: Node 3 swarm recovery of centralized AUC
  fig4_node2_25pct  paper Fig 4: Node 2 down-sampled to 25%: swarm vs local
  scarcity_node3_5pct  §4.1 extreme-scarcity trial (5%)
  tbl_dbi           §4.3 embedding quality: swarm DBI < local DBI
  tbl_minority      §4.3 minority-class recall improvement
  merge_kernel      fused swarm-merge: Pallas-fused vs unfused XLA timing
  lora_payload      §3.2 LoRA-only sync payload vs full-model payload
  gossip_spectrum   consensus rate (spectral gap) per topology
  sync_roundtrip    host-sim 4-node sync wall time (propose+gate+commit)
  engine_roundtrip  jitted stacked engine round (local steps + gated sync)

Full protocol runs live in examples/histopathology_swarm.py; these benchmarks
use a reduced-but-faithful configuration (and reuse cached full results from
experiments/histo/*.json when present).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULT_DIR = "experiments/histo"


def _time_us(fn, *args, reps=20):
    # block BEFORE t0 so compile + the warmup's async dispatch don't leak
    # into the timed region; block after so the queue is drained at t1.
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _histo_result(tag: str, **kw):
    """Cached-or-computed paper experiment."""
    from repro.experiments.histo import HistoExperimentConfig, run_experiment
    os.makedirs(RESULT_DIR, exist_ok=True)
    path = os.path.join(RESULT_DIR, f"{tag}.json")
    if os.path.exists(path):
        return json.load(open(path))
    cfg = HistoExperimentConfig(**kw)
    r = run_experiment(cfg)
    with open(path, "w") as f:
        json.dump(r, f, indent=2, default=float)
    return r


_BASE = dict(noise=0.8, steps=400, n_train=2000, n_test=500)


def fig2_node0():
    r = _histo_result("unbalanced", **_BASE)
    c, l, s = r["centralized"]["auc"], r["local"][0]["auc"], r["swarm"][0]["auc"]
    print(f"fig2_node0_central_auc,0,{c:.4f}")
    print(f"fig2_node0_local_auc,0,{l:.4f}")
    print(f"fig2_node0_swarm_auc,0,{s:.4f}")
    print(f"fig2_node0_swarm_gain,0,{s - l:.4f}")


def fig3_node3():
    r = _histo_result("unbalanced", **_BASE)
    s = r["swarm"][3]["auc"]
    rec = r["recovery"][3]
    print(f"fig3_node3_swarm_auc,0,{s:.4f}")
    print(f"fig3_node3_recovery_of_central,0,{rec:.4f}")


def fig4_node2_25pct():
    r = _histo_result("scarcity25", scarcity={2: 0.25}, **_BASE)
    l, s = r["local"][2]["auc"], r["swarm"][2]["auc"]
    print(f"fig4_node2_local_auc,0,{l:.4f}")
    print(f"fig4_node2_swarm_auc,0,{s:.4f}")
    print(f"fig4_node2_swarm_gain,0,{s - l:.4f}")


def scarcity_node3_5pct():
    r = _histo_result("scarcity5", scarcity={3: 0.05}, **_BASE)
    l, s = r["local"][3]["auc"], r["swarm"][3]["auc"]
    print(f"scarcity_node3_local_auc,0,{l:.4f}")
    print(f"scarcity_node3_swarm_auc,0,{s:.4f}")


def tbl_dbi():
    r = _histo_result("unbalanced", **_BASE)
    ld = float(np.mean([x["dbi"] for x in r["local"]]))
    sd = float(np.mean([x["dbi"] for x in r["swarm"]]))
    print(f"tbl_dbi_local,0,{ld:.3f}")
    print(f"tbl_dbi_swarm,0,{sd:.3f}")
    print(f"tbl_dbi_reduction_pct,0,{100 * (ld - sd) / ld:.1f}")


def tbl_minority():
    r = _histo_result("unbalanced", **_BASE)
    minority = 2  # rarest class by construction
    lr = float(np.mean([x["per_class_recall"][minority] for x in r["local"]]))
    sr = float(np.mean([x["per_class_recall"][minority] for x in r["swarm"]]))
    print(f"tbl_minority_recall_local,0,{lr:.4f}")
    print(f"tbl_minority_recall_swarm,0,{sr:.4f}")
    print(f"tbl_minority_recall_gain_pts,0,{100 * (sr - lr):.2f}")


def merge_kernel():
    from repro.kernels.fused_merge import fused_merge
    from repro.kernels.ref import fused_merge_ref
    n, d = 4, 1 << 20
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    w = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
    ref_jit = jax.jit(lambda: fused_merge_ref(x, w, 0, True))
    us_ref = _time_us(lambda: ref_jit())
    print(f"merge_unfused_xla_4x1M,{us_ref:.1f},baseline")
    # correctness of the fused kernel on the same inputs (interpret on CPU)
    got = fused_merge(x, w, 0, True, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_jit())))
    print(f"merge_fused_pallas_validated,0,maxerr={err:.2e}")
    # all-nodes form (the engine's commit): one launch for every node's row
    from repro.kernels.fused_merge import fused_merge_all
    Wm = jnp.tile(w[None, :], (n, 1))
    gates = jnp.asarray([1, 0, 1, 1], jnp.int32)
    got_all = fused_merge_all(x, Wm, gates, interpret=True)
    want_all = jnp.where(gates[:, None].astype(bool), Wm @ x, x)
    err = float(jnp.max(jnp.abs(got_all - want_all)))
    print(f"merge_fused_all_nodes_validated,0,maxerr={err:.2e}")
    # derived: HBM-roofline time for the fused pass on TPU v5e
    bytes_moved = (n + 1) * d * 4
    print(f"merge_fused_v5e_roofline_us,0,{bytes_moved / 819e9 * 1e6:.1f}")


def lora_payload():
    from repro.configs import get_config, smoke_variant
    from repro.core.lora import inject_lora, payload_bytes
    from repro.models import build_model
    cfg = get_config("internvl2-1b")
    model = build_model(smoke_variant(cfg).replace(vocab_size=2048))
    params = model.init(jax.random.key(0))
    lp = inject_lora(params, jax.random.key(1), rank=16)
    full = payload_bytes(lp, False)
    lora = payload_bytes(lp, True)
    print(f"lora_payload_bytes,0,{lora}")
    print(f"full_payload_bytes,0,{full}")
    print(f"lora_payload_fraction,0,{lora / full:.4f}")
    # production-scale derived numbers (analytic, bf16)
    big = get_config("command-r-plus-104b")
    full_b = big.param_count() * 2
    d, f, L = big.d_model, big.d_ff, big.n_layers
    ad = L * 16 * (4 * 2 * d + 3 * (d + f)) * 2  # rank-16 adapters, bf16
    print(f"command-r_full_sync_GiB,0,{full_b / 2**30:.1f}")
    print(f"command-r_lora_sync_GiB,0,{ad / 2**30:.3f}")


def gossip_spectrum():
    from repro.core.topology import build_matrix, spectral_gap
    for topo_name, n in [("full", 4), ("ring", 4), ("ring", 16)]:
        W = build_matrix(topo_name, n)
        print(f"gossip_gap_{topo_name}{n},0,{spectral_gap(W):.4f}")


def sync_roundtrip():
    from repro.configs.base import SwarmConfig
    from repro.core.swarm import NodeState, SwarmLearner
    rng = np.random.default_rng(0)
    tree = lambda: {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
    nodes = [NodeState(params=tree(), opt_state=None, data_size=100)
             for _ in range(4)]
    sw = SwarmLearner(
        SwarmConfig(n_nodes=4, sync_every=1, lora_only=False, topology="full"),
        train_step_fn=lambda p, o, b, s: (p, o, {}),
        eval_fn=lambda p, v: 1.0, nodes=nodes)
    sw.sync([1, 1, 1, 1])  # compile the jitted propose/commit outside timing
    t0 = time.perf_counter()
    reps = 10
    for _ in range(reps):
        sw.sync([1, 1, 1, 1])
    us = (time.perf_counter() - t0) / reps * 1e6
    print(f"sync_roundtrip_4node_host,{us:.1f},propose+gate+commit")


def engine_roundtrip():
    """The jitted stacked engine: sync_every local steps + propose + gate +
    fused commit as ONE compiled call (vs sync_roundtrip's host-driven sync)."""
    from repro.configs.base import SwarmConfig
    from repro.core.engine import SwarmEngine
    rng = np.random.default_rng(0)
    n, t = 4, 4
    params = {"w": jnp.asarray(rng.normal(0, 1, (n, 64, 64)), jnp.float32)}
    opt = {"m": jnp.zeros_like(params["w"])}

    def train_step(p, o, b, s):
        g = p["w"] * 1e-3
        return {"w": p["w"] - g}, {"m": o["m"] + g}, {"loss": jnp.sum(g * g)}

    def eval_fn(p, v):
        return 1.0 - 0.0 * jnp.sum(p["w"])  # always accept, stays in-graph

    eng = SwarmEngine(
        SwarmConfig(n_nodes=n, sync_every=t, lora_only=False, topology="full"),
        train_step, eval_fn)
    batches = jnp.zeros((t, n, 1))
    val = jnp.zeros((n, 1))
    state = {"p": params, "o": opt}

    def once():  # buffers are donated, so thread the state through
        p, o, _ = eng.round(state["p"], state["o"], batches, val, None, 0)
        state["p"], state["o"] = p, o
        return p["w"]

    us = _time_us(once)
    print(f"engine_round_4node_{t}steps,{us:.1f},"
          f"jitted local+propose+gate+fused_commit")


ALL = [fig2_node0, fig3_node3, fig4_node2_25pct, scarcity_node3_5pct,
       tbl_dbi, tbl_minority, merge_kernel, lora_payload, gossip_spectrum,
       sync_roundtrip, engine_roundtrip]


def roofline_table():
    """Append the §Roofline rows when a dry-run matrix is present."""
    from benchmarks.roofline import load_rows
    rows = load_rows("experiments/dryrun")
    for r in rows:
        print(f"roofline_{r['arch']}_{r['shape']},0,"
              f"compute={r['compute_s']:.3e};memory={r['memory_s']:.3e};"
              f"collective={r['collective_s']:.3e};dominant={r['dominant']};"
              f"useful={r['useful_ratio']:.3f};peakGiB={r['peak_gib']:.1f}")


def main() -> None:
    print("name,us_per_call,derived")
    for fn in ALL + [roofline_table]:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},0,ERROR:{e!r}")


if __name__ == "__main__":
    main()
