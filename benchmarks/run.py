"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall-time of the
jitted op where timing is meaningful; derived = the figure's headline metric).

  fig2_node0        paper Fig 2: centralized vs swarm vs local on Node 0 (10%)
  fig3_node3        paper Fig 3: Node 3 swarm recovery of centralized AUC
  fig4_node2_25pct  paper Fig 4: Node 2 down-sampled to 25%: swarm vs local
  scarcity_node3_5pct  §4.1 extreme-scarcity trial (5%)
  tbl_dbi           §4.3 embedding quality: swarm DBI < local DBI
  tbl_minority      §4.3 minority-class recall improvement
  merge_kernel      fused swarm-merge: Pallas-fused vs unfused XLA timing,
                    incl. the importance-weighted (fisher/gradmatch) form
  lora_payload      §3.2 LoRA-only sync payload vs full-model payload
  gossip_spectrum   consensus rate (spectral gap) per topology
  sync_roundtrip    host-sim 4-node sync wall time (propose+gate+commit)
  engine_roundtrip  jitted stacked engine round (local steps + gated sync)
  overlap_roundtrip double-buffered stale-by-one rounds vs serial rounds
  dynamic_membership SwarmSession join/leave schedule: wall time per round +
                    retrace count (must stay at the single warmup trace —
                    membership is runtime data in the compiled round)
  spmd_parity       full SwarmEngine(backend="gossip") round vs the host
                    backend on a forced CPU device mesh (subprocess):
                    wall time + estimated collective bytes per sync
  swarm_sync        wire-efficiency suite: wall time + cost-model predicted
                    bytes/sync for every sync schedule × topology × wire
                    dtype, written machine-readable to BENCH_swarm_sync.json
  ring_sync_parity  ring-native two-ppermute topo-fisher gossip vs the
                    single-gather fallback on a forced CPU mesh
                    (subprocess): committed-params diff vs the host oracle
                    + HLO-measured collective bytes (~4·P vs 2·N·P)
  mesh_wire         int8 EF wire on the mesh gossip path: q8 schedules vs
                    their f32 forms on a forced CPU mesh (subprocess) —
                    settled-parity diff, wall time, HLO-measured collective
                    bytes (the ~4x shrink)
  hier_sync         hierarchical pod-delegate q8 schedule vs the flat ring
                    q8 on a forced-CPU 2x2 ("pod", "node") mesh
                    (subprocess): wall time + HLO-measured bytes split per
                    link class (intra-pod vs cross-pod) next to the cost
                    model's per-class prediction
  serve             serving plane (PR 8): continuous batching vs naive
                    one-request-at-a-time dispatch × consensus/average
                    ensemble modes — requests/sec, p99 latency and timed-
                    region retrace counts, written to BENCH_serve.json
  hetero_swarm      heterogeneous swarm (ISSUE 10): the scenario grid
                    (iid / paper / biased-label / synthetic-augmented /
                    dirichlet partitions) over the frozen-backbone model
                    zoo with adapter-only ``payload="lora"`` int8 sync —
                    per-cell wall time, wire bytes vs the full-payload f32
                    counterfactual, per-site gate-metric spread vs the
                    centralized oracle and retrace counters, written to
                    BENCH_hetero.json
  fault_matrix      chaos plane (ISSUE 9): every FaultPlan kind (crash,
                    straggle, drop, corrupt, preempt) × backend × merge,
                    replayed against a fault-free twin — rounds-to-recover,
                    final loss delta and retrace counts per cell, written
                    to BENCH_faults.json (gossip q8 cells run in a
                    forced-CPU-mesh subprocess on full runs)

``--smoke`` runs a seconds-scale subset (tiny shapes, no cached experiment
protocol) so CI can exercise every benchmark entry point; a tier-1 test
invokes it, keeping this harness from rotting. Smoke JSON sections land in
the gitignored ``.bench/`` scratch copy, never in the committed
BENCH_swarm_sync.json (CI asserts the tree stays clean).

Full protocol runs live in examples/histopathology_swarm.py; these benchmarks
use a reduced-but-faithful configuration (and reuse cached full results from
experiments/histo/*.json when present).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULT_DIR = "experiments/histo"
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
BENCH_SYNC_JSON = os.path.join(_ROOT, "BENCH_swarm_sync.json")
# --smoke sections land in a gitignored scratch file: tier-1 / CI runs must
# never read-modify-write the committed perf-trajectory artifact (machine-
# local timings would dirty the tree on every test run)
BENCH_SCRATCH_JSON = os.path.join(_ROOT, ".bench", "BENCH_swarm_sync.json")
BENCH_SERVE_JSON = os.path.join(_ROOT, "BENCH_serve.json")


def _bench_json_update(section: str, data, smoke: bool = False,
                       filename: str = "BENCH_swarm_sync.json") -> str:
    """Merge one section into a machine-readable BENCH json (the committed
    file for explicit full runs, the ``.bench/`` scratch copy for --smoke)."""
    path = os.path.abspath(os.path.join(_ROOT, ".bench", filename) if smoke
                           else os.path.join(_ROOT, filename))
    os.makedirs(os.path.dirname(path), exist_ok=True)
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except Exception:  # noqa: BLE001 — regenerate a corrupt file
            doc = {}
    doc["schema"] = 1
    doc[section] = data
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=float)
    return path


def _time_us(fn, *args, reps=20):
    # block BEFORE t0 so compile + the warmup's async dispatch don't leak
    # into the timed region; block after so the queue is drained at t1.
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _histo_result(tag: str, **kw):
    """Cached-or-computed paper experiment."""
    from repro.experiments.histo import HistoExperimentConfig, run_experiment
    os.makedirs(RESULT_DIR, exist_ok=True)
    path = os.path.join(RESULT_DIR, f"{tag}.json")
    if os.path.exists(path):
        return json.load(open(path))
    cfg = HistoExperimentConfig(**kw)
    r = run_experiment(cfg)
    with open(path, "w") as f:
        json.dump(r, f, indent=2, default=float)
    return r


_BASE = dict(noise=0.8, steps=400, n_train=2000, n_test=500)


def fig2_node0():
    r = _histo_result("unbalanced", **_BASE)
    c, l, s = r["centralized"]["auc"], r["local"][0]["auc"], r["swarm"][0]["auc"]
    print(f"fig2_node0_central_auc,0,{c:.4f}")
    print(f"fig2_node0_local_auc,0,{l:.4f}")
    print(f"fig2_node0_swarm_auc,0,{s:.4f}")
    print(f"fig2_node0_swarm_gain,0,{s - l:.4f}")


def fig3_node3():
    r = _histo_result("unbalanced", **_BASE)
    s = r["swarm"][3]["auc"]
    rec = r["recovery"][3]
    print(f"fig3_node3_swarm_auc,0,{s:.4f}")
    print(f"fig3_node3_recovery_of_central,0,{rec:.4f}")


def fig4_node2_25pct():
    r = _histo_result("scarcity25", scarcity={2: 0.25}, **_BASE)
    l, s = r["local"][2]["auc"], r["swarm"][2]["auc"]
    print(f"fig4_node2_local_auc,0,{l:.4f}")
    print(f"fig4_node2_swarm_auc,0,{s:.4f}")
    print(f"fig4_node2_swarm_gain,0,{s - l:.4f}")


def scarcity_node3_5pct():
    r = _histo_result("scarcity5", scarcity={3: 0.05}, **_BASE)
    l, s = r["local"][3]["auc"], r["swarm"][3]["auc"]
    print(f"scarcity_node3_local_auc,0,{l:.4f}")
    print(f"scarcity_node3_swarm_auc,0,{s:.4f}")


def tbl_dbi():
    r = _histo_result("unbalanced", **_BASE)
    ld = float(np.mean([x["dbi"] for x in r["local"]]))
    sd = float(np.mean([x["dbi"] for x in r["swarm"]]))
    print(f"tbl_dbi_local,0,{ld:.3f}")
    print(f"tbl_dbi_swarm,0,{sd:.3f}")
    print(f"tbl_dbi_reduction_pct,0,{100 * (ld - sd) / ld:.1f}")


def tbl_minority():
    r = _histo_result("unbalanced", **_BASE)
    minority = 2  # rarest class by construction
    lr = float(np.mean([x["per_class_recall"][minority] for x in r["local"]]))
    sr = float(np.mean([x["per_class_recall"][minority] for x in r["swarm"]]))
    print(f"tbl_minority_recall_local,0,{lr:.4f}")
    print(f"tbl_minority_recall_swarm,0,{sr:.4f}")
    print(f"tbl_minority_recall_gain_pts,0,{100 * (sr - lr):.2f}")


def merge_kernel(d: int = 1 << 20):
    from repro.core.merge_impl import fisher_merge
    from repro.kernels.fused_merge import fused_merge
    from repro.kernels.ref import fused_merge_ref
    n = 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    w = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
    ref_jit = jax.jit(lambda: fused_merge_ref(x, w, 0, True))
    us_ref = _time_us(lambda: ref_jit())
    print(f"merge_unfused_xla_4x{d},{us_ref:.1f},baseline")
    # correctness of the fused kernel on the same inputs (interpret on CPU)
    got = fused_merge(x, w, 0, True, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_jit())))
    print(f"merge_fused_pallas_validated,0,maxerr={err:.2e}")
    # all-nodes form (the engine's commit): one launch for every node's row
    from repro.kernels.fused_merge import fused_merge_all
    Wm = jnp.tile(w[None, :], (n, 1))
    gates = jnp.asarray([1, 0, 1, 1], jnp.int32)
    got_all = fused_merge_all(x, Wm, gates, interpret=True)
    want_all = jnp.where(gates[:, None].astype(bool), Wm @ x, x)
    err = float(jnp.max(jnp.abs(got_all - want_all)))
    print(f"merge_fused_all_nodes_validated,0,maxerr={err:.2e}")
    # importance-weighted form (the fisher/gradmatch commit)
    f = jnp.asarray(np.abs(rng.normal(1, 0.5, (n, d))), jnp.float32) + 1e-8
    got_imp = fused_merge_all(x, jnp.ones((n, n)), gates, f, interpret=True)
    want_m = fisher_merge({"x": x}, {"x": f - 1e-8})["x"]
    want_imp = jnp.where(gates[:, None].astype(bool), want_m, x)
    err = float(jnp.max(jnp.abs(got_imp - want_imp)))
    print(f"merge_fused_weighted_validated,0,maxerr={err:.2e}")
    # derived: HBM-roofline time for the fused passes on TPU v5e
    bytes_moved = (n + 1) * d * 4
    print(f"merge_fused_v5e_roofline_us,0,{bytes_moved / 819e9 * 1e6:.1f}")
    bytes_weighted = (2 * n + 1) * d * 4  # params + importance tiles in
    print(f"merge_fused_weighted_v5e_roofline_us,0,"
          f"{bytes_weighted / 819e9 * 1e6:.1f}")


def lora_payload():
    from repro.configs import get_config, smoke_variant
    from repro.core.lora import inject_lora, payload_bytes
    from repro.models import build_model
    cfg = get_config("internvl2-1b")
    model = build_model(smoke_variant(cfg).replace(vocab_size=2048))
    params = model.init(jax.random.key(0))
    lp = inject_lora(params, jax.random.key(1), rank=16)
    full = payload_bytes(lp, False)
    lora = payload_bytes(lp, True)
    print(f"lora_payload_bytes,0,{lora}")
    print(f"full_payload_bytes,0,{full}")
    print(f"lora_payload_fraction,0,{lora / full:.4f}")
    # production-scale derived numbers (analytic, bf16)
    big = get_config("command-r-plus-104b")
    full_b = big.param_count() * 2
    d, f, L = big.d_model, big.d_ff, big.n_layers
    ad = L * 16 * (4 * 2 * d + 3 * (d + f)) * 2  # rank-16 adapters, bf16
    print(f"command-r_full_sync_GiB,0,{full_b / 2**30:.1f}")
    print(f"command-r_lora_sync_GiB,0,{ad / 2**30:.3f}")


def gossip_spectrum():
    from repro.core.topology import build_matrix, spectral_gap
    for topo_name, n in [("full", 4), ("ring", 4), ("ring", 16)]:
        W = build_matrix(topo_name, n)
        print(f"gossip_gap_{topo_name}{n},0,{spectral_gap(W):.4f}")


def sync_roundtrip():
    from repro.configs.base import SwarmConfig
    from repro.core.swarm import NodeState, SwarmLearner
    rng = np.random.default_rng(0)
    tree = lambda: {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
    nodes = [NodeState(params=tree(), opt_state=None, data_size=100)
             for _ in range(4)]
    sw = SwarmLearner(
        SwarmConfig(n_nodes=4, sync_every=1, lora_only=False, topology="full"),
        train_step_fn=lambda p, o, b, s: (p, o, {}),
        eval_fn=lambda p, v: 1.0, nodes=nodes)
    sw.sync([1, 1, 1, 1])  # compile the jitted propose/commit outside timing
    t0 = time.perf_counter()
    reps = 10
    for _ in range(reps):
        sw.sync([1, 1, 1, 1])
    us = (time.perf_counter() - t0) / reps * 1e6
    print(f"sync_roundtrip_4node_host,{us:.1f},propose+gate+commit")


def engine_roundtrip():
    """The jitted stacked engine: sync_every local steps + propose + gate +
    fused commit as ONE compiled call (vs sync_roundtrip's host-driven sync)."""
    from repro.configs.base import SwarmConfig
    from repro.core.engine import SwarmEngine
    rng = np.random.default_rng(0)
    n, t = 4, 4
    params = {"w": jnp.asarray(rng.normal(0, 1, (n, 64, 64)), jnp.float32)}
    opt = {"m": jnp.zeros_like(params["w"])}

    def train_step(p, o, b, s):
        g = p["w"] * 1e-3
        return {"w": p["w"] - g}, {"m": o["m"] + g}, {"loss": jnp.sum(g * g)}

    def eval_fn(p, v):
        return 1.0 - 0.0 * jnp.sum(p["w"])  # always accept, stays in-graph

    eng = SwarmEngine(
        SwarmConfig(n_nodes=n, sync_every=t, lora_only=False, topology="full"),
        train_step, eval_fn)
    batches = jnp.zeros((t, n, 1))
    val = jnp.zeros((n, 1))
    state = {"p": params, "o": opt}

    def once():  # buffers are donated, so thread the state through
        p, o, _ = eng.round(state["p"], state["o"], batches, val, None, 0)
        state["p"], state["o"] = p, o
        return p["w"]

    us = _time_us(once)
    print(f"engine_round_4node_{t}steps,{us:.1f},"
          f"jitted local+propose+gate+fused_commit")


def overlap_roundtrip(reps: int = 10):
    """Stale-by-one double-buffered rounds vs serial rounds, host backend:
    the overlap schedule must cost no more than serial (same work + one add;
    on hardware with async collectives the merge then hides behind the next
    round's local steps)."""
    from repro.configs.base import SwarmConfig
    from repro.core.engine import SwarmEngine
    rng = np.random.default_rng(0)
    n, t, r = 4, 8, 4
    w0 = jnp.asarray(rng.normal(0, 0.1, (n, 128, 128)), jnp.float32)
    batches = jnp.zeros((r, t, n, 1))
    val = jnp.zeros((n, 1))

    def train_step(p, o, b, s):
        # a real (matmul) local step so the sync/compute share is
        # representative — overlap's extra adds must amortize against it
        g = jnp.tanh(p["w"] @ p["w"].T) * 1e-3
        return {"w": p["w"] - g}, {"m": o["m"] + g}, {"loss": jnp.sum(g * g)}

    def eval_fn(p, v):
        return 1.0 - 0.0 * jnp.sum(p["w"])

    def make_runner(overlap):
        cfg = SwarmConfig(n_nodes=n, sync_every=t, topology="full",
                          merge="fedavg", lora_only=False, val_threshold=0.0,
                          overlap_sync=overlap)
        eng = SwarmEngine(cfg, train_step, eval_fn)
        # fresh buffers per config: the engine donates (params, opt_state)
        state = {"p": {"w": w0.copy()}, "o": {"m": jnp.zeros_like(w0)}}

        def once():
            p, o, _, _ = eng.run_rounds(state["p"], state["o"], batches, val,
                                        None, 0)
            state["p"], state["o"] = p, o
            return p["w"]

        return once

    runners = {ov: make_runner(ov) for ov in (False, True)}
    # alternate measurement passes and keep the per-mode minimum — the
    # robust floor estimate on a noisy shared-CPU runner
    times = {False: float("inf"), True: float("inf")}
    for _ in range(3):
        for ov in (False, True):
            times[ov] = min(times[ov], _time_us(runners[ov], reps=reps))
    for ov in (False, True):
        name = "overlap" if ov else "serial"
        print(f"engine_round_{name}_us,{times[ov] / r:.1f},"
              f"{r}rounds_x{t}steps_fedavg")
    print(f"overlap_vs_serial_ratio,0,{times[True] / times[False]:.3f}")


def dynamic_membership(rounds_per_phase: int = 4, d: int = 128):
    """ROADMAP dynamic-membership scenario: a join→leave→rejoin schedule
    driven through `SwarmSession.round` — wall time per round plus the
    retrace count across the whole schedule (the compiled round must be
    traced exactly once; membership flips are pure state updates)."""
    from repro.configs.base import SwarmConfig
    from repro.core.session import SwarmSession

    rng = np.random.default_rng(0)
    n, t = 4, 4
    traces = []

    def train_step(p, o, b, s):
        traces.append(1)  # python body runs once per (re)trace only
        g = jnp.tanh(p["w"] @ p["w"].T) * 1e-3
        return {"w": p["w"] - g}, {"m": o["m"] + g}, {"loss": jnp.sum(g * g)}

    def eval_fn(p, v):
        return 1.0 - 0.0 * jnp.sum(p["w"])

    w0 = jnp.asarray(rng.normal(0, 0.1, (d, d)), jnp.float32)
    sess = SwarmSession(
        SwarmConfig(n_nodes=n, sync_every=t, topology="dynamic",
                    merge="fedavg", lora_only=False, val_threshold=0.0),
        train_step, eval_fn, params={"w": w0},
        opt_state={"m": jnp.zeros_like(w0)}, data_sizes=[1.0] * n)
    batches = jnp.zeros((t, n, 1))
    val = jnp.zeros((n, 1))

    # schedule: all-active -> node 3 leaves -> node 3 rejoins & node 1 leaves
    phases = [lambda: None, lambda: sess.leave(3),
              lambda: (sess.join(3), sess.leave(1))]
    sess.round(batches, val)  # warmup: the one and only trace/compile
    warmup_traces = len(traces)
    t0 = time.perf_counter()
    n_rounds = 0
    for phase in phases:
        phase()
        for _ in range(rounds_per_phase):
            out = sess.round(batches, val)
            n_rounds += 1
    jax.block_until_ready(out["gates"])
    us = (time.perf_counter() - t0) / n_rounds * 1e6
    print(f"dynamic_membership_round_us,{us:.1f},"
          f"{n_rounds}rounds_join_leave_rejoin")
    print(f"dynamic_membership_retraces,0,"
          f"{len(traces) - warmup_traces}")
    print(f"dynamic_membership_final_active,0,"
          f"{''.join(str(int(b)) for b in sess.active)}")


def dynamic_membership_smoke():
    dynamic_membership(rounds_per_phase=2, d=32)


def _spmd_parity_inner(n: int, t: int, d: int, reps: int):
    """Runs inside the forced-device-count subprocess: one full engine round
    per backend (host vs gossip) on identical state, timed + compared."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import SwarmConfig
    from repro.core.engine import SwarmEngine

    assert jax.device_count() >= n, "inner bench needs the forced device count"
    mesh = jax.make_mesh((n,), ("node",), devices=jax.devices()[:n])
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    batches = jnp.zeros((t, n, 1))
    val = jnp.zeros((n, 1))
    sizes = [float(i + 1) for i in range(n)]

    finals = {}
    for backend in ("host", "gossip"):
        cfg = SwarmConfig(n_nodes=n, sync_every=t, topology="full",
                          merge="fedavg", lora_only=False, val_threshold=0.0)
        # fresh buffers per backend: the engine donates (params, opt_state)
        params, opt = {"w": w0.copy()}, {"m": jnp.zeros_like(w0)}
        kw = {}
        if backend == "gossip":
            kw = dict(backend="gossip", mesh=mesh, axis="node")
            sh = NamedSharding(mesh, P("node"))
            params = jax.device_put(params, sh)
            opt = jax.device_put(opt, sh)

        def train_step(p, o, b, s):
            g = p["w"] * 1e-3 + 0.0 * b.mean()
            return ({"w": p["w"] - g}, {"m": o["m"] + g},
                    {"loss": jnp.sum(g * g)})

        def eval_fn(p, v):
            return 1.0 - 0.0 * jnp.sum(p["w"])

        eng = SwarmEngine(cfg, train_step, eval_fn, data_sizes=sizes, **kw)
        state = {"p": params, "o": opt}

        def once():
            p, o, _ = eng.round(state["p"], state["o"], batches, val, None, 0)
            state["p"], state["o"] = p, o
            return p["w"]

        us = _time_us(once, reps=reps)
        finals[backend] = (us, np.asarray(state["p"]["w"]))
        print(f"spmd_parity_{backend}_round_us,{us:.1f},n={n};t={t};d={d}")

    err = float(np.abs(finals["host"][1] - finals["gossip"][1]).max())
    print(f"spmd_parity_max_abs_diff,0,{err:.2e}")
    print(f"spmd_parity_gossip_over_host,0,"
          f"{finals['gossip'][0] / finals['host'][0]:.3f}")
    # estimated collective bytes per sync, per device: the fedavg psum
    # lowers to a ring allreduce over the [d] merged payload
    bytes_sync = 2 * d * 4 * (n - 1) / n
    print(f"spmd_parity_collective_bytes_per_sync,0,{bytes_sync:.0f}")


def spmd_parity(smoke: bool = False):
    """ROADMAP SPMD engine parity: a full SwarmEngine(backend="gossip") round
    vs the host backend on a multi-device CPU mesh. Runs in a subprocess so
    the forced host device count doesn't leak into other benchmarks."""
    import subprocess
    import sys
    n, t, d, reps = (4, 2, 1 << 12, 3) if smoke else (4, 4, 1 << 16, 10)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}").strip()
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__),
         "--inner-spmd-parity", f"{n},{t},{d},{reps}"],
        capture_output=True, text=True, env=env, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(f"spmd parity subprocess failed: "
                           f"{out.stderr[-800:]}")
    print(out.stdout, end="")


def spmd_parity_smoke():
    spmd_parity(smoke=True)


def swarm_sync(smoke: bool = False):
    """Wire-efficiency suite (ISSUE 4): one engine-backend session per sync
    schedule × topology × merge × wire dtype, reporting the comms cost
    model's predicted bytes/sync next to measured round wall time; rows are
    written machine-readable to BENCH_swarm_sync.json so the perf
    trajectory populates."""
    from repro.configs.base import SwarmConfig
    from repro.core.session import SwarmSession

    n, t, d, reps = (4, 2, 1 << 12, 3) if smoke else (4, 4, 1 << 16, 10)
    if smoke:
        combos = [("full", "fedavg", "f32"), ("ring", "fisher", "f32"),
                  ("ring", "fisher", "int8"), ("dynamic", "fisher", "bf16")]
    else:
        combos = [(topo, merge, wd)
                  for topo in ("full", "ring", "dynamic")
                  for merge in ("fedavg", "fisher")
                  for wd in ("f32", "bf16", "int8")]
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(0, 0.1, (d,)), jnp.float32)
    batches = jnp.zeros((t, n, 1))
    val = jnp.zeros((n, 1))

    def train_step(p, o, b, s):
        g = p["w"] * 1e-3 + 0.0 * b.mean()
        return {"w": p["w"] - g}, {"m": o["m"] + g}, {"loss": jnp.sum(g * g)}

    def eval_fn(p, v):
        return 1.0 - 0.0 * jnp.sum(p["w"])

    rows = []
    for topo, merge, wd in combos:
        cfg = SwarmConfig(n_nodes=n, sync_every=t, topology=topo, merge=merge,
                          lora_only=False, val_threshold=0.0, wire_dtype=wd)
        sess = SwarmSession(cfg, train_step, eval_fn, params={"w": w0},
                            opt_state={"m": jnp.zeros_like(w0)},
                            data_sizes=[float(i + 1) for i in range(n)])

        def once():
            return sess.round(batches, val)["gates"]

        us = _time_us(once, reps=reps)
        s = sess.sync_schedule
        link = sess.predicted_link_bytes
        rows.append(dict(
            schedule=s.name, collective=s.collective, topology=topo,
            merge=merge, wire_dtype=wd, n_nodes=n,
            # engine-backend sessions simulate a flat 1-D swarm mesh; the
            # per-link-class split keys every row the same way the two-level
            # hier_sync rows are keyed (cross is 0 on a flat mesh)
            mesh_shape=[n],
            payload_params=sess.payload_params,
            predicted_bytes_per_sync=sess.predicted_sync_bytes,
            predicted_intra_bytes=link["intra"],
            predicted_cross_bytes=link["cross"],
            wall_us_per_round=us, simulated=s.simulated))
        print(f"swarm_sync_{topo}_{merge}_{wd},{us:.1f},"
              f"sched={s.name};bytes={sess.predicted_sync_bytes:.0f}")
    # smoke writes its own section INTO THE SCRATCH FILE so CI runs never
    # touch the committed full-grid rows (the perf-trajectory artifact)
    path = _bench_json_update("schedules_smoke" if smoke else "schedules",
                              rows, smoke=smoke)
    print(f"swarm_sync_json,0,{path}")


def swarm_sync_smoke():
    swarm_sync(smoke=True)


def _ring_sync_parity_inner(n: int, d: int, reps: int):
    """Runs inside the forced-device-count subprocess: ring-native
    two-ppermute topo-fisher gossip vs the single-gather fallback, both
    against the host numpy oracle, with HLO-measured collective bytes."""
    from repro.core import gossip
    from repro.core.merge_impl import topo_weighted_merge
    from repro.core.topology import build_matrix, ring_structured
    from repro.launch import hlo_stats

    assert jax.device_count() >= n, "inner bench needs the forced device count"
    mesh = jax.make_mesh((n,), ("node",), devices=jax.devices()[:n])
    rng = np.random.default_rng(0)
    x = {"w": jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)}
    f = {"w": jnp.asarray(np.abs(rng.normal(1, 0.4, (n, d))), jnp.float32)}
    W = build_matrix("ring", n)
    assert ring_structured(W)
    want = np.asarray(topo_weighted_merge(x, f, W)["w"])

    fns = {
        "ppermute": jax.jit(lambda a, b: gossip.ring_topo_fisher_gossip(
            a, b, W, mesh, "node")),
        "gathered": jax.jit(lambda a, b: gossip.topo_fisher_gossip(
            a, b, W, mesh, "node")),
    }
    got = {}
    for name, fn in fns.items():
        out = np.asarray(fn(x, f)["w"])
        err = float(np.abs(out - want).max())
        us = _time_us(lambda fn=fn: fn(x, f)["w"], reps=reps)
        coll = hlo_stats.collective_bytes(fn.lower(x, f).compile().as_text())
        got[name] = (us, err, coll["total"])
        print(f"ring_sync_{name}_us,{us:.1f},n={n};d={d}")
        print(f"ring_sync_{name}_max_diff,0,{err:.2e}")
        print(f"ring_sync_{name}_coll_bytes,0,{coll['total']}")
    # per the collective-bytes estimator: ring two-ppermute payload is the
    # fused (F⊙θ ⊕ F) side-channel = ~4·P f32 values; the gather is 2·N·P
    print(f"ring_sync_ppermute_P_values,0,{got['ppermute'][2] / 4 / d:.2f}")
    print(f"ring_sync_bytes_ratio,0,"
          f"{got['ppermute'][2] / got['gathered'][2]:.3f}")


def ring_sync_parity(smoke: bool = False):
    """Forced-CPU-mesh ring-ppermute parity (subprocess, like spmd_parity):
    keeps the ring-native schedule honest on dev boxes without a mesh."""
    import subprocess
    import sys
    n, d, reps = (4, 1 << 12, 3) if smoke else (4, 1 << 16, 10)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}").strip()
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__),
         "--inner-ring-sync", f"{n},{d},{reps}"],
        capture_output=True, text=True, env=env, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(f"ring sync parity subprocess failed: "
                           f"{out.stderr[-800:]}")
    print(out.stdout, end="")
    rows = [dict(zip(("name", "us", "derived"), line.split(",", 2)))
            for line in out.stdout.strip().splitlines() if "," in line]
    _bench_json_update("ring_parity_smoke" if smoke else "ring_parity", rows,
                       smoke=smoke)


def ring_sync_parity_smoke():
    ring_sync_parity(smoke=True)


def _mesh_wire_inner(n: int, d: int, reps: int):
    """Runs inside the forced-device-count subprocess: the int8 mesh EF wire
    (q8 ring + q8 psum schedules) vs their f32 forms — committed-params
    parity after EF settling, wall time, and HLO-measured collective bytes
    (the ~4x wire shrink the cost model promises)."""
    from repro.core import gossip
    from repro.core.merge_impl import topo_weighted_merge
    from repro.core.topology import build_matrix
    from repro.launch import hlo_stats

    assert jax.device_count() >= n, "inner bench needs the forced device count"
    mesh = jax.make_mesh((n,), ("node",), devices=jax.devices()[:n])
    rng = np.random.default_rng(0)
    wb = 128
    x = {"w": jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)}
    f = {"w": jnp.asarray(np.abs(rng.normal(1, 0.4, (n, d))), jnp.float32)}
    W = build_matrix("ring", n)
    want = np.asarray(topo_weighted_merge(x, f, W)["w"])

    wire0 = gossip.init_mesh_wire("ring_topo_ppermute", x, n_shards=n,
                                  wire_block=wb)
    q8 = jax.jit(lambda t, ff, w: gossip.ring_topo_fisher_gossip_q8(
        t, ff, W, w, mesh, "node", wire_block=wb))
    f32 = jax.jit(lambda t, ff: gossip.ring_topo_fisher_gossip(
        t, ff, W, mesh, "node"))
    wire = wire0
    for _ in range(6):   # settle the EF references
        merged, wire = q8(x, f, wire)
    err = float(np.abs(np.asarray(merged["w"]) - want).max())
    us_q8 = _time_us(lambda: q8(x, f, wire0)[0]["w"], reps=reps)
    us_f32 = _time_us(lambda: f32(x, f)["w"], reps=reps)
    cq = hlo_stats.collective_bytes(
        q8.lower(x, f, wire0).compile().as_text())
    cf = hlo_stats.collective_bytes(f32.lower(x, f).compile().as_text())
    print(f"mesh_wire_q8_round_us,{us_q8:.1f},n={n};d={d};wb={wb}")
    print(f"mesh_wire_f32_round_us,{us_f32:.1f},n={n};d={d}")
    print(f"mesh_wire_q8_settled_max_diff,0,{err:.2e}")
    print(f"mesh_wire_q8_coll_bytes,0,{cq['total']}")
    print(f"mesh_wire_f32_coll_bytes,0,{cf['total']}")
    print(f"mesh_wire_bytes_ratio,0,{cq['total'] / cf['total']:.3f}")
    # the compression-aware psum: int8 reduce-scatter chunks vs f32 psum
    wv = jnp.full((n,), 1.0 / n, jnp.float32)
    pw0 = gossip.init_mesh_wire("fedavg_psum_q8", x, n_shards=n,
                                wire_block=wb)
    pq = jax.jit(lambda t, w: gossip.fedavg_psum_q8(t, wv, w, mesh, "node",
                                                    wire_block=wb))
    pf = jax.jit(lambda t: gossip.fedavg_gossip(t, wv, mesh, "node"))
    cq2 = hlo_stats.collective_bytes(pq.lower(x, pw0).compile().as_text())
    cf2 = hlo_stats.collective_bytes(pf.lower(x).compile().as_text())
    print(f"mesh_wire_psum_q8_coll_bytes,0,{cq2['total']}")
    print(f"mesh_wire_psum_f32_coll_bytes,0,{cf2['total']}")


def mesh_wire(smoke: bool = False):
    """int8 EF wire on the mesh gossip path (ISSUE 5): forced-CPU-mesh
    subprocess measuring the q8 schedules' parity + collective bytes; rows
    land in BENCH_swarm_sync.json (committed on full runs, scratch on
    --smoke)."""
    import subprocess
    import sys
    n, d, reps = (4, 1 << 12, 3) if smoke else (4, 1 << 16, 10)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}").strip()
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__),
         "--inner-mesh-wire", f"{n},{d},{reps}"],
        capture_output=True, text=True, env=env, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(f"mesh wire subprocess failed: "
                           f"{out.stderr[-800:]}")
    print(out.stdout, end="")
    rows = [dict(zip(("name", "us", "derived"), line.split(",", 2)))
            for line in out.stdout.strip().splitlines() if "," in line]
    _bench_json_update("mesh_wire_smoke" if smoke else "mesh_wire", rows,
                       smoke=smoke)


def mesh_wire_smoke():
    mesh_wire(smoke=True)


def _hier_sync_inner(k: int, m: int, d: int, reps: int):
    """Runs inside the forced-device-count subprocess: the hierarchical
    pod-delegate q8 schedule vs the flat ring q8 over the joint axis on a
    (k pods, m nodes/pod) two-level mesh — wall time plus HLO-measured
    collective bytes split per link class (`hlo_stats.
    collective_bytes_by_link`), next to the cost model's per-class
    prediction."""
    import json as json_mod
    from repro.configs.base import SwarmConfig
    from repro.core import comms, gossip
    from repro.core.topology import ring_matrix
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_two_level_swarm_mesh

    n = k * m
    assert jax.device_count() >= n, "inner bench needs the forced device count"
    mesh, axis = make_two_level_swarm_mesh(k, m)
    wb = 128
    rng = np.random.default_rng(0)
    x = {"w": jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)}
    wv = jnp.full((n,), 1.0 / n, jnp.float32)
    Wp = jnp.asarray(ring_matrix(k, 0.5), jnp.float32)
    Wn = jnp.asarray(ring_matrix(n, 0.5), jnp.float32)
    pod_of = hlo_stats.pod_device_map(k, m)

    def predicted(cross_pod_cost):
        cfg = SwarmConfig(n_nodes=n, topology="ring", merge="fedavg",
                          lora_only=False, wire_dtype="int8", wire_block=wb,
                          cross_pod_cost=cross_pod_cost)
        return comms.pick_schedule(cfg, mesh_shape=(k, m))

    hier_sched = predicted(10.0)      # dominant DCN cost -> hierarchical
    flat_sched = predicted(1.0)       # neutral costs -> flat ring
    assert hier_sched.name == "hier_fedavg_ring_q8", hier_sched.name
    assert flat_sched.name == "ring_ppermute", flat_sched.name

    hw0 = gossip.init_mesh_wire("hier_fedavg_ring_q8", x, n_shards=n,
                                wire_block=wb, mesh_shape=(k, m))
    fw0 = gossip.init_mesh_wire("ring_ppermute", x, n_shards=n, wire_block=wb)
    cases = [
        ("hier_fedavg_ring_q8", hier_sched, hw0, jax.jit(
            lambda t, w: gossip.hier_fedavg_ring_q8(
                t, wv, Wp, w, mesh, axis, wire_block=wb))),
        ("flat_ring_q8", flat_sched, fw0, jax.jit(
            lambda t, w: gossip.ring_rows_gossip_q8(
                t, Wn, w, mesh, axis, wire_block=wb))),
    ]
    rows = []
    for name, sched, w0_, fn in cases:
        us = _time_us(lambda fn=fn, w0_=w0_: fn(x, w0_)[0]["w"], reps=reps)
        link = hlo_stats.collective_bytes_by_link(
            fn.lower(x, w0_).compile().as_text(), pod_of)
        pred = sched.bytes_by_link_class(d)
        rows.append(dict(
            schedule=sched.name, collective=sched.collective,
            topology="ring", merge="fedavg", wire_dtype="int8", n_nodes=n,
            mesh_shape=[k, m], payload_params=d,
            predicted_intra_bytes=pred["intra"],
            predicted_cross_bytes=pred["cross"],
            measured_intra_bytes=link["intra"],
            measured_cross_bytes=link["cross"],
            wall_us_per_round=us))
        print(f"hier_sync_{name}_us,{us:.1f},k={k};m={m};d={d};wb={wb}")
        print(f"hier_sync_{name}_intra_bytes,0,{link['intra']}")
        print(f"hier_sync_{name}_cross_bytes,0,{link['cross']}")
    ratio = rows[0]["measured_cross_bytes"] / rows[1]["measured_cross_bytes"]
    print(f"hier_sync_cross_bytes_ratio,0,{ratio:.3f}")
    print("hier_sync_rows_json,0," + json_mod.dumps(rows))


def hier_sync(smoke: bool = False):
    """Hierarchical two-level comms (ISSUE 7): forced-CPU 2x2 ("pod",
    "node") mesh subprocess comparing the pod-delegate q8 schedule against
    the flat ring q8 per link class; rows (intra- vs cross-pod bytes,
    predicted and HLO-measured) land in BENCH_swarm_sync.json (committed on
    full runs, scratch on --smoke)."""
    import subprocess
    import sys
    k, m, d, reps = (2, 2, 1 << 12, 3) if smoke else (2, 2, 1 << 16, 10)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={k * m}").strip()
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__),
         "--inner-hier-sync", f"{k},{m},{d},{reps}"],
        capture_output=True, text=True, env=env, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(f"hier sync subprocess failed: "
                           f"{out.stderr[-800:]}")
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("hier_sync_rows_json,"):
            rows = json.loads(line.split(",", 2)[2])
        elif line:
            print(line)
    if not rows:
        raise RuntimeError("hier sync subprocess emitted no JSON rows")
    path = _bench_json_update("hier_sync_smoke" if smoke else "hier_sync",
                              rows, smoke=smoke)
    print(f"hier_sync_json,0,{path}")


def hier_sync_smoke():
    hier_sync(smoke=True)


# ---------------------------------------------------------------------------
# serve — continuous-batching consensus inference (PR 8)
# ---------------------------------------------------------------------------

_SERVE_CONFIGS = {
    # naive: one request at a time, the pre-PR-8 dispatch discipline
    "naive_b1": dict(max_slots=1, batch_buckets=(1,)),
    # continuous batching: up to 8 co-resident requests, bucketed table
    "continuous_b8": dict(max_slots=8, batch_buckets=(1, 2, 4, 8)),
}


def serve(smoke: bool = False):
    """Requests/sec + p99 latency for batching config × consensus mode over
    a 4-node vmapped ensemble; writes BENCH_serve.json. The full bucket grid
    is warmed before t0 and the timed region asserts zero retraces — the
    comparison is dispatch discipline, not compile noise."""
    from repro.configs import get_config, smoke_variant
    from repro.models import build_model
    from repro.serve import BucketPolicy, ServeEngine

    cfg = smoke_variant(get_config("minicpm-2b")).replace(vocab_size=256)
    model = build_model(cfg)
    n_nodes = 4
    params = jax.vmap(model.init)(
        jax.random.split(jax.random.key(0), n_nodes))
    n_requests, max_new = (8, 8) if smoke else (32, 16)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in rng.integers(4, 16, size=n_requests)]

    rows, tput = [], {}
    for config, knobs in _SERVE_CONFIGS.items():
        for mode in ("consensus", "average"):
            eng = ServeEngine(
                model, params, mode=mode, max_len=48,
                max_slots=knobs["max_slots"],
                policy=BucketPolicy(batch_buckets=knobs["batch_buckets"],
                                    seq_buckets=(16,)))
            # warm every (batch, seq) bucket the timed run will touch
            for p in prompts[:min(8, n_requests)]:
                eng.submit(p, max_new=2)
            eng.drain()
            warm_traces = eng.total_traces
            t0 = time.perf_counter()
            for p in prompts:
                eng.submit(p, max_new=max_new)
            done = eng.drain()
            wall = time.perf_counter() - t0
            lat_ms = np.array([r.latency_s for r in done]) * 1e3
            new_tokens = sum(len(r.node_tokens) for r in done)
            row = {
                "config": config, "mode": mode,
                "max_slots": knobs["max_slots"],
                "batch_buckets": list(knobs["batch_buckets"]),
                "n_nodes": n_nodes, "n_requests": len(done),
                "max_new": max_new, "wall_s": wall,
                "requests_per_s": len(done) / wall,
                "tokens_per_s": new_tokens / wall,
                "p50_ms": float(np.percentile(lat_ms, 50)),
                "p99_ms": float(np.percentile(lat_ms, 99)),
                "retraces_timed": eng.total_traces - warm_traces,
            }
            rows.append(row)
            tput[config, mode] = row["requests_per_s"]
            print(f"serve_{config}_{mode},{wall / len(done) * 1e6:.0f},"
                  f"req_s={row['requests_per_s']:.2f};"
                  f"p99_ms={row['p99_ms']:.1f};"
                  f"retraces={row['retraces_timed']}")
    ratios = {mode: tput["continuous_b8", mode] / tput["naive_b1", mode]
              for mode in ("consensus", "average")}
    for mode, r in ratios.items():
        print(f"serve_continuous_vs_naive_{mode},0,{r:.2f}")
    data = {"model": "minicpm-2b (smoke variant, vocab 256)",
            "n_nodes": n_nodes, "n_requests": n_requests, "max_new": max_new,
            "rows": rows, "continuous_over_naive_throughput": ratios}
    path = _bench_json_update("serve_smoke" if smoke else "serve", data,
                              smoke=smoke, filename="BENCH_serve.json")
    print(f"serve_json,0,{path}")


def serve_smoke():
    serve(smoke=True)


# ---------------------------------------------------------------------------
# fault matrix — chaos plane (ISSUE 9)
# ---------------------------------------------------------------------------

def _fault_matrix_plans(n: int, rounds: int):
    from repro.faults import FaultPlan
    base = lambda: FaultPlan(n_nodes=n, n_rounds=rounds, seed=0)
    return {
        "crash": base().crash(1, at=2, rejoin=4),
        "straggle": base().straggle(2, at=2, rounds=2),
        "drop": base().drop(3, at=2),
        "corrupt": base().corrupt(1, at=2),
        "preempt": base().preempt(at=4),
    }


def _fault_matrix_cells(merges, fault_kinds, rounds: int, d: int, *,
                        backend: str = "engine", session_kw=None,
                        tol: float = 1e-3):
    """One (fault × merge) grid on one backend: each cell replays a fault
    plan against a fresh int8-wire session under contractive pull-to-target
    dynamics and reports rounds-to-recover (first round, counted from the
    fault's last affected round, within ``tol`` of the fault-free twin's
    trajectory), the final loss delta, and excess retraces (compiles beyond
    the one-per-session warmup — must be 0: faults are runtime data)."""
    import tempfile
    from repro.configs.base import SwarmConfig
    from repro.core.session import SwarmSession
    from repro.faults import FaultPlan, run_plan

    n, steps, lr = 4, 3, 0.5
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    # the per-node pull target rides in as the batch (train_step is vmapped
    # over nodes, so it can't index the stacked target itself)
    batches = jnp.tile(targets[None], (steps, 1, 1))
    val = jnp.zeros((n, 1))
    session_kw = dict(session_kw or {})
    tmp = tempfile.mkdtemp()
    plans = _fault_matrix_plans(n, rounds)

    def make(merge, traces):
        topo = "ring" if merge == "fisher" else "full"
        cfg = SwarmConfig(n_nodes=n, sync_every=steps, topology=topo,
                          merge=merge, lora_only=False, val_threshold=0.0,
                          wire_dtype="int8", wire_block=128)

        def pull_step(p, o, b, s):
            traces.append(1)      # python body runs only at (re)trace
            g = p["x"] - b
            return {"x": p["x"] - lr * g}, o, {"loss": jnp.sum(g * g)}

        def eval_fn(p, v):
            return 1.0 - 0.0 * jnp.sum(p["x"])

        return SwarmSession(cfg, pull_step, eval_fn,
                            params={"x": jnp.zeros((n, d), jnp.float32)},
                            stacked=True, data_sizes=[1.0] * n, **session_kw)

    def run(merge, plan):
        traces, traj = [], []
        box = {"sess": make(merge, traces)}

        def mk():                 # preempt rebuild: track the live session
            box["sess"] = make(merge, traces)
            return box["sess"]

        def obs(r, log):
            traj.append(np.asarray(box["sess"].state.params["x"],
                                   np.float64).copy())

        run_plan(box["sess"], plan, batches, val, make_session=mk,
                 checkpoint_path=os.path.join(tmp, "fault_preempt.msgpack"),
                 on_round=obs)
        n_sessions = 1 + sum(e.kind == "preempt" for e in plan.events)
        return np.stack(traj), len(traces) - n_sessions

    t64 = np.asarray(targets, np.float64)
    loss = lambda x: float(np.mean((x - t64) ** 2))
    rows = []
    for merge in merges:
        ref, _ = run(merge, FaultPlan(n_nodes=n, n_rounds=rounds, seed=0))
        for kind in fault_kinds:
            plan = plans[kind]
            traj, excess = run(merge, plan)
            low = plan.lower()
            faulty = (~low.active.all(axis=1)) | low.corrupt.any(axis=1) \
                | low.preempt
            fault_end = int(np.flatnonzero(faulty).max())
            delta = np.abs(traj - ref).max(axis=(1, 2))
            rec = next((r - fault_end for r in range(fault_end, rounds)
                        if delta[r] <= tol), -1)
            # diagnostic-quality recovery: fisher's mean-normalized Δθ²
            # importance remembers the fault window ~forever, so the exact
            # parameter trajectory may never rejoin the twin's — while the
            # quality metric (mean squared distance to the per-node optima)
            # still re-converges; report both
            ldelta = np.array([abs(loss(traj[r]) - loss(ref[r]))
                               / max(loss(ref[r]), 1e-9)
                               for r in range(rounds)])
            rec_loss = next((r - fault_end for r in range(fault_end, rounds)
                             if ldelta[r] <= tol), -1)
            rows.append(dict(
                backend=backend, merge=merge, fault=kind, rounds=rounds,
                fault_end_round=fault_end, rounds_to_recover=rec,
                rounds_to_recover_loss=rec_loss,
                final_max_delta=float(delta[-1]),
                final_rel_loss_delta=float(ldelta[-1]),
                excess_retraces=excess))
            print(f"fault_{backend}_{merge}_{kind},0,"
                  f"recover={rec};recover_loss={rec_loss};"
                  f"delta={delta[-1]:.2e};retraces={excess}")
    return rows


def _fault_matrix_gossip_inner(n: int, d: int, rounds: int):
    """Runs inside the forced-device-count subprocess: the gossip-backend
    q8 cells (corrupt degrades to a one-round drop — no in-graph wire
    injection on the mesh schedules, by design)."""
    import json as json_mod
    assert jax.device_count() >= n, "inner bench needs the forced device count"
    mesh = jax.make_mesh((n,), ("node",), devices=jax.devices()[:n])
    rows = _fault_matrix_cells(
        ("fedavg", "fisher"), ("crash", "drop", "corrupt"), rounds, d,
        backend="gossip",
        session_kw=dict(backend="gossip", mesh=mesh, axis="node"))
    print("fault_rows_json,0," + json_mod.dumps(rows))


def fault_matrix(smoke: bool = False):
    """Chaos-plane recovery matrix (ISSUE 9): every FaultPlan kind replayed
    against engine-backend int8 sessions (plus gossip q8 cells in full runs,
    forced-CPU-mesh subprocess), each versus its fault-free twin; rows land
    in BENCH_faults.json (committed on full runs, scratch on --smoke)."""
    kinds = ("crash", "straggle", "drop", "corrupt", "preempt")
    rounds, d = (8, 256) if smoke else (12, 1024)
    merges = ("fedavg",) if smoke else ("fedavg", "fisher")
    rows = _fault_matrix_cells(merges, kinds, rounds, d)
    if not smoke:
        import subprocess
        import sys
        n = 4
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()
        src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                           "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--inner-fault-gossip", f"{n},{d},{rounds}"],
            capture_output=True, text=True, env=env, timeout=600)
        if out.returncode != 0:
            raise RuntimeError(f"fault matrix gossip subprocess failed: "
                               f"{out.stderr[-800:]}")
        for line in out.stdout.splitlines():
            if line.startswith("fault_rows_json,"):
                rows += json.loads(line.split(",", 2)[2])
            elif line:
                print(line)
    data = dict(n_nodes=4, rounds=rounds, tol=1e-3, rows=rows)
    path = _bench_json_update("fault_smoke" if smoke else "fault_matrix",
                              data, smoke=smoke, filename="BENCH_faults.json")
    print(f"fault_matrix_json,0,{path}")


def fault_matrix_smoke():
    fault_matrix(smoke=True)


def hetero_swarm(smoke: bool = False):
    """Heterogeneous swarm scenario grid (ISSUE 10): every grid cell runs
    the frozen-backbone model zoo with adapter-only ``payload="lora"`` int8
    sync and the fairness floor; rows (wire bytes vs full-payload f32,
    per-site metric spread vs the centralized oracle, retrace counters)
    land in BENCH_hetero.json (committed on full runs, scratch on --smoke).
    Smoke keeps ALL grid cells — CI asserts ≥4 scenario rows — and shrinks
    only the run scale."""
    from repro.configs.base import SwarmConfig
    from repro.experiments import scenarios

    if smoke:
        rcfg = scenarios.ScenarioRunConfig(
            n_train=96, n_test=48, feat_dim=8, hidden=8, steps=8,
            batch_size=4,
            swarm=SwarmConfig(
                n_nodes=4, sync_every=4, topology="ring", merge="fedavg",
                payload="lora", wire_dtype="int8", wire_block=128,
                val_threshold=0.0, gate_metric="auc", fairness_floor=0.05))
    else:
        rcfg = scenarios.ScenarioRunConfig()
    rows = []
    for scn in scenarios.scenario_grid():
        t0 = time.perf_counter()
        row = scenarios.run_scenario(scn, rcfg)
        row["wall_s"] = time.perf_counter() - t0
        rows.append(row)
        print(f"hetero_{row['scenario']},{row['wall_s'] * 1e6:.0f},"
              f"wire_bytes={row['wire_bytes_per_sync']:.0f};"
              f"frac_of_full={row['wire_fraction_of_full']:.5f};"
              f"retraces={row['retraces']};"
              f"auc_spread={row['site_auc_spread']:.4f};"
              f"oracle_gap={row['oracle_gap_auc']:.4f};"
              f"fair_ok={row['fairness_ok_last']}")
    data = dict(n_nodes=rcfg.n_nodes, steps=rcfg.steps,
                sync_every=rcfg.swarm.sync_every,
                schedule=rows[0]["schedule"], rows=rows)
    path = _bench_json_update("hetero_smoke" if smoke else "hetero", data,
                              smoke=smoke, filename="BENCH_hetero.json")
    print(f"hetero_swarm_json,0,{path}")


def hetero_swarm_smoke():
    hetero_swarm(smoke=True)


def merge_kernel_smoke():
    merge_kernel(1 << 14)


def overlap_roundtrip_smoke():
    overlap_roundtrip(reps=3)


ALL = [fig2_node0, fig3_node3, fig4_node2_25pct, scarcity_node3_5pct,
       tbl_dbi, tbl_minority, merge_kernel, lora_payload, gossip_spectrum,
       sync_roundtrip, engine_roundtrip, overlap_roundtrip,
       dynamic_membership, spmd_parity, swarm_sync, ring_sync_parity,
       mesh_wire, hier_sync, serve, hetero_swarm, fault_matrix]

# seconds-scale subset covering every benchmark family (tier-1 smoke test)
SMOKE = [merge_kernel_smoke, gossip_spectrum, sync_roundtrip,
         engine_roundtrip, overlap_roundtrip_smoke, dynamic_membership_smoke,
         spmd_parity_smoke, swarm_sync_smoke, ring_sync_parity_smoke,
         mesh_wire_smoke, hier_sync_smoke, serve_smoke, hetero_swarm_smoke,
         fault_matrix_smoke]


def roofline_table():
    """Append the §Roofline rows when a dry-run matrix is present."""
    from benchmarks.roofline import load_rows
    rows = load_rows("experiments/dryrun")
    for r in rows:
        print(f"roofline_{r['arch']}_{r['shape']},0,"
              f"compute={r['compute_s']:.3e};memory={r['memory_s']:.3e};"
              f"collective={r['collective_s']:.3e};dominant={r['dominant']};"
              f"useful={r['useful_ratio']:.3f};peakGiB={r['peak_gib']:.1f}")


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description="benchmark harness")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI (no cached protocols)")
    ap.add_argument("--inner-spmd-parity", default="",
                    help="internal: n,t,d,reps (run inside the forced-device"
                         " subprocess)")
    ap.add_argument("--inner-ring-sync", default="",
                    help="internal: n,d,reps (run inside the forced-device"
                         " subprocess)")
    ap.add_argument("--inner-mesh-wire", default="",
                    help="internal: n,d,reps (run inside the forced-device"
                         " subprocess)")
    ap.add_argument("--inner-hier-sync", default="",
                    help="internal: k,m,d,reps (run inside the forced-device"
                         " subprocess)")
    ap.add_argument("--inner-fault-gossip", default="",
                    help="internal: n,d,rounds (run inside the forced-device"
                         " subprocess)")
    args = ap.parse_args(argv)

    if args.inner_spmd_parity:
        n, t, d, reps = map(int, args.inner_spmd_parity.split(","))
        _spmd_parity_inner(n, t, d, reps)
        return

    if args.inner_ring_sync:
        n, d, reps = map(int, args.inner_ring_sync.split(","))
        _ring_sync_parity_inner(n, d, reps)
        return

    if args.inner_mesh_wire:
        n, d, reps = map(int, args.inner_mesh_wire.split(","))
        _mesh_wire_inner(n, d, reps)
        return

    if args.inner_hier_sync:
        k, m, d, reps = map(int, args.inner_hier_sync.split(","))
        _hier_sync_inner(k, m, d, reps)
        return

    if args.inner_fault_gossip:
        n, d, rounds = map(int, args.inner_fault_gossip.split(","))
        _fault_matrix_gossip_inner(n, d, rounds)
        return

    print("name,us_per_call,derived")
    fns = SMOKE if args.smoke else ALL + [roofline_table]
    for fn in fns:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},0,ERROR:{e!r}")


if __name__ == "__main__":
    main()
