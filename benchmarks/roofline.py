"""Aggregate the dry-run JSONs into the §Roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Emits CSV: arch,shape,mesh,compute_s,memory_s,collective_s,dominant,
model_flops,useful_ratio,peak_GiB_per_dev,one-liner.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

MOVE_HINTS = {
    "collective": "shrink/overlap the per-layer activation AR/AG "
                  "(wider data axis, narrower model axis, or comm overlap)",
    "memory": "fuse elementwise chains & raise arithmetic intensity "
              "(bigger microbatch per chip, bf16 cache)",
    "compute": "already MXU-bound: improve tiling/padding so HLO FLOPs "
               "approach MODEL_FLOPS",
}


def load_rows(d: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*_single.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": rl["mesh"],
            "chips": rl["chips"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "model_flops": r["model_flops_global"],
            "useful_ratio": rl["useful_ratio"],
            "peak_gib": r["memory"]["peak_bytes_per_device"] / 2**30,
            "hint": MOVE_HINTS.get(rl["dominant"], ""),
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.dir)
    if args.markdown:
        print("| arch | shape | compute s | memory s | collective s | dominant "
              "| useful | peak GiB/dev |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
                  f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
                  f"| {r['dominant']} | {r['useful_ratio']:.2f} "
                  f"| {r['peak_gib']:.2f} |")
    else:
        print("arch,shape,mesh,chips,compute_s,memory_s,collective_s,dominant,"
              "model_flops,useful_ratio,peak_GiB_per_dev")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
                  f"{r['compute_s']:.4e},{r['memory_s']:.4e},"
                  f"{r['collective_s']:.4e},{r['dominant']},"
                  f"{r['model_flops']:.3e},{r['useful_ratio']:.3f},"
                  f"{r['peak_gib']:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
