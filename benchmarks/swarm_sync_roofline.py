"""Roofline of the P2P-SL sync step itself (the paper's technique on-mesh).

Lowers `propose` (the gossip merge) on the swarm mesh for a chosen arch and
compares the collective bytes of the schedules:

  fedavg/full payload   — faithful paper mechanism (dense weighted merge)
  ring/full payload     — beyond-paper sparse P2P (ppermute)
  fedavg/LoRA payload   — paper's payload optimization
  ring/LoRA payload     — both (the TPU-native endpoint)

Single-pod swarm mesh (node,data,model)=(4,4,16); multi-pod uses pod as the
gossip axis — there the collective term is DCN traffic, the scarce resource
the paper's schedule conserves.

Usage: PYTHONPATH=src python -m benchmarks.swarm_sync_roofline [--arch minicpm-2b]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse

import jax
import jax.numpy as jnp

from repro.configs import SwarmConfig, get_config
from repro.core.lora import inject_lora
from repro.launch import hlo_stats
from repro.launch.mesh import make_swarm_mesh
from repro.launch.specs import param_shapes
from repro.launch.train import make_swarm_sync_step
from repro.models import build_model
from repro.sharding.rules import shardings_for
from jax.sharding import NamedSharding, PartitionSpec as P


def stacked_param_sds(cfg, mesh, axis, n_nodes, lora):
    model = build_model(cfg)
    pshapes = param_shapes(model)
    if lora:
        pshapes = jax.eval_shape(
            lambda: inject_lora(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pshapes),
                jax.random.key(0), rank=16))
    pshard = shardings_for(pshapes, mesh)
    inner_specs = jax.tree.map(lambda sh: sh.spec, pshard)

    def stackit(s, sh):
        spec = P(axis, *sh.spec)
        return jax.ShapeDtypeStruct((n_nodes,) + s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree.map(stackit, pshapes, pshard), inner_specs


def measure(arch: str, topology: str, lora_only: bool, multi_pod: bool):
    mesh, axis = make_swarm_mesh(4, multi_pod=multi_pod)
    n_nodes = mesh.shape[axis]
    cfg = get_config(arch)
    scfg = SwarmConfig(n_nodes=n_nodes, topology=topology, merge="fedavg",
                       lora_only=lora_only)
    sds, inner = stacked_param_sds(cfg, mesh, axis, n_nodes, lora_only)
    propose, _ = make_swarm_sync_step(scfg, mesh, axis, [1.0] * n_nodes,
                                      param_specs=inner)
    compiled = jax.jit(propose).lower(sds).compile()
    coll = hlo_stats.collective_bytes(compiled.as_text())
    return coll, n_nodes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    print("schedule,payload,coll_bytes_per_device,coll_s_at_50GBps,detail")
    for topo in ("full", "ring"):
        for lora in (False, True):
            coll, n = measure(args.arch, topo, lora, args.multi_pod)
            t = coll["total"] / hlo_stats.ICI_BW
            detail = {k: v for k, v in coll.items()
                      if k not in ("total", "count") and v}
            print(f"{topo},{'lora' if lora else 'full'},{coll['total']},"
                  f"{t:.4f},{detail}")


if __name__ == "__main__":
    main()
