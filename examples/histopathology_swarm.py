"""End-to-end reproduction of the paper's cancer-histopathology experiments.

Runs the full §4 protocol: 4 nodes, unbalanced 10/30/30/30 shards, P2P-SL with
validation-gated FedAvg merging every `sync_every` steps, against centralized
and standalone baselines; then the 25% and 5% scarcity trials. Writes JSON
results into experiments/histo/ (consumed by benchmarks/run.py and
EXPERIMENTS.md).

Run:  PYTHONPATH=src python examples/histopathology_swarm.py [--steps 400]
"""
import argparse
import json
import os

from repro.experiments.histo import (HistoExperimentConfig, run_experiment,
                                     summarize)

OUT = "experiments/histo"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--seeds", type=int, default=1,
                    help="paper repeats 5 seeds; default 1 for CPU speed")
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)

    scenarios = {
        "unbalanced": {},
        "scarcity25": {"scarcity": {2: 0.25}},
        "scarcity5": {"scarcity": {3: 0.05}},
    }
    for tag, extra in scenarios.items():
        for seed in range(args.seeds):
            cfg = HistoExperimentConfig(
                steps=args.steps, n_train=args.n_train, noise=0.8,
                seed=seed, **extra)
            print(f"\n=== scenario {tag} (seed {seed}) "
                  f"steps={cfg.steps} ===")
            r = run_experiment(cfg)
            print(summarize(r))
            print("recovery of centralized AUC:",
                  [round(x, 2) for x in r["recovery"]])
            name = tag if seed == 0 else f"{tag}_seed{seed}"
            with open(os.path.join(OUT, f"{name}.json"), "w") as f:
                json.dump(r, f, indent=2, default=float)
    print(f"\nresults written to {OUT}/")


if __name__ == "__main__":
    main()
