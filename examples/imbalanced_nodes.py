"""Merge-strategy & topology ablation under node imbalance (beyond-paper).

The paper uses FedAvg-weighted full merging. Its §2 survey *cites* Fisher and
gradient-matching merging as principled upgrades but never builds them — this
example does, comparing on the same biased-shard setup:

  fedavg/full    the paper's mechanism (faithful baseline)
  mean/full      unweighted averaging (the paper's strawman)
  fedavg/ring    sparse P2P gossip (TPU-native ppermute schedule)
  fisher/full    diagonal-Fisher-weighted merging
  gradmatch/full uncertainty-based gradient matching [Daheim et al., cited]

Also demonstrates DYNAMIC MEMBERSHIP: node 3 leaves the swarm mid-training
via ``session.leave(3)`` and re-joins later via ``session.join(3)`` (the
paper's §3.1 join/leave semantics — runtime state, not reconfiguration).

Note on fisher/gradmatch here: importance mass comes from the strategy's
in-graph Δθ² accumulation (no host-side Fisher loop). Because this example
trains with AdamW — whose step sizes are ~lr regardless of gradient scale —
that proxy is closer to update-activity weighting than exact curvature, so
fisher/gradmatch land nearer fedavg than they would with true squared-grad
Fishers (set ``node.fisher`` explicitly to supply those; see
`merge_impl.FisherStrategy`).

Run:  PYTHONPATH=src python examples/imbalanced_nodes.py [--steps 150]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig, TrainConfig
from repro.core.session import SwarmSession
from repro.data import batches, make_histo_dataset, shard_to_nodes
from repro.metrics import classify_report
from repro.models.cnn import bce_loss, forward_cnn, init_cnn
from repro.optim import adamw_init, adamw_update


def run(swarm_cfg, steps, dynamic=False, seed=0):
    imgs, labels = make_histo_dataset(1200, size=24, noise=0.8,
                                      class_probs=(0.5, 0.3, 0.2), seed=seed)
    test_x, test_y = make_histo_dataset(400, size=24, noise=0.8,
                                        class_probs=(0.5, 0.3, 0.2),
                                        seed=seed + 99)
    # class-biased shards: each node sees a skewed class mix
    shards = shard_to_nodes(imgs, labels, [120, 360, 360, 360], seed=seed,
                            class_bias=[[5, 1, 1], [1, 5, 1], [1, 1, 5],
                                        [1, 1, 1]])
    tc = TrainConfig(lr=1e-3, weight_decay=1e-4)

    def loss(params, x, y):
        return bce_loss(forward_cnn(params, x), jax.nn.one_hot(y, 3))

    @jax.jit
    def train_step_(params, opt, x, y):
        l, g = jax.value_and_grad(loss)(params, x, y)
        params, opt = adamw_update(params, g, opt, tc, 1e-3)
        return params, opt, l

    def train_step(params, opt, batch, step):
        x, y = batch
        params, opt, l = train_step_(params, opt, jnp.asarray(x), jnp.asarray(y))
        return params, opt, {"loss": l}

    @jax.jit
    def predict(params, x):
        return jax.nn.sigmoid(forward_cnn(params, x))

    def eval_fn(params, val):
        x, y = val
        return classify_report(np.asarray(predict(params, jnp.asarray(x))),
                               y)["auc"]

    params = init_cnn(jax.random.key(42), None, growth=8, stem=16,
                      feat_dim=96, hidden=32)
    sw = SwarmSession(swarm_cfg, train_step, eval_fn, backend="host",
                      params=params, opt_state=adamw_init(params),
                      data_sizes=[len(s[1]) for s in shards])

    rngs = [np.random.default_rng(seed * 10 + i) for i in range(4)]
    iters = [iter(()) for _ in range(4)]
    vals = [(s[0][:48], s[1][:48]) for s in shards]
    t = swarm_cfg.sync_every
    for round_start in range(0, steps, t):
        if dynamic:  # node 3 leaves at 1/3, rejoins at 2/3 of the run
            if steps // 3 <= round_start < 2 * steps // 3:
                sw.leave(3)
            else:
                sw.join(3)
        round_batches = []
        for _ in range(min(t, steps - round_start)):
            bs = []
            for i, s in enumerate(shards):
                if not sw.active[i]:
                    bs.append(None)
                    continue
                try:
                    b = next(iters[i])
                except StopIteration:
                    iters[i] = batches(s[0], s[1], 16, rngs[i])
                    b = next(iters[i])
                bs.append(b)
            round_batches.append(bs)
        # fisher/gradmatch importance mass accumulates inside the round
        # via the configured MergeStrategy — no host-side estimation loop
        sw.round(round_batches, vals)

    aucs = [classify_report(np.asarray(predict(p, jnp.asarray(test_x))),
                            test_y)["auc"] for p in sw.node_params]
    return aucs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    settings = [
        ("fedavg/full (paper)", SwarmConfig(n_nodes=4, sync_every=15,
         topology="full", merge="fedavg", lora_only=False)),
        ("mean/full", SwarmConfig(n_nodes=4, sync_every=15, topology="full",
         merge="mean", lora_only=False)),
        ("fedavg/ring (P2P)", SwarmConfig(n_nodes=4, sync_every=15,
         topology="ring", merge="fedavg", lora_only=False)),
        ("fisher/full", SwarmConfig(n_nodes=4, sync_every=15, topology="full",
         merge="fisher", lora_only=False)),
        ("gradmatch/full", SwarmConfig(n_nodes=4, sync_every=15,
         topology="full", merge="gradmatch", lora_only=False)),
    ]
    print(f"{'setting':22s}  node AUCs (scarce node first)        mean")
    for name, cfg in settings:
        aucs = run(cfg, args.steps)
        print(f"{name:22s}  {[round(a, 3) for a in aucs]}  {np.mean(aucs):.3f}")

    aucs = run(SwarmConfig(n_nodes=4, sync_every=15, topology="dynamic",
                           merge="fedavg", lora_only=False),
               args.steps, dynamic=True)
    print(f"{'dynamic membership':22s}  {[round(a, 3) for a in aucs]}  "
          f"{np.mean(aucs):.3f}   (node 3 left & re-joined)")


if __name__ == "__main__":
    main()
