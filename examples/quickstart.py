"""Quickstart: the P2P-SL framework in ~60 lines.

Builds a reduced LM, trains a 4-node swarm on heterogeneous token streams
with LoRA-only peer exchanges, and prints per-round gates. Uses
`SwarmSession` with ``backend="host"`` — the compatibility backend for
arbitrary Python ``train_step_fn``/``eval_fn`` callables (batches are
``[T][N]`` nested lists). Fully-traceable workloads should drop the
``backend`` argument to get the compiled engine (see engine_swarm.py).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SwarmConfig, TrainConfig, get_config, smoke_variant
from repro.core.lora import inject_lora
from repro.core.session import SwarmSession
from repro.data import make_lm_stream
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import adamw_init


def main():
    # 1. pick an assigned architecture, reduced for CPU
    cfg = smoke_variant(get_config("minicpm-2b"))
    model = build_model(cfg)
    tc = TrainConfig(lr=3e-3, remat=False, warmup_steps=5, max_steps=200)
    base_step = jax.jit(make_train_step(model, tc))

    def train_step(params, opt_state, batch, step):
        return base_step(params, opt_state, batch)

    def eval_fn(params, val):
        loss, _ = model.loss_fn(params, val, remat=False)
        return 1.0 / (1.0 + float(loss))  # higher = better

    # 2. four nodes, shared pre-trained-style init, LoRA adapters injected
    base = model.init(jax.random.key(0))
    node_params = [inject_lora(base, jax.random.key(i + 1), rank=8)
                   for i in range(4)]

    swarm = SwarmSession(
        SwarmConfig(n_nodes=4, sync_every=10, topology="ring",
                    merge="fedavg", lora_only=True, val_threshold=0.8),
        train_step, eval_fn, backend="host",
        params=node_params, opt_state=[adamw_init(p) for p in node_params],
        data_sizes=[100, 300, 300, 300])

    # 3. heterogeneous local data (topic-biased token streams)
    streams = [make_lm_stream(64, 32, cfg.vocab_size, seed=i, topic_bias=1.0)
               for i in range(4)]
    rng = np.random.default_rng(0)
    vals = [{k: jnp.asarray(v[:8]) for k, v in s.items()} for s in streams]

    def draw():  # one [N] list of per-node batches
        return [{k: jnp.asarray(v[rng.integers(0, 64, 8)])
                 for k, v in s.items()} for s in streams]

    # 4. train + gossip: each round = sync_every local steps + gated merge
    for _ in range(5):
        log = swarm.round([draw() for _ in range(10)], vals)
        print(f"step {log['step']:3d} gossip: gates={log['gates']} "
              f"merged-metric={[round(m, 4) for m in log['metric_merged']]}")

    for i, p in enumerate(swarm.node_params):
        loss, _ = model.loss_fn(p, vals[i], remat=False)
        print(f"node {i}: final val loss = {float(loss):.3f}")
    print("OK — swarm training with LoRA-only P2P sync complete.")


if __name__ == "__main__":
    main()
