"""Quickstart: the P2P-SL framework in ~60 lines.

Builds a reduced LM, trains a 4-node swarm on heterogeneous token streams with
LoRA-only peer exchanges, and prints per-node losses before/after gossip.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SwarmConfig, TrainConfig, get_config, smoke_variant
from repro.core.lora import inject_lora
from repro.core.swarm import NodeState, SwarmLearner
from repro.data import make_lm_stream
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import adamw_init


def main():
    # 1. pick an assigned architecture, reduced for CPU
    cfg = smoke_variant(get_config("minicpm-2b"))
    model = build_model(cfg)
    tc = TrainConfig(lr=3e-3, remat=False, warmup_steps=5, max_steps=200)
    base_step = jax.jit(make_train_step(model, tc))

    def train_step(params, opt_state, batch, step):
        return base_step(params, opt_state, batch)

    # 2. four nodes, shared pre-trained-style init, LoRA adapters injected
    key = jax.random.key(0)
    base = model.init(key)
    nodes = []
    for i in range(4):
        p = inject_lora(base, jax.random.key(i + 1), rank=8)
        nodes.append(NodeState(params=p, opt_state=adamw_init(p),
                               data_size=[100, 300, 300, 300][i]))

    # 3. heterogeneous local data (topic-biased token streams)
    streams = [make_lm_stream(64, 32, cfg.vocab_size, seed=i, topic_bias=1.0)
               for i in range(4)]

    def eval_fn(params, val):
        loss, _ = model.loss_fn(params, val, remat=False)
        return 1.0 / (1.0 + float(loss))  # higher = better

    swarm = SwarmLearner(
        SwarmConfig(n_nodes=4, sync_every=10, topology="ring",
                    merge="fedavg", lora_only=True, val_threshold=0.8),
        train_step, eval_fn, nodes)

    # 4. train + gossip
    rng = np.random.default_rng(0)
    vals = [{k: jnp.asarray(v[:8]) for k, v in s.items()} for s in streams]
    for step in range(50):
        batches = []
        for s in streams:
            idx = rng.integers(0, 64, 8)
            batches.append({k: jnp.asarray(v[idx]) for k, v in s.items()})
        swarm.local_steps(batches)
        log = swarm.maybe_sync(vals)
        if log:
            print(f"step {log['step']:3d} gossip: gates={log['gates']} "
                  f"merged-metric={[round(m, 4) for m in log['metric_merged']]}")

    for i, n in enumerate(swarm.nodes):
        print(f"node {i}: final local loss = {n.history[-1]['loss']:.3f}")
    print("OK — swarm training with LoRA-only P2P sync complete.")


if __name__ == "__main__":
    main()
