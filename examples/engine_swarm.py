"""The compiled swarm session in ~50 lines.

Where quickstart.py drives arbitrary Python callables (`backend="host"`),
this example hands the whole P2P-SL schedule to the default engine backend
of `SwarmSession.run_rounds`: every round — `sync_every` vmapped local
steps, in-graph validation of local and merged params, the 80% gate, and
the fused Pallas commit — is part of ONE compiled program; rounds are
scanned with zero host round-trips. Mid-run membership changes
(`session.leave` / `session.join`) are pure state updates: the second
`run_rounds` call below reuses the already-compiled round.

Run:  PYTHONPATH=src python examples/engine_swarm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SwarmConfig, TrainConfig
from repro.core.session import SwarmSession
from repro.data import make_lm_stream
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import adamw_init


def main():
    n_nodes, rounds, sync_every, batch, seq = 4, 3, 5, 8, 32
    cfg = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256)
    model = build_model(cfg)
    base_step = make_train_step(model, TrainConfig(lr=3e-3, remat=False,
                                                   warmup_steps=2,
                                                   max_steps=rounds * sync_every))

    # heterogeneous local shards: topic-biased token streams per node
    streams = [make_lm_stream(128, seq, cfg.vocab_size, seed=i, topic_bias=1.0)
               for i in range(n_nodes)]
    rng = np.random.default_rng(0)

    def block(count):  # [rounds, T, N, B, S] stacked batch schedule
        # one index draw per node, shared by every key (tokens/labels pair up)
        idx = [rng.integers(0, len(s["tokens"]), (rounds, count, batch))
               for s in streams]
        return {k: jnp.asarray(np.stack([s[k][i] for s, i
                                         in zip(streams, idx)], axis=2))
                for k in streams[0]}

    vals = {k: jnp.asarray(np.stack([s[k][:8] for s in streams]))
            for k in streams[0]}
    params = model.init(jax.random.key(0))

    session = SwarmSession(
        SwarmConfig(n_nodes=n_nodes, sync_every=sync_every, topology="full",
                    merge="fedavg", lora_only=False, val_threshold=0.8),
        lambda p, o, b, s: base_step(p, o, b),
        lambda p, v: 1.0 / (1.0 + model.loss_fn(p, v, remat=False)[0]),
        params=params, opt_state=adamw_init(params),
        data_sizes=[len(s["tokens"]) for s in streams])

    logs = session.run_rounds(block(sync_every), vals)
    losses = np.asarray(logs["train"]["loss"])     # [rounds, T, N]
    for r in range(rounds):
        print(f"round {r}: loss={[f'{l:.3f}' for l in losses[r, -1]]} "
              f"gates={np.asarray(logs['gates'][r]).astype(bool).tolist()}")

    # dynamic membership: node 3 drops out; the SAME compiled round serves
    # the new configuration (active mask is runtime data, zero retraces)
    session.leave(3)
    logs = session.run_rounds(block(sync_every), vals)
    print(f"node 3 left: gates={np.asarray(logs['gates'][-1]).tolist()} "
          f"(round {int(session.state.round)}, step {int(session.state.step)})")
    session.join(3)
    print("OK — every round above ran as one compiled session call.")


if __name__ == "__main__":
    main()
