"""The jitted stacked swarm engine in ~50 lines.

Where quickstart.py drives a Python loop over nodes (`SwarmLearner`), this
example hands the whole P2P-SL schedule to `SwarmEngine.run_rounds`: every
round — `sync_every` vmapped local steps, in-graph validation of local and
merged params, the 80% gate, and the fused Pallas commit — is part of ONE
compiled program; rounds are scanned with zero host round-trips.

Run:  PYTHONPATH=src python examples/engine_swarm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SwarmConfig, TrainConfig
from repro.core import merge_impl as merge_lib
from repro.core.engine import SwarmEngine
from repro.data import make_lm_stream
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import adamw_init


def main():
    n_nodes, rounds, sync_every, batch, seq = 4, 3, 5, 8, 32
    cfg = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256)
    model = build_model(cfg)
    base_step = make_train_step(model, TrainConfig(lr=3e-3, remat=False,
                                                   warmup_steps=2,
                                                   max_steps=rounds * sync_every))

    # heterogeneous local shards: topic-biased token streams per node
    streams = [make_lm_stream(128, seq, cfg.vocab_size, seed=i, topic_bias=1.0)
               for i in range(n_nodes)]
    rng = np.random.default_rng(0)

    def block(count):  # [rounds, T, N, B, S] stacked batch schedule
        # one index draw per node, shared by every key (tokens/labels pair up)
        idx = [rng.integers(0, len(s["tokens"]), (rounds, count, batch))
               for s in streams]
        return {k: jnp.asarray(np.stack([s[k][i] for s, i
                                         in zip(streams, idx)], axis=2))
                for k in streams[0]}

    vals = {k: jnp.asarray(np.stack([s[k][:8] for s in streams]))
            for k in streams[0]}
    params = model.init(jax.random.key(0))
    stacked = merge_lib.stack_params([params] * n_nodes)
    opts = merge_lib.stack_params([adamw_init(params)] * n_nodes)

    engine = SwarmEngine(
        SwarmConfig(n_nodes=n_nodes, sync_every=sync_every, topology="full",
                    merge="fedavg", lora_only=False, val_threshold=0.8),
        lambda p, o, b, s: base_step(p, o, b),
        lambda p, v: 1.0 / (1.0 + model.loss_fn(p, v, remat=False)[0]),
        data_sizes=[len(s["tokens"]) for s in streams])

    stacked, opts, train_ms, logs = engine.run_rounds(
        stacked, opts, block(sync_every), vals, None, 0)

    losses = np.asarray(train_ms["loss"])          # [rounds, T, N]
    for r in range(rounds):
        print(f"round {r}: loss={[f'{l:.3f}' for l in losses[r, -1]]} "
              f"gates={np.asarray(logs['gates'][r]).astype(bool).tolist()}")
    print("OK — every round above ran as one compiled engine call.")


if __name__ == "__main__":
    main()
