"""Serving demo: batched greedy generation against a sharded-layout KV cache
(the decode path the dry-run lowers for decode_32k / long_500k).

Shows all three decode-state families: KV cache (dense), recurrent SSM state
(mamba2 — O(1) memory, the long_500k path), and enc-dec cross-attention.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.launch.serve import generate, make_serve_step
from repro.models import build_model


def demo(arch: str, max_new: int = 16):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, prompt_len, max_len = 4, 8, 64
    prompt = jax.random.randint(jax.random.key(1), (b, prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    if cfg.is_encdec:
        caches = model.init_cache(b, max_len)
        from repro.models.encdec import encode
        frames = jax.random.normal(jax.random.key(2),
                                   (b, cfg.enc_seq_len, cfg.frontend_dim))
        caches = dict(caches, enc_out=encode(params, cfg, frames))
        step = jax.jit(make_serve_step(model))
        tok = jnp.zeros((b, 1), jnp.int32)
        outs = []
        for i in range(max_new):
            tok, caches = step(params, tok, caches, jnp.int32(i))
            outs.append(tok)
        out = jnp.concatenate(outs, axis=1)
    else:
        out = generate(model, params, prompt, max_new, max_len)
    dt = time.time() - t0
    per_tok = dt / max_new * 1000
    print(f"{arch:24s} [{cfg.family:6s}] generated {out.shape} "
          f"({per_tok:.1f} ms/token incl. compile) sample: {out[0, :8].tolist()}")


def main():
    for arch in ("minicpm-2b",          # dense, KV cache
                 "mamba2-370m",         # ssm, O(1) state (long_500k family)
                 "phi3.5-moe-42b-a6.6b",  # moe decode w/ expert routing
                 "seamless-m4t-medium"):  # enc-dec cross-attention
        demo(arch)
    print("OK — batched greedy serving across 4 decode-state families.")


if __name__ == "__main__":
    main()
