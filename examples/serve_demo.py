"""Serving demo: batched greedy generation against a sharded-layout KV cache
(the decode path the dry-run lowers for decode_32k / long_500k), plus the
PR 8 serving plane — a continuous-batching consensus ensemble with
zero-downtime hot-swap (docs/serving.md).

Shows all three decode-state families: KV cache (dense), recurrent SSM state
(mamba2 — O(1) memory, the long_500k path), and enc-dec cross-attention.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.serve import generate, serve_step_for
from repro.models import build_model
from repro.serve import BucketPolicy, ServeEngine


def demo(arch: str, max_new: int = 16):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, prompt_len, max_len = 4, 8, 64
    prompt = jax.random.randint(jax.random.key(1), (b, prompt_len), 0,
                                cfg.vocab_size)

    def run():
        if cfg.is_encdec:
            caches = model.init_cache(b, max_len)
            from repro.models.encdec import encode
            frames = jax.random.normal(jax.random.key(2),
                                       (b, cfg.enc_seq_len, cfg.frontend_dim))
            caches = dict(caches, enc_out=encode(params, cfg, frames))
            step = serve_step_for(model)
            tok = jnp.zeros((b, 1), jnp.int32)
            outs = []
            for i in range(max_new):
                tok, caches = step(params, tok, caches, jnp.int32(i))
                outs.append(tok)
            return jnp.concatenate(outs, axis=1)
        return generate(model, params, prompt, max_new, max_len)

    # warmup: compile outside the timed region (the seed stub started t0
    # before the first jitted call, so "ms/token" was mostly compile time)
    jax.block_until_ready(run())
    t0 = time.time()
    out = jax.block_until_ready(run())
    dt = time.time() - t0
    per_tok = dt / max_new * 1000
    print(f"{arch:24s} [{cfg.family:6s}] generated {out.shape} "
          f"({per_tok:.1f} ms/token) sample: {out[0, :8].tolist()}")


def demo_ensemble(arch: str = "minicpm-2b", n_nodes: int = 4):
    """Continuous-batching consensus over N stacked per-node variants — the
    SwarmState.params layout served directly as one vmapped ensemble."""
    cfg = smoke_variant(get_config(arch)).replace(vocab_size=256)
    model = build_model(cfg)
    params = jax.vmap(model.init)(
        jax.random.split(jax.random.key(0), n_nodes))
    eng = ServeEngine(model, params, mode="consensus", max_len=48,
                      max_slots=4,
                      policy=BucketPolicy(batch_buckets=(1, 2, 4),
                                          seq_buckets=(16,)))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(n), dtype=np.int32)
               for n in rng.integers(4, 12, size=6)]
    for p in prompts[:4]:                      # warm the bucket grid
        eng.submit(p, max_new=2)
    eng.drain()
    t0 = time.time()
    reqs = [eng.submit(p, max_new=8) for p in prompts]
    eng.drain()
    dt = time.time() - t0
    print(f"{arch:24s} [swarm ] {n_nodes}-node consensus served "
          f"{len(reqs)} reqs in {dt * 1000:.0f} ms "
          f"({len(reqs) / dt:.1f} req/s, {eng.total_traces} compiles) "
          f"sample: {reqs[0].tokens}")


def main():
    for arch in ("minicpm-2b",          # dense, KV cache
                 "mamba2-370m",         # ssm, O(1) state (long_500k family)
                 "phi3.5-moe-42b-a6.6b",  # moe decode w/ expert routing
                 "seamless-m4t-medium"):  # enc-dec cross-attention
        demo(arch)
    demo_ensemble()
    print("OK — batched greedy serving across 4 decode-state families "
          "+ continuous-batching swarm consensus.")


if __name__ == "__main__":
    main()
