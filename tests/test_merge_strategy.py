"""The compiled MergeStrategy layer: fisher/gradmatch in-graph.

Invariants:
  * strategy propose == the `merge_impl.merge(...)` ground truth,
  * the engine's weighted commit (Pallas imp kernel) == merge + gated select,
  * fisher/gradmatch `run_rounds` trace end-to-end (zero host transfers)
    and commit through the fused Pallas kernel,
  * the jitted engine matches the host-driven SwarmLearner loop for the
    weighted merges on the toy quadratic model,
  * the stale-by-one overlap mode stays a convergent gossip scheme.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.core import merge_impl as merge_lib
from repro.core.engine import SwarmEngine, active_weights, mixing_matrix
from repro.core.merge_impl import get_strategy
from repro.core.swarm import NodeState, SwarmLearner

N = 4
SEEDS = range(3)


def _cfg(**kw):
    kw.setdefault("n_nodes", N)
    kw.setdefault("sync_every", 2)
    kw.setdefault("merge", "fisher")
    kw.setdefault("topology", "full")
    kw.setdefault("lora_only", False)
    kw.setdefault("val_threshold", 0.0)
    return SwarmConfig(**kw)


def _rand_tree(rng, n=N):
    mk = lambda *s: jnp.asarray(rng.normal(0, 1, (n, *s)), jnp.float32)
    return {"w": mk(8, 16), "b": mk(16)}


def _rand_fishers(rng, tree):
    return jax.tree.map(
        lambda x: jnp.asarray(np.abs(rng.normal(1, 0.5, x.shape)),
                              jnp.float32), tree)


def _toy_fns():
    def train_step(params, opt_state, batch, step):
        g = params["x"] - batch
        return {"x": params["x"] - 0.1 * g}, opt_state, {"loss": jnp.sum(g * g)}

    def eval_fn(params, val):
        return 1.0 - 0.0 * jnp.sum(params["x"])  # always accept, in-graph

    return train_step, eval_fn


def _targets():
    return jnp.asarray([np.full((4,), t, np.float32) for t in range(N)])


# ---------------------------------------------------------------------------
# strategy propose == merge_impl ground truth
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fisher", "gradmatch"])
@pytest.mark.parametrize("seed", SEEDS)
def test_strategy_propose_matches_merge_impl(method, seed):
    rng = np.random.default_rng(seed)
    st = _rand_tree(rng)
    fishers = _rand_fishers(rng, st)
    w = jnp.asarray(rng.dirichlet(np.ones(N)), jnp.float32)
    W = jnp.asarray(mixing_matrix(_cfg(merge=method), np.ones(N)), jnp.float32)
    strategy = get_strategy(_cfg(merge=method))
    cand, W_eff, imp = strategy.propose(st, W, weights=w, fishers=fishers)
    want = merge_lib.merge(st, method, W=W, fishers=fishers, weights=w)
    for a, b in zip(jax.tree.leaves(cand), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert imp is not None and W_eff.shape == (N, N)


@pytest.mark.parametrize("seed", SEEDS)
def test_mix_strategy_matches_mix(seed):
    rng = np.random.default_rng(seed)
    st = _rand_tree(rng)
    W = jnp.asarray(mixing_matrix(_cfg(merge="fedavg"),
                                  rng.integers(1, 10, N)), jnp.float32)
    cand, W_eff, imp = get_strategy(_cfg(merge="fedavg")).propose(st, W)
    want = merge_lib.mix(st, W)
    for a, b in zip(jax.tree.leaves(cand), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert imp is None and W_eff is W


# ---------------------------------------------------------------------------
# engine sync: the fused weighted commit == merge + gated select
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fisher", "gradmatch"])
def test_engine_weighted_commit_matches_host_merge(method):
    rng = np.random.default_rng(0)
    params = {"x": jnp.asarray(rng.normal(0, 1, (N, 6)), jnp.float32)}
    stats = {"x": jnp.asarray(np.abs(rng.normal(1, 0.5, (N, 6))), jnp.float32)}
    _, eval_fn = _toy_fns()
    eng = SwarmEngine(_cfg(merge=method), None, eval_fn,
                      data_sizes=[100 * (i + 1) for i in range(N)])
    committed, log = jax.jit(eng.sync)(params, jnp.zeros((N, 1)), None, stats)
    assert np.asarray(log["gates"]).all()
    w = active_weights([100 * (i + 1) for i in range(N)])
    want = merge_lib.merge(params, method, fishers=stats,
                           weights=jnp.asarray(w, jnp.float32))
    np.testing.assert_allclose(np.asarray(committed["x"]),
                               np.asarray(want["x"]), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("method", ["fisher", "gradmatch"])
def test_engine_weighted_commit_respects_gates_and_active(method):
    """Rejected / inactive nodes keep their params even on the imp path."""
    rng = np.random.default_rng(1)
    params = {"x": jnp.asarray(rng.normal(0, 1, (N, 6)), jnp.float32)}
    stats = {"x": jnp.ones((N, 6), jnp.float32)}
    _, eval_fn = _toy_fns()
    eng = SwarmEngine(_cfg(merge=method), None, eval_fn, data_sizes=[1] * N)
    active = jnp.asarray([True, True, False, True])
    committed, log = jax.jit(eng.sync)(params, jnp.zeros((N, 1)), active,
                                       stats)
    gates = np.asarray(log["gates"])
    assert not gates[2] and gates[[0, 1, 3]].all()
    np.testing.assert_allclose(np.asarray(committed["x"][2]),
                               np.asarray(params["x"][2]))
    # active nodes merge over the active membership only (uniform fishers →
    # mean of the active rows; the inactive row's eps mass is negligible)
    want = np.asarray(params["x"])[[0, 1, 3]].mean(0)
    for i in (0, 1, 3):
        np.testing.assert_allclose(np.asarray(committed["x"][i]), want,
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# compiled round: traces end-to-end, commits through Pallas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fisher", "gradmatch"])
def test_run_rounds_weighted_is_fully_traced_with_pallas_commit(method):
    """`run_rounds` with fisher/gradmatch builds one jaxpr — no host
    round-trips (a `float()` anywhere on the path would raise a tracer
    error) — and the commit goes through the Pallas fused_merge kernel."""
    train_step, eval_fn = _toy_fns()
    eng = SwarmEngine(_cfg(merge=method), train_step, eval_fn,
                      data_sizes=[1] * N)
    batches = jnp.broadcast_to(_targets(), (3, 2, N, 4))
    jaxpr = jax.make_jaxpr(eng._run_rounds)(
        {"x": jnp.zeros((N, 4))}, None, batches, jnp.zeros((N, 1)))
    assert "pallas_call" in str(jaxpr)


def test_round_returns_and_threads_stats():
    train_step, eval_fn = _toy_fns()
    eng = SwarmEngine(_cfg(merge="fisher"), train_step, eval_fn,
                      data_sizes=[1] * N)
    batches = jnp.broadcast_to(_targets(), (2, N, 4))
    p, o, out = eng.round({"x": jnp.zeros((N, 4))}, None, batches,
                          jnp.zeros((N, 1)), None, 0)
    assert "stats" in out and out["stats"]["x"].shape == (N, 4)
    assert float(jnp.abs(out["stats"]["x"]).sum()) > 0  # mass accumulated
    # stats keep riding through run_local
    p, o, _, stats = eng.run_local(p, None, batches, 2, out["stats"])
    assert stats["x"].shape == (N, 4)
    # ... and run_rounds hands the final accumulators back for chunked calls
    rb = jnp.broadcast_to(_targets(), (2, 2, N, 4))
    p, o, _, logs = eng.run_rounds(p, None, rb, jnp.zeros((N, 1)), None, 4,
                                   stats)
    assert logs["stats"]["x"].shape == (N, 4)
    assert float(jnp.abs(logs["stats"]["x"]).sum()) > 0


def test_untrained_node_does_not_dominate_fisher_merge():
    """Regression: an active node that never accumulated mass must get ~zero
    importance. A ones_like default would dwarf the trained nodes'
    lr²-scaled Δθ² mass and hand the merge its (stale) params."""
    train_step, eval_fn = _toy_fns()
    cfg = _cfg(merge="fisher", sync_every=2)
    # trained nodes start away from their targets so every step accumulates
    # Δθ² mass; node 3 (params 100.0) is active but never gets a batch
    nodes = [NodeState(params={"x": jnp.full((4,), 100.0 if i == 3 else -1.0,
                                             jnp.float32)},
                       opt_state=None, data_size=100) for i in range(N)]
    sw = SwarmLearner(cfg, train_step, eval_fn, nodes)
    targets = list(_targets())
    for _ in range(2):  # node 3 is active but never gets a batch
        sw.local_steps(targets[:3] + [None])
    log = sw.sync([1] * N)
    assert all(log["gates"])
    for i in range(3):
        merged = np.asarray(sw.nodes[i].params["x"])
        assert np.abs(merged).max() < 5.0, "untrained node took over the merge"


def test_explicit_fisher_survives_local_steps():
    """An explicitly set node.fisher (true squared-grad estimates) is never
    decayed into the Δθ² proxy — accumulation goes to fisher_stats and the
    explicit estimate wins at sync."""
    train_step, eval_fn = _toy_fns()
    nodes = [NodeState(params={"x": jnp.zeros((4,))}, opt_state=None,
                       data_size=100) for _ in range(N)]
    explicit = {"x": jnp.full((4,), 7.0, jnp.float32)}
    nodes[1].fisher = explicit
    sw = SwarmLearner(_cfg(merge="fisher"), train_step, eval_fn, nodes)
    for _ in range(3):
        sw.local_steps(list(_targets()))
    assert sw.nodes[1].fisher is explicit            # untouched object
    assert sw.nodes[1].fisher_stats is not None      # proxy still tracked
    # node 2 moves toward a nonzero target, so it accumulated real mass
    assert float(jnp.abs(sw.nodes[2].fisher_stats["x"]).sum()) > 0


def test_tiny_accumulated_mass_survives_eps_floor():
    """Regression: lr²-scaled Δθ² mass (≪ eps) must still drive the merge.
    Finalization normalizes post-mask, so relative fisher weighting is
    preserved and a departed node's huge stale mass stays excluded instead
    of re-entering as a uniform-mean term."""
    rng = np.random.default_rng(2)
    params = {"x": jnp.asarray(rng.normal(0, 1, (N, 6)), jnp.float32)}
    mass = np.full((N, 6), 1e-9, np.float32)   # ≪ the 1e-8 eps floor
    mass[0] = 3e-9                             # node 0: 3x the mass
    mass[2] = 1e6                              # node 2: huge but departed
    stats = {"x": jnp.asarray(mass)}
    _, eval_fn = _toy_fns()
    eng = SwarmEngine(_cfg(merge="fisher"), None, eval_fn, data_sizes=[1] * N)
    active = jnp.asarray([True, True, False, True])
    committed, log = jax.jit(eng.sync)(params, jnp.zeros((N, 1)), active,
                                       stats)
    x = np.asarray(params["x"])
    want = (3 * x[0] + x[1] + x[3]) / 5.0      # mass-weighted active mean
    for i in (0, 1, 3):
        np.testing.assert_allclose(np.asarray(committed["x"][i]), want,
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(committed["x"][2]), x[2])


@pytest.mark.parametrize("method", ["fisher", "gradmatch"])
def test_engine_matches_swarm_learner_weighted(method):
    """The compiled engine == the host SwarmLearner loop for the weighted
    merges on the toy quadratic (strategy accumulation on both paths)."""
    train_step, eval_fn = _toy_fns()
    cfg = _cfg(merge=method)
    targets = _targets()
    rounds, t = 3, cfg.sync_every

    nodes = [NodeState(params={"x": jnp.zeros((4,))}, opt_state=None,
                       data_size=100 * (i + 1)) for i in range(N)]
    sw = SwarmLearner(cfg, train_step, eval_fn, nodes)
    for _ in range(rounds):
        for _ in range(t):
            sw.local_steps(list(targets))
        assert sw.maybe_sync([1] * N) is not None

    eng = SwarmEngine(cfg, train_step, eval_fn,
                      data_sizes=[100 * (i + 1) for i in range(N)])
    batches = jnp.broadcast_to(targets, (rounds, t, N, 4))
    params, _, _, logs = eng.run_rounds({"x": jnp.zeros((N, 4))}, None,
                                        batches, jnp.zeros((N, 1)), None, 0)
    assert np.asarray(logs["gates"]).all()
    want = np.stack([np.asarray(n.params["x"]) for n in sw.nodes])
    np.testing.assert_allclose(np.asarray(params["x"]), want,
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# stale-by-one overlap mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("merge", ["fedavg", "fisher"])
def test_overlap_mode_converges_toy(merge):
    """Double-buffered rounds remain a convergent gossip scheme: nodes end
    near the serial-mode consensus, one round of staleness at most. The
    fisher case exercises the stats carry riding next to the pending-delta
    double buffer in the overlap scan body."""
    train_step, eval_fn = _toy_fns()
    targets = _targets()
    finals = {}
    for overlap in (False, True):
        cfg = _cfg(merge=merge, sync_every=1, overlap_sync=overlap)
        eng = SwarmEngine(cfg, train_step, eval_fn, data_sizes=[1] * N)
        batches = jnp.broadcast_to(targets, (12, 1, N, 4))
        p, _, _, logs = eng.run_rounds({"x": jnp.zeros((N, 4))}, None,
                                       batches, jnp.zeros((N, 1)), None, 0)
        assert np.asarray(logs["gates"]).all()
        if merge == "fisher":
            assert logs["stats"]["x"].shape == (N, 4)
        finals[overlap] = np.asarray(p["x"])
    serial, stale = finals[False], finals[True]
    # serial reaches exact consensus; stale-by-one stays within one round of
    # local drift (0.1 * max target distance) of it
    assert np.abs(serial - serial.mean(0)).max() < 1e-5
    assert np.abs(stale - serial).max() < 0.35
    assert np.abs(stale.mean() - serial.mean()) < 0.15


def test_mixed_explicit_and_proxy_fishers_do_not_collapse():
    """Regression: one node supplying explicit squared-grad Fishers (~O(1))
    among proxy-accumulating peers (~lr² mass) must not swallow the merge —
    mixed sources are normalized per node before stacking."""
    train_step, eval_fn = _toy_fns()
    nodes = [NodeState(params={"x": jnp.full((4,), float(i), jnp.float32)},
                       opt_state=None, data_size=100) for i in range(N)]
    nodes[0].fisher = {"x": jnp.ones((4,), jnp.float32)}  # explicit, O(1)
    sw = SwarmLearner(_cfg(merge="fisher"), train_step, eval_fn, nodes)
    # batch targets sit off every node's params so each step moves the
    # params and deposits (tiny, lr²-scaled) Δθ² mass
    offset = [jnp.full((4,), i + 0.5, jnp.float32) for i in range(N)]
    for _ in range(2):
        sw.local_steps(offset)
    x0 = np.asarray(sw.nodes[0].params["x"]).copy()  # pre-sync local params
    log = sw.sync([1] * N)
    assert all(log["gates"])
    merged = np.asarray(sw.nodes[1].params["x"])
    # a genuine blend: clearly away from node 0's params (pre-fix the merge
    # collapsed onto them) and inside the swarm's param range
    assert np.abs(merged - x0).min() > 0.3
    assert merged.max() <= 3.2 and merged.min() >= 0.0


def test_overlap_histo_smoke_converges():
    """The stale-by-one schedule trains the tiny histo swarm end-to-end."""
    from repro.data import make_histo_dataset, paper_splits, shard_to_nodes
    from repro.experiments.histo import (HistoExperimentConfig,
                                         _make_model_fns, _train_loop)

    ecfg = HistoExperimentConfig(
        n_train=160, n_test=32, steps=6, image_size=16, batch_size=8,
        noise=0.6, growth=4, stem=8, feat_dim=32, hidden=16, n_blocks=1,
        layers_per_block=2, seed=3,
        swarm=SwarmConfig(n_nodes=4, sync_every=3, topology="full",
                          merge="fedavg", lora_only=False, val_threshold=0.8,
                          overlap_sync=True))
    images, labels = make_histo_dataset(ecfg.n_train, size=ecfg.image_size,
                                        noise=ecfg.noise, seed=ecfg.seed)
    shards = shard_to_nodes(images, labels,
                            paper_splits(ecfg.n_train, ecfg.fractions),
                            seed=ecfg.seed)
    train_step, predict, _ = _make_model_fns(ecfg)
    params, sync_log = _train_loop(ecfg, train_step, shards,
                                   swarm_cfg=ecfg.swarm)
    assert len(params) == 4 and sync_log
    for s in sync_log:
        assert all(0.0 <= m <= 1.0 for m in s["metric_local"])
    probs = np.asarray(predict(params[0], images[:64]))
    assert np.isfinite(probs).all()
