"""SPMD gossip collectives need >1 device; all checks run in ONE subprocess
with XLA_FLAGS forcing 8 host devices (the main test process keeps seeing 1
CPU device), each printing an `OK <tag>` marker the tests assert on —
amortizing the jax import + mesh setup across the whole module."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.spmd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH=SRC)
    # append so conftest's compile-time flags survive in the subprocess
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


_CHECKS = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, SwarmConfig, TrainConfig
from repro.core.gossip import (fedavg_gossip, fisher_gossip, matrix_gossip,
                               ring_gossip)
from repro.core.merge_impl import fisher_merge
from repro.core.swarm import gate_decisions, gated_commit
from repro.core.topology import dynamic_matrix, full_matrix, ring_matrix
from repro.launch.train import (make_swarm_train_step, make_swarm_sync_step,
                                init_train_state)
from repro.models import build_model

mesh = jax.make_mesh((4, 2), ("node", "model"), devices=jax.devices())

# --- fedavg gossip == host weighted merge -------------------------------
rng = np.random.default_rng(0)
tree = {"w": jnp.asarray(rng.normal(0, 1, (4, 8, 6)), jnp.float32),
        "skip": None}
w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
out = jax.jit(lambda t: fedavg_gossip(t, w, mesh, "node"))(tree)
want = np.tensordot(np.asarray(w), np.asarray(tree["w"]), axes=(0, 0))
for i in range(4):
    np.testing.assert_allclose(np.asarray(out["w"][i]), want, rtol=1e-5)
assert out["skip"] is None
print("OK fedavg")

# --- ring gossip == ring mixing matrix ----------------------------------
rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(0, 1, (4, 5, 3)), jnp.float32)
out = jax.jit(lambda t: ring_gossip(t, mesh, "node", 0.5))({"x": x})["x"]
want = np.tensordot(ring_matrix(4, 0.5), np.asarray(x), axes=(1, 0))
np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)
print("OK ring")

# --- matrix gossip with dynamic membership ------------------------------
rng = np.random.default_rng(2)
x = jnp.asarray(rng.normal(0, 1, (4, 7)), jnp.float32)
W = dynamic_matrix(full_matrix(4, [1, 3, 3, 3]), [True, True, False, True])
out = jax.jit(lambda t: matrix_gossip(t, W, mesh, "node"))({"x": x})["x"]
np.testing.assert_allclose(np.asarray(out), W @ np.asarray(x),
                           rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(np.asarray(out[2]), np.asarray(x[2]))
print("OK matrix_dynamic")

# --- fisher gossip == host fisher merge ---------------------------------
rng = np.random.default_rng(3)
x = {"w": jnp.asarray(rng.normal(0, 1, (4, 6, 4)), jnp.float32)}
f = {"w": jnp.asarray(np.abs(rng.normal(1, 0.3, (4, 6, 4))), jnp.float32)}
out = jax.jit(lambda t, ff: fisher_gossip(t, ff, mesh, "node"))(x, f)["w"]
np.testing.assert_allclose(np.asarray(out), np.asarray(fisher_merge(x, f)["w"]),
                           rtol=1e-5, atol=1e-6)
print("OK fisher")

# --- ring-native topo fisher: two ppermutes, no gather, oracle parity ----
from repro.core.gossip import ring_topo_fisher_gossip, ring_rows_gossip, \
    topo_fisher_gossip
from repro.core.merge_impl import topo_weighted_merge
from repro.core.topology import ring_structured
from repro.launch import hlo_stats
rW = dynamic_matrix(ring_matrix(4, 0.5), [True, True, False, True])
assert ring_structured(rW)
ring_fn = jax.jit(lambda t, ff: ring_topo_fisher_gossip(t, ff, rW, mesh,
                                                        "node"))
want = topo_weighted_merge(x, f, rW)["w"]
np.testing.assert_allclose(np.asarray(ring_fn(x, f)["w"]), np.asarray(want),
                           rtol=1e-5, atol=1e-6)
coll = hlo_stats.collective_bytes(ring_fn.lower(x, f).compile().as_text())
d = x["w"][0].size
assert coll["all-gather"] == 0, coll
# two ppermutes of the fused (F*theta + F) payload: 4*P f32 values
assert coll["collective-permute"] == 4 * d * 4, (coll, d)
np.testing.assert_allclose(
    np.asarray(jax.jit(lambda t, ff: ring_topo_fisher_gossip(
        t, ff, rW, mesh, "node", wire_dtype="bf16"))(x, f)["w"]),
    np.asarray(want), rtol=2e-2, atol=2e-2)
print("OK ring_topo_fisher")

# --- single-gather fallback: exactly ONE all_gather of (num + mass) ------
gat_fn = jax.jit(lambda t, ff: topo_fisher_gossip(t, ff, rW, mesh, "node"))
np.testing.assert_allclose(np.asarray(gat_fn(x, f)["w"]), np.asarray(want),
                           rtol=1e-5, atol=1e-6)
coll = hlo_stats.collective_bytes(gat_fn.lower(x, f).compile().as_text())
assert coll["collective-permute"] == 0, coll
# one gather of the stacked [2N, P] payload -> 2*N*P f32 result bytes;
# two separate gathers would land 2x this from 2 ops
assert coll["all-gather"] == 2 * 4 * d * 4, (coll, d)
assert coll["count"] == 1, coll
print("OK topo_single_gather")

# --- ring rows gossip (mean/fedavg ring with a masked matrix) ------------
got = jax.jit(lambda t: ring_rows_gossip(t, rW, mesh, "node"))(x)["w"]
want_rows = np.tensordot(rW, np.asarray(x["w"]), axes=(1, 0))
np.testing.assert_allclose(np.asarray(got), want_rows, rtol=1e-5, atol=1e-6)
print("OK ring_rows")


# --- gradmatch via the engine gossip backend == host gradmatch merge -----
from repro.core.engine import SwarmEngine
from repro.core.merge_impl import gradmatch_merge
gm_mesh = jax.make_mesh((4,), ("gnode",), devices=jax.devices()[:4])  # noqa: SWL001 — off-registry on purpose: the engine's gossip backend must be axis-name-agnostic (axis is a parameter, never hardcoded)
sizes = [1.0, 3.0, 3.0, 3.0]
gcfg = SwarmConfig(n_nodes=4, topology="full", merge="gradmatch",
                   lora_only=False)
geng = SwarmEngine(gcfg, None, None, data_sizes=sizes, backend="gossip",
                   mesh=gm_mesh, axis="gnode")
cand, _, _ = jax.jit(lambda p, ff: geng.propose(p, fishers=ff))(x, f)
w = jnp.asarray(np.asarray(sizes) / np.sum(sizes), jnp.float32)
np.testing.assert_allclose(np.asarray(cand["w"]),
                           np.asarray(gradmatch_merge(x, f, w)["w"]),
                           rtol=1e-5, atol=1e-6)
print("OK gradmatch_gossip")

# --- engine gossip backend lowers ring fisher to the ppermute schedule ---
rcfg = SwarmConfig(n_nodes=4, topology="ring", merge="fisher",
                   lora_only=False)
reng = SwarmEngine(rcfg, None, None, data_sizes=[1.0] * 4, backend="gossip",
                   mesh=gm_mesh, axis="gnode")
assert reng.sync_schedule.name == "ring_topo_ppermute"
rcand_fn = jax.jit(lambda p, ff: reng.propose(p, fishers=ff)[0])
# engine applies finalize_mass (mean-1 normalization) before the merge;
# scale cancels in the ratio, so the unnormalized oracle still matches
want_eng = topo_weighted_merge(x, f, ring_matrix(4, 0.5))["w"]
np.testing.assert_allclose(np.asarray(rcand_fn(x, f)["w"]),
                           np.asarray(want_eng), rtol=1e-4, atol=1e-5)
print("OK engine_ring_schedule")

# --- full SPMD swarm step: vmapped train + gossip + gated commit --------
cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128)
model = build_model(cfg)
tc = TrainConfig(lr=1e-3, remat=False, warmup_steps=1, max_steps=10)
keys = jax.random.split(jax.random.key(0), 4)
ps, os_ = [], []
for k in keys:
    p, o = init_train_state(model, k)
    ps.append(p); os_.append(o)
stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
params, opts = stack(ps), stack(os_)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 128, (4, 2, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 128, (4, 2, 16)), jnp.int32)}
step = jax.jit(make_swarm_train_step(model, tc))
params2, opts2, metrics = step(params, opts, batch)
assert metrics["loss"].shape == (4,)
assert np.isfinite(np.asarray(metrics["loss"])).all()

scfg = SwarmConfig(n_nodes=4, topology="ring", merge="fedavg",
                   lora_only=False, val_threshold=0.8)
propose, commit = make_swarm_sync_step(scfg, mesh, "node", [1, 3, 3, 3])
cand = jax.jit(propose)(params2)
assert all(jax.tree.leaves(
    jax.tree.map(lambda a, b: a.shape == b.shape, cand, params2)))
merged_metric = jnp.asarray([1.0, 1.0, 0.1, 1.0])
local_metric = jnp.ones(4)
final = jax.jit(commit)(cand, params2, merged_metric, local_metric)
# node 2 rejected -> keeps local
l2 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a[2]-b[2]).max()),
                                  final, params2))
assert max(l2) == 0.0
# node 0 accepted -> took the merge
l0 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a[0]-b[0]).max()),
                                  final, cand))
assert max(l0) == 0.0
print("OK swarm_step")

# --- full-topology fedavg keeps the psum schedule under a runtime mask ---
fcfg = SwarmConfig(n_nodes=4, topology="full", merge="fedavg",
                   lora_only=False)
feng = SwarmEngine(fcfg, None, None, data_sizes=[1, 3, 3, 3],
                   backend="gossip", mesh=gm_mesh, axis="gnode")
assert feng.sync_schedule.name == "fedavg_psum"
xa = {"w": jnp.asarray(np.random.default_rng(9).normal(0, 1, (4, 7)),
                       jnp.float32)}
amask = jnp.asarray([True, True, False, True])
fcand = jax.jit(lambda p, a: feng.propose(p, active=a)[0])(xa, amask)
Wdyn = dynamic_matrix(full_matrix(4, [1, 3, 3, 3]),
                      np.asarray(amask))
np.testing.assert_allclose(np.asarray(fcand["w"]),
                           Wdyn @ np.asarray(xa["w"]), rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(np.asarray(fcand["w"][2]), np.asarray(xa["w"][2]))
coll = hlo_stats.collective_bytes(
    jax.jit(lambda p, a: feng.propose(p, active=a)[0])
    .lower(xa, amask).compile().as_text())
# masked fedavg stays on the psum wire: no payload-sized all_gather (XLA
# may still gather the tiny [N] weights vector)
assert coll["all-gather"] < 4 * 7 * 4, coll
# merge="mean" must stay UNIFORM under the mask (host W is uniform),
# ignoring data sizes
mcfg = SwarmConfig(n_nodes=4, topology="full", merge="mean",
                   lora_only=False)
meng = SwarmEngine(mcfg, None, None, data_sizes=[1, 3, 3, 3],
                   backend="gossip", mesh=gm_mesh, axis="gnode")
mcand = jax.jit(lambda p, a: meng.propose(p, active=a)[0])(xa, amask)
Wuni = dynamic_matrix(full_matrix(4), np.asarray(amask))
np.testing.assert_allclose(np.asarray(mcand["w"]),
                           Wuni @ np.asarray(xa["w"]), rtol=1e-5, atol=1e-6)
print("OK full_psum_masked")

# --- dynamic membership with a TRACED active mask under jit --------------
dcfg = SwarmConfig(n_nodes=4, topology="dynamic", merge="fedavg",
                   lora_only=False)
prop_dyn, _ = make_swarm_sync_step(dcfg, mesh, "node", [1, 3, 3, 3])
active = jnp.asarray([True, True, False, True])
cand2 = jax.jit(lambda p, a: prop_dyn(p, active=a))(params2, active)
l2 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a[2]-b[2]).max()),
                                  cand2, params2))
assert max(l2) == 0.0  # absent node keeps its params
print("OK dynamic_traced")

# --- production mesh guard ----------------------------------------------
from repro.launch.mesh import make_production_mesh
try:
    make_production_mesh()
    raise SystemExit("should have raised")
except RuntimeError as e:
    assert "need" in str(e) and "XLA_FLAGS" in str(e)
print("OK mesh_guard")
"""

@pytest.fixture(scope="module")
def spmd_out():
    return _run(_CHECKS)  # module scope: the subprocess runs once


def test_fedavg_gossip_matches_host_merge(spmd_out):
    assert "OK fedavg" in spmd_out


def test_ring_gossip_matches_mixing_matrix(spmd_out):
    assert "OK ring" in spmd_out


def test_matrix_gossip_dynamic_membership(spmd_out):
    assert "OK matrix_dynamic" in spmd_out


def test_fisher_gossip_matches_host_merge(spmd_out):
    assert "OK fisher" in spmd_out


def test_gradmatch_engine_gossip_matches_host_merge(spmd_out):
    """The engine's gossip backend realizes gradmatch as the weighted-fisher
    psum ratio — must equal the host `gradmatch_merge` closed form."""
    assert "OK gradmatch_gossip" in spmd_out


def test_ring_topo_fisher_ppermute_parity_and_bytes(spmd_out):
    """Ring-native topo-fisher gossip == the host oracle, lowered to two
    ppermutes of the fused (F⊙θ ⊕ F) payload (4·P values) with ZERO
    all_gathers; bf16 wire casting stays within cast tolerance."""
    assert "OK ring_topo_fisher" in spmd_out


def test_topo_fisher_single_gather(spmd_out):
    """The general-rows fallback issues exactly ONE all_gather (the stacked
    (num ⊕ mass) payload) instead of the former two matrix_gossip passes."""
    assert "OK topo_single_gather" in spmd_out


def test_ring_rows_gossip_matches_masked_matrix(spmd_out):
    """ppermute row mixing honours a membership-masked ring matrix."""
    assert "OK ring_rows" in spmd_out


def test_engine_gossip_ring_fisher_uses_ppermute_schedule(spmd_out):
    """The comms cost model routes ring+fisher through ring_topo_ppermute
    end-to-end in the engine's gossip backend."""
    assert "OK engine_ring_schedule" in spmd_out


def test_swarm_spmd_train_and_sync_step(spmd_out):
    """Full SPMD swarm step: vmapped local training + gossip + gated commit."""
    assert "OK swarm_step" in spmd_out


def test_dynamic_membership_traced_active_mask(spmd_out):
    """Gossip propose works under jit with a traced (runtime) active mask."""
    assert "OK dynamic_traced" in spmd_out


def test_full_fedavg_mask_stays_on_psum_schedule(spmd_out):
    """A runtime membership mask must not silently demote full-topology
    fedavg from the psum schedule (2·P·(N−1)/N) to an N·P all_gather: the
    weights are active-masked in-graph and absent nodes keep their params."""
    assert "OK full_psum_masked" in spmd_out


def test_production_mesh_requires_devices(spmd_out):
    assert "OK mesh_guard" in spmd_out
