"""SPMD gossip collectives need >1 device; all checks run in ONE subprocess
with XLA_FLAGS forcing 8 host devices (the main test process keeps seeing 1
CPU device), each printing an `OK <tag>` marker the tests assert on —
amortizing the jax import + mesh setup across the whole module."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH=SRC)
    # append so conftest's compile-time flags survive in the subprocess
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


_CHECKS = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, SwarmConfig, TrainConfig
from repro.core.gossip import (fedavg_gossip, fisher_gossip, matrix_gossip,
                               ring_gossip)
from repro.core.merge_impl import fisher_merge
from repro.core.swarm import gate_decisions, gated_commit
from repro.core.topology import dynamic_matrix, full_matrix, ring_matrix
from repro.launch.train import (make_swarm_train_step, make_swarm_sync_step,
                                init_train_state)
from repro.models import build_model

mesh = jax.make_mesh((4, 2), ("node", "model"), devices=jax.devices())

# --- fedavg gossip == host weighted merge -------------------------------
rng = np.random.default_rng(0)
tree = {"w": jnp.asarray(rng.normal(0, 1, (4, 8, 6)), jnp.float32),
        "skip": None}
w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
out = jax.jit(lambda t: fedavg_gossip(t, w, mesh, "node"))(tree)
want = np.tensordot(np.asarray(w), np.asarray(tree["w"]), axes=(0, 0))
for i in range(4):
    np.testing.assert_allclose(np.asarray(out["w"][i]), want, rtol=1e-5)
assert out["skip"] is None
print("OK fedavg")

# --- ring gossip == ring mixing matrix ----------------------------------
rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(0, 1, (4, 5, 3)), jnp.float32)
out = jax.jit(lambda t: ring_gossip(t, mesh, "node", 0.5))({"x": x})["x"]
want = np.tensordot(ring_matrix(4, 0.5), np.asarray(x), axes=(1, 0))
np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)
print("OK ring")

# --- matrix gossip with dynamic membership ------------------------------
rng = np.random.default_rng(2)
x = jnp.asarray(rng.normal(0, 1, (4, 7)), jnp.float32)
W = dynamic_matrix(full_matrix(4, [1, 3, 3, 3]), [True, True, False, True])
out = jax.jit(lambda t: matrix_gossip(t, W, mesh, "node"))({"x": x})["x"]
np.testing.assert_allclose(np.asarray(out), W @ np.asarray(x),
                           rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(np.asarray(out[2]), np.asarray(x[2]))
print("OK matrix_dynamic")

# --- fisher gossip == host fisher merge ---------------------------------
rng = np.random.default_rng(3)
x = {"w": jnp.asarray(rng.normal(0, 1, (4, 6, 4)), jnp.float32)}
f = {"w": jnp.asarray(np.abs(rng.normal(1, 0.3, (4, 6, 4))), jnp.float32)}
out = jax.jit(lambda t, ff: fisher_gossip(t, ff, mesh, "node"))(x, f)["w"]
np.testing.assert_allclose(np.asarray(out), np.asarray(fisher_merge(x, f)["w"]),
                           rtol=1e-5, atol=1e-6)
print("OK fisher")

# --- gradmatch via the engine gossip backend == host gradmatch merge -----
from repro.core.engine import SwarmEngine
from repro.core.merge_impl import gradmatch_merge
gm_mesh = jax.make_mesh((4,), ("gnode",), devices=jax.devices()[:4])
sizes = [1.0, 3.0, 3.0, 3.0]
gcfg = SwarmConfig(n_nodes=4, topology="full", merge="gradmatch",
                   lora_only=False)
geng = SwarmEngine(gcfg, None, None, data_sizes=sizes, backend="gossip",
                   mesh=gm_mesh, axis="gnode")
cand, _, _ = jax.jit(lambda p, ff: geng.propose(p, fishers=ff))(x, f)
w = jnp.asarray(np.asarray(sizes) / np.sum(sizes), jnp.float32)
np.testing.assert_allclose(np.asarray(cand["w"]),
                           np.asarray(gradmatch_merge(x, f, w)["w"]),
                           rtol=1e-5, atol=1e-6)
print("OK gradmatch_gossip")

# --- full SPMD swarm step: vmapped train + gossip + gated commit --------
cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128)
model = build_model(cfg)
tc = TrainConfig(lr=1e-3, remat=False, warmup_steps=1, max_steps=10)
keys = jax.random.split(jax.random.key(0), 4)
ps, os_ = [], []
for k in keys:
    p, o = init_train_state(model, k)
    ps.append(p); os_.append(o)
stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
params, opts = stack(ps), stack(os_)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 128, (4, 2, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 128, (4, 2, 16)), jnp.int32)}
step = jax.jit(make_swarm_train_step(model, tc))
params2, opts2, metrics = step(params, opts, batch)
assert metrics["loss"].shape == (4,)
assert np.isfinite(np.asarray(metrics["loss"])).all()

scfg = SwarmConfig(n_nodes=4, topology="ring", merge="fedavg",
                   lora_only=False, val_threshold=0.8)
propose, commit = make_swarm_sync_step(scfg, mesh, "node", [1, 3, 3, 3])
cand = jax.jit(propose)(params2)
assert all(jax.tree.leaves(
    jax.tree.map(lambda a, b: a.shape == b.shape, cand, params2)))
merged_metric = jnp.asarray([1.0, 1.0, 0.1, 1.0])
local_metric = jnp.ones(4)
final = jax.jit(commit)(cand, params2, merged_metric, local_metric)
# node 2 rejected -> keeps local
l2 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a[2]-b[2]).max()),
                                  final, params2))
assert max(l2) == 0.0
# node 0 accepted -> took the merge
l0 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a[0]-b[0]).max()),
                                  final, cand))
assert max(l0) == 0.0
print("OK swarm_step")

# --- dynamic membership with a TRACED active mask under jit --------------
dcfg = SwarmConfig(n_nodes=4, topology="dynamic", merge="fedavg",
                   lora_only=False)
prop_dyn, _ = make_swarm_sync_step(dcfg, mesh, "node", [1, 3, 3, 3])
active = jnp.asarray([True, True, False, True])
cand2 = jax.jit(lambda p, a: prop_dyn(p, active=a))(params2, active)
l2 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a[2]-b[2]).max()),
                                  cand2, params2))
assert max(l2) == 0.0  # absent node keeps its params
print("OK dynamic_traced")

# --- production mesh guard ----------------------------------------------
from repro.launch.mesh import make_production_mesh
try:
    make_production_mesh()
    raise SystemExit("should have raised")
except RuntimeError as e:
    assert "need" in str(e) and "XLA_FLAGS" in str(e)
print("OK mesh_guard")
"""

@pytest.fixture(scope="module")
def spmd_out():
    return _run(_CHECKS)  # module scope: the subprocess runs once


def test_fedavg_gossip_matches_host_merge(spmd_out):
    assert "OK fedavg" in spmd_out


def test_ring_gossip_matches_mixing_matrix(spmd_out):
    assert "OK ring" in spmd_out


def test_matrix_gossip_dynamic_membership(spmd_out):
    assert "OK matrix_dynamic" in spmd_out


def test_fisher_gossip_matches_host_merge(spmd_out):
    assert "OK fisher" in spmd_out


def test_gradmatch_engine_gossip_matches_host_merge(spmd_out):
    """The engine's gossip backend realizes gradmatch as the weighted-fisher
    psum ratio — must equal the host `gradmatch_merge` closed form."""
    assert "OK gradmatch_gossip" in spmd_out


def test_swarm_spmd_train_and_sync_step(spmd_out):
    """Full SPMD swarm step: vmapped local training + gossip + gated commit."""
    assert "OK swarm_step" in spmd_out


def test_dynamic_membership_traced_active_mask(spmd_out):
    """Gossip propose works under jit with a traced (runtime) active mask."""
    assert "OK dynamic_traced" in spmd_out


def test_production_mesh_requires_devices(spmd_out):
    assert "OK mesh_guard" in spmd_out
