"""SPMD gossip collectives need >1 device; run each check in a subprocess
with XLA_FLAGS so the main test process keeps seeing 1 CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_fedavg_gossip_matches_host_merge():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.gossip import fedavg_gossip
    mesh = jax.make_mesh((4, 2), ("node", "model"), devices=jax.devices())
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(0, 1, (4, 8, 6)), jnp.float32),
            "skip": None}
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
    out = jax.jit(lambda t: fedavg_gossip(t, w, mesh, "node"))(tree)
    want = np.tensordot(np.asarray(w), np.asarray(tree["w"]), axes=(0, 0))
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out["w"][i]), want, rtol=1e-5)
    assert out["skip"] is None
    print("OK")
    """)


def test_ring_gossip_matches_mixing_matrix():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.gossip import ring_gossip
    from repro.core.topology import ring_matrix
    mesh = jax.make_mesh((4, 2), ("node", "model"), devices=jax.devices())
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (4, 5, 3)), jnp.float32)
    out = jax.jit(lambda t: ring_gossip(t, mesh, "node", 0.5))({"x": x})["x"]
    want = np.tensordot(ring_matrix(4, 0.5), np.asarray(x), axes=(1, 0))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)
    print("OK")
    """)


def test_matrix_gossip_dynamic_membership():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.gossip import matrix_gossip
    from repro.core.topology import dynamic_matrix, full_matrix
    mesh = jax.make_mesh((4, 2), ("node", "model"), devices=jax.devices())
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (4, 7)), jnp.float32)
    W = dynamic_matrix(full_matrix(4, [1, 3, 3, 3]), [True, True, False, True])
    out = jax.jit(lambda t: matrix_gossip(t, W, mesh, "node"))({"x": x})["x"]
    want = W @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)
    # absent node 2 keeps its params exactly
    np.testing.assert_allclose(np.asarray(out[2]), np.asarray(x[2]))
    print("OK")
    """)


def test_swarm_spmd_train_and_sync_step():
    """Full SPMD swarm step: vmapped local training + gossip + gated commit."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig, SwarmConfig, TrainConfig
    from repro.core.swarm import gate_decisions, gated_commit
    from repro.launch.train import (make_swarm_train_step, make_swarm_sync_step,
                                    init_train_state)
    from repro.models import build_model
    mesh = jax.make_mesh((4, 2), ("node", "model"), devices=jax.devices())
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=128)
    model = build_model(cfg)
    tc = TrainConfig(lr=1e-3, remat=False, warmup_steps=1, max_steps=10)
    # stacked per-node state
    keys = jax.random.split(jax.random.key(0), 4)
    ps, os_ = [], []
    for k in keys:
        p, o = init_train_state(model, k)
        ps.append(p); os_.append(o)
    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    params, opts = stack(ps), stack(os_)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (4, 2, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 128, (4, 2, 16)), jnp.int32)}
    step = jax.jit(make_swarm_train_step(model, tc))
    params2, opts2, metrics = step(params, opts, batch)
    assert metrics["loss"].shape == (4,)
    assert np.isfinite(np.asarray(metrics["loss"])).all()

    scfg = SwarmConfig(n_nodes=4, topology="ring", merge="fedavg",
                       lora_only=False, val_threshold=0.8)
    propose, commit = make_swarm_sync_step(scfg, mesh, "node", [1, 3, 3, 3])
    cand = jax.jit(propose)(params2)
    # gossip changed params (nodes differ) but preserved shapes
    assert jax.tree.map(lambda a, b: a.shape == b.shape, cand, params2)
    merged_metric = jnp.asarray([1.0, 1.0, 0.1, 1.0])
    local_metric = jnp.ones(4)
    final = jax.jit(commit)(cand, params2, merged_metric, local_metric)
    # node 2 rejected -> keeps local
    l2 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a[2]-b[2]).max()),
                                      final, params2))
    assert max(l2) == 0.0
    # node 0 accepted -> took the merge
    l0 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a[0]-b[0]).max()),
                                      final, cand))
    assert max(l0) == 0.0
    print("OK")
    """)


def test_fisher_gossip_matches_host_merge():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.gossip import fisher_gossip
    from repro.core.merge_impl import fisher_merge
    mesh = jax.make_mesh((4, 2), ("node", "model"), devices=jax.devices())
    rng = np.random.default_rng(3)
    x = {"w": jnp.asarray(rng.normal(0, 1, (4, 6, 4)), jnp.float32)}
    f = {"w": jnp.asarray(np.abs(rng.normal(1, 0.3, (4, 6, 4))), jnp.float32)}
    out = jax.jit(lambda t, ff: fisher_gossip(t, ff, mesh, "node"))(x, f)["w"]
    want = fisher_merge(x, f)["w"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    print("OK")
    """)


def test_production_mesh_requires_devices():
    _run("""
    from repro.launch.mesh import make_production_mesh
    # only 8 devices in this subprocess: expect the informative failure
    try:
        make_production_mesh()
        raise SystemExit("should have raised")
    except RuntimeError as e:
        assert "need" in str(e) and "XLA_FLAGS" in str(e)
    print("OK")
    """)
