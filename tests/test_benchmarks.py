"""`benchmarks/run.py --smoke` must keep working: every benchmark family has
a seconds-scale entry point, so the harness can't silently rot. One
subprocess runs the whole smoke suite; assertions read its CSV output."""
import json
import os
import subprocess
import sys

import pytest

# the smoke suite spawns the same forced-CPU-mesh subprocesses as the SPMD
# parity tests — shard it into the parallel CI job with them
pytestmark = pytest.mark.spmd

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _row(smoke_out, name):
    for line in smoke_out.splitlines():
        if line.startswith(name + ","):
            return line.split(",", 2)
    raise AssertionError(f"no {name} row")


@pytest.fixture(scope="module")
def smoke_out():
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "run.py"),
         "--smoke"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_smoke_emits_csv_without_errors(smoke_out):
    lines = [l for l in smoke_out.strip().splitlines() if l]
    assert lines[0] == "name,us_per_call,derived"
    assert len(lines) > 8
    assert all(len(l.split(",", 2)) == 3 for l in lines[1:])
    assert "ERROR" not in smoke_out


def test_smoke_covers_weighted_kernel(smoke_out):
    assert "merge_fused_weighted_validated" in smoke_out


def test_smoke_covers_spmd_parity(smoke_out):
    """Gossip-vs-host engine parity numbers (wall time, committed-params
    diff, collective bytes) are part of the benchmark output."""
    assert "spmd_parity_host_round_us" in smoke_out
    assert "spmd_parity_gossip_round_us" in smoke_out
    assert "spmd_parity_collective_bytes_per_sync" in smoke_out
    for line in smoke_out.splitlines():
        if line.startswith("spmd_parity_max_abs_diff"):
            assert float(line.split(",")[2]) < 1e-4
            break
    else:
        raise AssertionError("no parity diff row")


def test_smoke_covers_overlap_round(smoke_out):
    assert "engine_round_serial_us" in smoke_out
    assert "engine_round_overlap_us" in smoke_out
    assert "overlap_vs_serial_ratio" in smoke_out


def test_smoke_covers_swarm_sync_suite(smoke_out):
    """The wire-efficiency suite reports schedule + predicted bytes per
    combo and writes machine-readable BENCH_swarm_sync.json."""
    assert "sched=ring_topo_ppermute" in smoke_out
    assert "sched=gathered_topo_stack" in smoke_out
    path = _row(smoke_out, "swarm_sync_json")[2].strip()
    assert os.path.exists(path)
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == 1
    rows = doc["schedules_smoke"]   # smoke keeps its own section: CI must
    assert len(rows) >= 4           # not clobber the committed full grid
    by_key = {(r["topology"], r["merge"], r["wire_dtype"]): r for r in rows}
    ring_f32 = by_key[("ring", "fisher", "f32")]
    ring_i8 = by_key[("ring", "fisher", "int8")]
    # ring topo-fisher: 4·P values; int8 wire shrinks predicted bytes ~4x
    p = ring_f32["payload_params"]
    assert ring_f32["predicted_bytes_per_sync"] == pytest.approx(16 * p)
    assert ring_i8["predicted_bytes_per_sync"] < ring_f32[
        "predicted_bytes_per_sync"] / 3
    # every row is tagged with its mesh shape and per-link-class bytes;
    # engine-backend sessions simulate a flat 1-D mesh, so everything is
    # intra-class and the split sums back to the total
    for r in rows:
        assert r["mesh_shape"] == [r["n_nodes"]]
        assert r["predicted_cross_bytes"] == 0.0
        assert (r["predicted_intra_bytes"] + r["predicted_cross_bytes"]
                == pytest.approx(r["predicted_bytes_per_sync"]))
    assert doc["ring_parity_smoke"]  # subprocess rows made it into the JSON


def test_smoke_covers_ring_sync_parity(smoke_out):
    """Forced-CPU-mesh ring-ppermute parity: committed params within 1e-5
    of the host oracle, and the collective-bytes estimator confirms the
    ~4·P point-to-point schedule vs the gather's 2·N·P."""
    assert float(_row(smoke_out, "ring_sync_ppermute_max_diff")[2]) < 1e-5
    assert float(_row(smoke_out, "ring_sync_gathered_max_diff")[2]) < 1e-5
    assert float(_row(smoke_out, "ring_sync_ppermute_P_values")[2]) <= 4.5
    assert float(_row(smoke_out, "ring_sync_bytes_ratio")[2]) < 1.0


def test_smoke_covers_hier_sync(smoke_out):
    """The two-level-mesh rows (ISSUE 7): HLO-measured cross-pod bytes of
    the hierarchical int8 fedavg ≤ 0.35× the flat ring q8's, the flat form
    prices 100% cross-pod, and every row carries its mesh shape plus the
    predicted and measured per-link-class byte split."""
    assert float(_row(smoke_out, "hier_sync_cross_bytes_ratio")[2]) <= 0.35
    path = _row(smoke_out, "hier_sync_json")[2].strip()
    with open(path) as f:
        doc = json.load(f)
    rows = doc["hier_sync_smoke"]
    by_sched = {r["schedule"]: r for r in rows}
    assert len(by_sched) == len(rows) == 2
    hier = by_sched["hier_fedavg_ring_q8"]
    flat = by_sched["ring_ppermute"]
    for r in rows:
        assert r["mesh_shape"] == [2, 2]
        assert r["wire_dtype"] == "int8"
    # the flat joint-axis ring has no intra-pod class: every ppermute hop
    # may span pods, so measurement and prediction both price it all-cross
    assert flat["measured_intra_bytes"] == 0
    assert flat["predicted_intra_bytes"] == 0.0
    assert flat["measured_cross_bytes"] == pytest.approx(
        flat["predicted_cross_bytes"])
    # hierarchical: cross is exactly the delegate-chunk wire, intra within
    # a scalar all-reduce of the predicted psum + all_gather payload
    assert hier["measured_cross_bytes"] == pytest.approx(
        hier["predicted_cross_bytes"])
    assert hier["measured_intra_bytes"] == pytest.approx(
        hier["predicted_intra_bytes"], rel=0.01)
    assert (hier["measured_cross_bytes"]
            <= 0.35 * flat["measured_cross_bytes"])


def test_smoke_covers_mesh_wire(smoke_out):
    """The int8 mesh EF wire rows: settled parity ≤ 1e-5 vs the host oracle
    and HLO-measured collective bytes ≤ 0.30× the f32 schedule."""
    assert float(_row(smoke_out, "mesh_wire_q8_settled_max_diff")[2]) < 1e-5
    assert float(_row(smoke_out, "mesh_wire_bytes_ratio")[2]) <= 0.30


def test_smoke_sections_go_to_scratch_not_the_committed_json(smoke_out):
    """Bench artifact hygiene (ROADMAP item): --smoke writes its JSON to the
    gitignored .bench/ scratch path, so tier-1 leaves the committed
    BENCH_swarm_sync.json untouched (CI runs `git diff --exit-code`)."""
    path = _row(smoke_out, "swarm_sync_json")[2].strip()
    assert os.path.basename(os.path.dirname(path)) == ".bench"
    with open(path) as f:
        doc = json.load(f)
    assert "mesh_wire_smoke" in doc


def test_smoke_covers_serve(smoke_out):
    """The serving plane (PR 8): every batching-config × consensus-mode combo
    reports requests/sec + p99 latency with a retrace-free timed region, and
    the rows land in the .bench/ scratch copy of BENCH_serve.json."""
    path = _row(smoke_out, "serve_json")[2].strip()
    assert os.path.basename(os.path.dirname(path)) == ".bench"
    with open(path) as f:
        doc = json.load(f)
    sect = doc["serve_smoke"]
    rows = sect["rows"]
    assert {(r["config"], r["mode"]) for r in rows} == {
        ("naive_b1", "consensus"), ("naive_b1", "average"),
        ("continuous_b8", "consensus"), ("continuous_b8", "average")}
    for r in rows:
        assert r["requests_per_s"] > 0 and r["p99_ms"] > 0
        assert r["retraces_timed"] == 0     # bucket grid fully warmed
    # the continuous-beats-naive ordering is pinned on the committed
    # full-run numbers below; smoke machines only have to report the ratio
    assert set(sect["continuous_over_naive_throughput"]) == {"consensus",
                                                             "average"}


def test_committed_serve_bench_reports_continuous_win():
    """ISSUE 8 acceptance: in the committed full-run BENCH_serve.json,
    continuous batching beats naive one-request-at-a-time dispatch on
    throughput for every consensus mode (deterministic artifact read — no
    machine timing involved)."""
    with open(os.path.join(ROOT, "BENCH_serve.json")) as f:
        doc = json.load(f)
    sect = doc["serve"]
    assert len(sect["rows"]) >= 4
    assert all(r["retraces_timed"] == 0 for r in sect["rows"])
    ratios = sect["continuous_over_naive_throughput"]
    assert set(ratios) == {"consensus", "average"}
    assert all(v > 1.0 for v in ratios.values())


def test_smoke_covers_hetero_swarm(smoke_out):
    """The heterogeneous-swarm grid (ISSUE 10): ≥4 scenario cells land in
    the .bench/ scratch copy of BENCH_hetero.json, each with a wire-bytes
    figure, a full-payload comparison and a zero retrace counter."""
    path = _row(smoke_out, "hetero_swarm_json")[2].strip()
    assert os.path.basename(os.path.dirname(path)) == ".bench"
    with open(path) as f:
        doc = json.load(f)
    rows = doc["hetero_smoke"]["rows"]
    assert len(rows) >= 4
    names = {r["scenario"] for r in rows}
    assert {"label_skew", "label_skew_synth"} <= names
    for r in rows:
        assert r["payload_class"] == "lora"
        assert r["wire_bytes_per_sync"] > 0
        assert r["wire_fraction_of_full"] <= 0.05
        assert r["retraces"] == 0
        assert len(r["per_site"]) == doc["hetero_smoke"]["n_nodes"]
        assert r["site_auc_spread"] >= 0


def test_committed_hetero_bench_reports_wire_shrink_and_fairness():
    """ISSUE 10 acceptance: the committed full-run BENCH_hetero.json carries
    the fairness-gated biased-label scenario with its per-site metric spread,
    and every cell's adapter-only int8 wire is ≤5% of the full-payload f32
    bytes with zero retraces (deterministic artifact read)."""
    with open(os.path.join(ROOT, "BENCH_hetero.json")) as f:
        doc = json.load(f)
    rows = doc["hetero"]["rows"]
    assert len(rows) >= 4
    by_name = {r["scenario"]: r for r in rows}
    assert "label_skew" in by_name
    skew = by_name["label_skew"]
    assert skew["fairness_ok_last"] is True
    assert skew["site_auc_spread"] >= 0
    assert len(skew["per_site"]) == doc["hetero"]["n_nodes"]
    for r in rows:
        assert r["payload_class"] == "lora"
        assert r["wire_fraction_of_full"] <= 0.05
        assert r["retraces"] == 0


def test_smoke_covers_dynamic_membership(smoke_out):
    """The join/leave/rejoin schedule runs and never retraces the compiled
    round: membership is runtime state, not a compile-time constant."""
    assert "dynamic_membership_round_us" in smoke_out
    for line in smoke_out.splitlines():
        if line.startswith("dynamic_membership_retraces"):
            assert int(line.split(",")[2]) == 0
            break
    else:
        raise AssertionError("no dynamic_membership retrace row")
