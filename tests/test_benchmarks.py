"""`benchmarks/run.py --smoke` must keep working: every benchmark family has
a seconds-scale entry point, so the harness can't silently rot. One
subprocess runs the whole smoke suite; assertions read its CSV output."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def smoke_out():
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "run.py"),
         "--smoke"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_smoke_emits_csv_without_errors(smoke_out):
    lines = [l for l in smoke_out.strip().splitlines() if l]
    assert lines[0] == "name,us_per_call,derived"
    assert len(lines) > 8
    assert all(len(l.split(",", 2)) == 3 for l in lines[1:])
    assert "ERROR" not in smoke_out


def test_smoke_covers_weighted_kernel(smoke_out):
    assert "merge_fused_weighted_validated" in smoke_out


def test_smoke_covers_spmd_parity(smoke_out):
    """Gossip-vs-host engine parity numbers (wall time, committed-params
    diff, collective bytes) are part of the benchmark output."""
    assert "spmd_parity_host_round_us" in smoke_out
    assert "spmd_parity_gossip_round_us" in smoke_out
    assert "spmd_parity_collective_bytes_per_sync" in smoke_out
    for line in smoke_out.splitlines():
        if line.startswith("spmd_parity_max_abs_diff"):
            assert float(line.split(",")[2]) < 1e-4
            break
    else:
        raise AssertionError("no parity diff row")


def test_smoke_covers_overlap_round(smoke_out):
    assert "engine_round_serial_us" in smoke_out
    assert "engine_round_overlap_us" in smoke_out
    assert "overlap_vs_serial_ratio" in smoke_out


def test_smoke_covers_dynamic_membership(smoke_out):
    """The join/leave/rejoin schedule runs and never retraces the compiled
    round: membership is runtime state, not a compile-time constant."""
    assert "dynamic_membership_round_us" in smoke_out
    for line in smoke_out.splitlines():
        if line.startswith("dynamic_membership_retraces"):
            assert int(line.split(",")[2]) == 0
            break
    else:
        raise AssertionError("no dynamic_membership retrace row")
