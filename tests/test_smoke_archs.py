"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture gets a REDUCED same-family variant (2 layers,
d_model ≤ 512, ≤ 4 experts) that runs a real forward + train step + decode
step on CPU, asserting output shapes and absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant, TrainConfig
from repro.launch.train import init_train_state, make_train_step
from repro.models import build_model

# (cfg, model, params, opt_state) per arch, shared by the smoke tests below —
# building + initializing every arch once halves this module's compile load
_CACHE = {}


def _built(arch):
    if arch not in _CACHE:
        cfg = smoke_variant(get_config(arch))
        model = build_model(cfg)
        params, opt_state = init_train_state(model, jax.random.key(0))
        _CACHE[arch] = (cfg, model, params, opt_state)
    return _CACHE[arch]


def _batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    out = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.n_patches, cfg.frontend_dim)), jnp.float32)
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.enc_seq_len, cfg.frontend_dim)), jnp.float32)
    return out


# remat is arch-agnostic (a jax.checkpoint wrapper around the same loss);
# exercising it on one dense and one hybrid arch keeps the coverage while
# sparing the (much larger) rematerialized grad compile for the other eight
_REMAT_ARCHS = {"minicpm-2b", "hymba-1.5b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg, model, params, opt_state = _built(arch)
    batch = _batch(cfg)

    tc = TrainConfig(lr=1e-3, remat=arch in _REMAT_ARCHS, warmup_steps=1,
                     max_steps=10)
    step = jax.jit(make_train_step(model, tc))
    new_params, new_opt, m = step(params, opt_state, batch)
    assert jnp.isfinite(m["loss"]), f"{arch}: train-step loss {m['loss']}"
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0].astype(jnp.float32)
                                               - x[1].astype(jnp.float32)))),
        jax.tree.map(lambda a, b: (a, b), new_params, params), 0.0)
    assert delta > 0, f"{arch}: train step did not update params"
    # loss decreases over a few steps on a repeated batch
    p, o = params, opt_state
    first = float(m["loss"])
    for _ in range(3):
        p, o, m = step(p, o, batch)
    assert float(m["loss"]) < first, f"{arch}: loss not decreasing"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg, model, params, _ = _built(arch)
    b, max_len = 2, 64
    caches = model.init_cache(b, max_len)
    if cfg.is_encdec:
        from repro.models.encdec import encode
        frames = jnp.ones((b, cfg.enc_seq_len, cfg.frontend_dim))
        caches = dict(caches, enc_out=encode(params, cfg, frames))
    tok = jnp.ones((b, 1), jnp.int32)
    for pos in range(3):
        logits, caches = model.decode(params, tok, caches, jnp.int32(pos))
        assert logits.shape == (b, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN logits at {pos}"
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["mamba2-370m", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode == teacher-forced forward (recurrent families)."""
    cfg = smoke_variant(get_config(arch)).replace(ssm_chunk=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    s = 16
    toks = jax.random.randint(jax.random.key(3), (1, s), 0, cfg.vocab_size)
    from repro.models.transformer import forward_lm
    full, _, _ = forward_lm(params, cfg, toks)
    caches = model.init_cache(1, s)
    outs = []
    for i in range(s):
        lg, caches = model.decode(params, toks[:, i:i + 1], caches, jnp.int32(i))
        outs.append(lg[:, 0])
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-2, atol=2e-3)
