# swarmlint: treat-as=src/repro/fixture_swl002.py
"""SWL002 fixture: host syncs reachable from jit/shard_map entry points.

The treat-as directive makes this file count as library code under
src/repro/ so the callgraph-scoped rule applies. Marked lines are the
expected findings; everything else (shape math, never-traced host helpers)
must stay clean.
"""
import jax
import numpy as np
from jax.experimental.shard_map import shard_map


def _helper(x):
    return float(x.mean())  # LINT-EXPECT: SWL002


def _static_math(x):
    # shape arithmetic is trace-static: float() here is fine
    return float(x.shape[0] * x.shape[1])


@jax.jit
def entry(x):
    y = _helper(x)
    z = _static_math(x)
    host = np.tanh(3.0)  # LINT-EXPECT: SWL002
    s = x.sum().item()  # LINT-EXPECT: SWL002
    return x * y + z + host + s


def _shard_body(x):
    return jax.device_get(x)  # LINT-EXPECT: SWL002


def launch(x, mesh):
    # call-site wrapping also creates an entry point
    f = shard_map(_shard_body, mesh=mesh, in_specs=None, out_specs=None)
    return f(x)


def never_traced(x):
    # unreachable from any entry: host-side analysis code may sync freely
    return float(np.asarray(x).mean())
