# swarmlint: treat-as=src/repro/fixture_swl004.py
"""SWL004 fixture: a rogue second implementation of the q8 quant core.

The sole_impl registry declares that the int8 block-quantization core
(127.0 scale constant + round()) lives only in core/comms.py; any other
scope containing the full signature is a finding. Partial matches (round
without the scale, the scale without round) must stay clean.
"""
import jax.numpy as jnp


def rogue_quant(v):  # LINT-EXPECT: SWL004
    scale = jnp.max(jnp.abs(v)) / 127.0
    q = jnp.round(v / scale).astype(jnp.int8)
    return q, scale


def unrelated_round(v):
    # rounding without the 127 scale constant is not the quant core
    return jnp.round(v)


def unrelated_scale(v):
    # the scale constant without round() is not the quant core either
    return v / 127.0
