# swarmlint: treat-as=src/repro/fixture_swl004_adapter.py
"""SWL004 fixture: a rogue second implementation of the adapter flatten core.

The sole_impl registry declares that the adapter payload flatten/unflatten
core (``tree_flatten_with_path`` + the ``"lora_"`` adapter-path marker) lives
only in core/lora.py — engine, gossip, and kernel paths must delegate to
``lora.flatten_payload`` / ``lora.unflatten_payload`` rather than growing
their own path-keyed dict builders. Partial matches (the tree walk without
the marker, the marker without the tree walk) must stay clean.
"""
import jax


def rogue_flatten(params):  # LINT-EXPECT: SWL004
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    return {"/".join(str(k) for k in p): v for p, v in leaves
            if "lora_" in "/".join(str(k) for k in p)}


def unrelated_tree_walk(params):
    # walking the tree with paths is not the adapter core by itself
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    return len(leaves)


def unrelated_marker(path):
    # the adapter-path marker alone is not the core either
    return "lora_" in path
