# swarmlint: treat-as=src/repro/core/engine.py
"""SWL003 fixture: round-class jit entry points missing buffer donation.

Masquerades as core/engine.py (the rule is scoped to the two engine files).
Round-class names (round/rounds/local) jitted without donate_argnums copy
params/opt-state every round; marked lines are the expected findings.
"""
import functools

import jax


class FixtureEngine:
    def _round(self, params, opt_state, batch):
        return params, opt_state

    def _gate(self, x):
        return x

    def __init__(self):
        self.round = jax.jit(self._round)  # LINT-EXPECT: SWL003
        self.round_ok = jax.jit(self._round, donate_argnums=(0, 1))
        self.gate = jax.jit(self._gate)  # not round-class: allowed


@functools.partial(jax.jit, static_argnames=("n",))
def run_rounds(params, n):  # LINT-EXPECT: SWL003
    return params


@functools.partial(jax.jit, donate_argnums=(0,))
def run_local(params):
    return params


@jax.jit
def round_step(params):  # LINT-EXPECT: SWL003
    return params
