# swarmlint: treat-as=src/repro/kernels/fixture_swl006.py
"""SWL006 fixture: bare-literal Pallas block shapes / unchecked tile params.

Masquerades as a kernels/ module. A bare int literal in a BlockSpec/VMEM
shape is the N=64 VMEM-overflow class of bug; a tile-size parameter that
reaches pallas_call without going through auto_block/min or a divisibility
check is the same hazard one call earlier.
"""
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_literal_blocks(x):
    n = x.shape[0]
    return pl.pallas_call(
        _copy_kernel,
        out_shape=x,
        in_specs=[pl.BlockSpec((n, 16384), lambda i: (0, i))],  # LINT-EXPECT: SWL006
        out_specs=pl.BlockSpec((n, 8192), lambda i: (0, i)),  # LINT-EXPECT: SWL006
    )(x)


def bad_unchecked_tile(x, block=4096):  # LINT-EXPECT: SWL006
    return pl.pallas_call(
        _copy_kernel,
        out_shape=x,
        in_specs=[pl.BlockSpec((x.shape[0], block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((x.shape[0], block), lambda i: (0, i)),
    )(x)


def good_bounded_tile(x, block=4096):
    block = min(block, x.shape[1])
    return pl.pallas_call(
        _copy_kernel,
        out_shape=x,
        in_specs=[pl.BlockSpec((x.shape[0], block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((x.shape[0], block), lambda i: (0, i)),
    )(x)


def good_divisibility_checked(x, chunk=512):
    assert x.shape[1] % chunk == 0
    return pl.pallas_call(
        _copy_kernel,
        out_shape=x,
        in_specs=[pl.BlockSpec((x.shape[0], chunk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((x.shape[0], chunk), lambda i: (0, i)),
    )(x)
