"""SWL000 fixture: noqa suppression hygiene.

A justified ``noqa: SWLxxx — why`` comment silences its finding; a
suppression without a justification, or a blanket ``noqa`` naming no code,
is itself an (unsuppressible) SWL000 finding. With respect_noqa=False both psum lines
report their raw SWL001 findings and no SWL000 is emitted.
"""
import jax


def justified_suppression(x):
    return jax.lax.psum(x, "offgrid")  # noqa: SWL001 — fixture: a justified suppression is honored


def unjustified_suppression(x):
    return jax.lax.psum(x, "offgrid")  # noqa: SWL001  # LINT-EXPECT: SWL000


def blanket_noqa(x):
    return x  # noqa  # LINT-EXPECT: SWL000
