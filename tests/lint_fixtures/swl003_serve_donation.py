# swarmlint: treat-as=src/repro/serve/engine.py
"""SWL003 fixture (serve scope): decode/prefill/commit/swap-class jit entry
points in ``src/repro/serve/`` must donate their buffers.

Masquerades as serve/engine.py. An undonated decode/commit entry copies the
whole ensemble slot-cache table on every tick; marked lines are the expected
findings, the ``_ok`` / non-hot forms prove the negatives.
"""
import functools

import jax


class FixtureServe:
    def _decode_commit_impl(self, params, caches, tokens):
        return tokens, caches

    def _prefill_commit_impl(self, params, caches, prompt):
        return prompt, caches

    def _score(self, x):
        return x

    def __init__(self):
        self.decode = jax.jit(self._decode_commit_impl)  # LINT-EXPECT: SWL003
        self.decode_ok = jax.jit(self._decode_commit_impl,
                                 donate_argnums=(1,))
        self.prefill_ok = jax.jit(self._prefill_commit_impl,
                                  donate_argnames=("caches",))
        self.score = jax.jit(self._score)  # not decode/commit-class: allowed


@jax.jit
def swap_params(old, new):  # LINT-EXPECT: SWL003
    return new


@functools.partial(jax.jit, static_argnames=("mode",))
def decode_tick(params, caches, mode):  # LINT-EXPECT: SWL003
    return caches


@functools.partial(jax.jit, donate_argnums=(1,))
def commit_caches(params, caches):
    return caches


# round-class names are NOT hot in the serve scope (the serve regex replaces
# the engine/session one rather than extending it)
@jax.jit
def run_rounds(params):
    return params
