"""SWL001 fixture: literal axis names that drift off the MESH_AXES registry.

Intentionally violating — tests/test_lint.py asserts the exact finding set
declared by the `LINT-EXPECT` markers, so marked lines prove true positives
and every unmarked line proves a true negative. The lint_fixtures/ directory
is excluded from normal directory walks; fixtures are linted only when
passed as explicit paths.
"""
import jax


def bad_psum(x):
    return jax.lax.psum(x, "nodes")  # LINT-EXPECT: SWL001


def good_psum(x):
    return jax.lax.psum(x, "node")


def bad_ppermute(x, perm):
    return jax.lax.ppermute(x, "swarm", perm)  # LINT-EXPECT: SWL001


def bad_mesh():
    return jax.make_mesh((4,), ("hospitals",))  # LINT-EXPECT: SWL001


def good_mesh_kwarg():
    return jax.make_mesh((2, 2), axis_names=("data", "model"))


def bad_axis_index():
    return jax.lax.axis_index("replica")  # LINT-EXPECT: SWL001


def dynamic_axis_ok(x, axis):
    # a runtime axis variable is not a literal — out of scope by design
    return jax.lax.psum(x, axis)


def bad_embedded_subprocess_style():
    # the subprocess-based SPMD tests build their programs as code strings;
    # SWL001 parses those too and maps findings back onto physical lines
    code = """
import jax
mesh = jax.make_mesh((2,), ("clinic",))  # LINT-EXPECT: SWL001
x = jax.lax.psum(1.0, "data")
"""
    return code
