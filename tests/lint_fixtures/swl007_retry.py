# swarmlint: treat-as=src/repro/checkpointing/fixture_swl007.py
"""SWL007 fixture: hand-rolled retry loops in library code.

Masquerades as host-side checkpointing code. A loop that both catches
exceptions and sleeps is re-implementing bounded retry/backoff ad hoc —
every such loop must delegate to `repro.faults.retry.with_retry`, the one
home for attempt bounds, exponential backoff, and timeout budgets.
A try/except loop that never sleeps (an event pump) and a sleeping loop
that never catches (a pacer) are both fine.
"""
import time

from repro.faults.retry import with_retry


def bad_write_retries(write, attempts=3):
    for i in range(attempts):  # LINT-EXPECT: SWL007
        try:
            return write()
        except OSError:
            time.sleep(0.05 * (2 ** i))
    raise RuntimeError("write failed")


def bad_poll_until_ready(probe):
    while True:  # LINT-EXPECT: SWL007
        try:
            return probe()
        except ConnectionError:
            pass
        time.sleep(0.1)


def good_write(write):
    return with_retry(write, attempts=3, retry_on=(OSError,),
                      describe="fixture write")


def good_event_loop(pop):
    while True:
        try:
            event = pop()
        except KeyError:
            return None
        if event is not None:
            return event


def good_paced_loop(tick, n):
    for _ in range(n):
        tick()
        time.sleep(0.01)
