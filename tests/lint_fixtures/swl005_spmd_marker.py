# swarmlint: treat-as=tests/test_swl005_fixture.py
"""SWL005 fixture: mesh-touching tests without the spmd CI-shard marker.

Masquerades as a tests/test_*.py file. CI shards the suite on the spmd
marker; an unmarked mesh-touching test lands in the wrong shard. Direct
mesh use, helper-transitive use, and mesh code inside subprocess strings
must all be caught; docstring prose mentioning ppermute must not.
"""
import jax
import pytest


def _mesh_helper():
    return jax.make_mesh((1,), ("node",))


def test_direct_mesh_unmarked():  # LINT-EXPECT: SWL005
    mesh = jax.make_mesh((1,), ("node",))
    assert mesh is not None


def test_helper_mesh_unmarked():  # LINT-EXPECT: SWL005
    assert _mesh_helper() is not None


@pytest.mark.spmd
def test_mesh_marked():
    assert jax.make_mesh((1,), ("node",)) is not None


def test_subprocess_string_unmarked():  # LINT-EXPECT: SWL005
    code = """
import jax
mesh = jax.make_mesh((2,), ("node",))
"""
    assert "shard" not in code


def test_docstring_mention_is_fine():
    """Prose describing ppermute schedules is not mesh-touching code."""
    assert True
