"""SWL001 fixture (two-level meshes): the registry ``pod``/``node`` axes —
alone, as the joint ``("pod", "node")`` swarm-axis tuple, and inside mesh
construction — are clean; an off-registry ``"dcn"`` axis flags, including
inside embedded subprocess code strings.

Intentionally violating — tests/test_lint.py asserts the exact finding set
declared by the `LINT-EXPECT` markers, so marked lines prove true positives
and every unmarked line proves a true negative.
"""
import jax


def good_two_level_mesh():
    return jax.make_mesh((2, 2), ("pod", "node"))


def good_joint_axis_psum(x):
    # flat gossip schedules run over the joint axis tuple on a 2-D mesh
    return jax.lax.psum(x, ("pod", "node"))


def good_hier_legs(x, perm):
    # the hierarchical pod-delegate schedule's per-leg collectives
    num = jax.lax.psum(x, "node")
    lft = jax.lax.ppermute(num, "pod", perm)
    return jax.lax.all_gather(lft, "node", tiled=True)


def bad_dcn_psum(x):
    return jax.lax.psum(x, "dcn")  # LINT-EXPECT: SWL001


def bad_dcn_in_axis_tuple(x):
    # one off-registry element poisons an otherwise-good tuple
    return jax.lax.psum(x, ("pod", "dcn"))  # LINT-EXPECT: SWL001


def bad_dcn_mesh():
    return jax.make_mesh((2, 2), ("dcn", "node"))  # LINT-EXPECT: SWL001


def embedded_two_level_subprocess():
    # the 2x2 ("pod", "node") SPMD tests build their programs as code
    # strings; SWL001 parses those too — registry axes pass, "dcn" flags
    code = """
import jax
mesh = jax.make_mesh((2, 2), ("pod", "node"))
g = jax.lax.all_gather(1.0, "node", tiled=True)
bad = jax.lax.ppermute(1.0, "dcn", [(0, 1)])  # LINT-EXPECT: SWL001
"""
    return code
