"""swarmlint self-tests: per-rule fixture corpora, suppression behavior, and
the repo-wide clean-run gate.

Each file under tests/lint_fixtures/ is an intentionally-violating snippet;
its ``LINT-EXPECT: SWLxxx`` markers declare the exact expected finding set.
Asserting set equality proves both directions at once: every marked line is
a fixture-proven true positive, and every unmarked line (the good_* /
*_ok variants sitting next to the violations) is a true negative.
"""
import re
from pathlib import Path

import pytest

from repro.analysis.lint import main, run_paths
from repro.analysis.rules import RULES

FIXTURES = Path(__file__).parent / "lint_fixtures"
_EXPECT_RE = re.compile(r"LINT-EXPECT:\s*(SWL\d+)")
_FIXTURE_FILES = sorted(p.name for p in FIXTURES.glob("swl*.py"))


def _expected(path: Path):
    want = set()
    for i, line in enumerate(path.read_text().splitlines(), 1):
        for code in _EXPECT_RE.findall(line):
            want.add((i, code))
    return want


@pytest.mark.parametrize("name", _FIXTURE_FILES)
def test_fixture_findings_match_markers(name):
    path = FIXTURES / name
    want = _expected(path)
    assert want, f"{name} declares no LINT-EXPECT markers"
    findings = run_paths([str(path)])
    got = {(f.line, f.rule) for f in findings}
    assert got == want, (
        f"{name}: expected {sorted(want)}, got:\n"
        + "\n".join(f.render() for f in findings))


def test_every_rule_has_a_fixture_true_positive():
    covered = set()
    for name in _FIXTURE_FILES:
        covered |= {code for _, code in _expected(FIXTURES / name)}
    assert {cls.id for cls in RULES} <= covered, covered
    assert "SWL000" in covered  # the runner's own hygiene rule


def test_trace_hazard_severity_split():
    """Value-forcing conversions are errors; trace-time numpy (legitimate on
    static data, then suppressed with a reason) is a warning."""
    path = FIXTURES / "swl002_trace_hazard.py"
    sev = {f.line: f.severity for f in run_paths([str(path)])}
    lines = path.read_text().splitlines()
    np_line = next(i for i, l in enumerate(lines, 1) if "np.tanh" in l)
    float_line = next(i for i, l in enumerate(lines, 1) if "float(x.mean" in l)
    assert sev[np_line] == "warning"
    assert sev[float_line] == "error"


def test_noqa_raw_mode_reports_suppressed_findings():
    """respect_noqa=False surfaces everything a suppression hides (and emits
    no hygiene findings — there is nothing being suppressed)."""
    path = FIXTURES / "swl000_noqa.py"
    raw = run_paths([str(path)], respect_noqa=False)
    psum_lines = [i for i, l in enumerate(path.read_text().splitlines(), 1)
                  if '"offgrid"' in l]
    assert len(psum_lines) == 2
    assert [f.line for f in raw if f.rule == "SWL001"] == psum_lines
    assert not [f for f in raw if f.rule == "SWL000"]


def test_rule_allowlist_filters():
    path = FIXTURES / "swl001_collective_axis.py"
    assert run_paths([str(path)], rules=["SWL006"]) == []
    only = run_paths([str(path)], rules=["SWL001"])
    assert only and all(f.rule == "SWL001" for f in only)


def test_cli_exit_codes(capsys):
    assert main([str(FIXTURES / "swl001_collective_axis.py")]) == 1
    assert main(["--list-rules"]) == 0
    capsys.readouterr()  # swallow the CLI output


def test_repo_src_and_tests_are_lint_clean():
    """The CI gate: the committed tree carries zero unsuppressed findings."""
    findings = run_paths(["src", "tests"])
    assert findings == [], "\n".join(f.render() for f in findings)
