"""Mesh int8 error-feedback wire (ISSUE 5): the ``*_q8`` gossip schedules.

All checks need >1 device, so they run in ONE subprocess with XLA_FLAGS
forcing 4 host devices (same pattern as test_gossip_spmd), each printing an
``OK <tag>`` marker the tests assert on. Pins the acceptance criteria:

  * every q8 schedule (ring ppermute, gathered, psum reduce-scatter) settles
    to its numpy oracle — committed params ≤ 1e-5 after the EF wire settles,
  * gossip-backend int8 committed params match the engine-backend int8 wire
    in the settled regime,
  * the EF residual telescopes ON THE MESH (geometric contraction),
  * HLO-measured collective bytes of the q8 ring schedule are ≤ 0.30× the
    f32 equivalent at N = 4, and the q8 psum moves int8 (not f32) payloads,
  * lora_only payloads, checkpoint round-trips (bit-identical EF state after
    resume), and bitwise determinism all compose with the mesh wire.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.spmd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH=SRC)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


_CHECKS = """
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import SwarmConfig
from repro.core import comms, gossip
from repro.core.merge_impl import fisher_merge, topo_weighted_merge
from repro.core.session import SwarmSession
from repro.core.topology import build_matrix, dynamic_matrix, full_matrix
from repro.launch import hlo_stats

mesh = jax.make_mesh((4,), ("node",), devices=jax.devices()[:4])
N, D, WB = 4, 640, 128
rng = np.random.default_rng(0)
w0 = jnp.asarray(rng.normal(0, 1, (N, D)), jnp.float32)
fish = {"w": jnp.asarray(np.abs(rng.normal(1, 0.3, (N, D))), jnp.float32)}
x = {"w": w0}

# --- raw q8 schedules settle to their numpy oracles ----------------------
def settle(fn, wire, rounds=6):
    for _ in range(rounds):
        merged, wire = fn(wire)
    return np.asarray(merged["w"]), wire

Wring = build_matrix("ring", N)
Wdyn = dynamic_matrix(full_matrix(N, [1, 3, 3, 3]), [True, True, False, True])
wvec = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
topo_want = np.asarray(topo_weighted_merge(x, fish, Wring)["w"])
cases = [
    ("ring_ppermute", Wring @ np.asarray(w0),
     lambda w: gossip.ring_rows_gossip_q8(x, Wring, w, mesh, "node",
                                          wire_block=WB)),
    ("gathered_rows", Wdyn @ np.asarray(w0),
     lambda w: gossip.matrix_gossip_q8(x, Wdyn, w, mesh, "node",
                                       wire_block=WB)),
    ("fedavg_psum_q8",
     np.tensordot(np.asarray(wvec), np.asarray(w0), axes=(0, 0)),
     lambda w: gossip.fedavg_psum_q8(x, wvec, w, mesh, "node",
                                     wire_block=WB)),
    ("fisher_psum_q8", np.asarray(fisher_merge(x, fish)["w"]),
     lambda w: gossip.fisher_psum_q8(x, fish, w, mesh, "node",
                                     wire_block=WB)),
    ("ring_topo_ppermute", topo_want,
     lambda w: gossip.ring_topo_fisher_gossip_q8(x, fish, Wring, w, mesh,
                                                 "node", wire_block=WB)),
    ("gathered_topo_stack", topo_want,
     lambda w: gossip.topo_fisher_gossip_q8(x, fish, Wring, w, mesh, "node",
                                            wire_block=WB)),
]
for sched, want, fn in cases:
    wire = gossip.init_mesh_wire(sched, x, n_shards=N, wire_block=WB)
    got, _ = settle(jax.jit(fn), wire)
    err = np.abs(got - want).max()
    assert err < 1e-5, (sched, err)
print("OK schedule_parity")

# --- EF residual telescopes on the mesh ----------------------------------
wire = gossip.init_mesh_wire("ring_ppermute", x, n_shards=N, wire_block=WB)
fn = jax.jit(lambda w: gossip.ring_rows_gossip_q8(x, Wring, w, mesh, "node",
                                                  wire_block=WB))
prev = np.inf
for r in range(5):
    _, wire = fn(wire)
    res = float(np.abs(np.asarray(wire["ref"]["w"]) - np.asarray(w0)).max())
    if r >= 1:
        assert res <= prev / 32 + 1e-9, (r, res, prev)
    prev = res
assert prev < 1e-6
# neighbour replicas never diverge from the senders' own references
ref = np.asarray(wire["ref"]["w"])
np.testing.assert_array_equal(np.asarray(wire["left"]["w"]),
                              ref[np.r_[3, 0, 1, 2]])
np.testing.assert_array_equal(np.asarray(wire["right"]["w"]),
                              ref[np.r_[1, 2, 3, 0]])
print("OK telescoping")

# --- engine gossip backend: settled committed params == numpy oracle -----
def id_step(p, o, b, s):
    return p, o, {"loss": 0.0 * jnp.sum(p["w"])}

def eval_fn(p, v):
    return 1.0 - 0.0 * jnp.sum(p["w"])

batches = jnp.zeros((1, N, 1))
val = jnp.zeros((N, 1))

def settled_commit(topo, merge, backend="gossip"):
    # phase 1: gates reject (metric 1.0 < 1.5 * 1.0) so params stay put
    # while the wire settles; phase 2: same state, accepting gates, one
    # committed round — the acceptance-criterion regime
    mk = lambda thr: SwarmConfig(
        n_nodes=N, sync_every=1, topology=topo, merge=merge,
        lora_only=False, val_threshold=thr, wire_dtype="int8", wire_block=WB)
    kw = dict(params={"w": w0.copy()}, stacked=True,
              data_sizes=[1.0] * N)
    if backend == "gossip":
        kw.update(backend="gossip", mesh=mesh, axis="node")
    sa = SwarmSession(mk(1.5), id_step, eval_fn, **kw)
    for _ in range(6):
        out = sa.round(batches, val)
        assert not np.asarray(out["gates"]).any()
    sb = SwarmSession(mk(0.0), id_step, eval_fn, **kw)
    sb.load_state(sa.state)
    out = sb.round(batches, val)
    assert np.asarray(out["gates"]).all()
    return np.asarray(sb.state.params["w"])

zero_mass = jax.tree.map(jnp.zeros_like, x)   # strategy stats: eps floor
oracles = {
    ("full", "fedavg"): build_matrix("full", N) @ np.asarray(w0),
    ("full", "fisher"): np.asarray(fisher_merge(x, zero_mass)["w"]),
    ("ring", "fisher"): np.asarray(
        topo_weighted_merge(x, zero_mass, Wring)["w"]),
    ("dynamic", "fedavg"): build_matrix("dynamic", N) @ np.asarray(w0),
}
for (topo, merge), want in oracles.items():
    got = settled_commit(topo, merge)
    err = np.abs(got - want).max()
    assert err < 1e-5, (topo, merge, err)
print("OK engine_committed_parity")

# --- parity vs the engine-backend int8 wire ------------------------------
g = settled_commit("ring", "fisher", backend="gossip")
e = settled_commit("ring", "fisher", backend="engine")
assert np.abs(g - e).max() < 1e-5, np.abs(g - e).max()
print("OK engine_backend_parity")

# --- bitwise determinism across runs -------------------------------------
def run_rounds_once():
    cfg = SwarmConfig(n_nodes=N, sync_every=1, topology="ring",
                      merge="fisher", lora_only=False, val_threshold=0.0,
                      wire_dtype="int8", wire_block=WB)
    sess = SwarmSession(cfg, id_step, eval_fn, params={"w": w0.copy()},
                        stacked=True, backend="gossip", mesh=mesh,
                        axis="node", data_sizes=[1.0] * N)
    for _ in range(3):
        sess.round(batches, val)
    return (np.asarray(sess.state.params["w"]).copy(),
            np.asarray(sess.state.wire["ref"]["num"]["w"]).copy())

pa, wa = run_rounds_once()
pb, wb = run_rounds_once()
np.testing.assert_array_equal(pa, pb)
np.testing.assert_array_equal(wa, wb)
print("OK determinism")

# --- HLO-measured collective bytes: the 4x shrink ------------------------
wire = gossip.init_mesh_wire("ring_topo_ppermute", x, n_shards=N,
                             wire_block=WB)
q8fn = jax.jit(lambda t, ff, w: gossip.ring_topo_fisher_gossip_q8(
    t, ff, Wring, w, mesh, "node", wire_block=WB))
f32fn = jax.jit(lambda t, ff: gossip.ring_topo_fisher_gossip(
    t, ff, Wring, mesh, "node"))
cq = hlo_stats.collective_bytes(q8fn.lower(x, fish, wire).compile().as_text())
cf = hlo_stats.collective_bytes(f32fn.lower(x, fish).compile().as_text())
ratio = cq["total"] / cf["total"]
assert ratio <= 0.30, (cq, cf)
# int8 payload + f32 scales: 4·P·(1 + 4/WB) bytes, nothing gathered; the
# (num ⊕ mass) streams ride STACKED — 2 payload + 2 scale ppermutes, not 8
assert cq["all-gather"] == 0 and cq["all-to-all"] == 0, cq
assert cq["collective-permute"] == 4 * D * 1 + 4 * (D // WB) * 4, cq
assert cq["count"] == 4, cq
# gathered fisher q8: ONE stacked int8 gather + one scale gather per leaf
gwire = gossip.init_mesh_wire("gathered_topo_stack", x, n_shards=N,
                              wire_block=WB)
gfn = jax.jit(lambda t, ff, w: gossip.topo_fisher_gossip_q8(
    t, ff, Wring, w, mesh, "node", wire_block=WB))
cg = hlo_stats.collective_bytes(
    gfn.lower(x, fish, gwire).compile().as_text())
assert cg["count"] == 2 and cg["collective-permute"] == 0, cg
# the q8 psum reduction moves int8 chunks (all_to_all + all_gather), less
# wire than the f32 psum's allreduce (payload on the N·wire_block chunk
# grid so padding doesn't distort the ratio)
x2 = {"w": jnp.asarray(rng.normal(0, 1, (N, N * WB * 2)), jnp.float32)}
pw = gossip.init_mesh_wire("fedavg_psum_q8", x2, n_shards=N, wire_block=WB)
pq = jax.jit(lambda t, w: gossip.fedavg_psum_q8(t, wvec, w, mesh, "node",
                                                wire_block=WB))
pf = jax.jit(lambda t: gossip.fedavg_gossip(t, wvec, mesh, "node"))
cq2 = hlo_stats.collective_bytes(pq.lower(x2, pw).compile().as_text())
cf2 = hlo_stats.collective_bytes(pf.lower(x2).compile().as_text())
assert cq2["all-reduce"] == 0 and cq2["all-to-all"] > 0, cq2
assert cq2["total"] < 0.6 * cf2["total"], (cq2, cf2)
print(f"OK hlo_bytes ratio={ratio:.3f}")

# --- lora_only payload on the mesh wire ----------------------------------
params = {"attn": {"w": jnp.asarray(rng.normal(0, 1, (8, 6)), jnp.float32),
                   "lora_A": jnp.asarray(rng.normal(0, 0.1, (8, 2)),
                                         jnp.float32),
                   "lora_B": jnp.zeros((2, 6)),
                   "lora_scale": jnp.asarray(2.0)}}

def lora_step(p, o, b, s):
    return jax.tree.map(lambda t: t + 0.01, p), o, {"loss": jnp.sum(b)}

def lora_eval(p, v):
    return 1.0 - 0.0 * jnp.sum(p["attn"]["w"])

lcfg = SwarmConfig(n_nodes=N, sync_every=1, topology="full", merge="fedavg",
                   lora_only=True, val_threshold=0.0, wire_dtype="int8",
                   wire_block=WB)
ls = SwarmSession(lcfg, lora_step, lora_eval, params=params,
                  backend="gossip", mesh=mesh, axis="node",
                  data_sizes=[1.0] * N)
assert ls.state.wire["ref"]["attn"]["w"] is None      # base: no wire state
assert ls.state.wire["ref"]["attn"]["lora_A"] is not None
ls.round(jnp.zeros((1, N, 4)), val)
got_w = np.asarray(ls.state.params["attn"]["w"])      # base stays local
want_w = np.asarray(params["attn"]["w"]) + 0.01
np.testing.assert_array_equal(got_w, np.broadcast_to(want_w, got_w.shape))
print("OK lora_wire")

# --- checkpoint: save -> restore -> continue == never stopping -----------
def decay_step(p, o, b, s):
    return {"w": p["w"] * 0.999}, o, {"loss": 0.0 * jnp.sum(p["w"])}

ccfg = SwarmConfig(n_nodes=N, sync_every=1, topology="ring", merge="fisher",
                   lora_only=False, val_threshold=0.0, wire_dtype="int8",
                   wire_block=WB)
ckw = dict(stacked=True, backend="gossip", mesh=mesh, axis="node",
           data_sizes=[1.0] * N)
ref_sess = SwarmSession(ccfg, decay_step, eval_fn,
                        params={"w": w0.copy()}, **ckw)
for _ in range(4):
    ref_sess.round(batches, val)
s1 = SwarmSession(ccfg, decay_step, eval_fn, params={"w": w0.copy()}, **ckw)
for _ in range(2):
    s1.round(batches, val)
path = os.path.join(tempfile.mkdtemp(), "mesh_wire.msgpack")
s1.save(path)
s2 = SwarmSession.restore(path, ccfg, decay_step, eval_fn,
                          params={"w": w0.copy()}, **ckw)
for _ in range(2):
    s2.round(batches, val)
np.testing.assert_array_equal(np.asarray(s2.state.params["w"]),
                              np.asarray(ref_sess.state.params["w"]))
for a, b in zip(jax.tree.leaves(s2.state.wire),
                jax.tree.leaves(ref_sess.state.wire)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK checkpoint")

# --- the cost model routes int8 gossip onto the q8 schedules -------------
from repro.core.engine import SwarmEngine
for topo, merge, want in [("full", "fedavg", "fedavg_psum_q8"),
                          ("full", "fisher", "fisher_psum_q8"),
                          ("ring", "fisher", "ring_topo_ppermute"),
                          ("dynamic", "fedavg", "gathered_rows")]:
    cfg = SwarmConfig(n_nodes=N, topology=topo, merge=merge, lora_only=False,
                      wire_dtype="int8", wire_block=WB)
    eng = SwarmEngine(cfg, None, None, data_sizes=[1.0] * N,
                      backend="gossip", mesh=mesh, axis="node")
    assert eng.sync_schedule.name == want, (topo, merge,
                                            eng.sync_schedule.name)
print("OK schedule_picks")

# --- mesh wire composes with the stale-by-one overlap schedule -----------
ocfg = SwarmConfig(n_nodes=N, sync_every=1, topology="ring", merge="fisher",
                   lora_only=False, val_threshold=0.0, overlap_sync=True,
                   wire_dtype="int8", wire_block=WB)
osess = SwarmSession(ocfg, id_step, eval_fn, params={"w": w0.copy()},
                     stacked=True, backend="gossip", mesh=mesh, axis="node",
                     data_sizes=[1.0] * N)
ologs = osess.run_rounds(jnp.zeros((4, 1, N, 1)), val)
assert np.asarray(ologs["gates"]).all()
assert np.isfinite(np.asarray(osess.state.params["w"])).all()
assert osess.state.wire is not None
print("OK overlap")
"""


@pytest.fixture(scope="module")
def spmd_out():
    return _run(_CHECKS)  # module scope: the subprocess runs once


def test_q8_schedules_match_numpy_oracles(spmd_out):
    """Every q8 schedule (ring ppermute, gathered, psum reduce-scatter)
    settles to its uncompressed numpy oracle ≤ 1e-5."""
    assert "OK schedule_parity" in spmd_out


def test_mesh_ef_residual_telescopes(spmd_out):
    """The sharded EF reference contracts geometrically toward the payload
    on constant inputs, and neighbour replicas stay bit-identical to the
    senders' own references."""
    assert "OK telescoping" in spmd_out


def test_gossip_int8_committed_params_match_oracle(spmd_out):
    """wire_dtype="int8" on backend="gossip": committed params ≤ 1e-5 of
    the numpy oracle after EF settling — the headline acceptance check."""
    assert "OK engine_committed_parity" in spmd_out


def test_gossip_int8_matches_engine_backend_wire(spmd_out):
    """The mesh EF wire and the engine-backend EF wire agree in the settled
    regime."""
    assert "OK engine_backend_parity" in spmd_out


def test_mesh_wire_bitwise_deterministic(spmd_out):
    assert "OK determinism" in spmd_out


def test_q8_collective_bytes_shrink_4x(spmd_out):
    """HLO-measured collective bytes of the q8 ring schedule ≤ 0.30× the
    f32 equivalent at N=4; the q8 psum moves int8 chunks, no f32 allreduce."""
    assert "OK hlo_bytes" in spmd_out


def test_mesh_wire_lora_only_payload(spmd_out):
    """Only adapters get mesh wire state; base params stay bit-exact."""
    assert "OK lora_wire" in spmd_out


def test_mesh_wire_checkpoint_round_trip(spmd_out):
    """session.save/restore with a gossip-backend int8 wire: bit-identical
    params AND EF residuals after resume (ISSUE 5 satellite)."""
    assert "OK checkpoint" in spmd_out


def test_engine_routes_int8_to_q8_schedules(spmd_out):
    """pick_schedule routes every int8 gossip config onto a q8-capable
    schedule end-to-end in the engine."""
    assert "OK schedule_picks" in spmd_out


def test_mesh_wire_composes_with_overlap_sync(spmd_out):
    """The sharded EF state rides the double-buffered stale-by-one round
    scan (overlap_sync) without retraces or structure churn."""
    assert "OK overlap" in spmd_out
