"""Wire-efficient sync layer (`core.comms`): cost model, schedule picker,
and the quantized error-feedback wire.

Pins the ISSUE 4 acceptance criteria:
  * the analytic bytes/sync model matches the schedule table (topology ×
    merge × wire dtype × N), and the picker selects the cheapest CORRECT
    schedule — including the int8-flips-the-argmin case,
  * quantized EF sync is bitwise deterministic, drifts from the f32 oracle
    by no more than the per-block quantization bound per round, and its
    residual telescopes to zero on constant inputs,
  * wire compression composes with lora_only payloads, checkpoints (the
    wire reference rides SwarmState), and the histo smoke loop (convergence
    non-regression),
  * invalid combinations fail loudly (host backend, mesh int8, bad dtypes).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.core import comms
from repro.core.session import SwarmSession

N = 4


def _cfg(**kw):
    kw.setdefault("n_nodes", N)
    kw.setdefault("sync_every", 2)
    kw.setdefault("merge", "fedavg")
    kw.setdefault("topology", "full")
    kw.setdefault("lora_only", False)
    kw.setdefault("val_threshold", 0.0)
    return SwarmConfig(**kw)


def _toy_fns():
    def train_step(params, opt_state, batch, step):
        g = params["x"] - batch
        return {"x": params["x"] - 0.1 * g}, opt_state, {"loss": jnp.sum(g * g)}

    def eval_fn(params, val):
        return 1.0 - 0.0 * jnp.sum(params["x"])

    return train_step, eval_fn


def _targets(d=4):
    return jnp.asarray([np.full((d,), t, np.float32) for t in range(N)])


def _session(cfg, d=4, **kw):
    kw.setdefault("params", {"x": jnp.zeros((d,))})
    kw.setdefault("data_sizes", [100 * (i + 1) for i in range(N)])
    return SwarmSession(cfg, *_toy_fns(), **kw)


# ---------------------------------------------------------------------------
# cost model + picker
# ---------------------------------------------------------------------------

def test_cost_model_matches_schedule_table():
    """bytes/sync formulas: the docstring table, at P=1 payload value."""
    p = 1 << 16
    rows = {
        ("full", "fedavg"): ("fedavg_psum", 2.0 * (N - 1) / N * 4),
        ("ring", "fedavg"): ("ring_ppermute", 2.0 * 4),
        ("dynamic", "fedavg"): ("gathered_rows", N * 4.0),
        ("full", "fisher"): ("fisher_psum", 4.0 * (N - 1) / N * 4),
        ("ring", "fisher"): ("ring_topo_ppermute", 4.0 * 4),
        ("dynamic", "gradmatch"): ("gathered_topo_stack", 2.0 * N * 4),
    }
    for (topo, merge), (name, bytes_per_p) in rows.items():
        s = comms.pick_schedule(_cfg(topology=topo, merge=merge))
        assert s.name == name, (topo, merge, s.name)
        assert s.bytes_per_sync(p) == pytest.approx(bytes_per_p * p)


def test_ring_schedules_beat_gather_by_n_over_constant():
    """Ring topo-fisher moves ≤ ~4·P values vs the gather form's 2·N·P —
    the headline acceptance number, straight from the estimator."""
    for n in (3, 4, 16, 64):
        cfg = _cfg(n_nodes=n, topology="ring", merge="fisher")
        ring = comms.pick_schedule(cfg)
        assert ring.name == "ring_topo_ppermute"
        assert ring.payload_factor <= 4.0
        gather = [s for s in comms.candidate_schedules(cfg)
                  if s.name == "gathered_topo_stack"][0]
        assert gather.payload_factor == 2.0 * n
        assert ring.bytes_per_sync(1 << 20) < gather.bytes_per_sync(1 << 20)


def test_int8_wire_flips_full_fisher_off_the_f32_psum():
    """Cost-model-driven choice, not a hardcoded table: the plain psum must
    reduce in f32, so an int8 wire flips full-topology fisher off it — onto
    the compression-aware ``fisher_psum_q8`` reduction (4·P int8 values),
    which also undercuts the gathered stack (2·N·P int8). The picker follows
    the bytes."""
    f32 = comms.pick_schedule(_cfg(topology="full", merge="fisher"))
    assert f32.name == "fisher_psum"
    i8 = comms.pick_schedule(
        _cfg(topology="full", merge="fisher", wire_dtype="int8"))
    assert i8.name == "fisher_psum_q8"
    p = 1 << 20
    assert i8.bytes_per_sync(p) < f32.bytes_per_sync(p)
    gathered = [s for s in comms.candidate_schedules(
        _cfg(topology="full", merge="fisher", wire_dtype="int8"))
        if s.name == "gathered_topo_stack"][0]
    assert i8.bytes_per_sync(p) < gathered.bytes_per_sync(p)


def test_int8_bytes_include_per_block_scale_overhead():
    s = comms.SyncSchedule("gathered_rows", "all_gather", float(N),
                           wire_dtype="int8", wire_block=512)
    p = 1 << 20
    vals = N * p
    assert s.bytes_per_sync(p) == pytest.approx(vals + vals / 512 * 4)


def test_model_sharded_payloads_skip_the_q8_psums():
    """The q8 psum reductions chunk the globally-flattened payload, which a
    model axis would scramble — a model-sharded layout must fall back to a
    q8 schedule that supports inner specs instead of picking one that
    raises at trace time."""
    from jax.sharding import PartitionSpec as P
    cfg = _cfg(topology="full", merge="fisher", wire_dtype="int8")
    assert comms.pick_schedule(cfg).name == "fisher_psum_q8"
    sharded = comms.pick_schedule(cfg, model_sharded=True)
    assert sharded.name == "gathered_topo_stack"
    assert comms.has_inner_sharding({"w": P("model"), "b": P()})
    assert not comms.has_inner_sharding({"w": P(None), "b": P()})
    assert not comms.has_inner_sharding(None)


def test_ring_schedule_needs_one_node_per_shard_and_n3():
    """per>1 or N<3 invalidates the ppermute schedules (gathered fallback)."""
    cfg = _cfg(topology="ring", merge="fisher")
    assert comms.pick_schedule(cfg, per=2).name == "gathered_topo_stack"
    cfg2 = _cfg(n_nodes=2, topology="ring", merge="fedavg")
    assert comms.pick_schedule(cfg2).name == "gathered_rows"


def test_ring_masking_preserves_ring_structure():
    """The ring-ppermute schedules assume membership masking never creates
    non-neighbour coupling — `topology.ring_structured` pins that."""
    from repro.core import topology as topo
    for n in (3, 4, 7):
        base = topo.ring_matrix(n)
        assert topo.ring_structured(base)
        masked = topo.dynamic_matrix(base, [i != 1 for i in range(n)])
        assert topo.ring_structured(masked)
    assert not topo.ring_structured(topo.full_matrix(4))


def test_validation_errors():
    with pytest.raises(ValueError, match="unknown wire_dtype"):
        comms.validate_wire_dtype("fp4")
    with pytest.raises(ValueError, match="multiple of 128"):
        comms.validate_wire_block(100)
    with pytest.raises(ValueError, match="host loop is uncompressed"):
        _session(_cfg(wire_dtype="int8"), backend="host")


# ---------------------------------------------------------------------------
# cost-model drift gate: CHANGES.md table == pick_schedule, row for row
# ---------------------------------------------------------------------------

_CHANGES_MD = os.path.join(os.path.dirname(__file__), "..", "CHANGES.md")


def _parse_schedule_table():
    """Rows of the CHANGES.md comms schedule table:
    (topology, merges, wires, schedule, values-expr, collective)."""
    lines = open(_CHANGES_MD).read().splitlines()
    start = next(i for i, l in enumerate(lines)
                 if l.startswith("## Comms schedule table"))
    rows = []
    for line in lines[start:]:
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if len(cells) != 6 or cells[0] in ("topology", ""):
            continue
        if set(cells[0]) <= {"-"}:
            continue
        topo, merges, wires, sched, vals, coll = cells
        rows.append((topo, merges.split("/"),
                     ["f32", "bf16", "int8"] if wires == "any"
                     else wires.split("/"), sched, vals, coll))
    assert rows, "no schedule table found in CHANGES.md"
    return rows


def _values_per_sync(expr: str, n: int) -> float:
    """Evaluate a table values/sync expression ('2P·(N−1)/N', '2N·P', …)."""
    import re
    s = expr.replace("·", "*").replace("−", "-")
    s = re.sub(r"(?<=[0-9NP\)])(?=[NP\(])", "*", s)
    return float(eval(s, {"__builtins__": {}}, {"N": n, "P": 1.0}))


def _parse_hier_schedule_table():
    """Rows of the CHANGES.md hierarchical (two-level mesh) schedule table:
    (topology, merges, wires, schedule, intra-expr, cross-expr, collective).
    The 7-cell format is deliberately invisible to the flat-table parser."""
    lines = open(_CHANGES_MD).read().splitlines()
    start = next(i for i, l in enumerate(lines)
                 if l.startswith("## Hierarchical schedule table"))
    rows = []
    for line in lines[start:]:
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if len(cells) != 7 or cells[0] in ("topology", ""):
            continue
        if set(cells[0]) <= {"-"}:
            continue
        topo, merges, wires, sched, intra, cross, coll = cells
        rows.append((topo, merges.split("/"), wires.split("/"), sched,
                     intra, cross, coll))
    assert rows, "no hierarchical schedule table found in CHANGES.md"
    return rows


def _parse_lora_schedule_table():
    """Rows of the CHANGES.md adapter-only payload schedule table:
    (topology, merges, wire, schedule, values-expr). The 5-cell format is
    deliberately invisible to the flat (6-cell) and hier (7-cell) parsers."""
    lines = open(_CHANGES_MD).read().splitlines()
    start = next(i for i, l in enumerate(lines)
                 if l.startswith("## Adapter-only payload schedule table"))
    rows = []
    for line in lines[start:]:
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if len(cells) != 5 or cells[0] in ("topology", ""):
            continue
        if set(cells[0]) <= {"-"}:
            continue
        topo, merges, wire, sched, vals = cells
        rows.append((topo, merges.split("/"), wire, sched, vals))
    assert rows, "no adapter-only payload schedule table found in CHANGES.md"
    return rows


def _hier_values_per_sync(expr: str, k: int, m: int) -> float:
    """Evaluate a hierarchical-table expression ('(2(M−1)/M + 1)·P',
    'h·P/M', …): M = nodes/pod, h = cross-pod hops (1 at K=2, else 2)."""
    import re
    s = expr.replace("·", "*").replace("−", "-")
    s = re.sub(r"(?<=[0-9MPh\)])(?=[MPh\(])", "*", s)
    return float(eval(s, {"__builtins__": {}},
                      {"M": m, "P": 1.0, "h": 1.0 if k == 2 else 2.0}))


def test_cost_model_drift_gate():
    """The documented schedule table IS the cost model: re-derive every row
    (topology × merge × wire, at several N) from `comms.pick_schedule` and
    fail when code and table diverge — in either direction (a schedule the
    picker chooses that the table doesn't name also fails)."""
    rows = _parse_schedule_table()
    table = {}
    for topo, merges, wires, sched, vals, coll in rows:
        for m in merges:
            for wd in wires:
                assert (topo, m, wd) not in table, ("duplicate table row",
                                                    topo, m, wd)
                table[(topo, m, wd)] = (sched, vals, coll)
    for n in (3, 4, 16):
        for topo in ("full", "ring", "dynamic"):
            for m in ("mean", "fedavg", "fisher", "gradmatch"):
                for wd in ("f32", "bf16", "int8"):
                    got = comms.pick_schedule(
                        _cfg(n_nodes=n, topology=topo, merge=m, wire_dtype=wd))
                    key = (topo, m, wd)
                    assert key in table, f"picker chose {got.name} for " \
                        f"{key} but the CHANGES.md table has no such row"
                    sched, vals, coll = table[key]
                    assert got.name == sched, (key, n, got.name, sched)
                    assert got.collective == coll, (key, n, got.collective)
                    assert got.payload_factor == pytest.approx(
                        _values_per_sync(vals, n)), (key, n, vals)
                    # the documented scale-overhead formula: int8 moves one
                    # byte per value plus 4/wire_block bytes of f32 scales
                    p = 1 << 18
                    v = got.payload_factor * p
                    want = v * comms.WIRE_BYTES[got.wire_dtype]
                    if got.wire_dtype == "int8":
                        want += v / got.wire_block * 4.0
                    assert got.bytes_per_sync(p) == pytest.approx(want)

    # -- adapter-only payload class: the lora table ---------------------------
    # both routes into the class must produce identical tagged schedules:
    # lora_only (carve the adapters out of a full state at sync) and
    # payload="lora" (the state IS the flat adapter payload, PR 10)
    ltable = {}
    for topo, merges, wire, sched, vals in _parse_lora_schedule_table():
        for m in merges:
            assert (topo, m, wire) not in ltable, ("duplicate lora row",
                                                   topo, m, wire)
            ltable[(topo, m, wire)] = (sched, vals)
    for n in (3, 4, 16):
        for topo in ("full", "ring", "dynamic"):
            for m in ("mean", "fedavg", "fisher", "gradmatch"):
                for wd in ("f32", "int8"):
                    key = (topo, m, wd)
                    picks = [
                        comms.pick_schedule(_cfg(
                            n_nodes=n, topology=topo, merge=m, wire_dtype=wd,
                            lora_only=True)),
                        comms.pick_schedule(_cfg(
                            n_nodes=n, topology=topo, merge=m, wire_dtype=wd,
                            payload="lora")),
                    ]
                    for got in picks:
                        assert key in ltable, f"picker chose {got.name} " \
                            f"for lora {key} but the table has no such row"
                        sched, vals = ltable[key]
                        assert got.payload == "lora", (key, got.name)
                        assert "/lora" in got.describe(), got.describe()
                        assert got.name == sched, (key, n, got.name, sched)
                        assert got.payload_factor == pytest.approx(
                            _values_per_sync(vals, n)), (key, n, vals)
                    # untagged twin: same schedule/bytes, full payload class
                    plain = comms.pick_schedule(
                        _cfg(n_nodes=n, topology=topo, merge=m, wire_dtype=wd))
                    assert plain.payload == "full"
                    assert "/lora" not in plain.describe()

    # -- two-level (pod, node) meshes: the hierarchical table -----------------
    htable = {}
    for topo, merges, wires, sched, intra, cross, coll in \
            _parse_hier_schedule_table():
        for m in merges:
            for wd in wires:
                assert (topo, m, wd) not in htable, ("duplicate hier row",
                                                     topo, m, wd)
                htable[(topo, m, wd)] = (sched, intra, cross, coll)
    p = 1 << 18
    for k, mm in ((2, 2), (2, 4), (4, 4)):
        n = k * mm
        for topo in ("full", "ring", "dynamic"):
            for m in ("mean", "fedavg", "fisher", "gradmatch"):
                for wd in ("f32", "bf16", "int8"):
                    key = (topo, m, wd)
                    # dominant cross-pod cost: the hier row must win exactly
                    # where the table has one
                    got = comms.pick_schedule(
                        _cfg(n_nodes=n, topology=topo, merge=m, wire_dtype=wd,
                             cross_pod_cost=50.0), mesh_shape=(k, mm))
                    if key in htable:
                        sched, intra, cross, coll = htable[key]
                        assert got.name == sched, (key, k, mm, got.name)
                        assert got.collective == coll, (key, got.collective)
                        assert got.intra_factor == pytest.approx(
                            _hier_values_per_sync(intra, k, mm)), (key, k, mm)
                        assert got.cross_factor == pytest.approx(
                            _hier_values_per_sync(cross, k, mm)), (key, k, mm)
                        # intra legs move f32; the cross leg is the int8 EF
                        # wire with its documented per-block scale overhead
                        b = got.bytes_by_link_class(p)
                        assert b["intra"] == pytest.approx(
                            got.intra_factor * p * 4.0)
                        assert b["cross"] == pytest.approx(
                            got.cross_factor * p * (1 + 4.0 / got.wire_block))
                    else:
                        # no hierarchical form exists for this key: however
                        # costly the DCN hop, the picker stays on the flat
                        # table row — priced 100% cross-pod on the 2-D mesh
                        assert got.name == table[key][0], (key, k, mm,
                                                           got.name)
                        assert got.cross_factor == got.payload_factor
        # neutral link costs: flat wins even where a hier row is offered
        # (it moves fewer total bytes) — the other pick direction
        for m, wd in (("fedavg", "int8"), ("fisher", "int8")):
            got = comms.pick_schedule(
                _cfg(n_nodes=n, topology="ring", merge=m, wire_dtype=wd),
                mesh_shape=(k, mm))
            assert got.name == table[("ring", m, wd)][0], (k, mm, got.name)


# ---------------------------------------------------------------------------
# shared quantization core: one implementation, everywhere
# ---------------------------------------------------------------------------

def test_quant_encode_decode_bit_identical_to_round_trip():
    """decode(encode(v)) == quant_dequant_block(v) == the Pallas kernel's
    round-trip, bit for bit — the wire payload and the fused commit can
    never diverge from the EF contract."""
    from repro.kernels.fused_merge import _quant_block
    rng = np.random.default_rng(7)
    v = jnp.asarray(rng.normal(0, 2, (N, 1024)), jnp.float32)
    a = np.asarray(comms.quant_dequant_block(v, "int8", 128))
    q, s = comms.quant_encode(v, 128)
    assert q.dtype == jnp.int8 and s.shape == (N, 8)
    np.testing.assert_array_equal(np.asarray(comms.quant_decode(q, s, 128)),
                                  a)
    np.testing.assert_array_equal(np.asarray(_quant_block(v, "int8", 128)),
                                  a)
    for wd in ("f32", "bf16"):
        np.testing.assert_array_equal(
            np.asarray(comms.quant_dequant_block(v, wd, 128)),
            np.asarray(_quant_block(v, wd, 128)))


def test_quant_core_has_single_implementation():
    """The per-block scale arithmetic (the `/ 127` max-abs scale + round)
    lives ONLY in core/comms.py. Enforced by swarmlint's declarative
    sole_impl registry (SWL004) — any second implementation site under src/
    is a finding."""
    from repro.analysis.lint import run_paths
    findings = run_paths(["src"], rules=["SWL004"])
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# stateless quant + EF advance (XLA ground truth)
# ---------------------------------------------------------------------------

def test_quant_dequant_error_bound():
    """int8 per-block round-trip error ≤ max|block|/254 + float slack."""
    rng = np.random.default_rng(0)
    wb = 128
    x = jnp.asarray(rng.normal(0, 3, (N, 1000)), jnp.float32)
    deq = comms.quant_dequant_tree({"x": x}, "int8", wb)["x"]
    xe = np.pad(np.asarray(x), ((0, 0), (0, (-1000) % wb)))
    blocks = xe.reshape(N, -1, wb)
    bound = (np.abs(blocks).max(-1, keepdims=True) / 254.0 + 1e-6)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert (err <= np.broadcast_to(bound, blocks.shape).reshape(N, -1)[:, :1000]).all()


def test_wire_effective_residual_is_quant_error():
    """θ − θ̂' == the current round's quantization error (nothing dropped)."""
    rng = np.random.default_rng(1)
    p = {"x": jnp.asarray(rng.normal(0, 1, (N, 300)), jnp.float32),
         "skip": None}
    wire = comms.init_wire(p)
    eff = comms.wire_effective(p, wire, "int8", 128)
    assert eff["skip"] is None
    res = comms.wire_residual(p, eff)
    # second advance transmits most of the residual: geometric contraction
    eff2 = comms.wire_effective(p, eff, "int8", 128)
    res2 = comms.wire_residual(p, eff2)
    r1 = float(jnp.abs(res["x"]).max())
    r2 = float(jnp.abs(res2["x"]).max())
    assert r2 <= r1 / 64 + 1e-7   # ≥127× in exact arithmetic; allow slack


# ---------------------------------------------------------------------------
# quantized EF sessions: determinism, drift, telescoping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("merge,topo", [("fedavg", "full"),
                                        ("fisher", "ring"),
                                        ("gradmatch", "dynamic")])
def test_wire_session_bounded_drift_and_determinism(merge, topo):
    """int8 EF sync: (a) bitwise deterministic across runs, (b) committed
    params stay within a quantization-scale band of the f32 session every
    round — the parity harness vs the f32 host oracle."""
    batches = jnp.broadcast_to(_targets(), (2, N, 4))
    val = jnp.zeros((N, 1))

    def run(wd):
        cfg = _cfg(merge=merge, topology=topo, wire_dtype=wd, wire_block=128)
        sess = _session(cfg)
        drift = []
        for _ in range(4):
            sess.round(batches, val)
            drift.append(np.asarray(sess.state.params["x"]).copy())
        return drift

    a = run("int8")
    b = run("int8")
    for xa, xb in zip(a, b):
        np.testing.assert_array_equal(xa, xb)   # bitwise determinism
    f = run("f32")
    for r, (xa, xf) in enumerate(zip(a, f)):
        # params are O(node index) ≤ 3: per-block scale ≤ 3/127; EF keeps
        # the accumulated drift within a few quantization steps
        assert np.abs(xa - xf).max() < 0.1, f"round {r} drift too large"


def test_wire_residual_telescopes_on_constant_inputs():
    """Constant inputs (identity train step, every node inactive so no
    commit ever lands): the EF residual contracts geometrically to zero —
    untransmitted mass is delayed, never lost."""
    def train_step(p, o, b, s):
        return p, o, {"loss": 0.0 * jnp.sum(p["x"])}

    def eval_fn(p, v):
        return 0.0 * jnp.sum(p["x"])

    rng = np.random.default_rng(3)
    x0 = jnp.asarray(rng.normal(0, 1, (N, 64)), jnp.float32)
    cfg = _cfg(merge="fedavg", topology="dynamic", val_threshold=0.9,
               wire_dtype="int8", wire_block=128, sync_every=1)
    sess = SwarmSession(cfg, train_step, eval_fn, params={"x": x0},
                        stacked=True, data_sizes=[1.0] * N)
    sess.set_active([False] * N)   # merges rejected; wire still advances
    batches = jnp.zeros((1, N, 4))
    val = jnp.zeros((N, 1))
    prev = np.inf
    for r in range(5):
        out = sess.round(batches, val)
        assert not np.asarray(out["gates"]).any()   # params stay constant
        res = float(np.abs(np.asarray(sess.state.params["x"])
                           - np.asarray(sess.state.wire["x"])).max())
        if r >= 1:
            assert res <= prev / 32 + 1e-9, f"round {r}: {res} vs {prev}"
        prev = res
    assert prev < 1e-7   # telescoped to (float) zero


def test_wire_with_lora_only_payload():
    """Wire state mirrors the adapter payload (None base leaves); base
    params never cross the wire and stay bit-exact."""
    rng = np.random.default_rng(4)
    params = {"attn": {"w": jnp.asarray(rng.normal(0, 1, (8, 6)), jnp.float32),
                       "lora_A": jnp.asarray(rng.normal(0, 0.1, (8, 2)),
                                             jnp.float32),
                       "lora_B": jnp.zeros((2, 6)),
                       "lora_scale": jnp.asarray(2.0)}}

    def train_step(p, o, b, s):
        return jax.tree.map(lambda x: x + 0.01, p), o, {"loss": jnp.sum(b)}

    def eval_fn(p, v):
        return 1.0 - 0.0 * jnp.sum(p["attn"]["w"])

    cfg = _cfg(lora_only=True, wire_dtype="int8", wire_block=128,
               sync_every=1)
    sess = SwarmSession(cfg, train_step, eval_fn, params=params,
                        data_sizes=[1.0] * N)
    assert sess.state.wire["attn"]["w"] is None        # base: no wire state
    assert sess.state.wire["attn"]["lora_A"] is not None
    batches = jnp.zeros((1, N, 4))
    sess.round(batches, jnp.zeros((N, 1)))
    got_w = np.asarray(sess.state.params["attn"]["w"])
    want_w = np.asarray(params["attn"]["w"]) + 0.01    # local steps only
    np.testing.assert_array_equal(got_w, np.broadcast_to(want_w, got_w.shape))


def test_wire_checkpoint_resume_bit_identical(tmp_path):
    """save → restore → continue == never stopping, wire reference included."""
    cfg = _cfg(merge="fisher", topology="ring", wire_dtype="int8",
               wire_block=128)
    batches = jnp.broadcast_to(_targets(), (2, N, 4))
    val = jnp.zeros((N, 1))
    path = str(tmp_path / "wire.msgpack")

    ref = _session(cfg)
    for _ in range(4):
        ref.round(batches, val)

    sess = _session(cfg)
    for _ in range(2):
        sess.round(batches, val)
    sess.save(path)
    resumed = SwarmSession.restore(path, cfg, *_toy_fns(),
                                   params={"x": jnp.zeros((4,))},
                                   data_sizes=[100 * (i + 1)
                                               for i in range(N)])
    for _ in range(2):
        resumed.round(batches, val)
    np.testing.assert_array_equal(np.asarray(resumed.state.params["x"]),
                                  np.asarray(ref.state.params["x"]))
    np.testing.assert_array_equal(np.asarray(resumed.state.wire["x"]),
                                  np.asarray(ref.state.wire["x"]))


def test_wire_overlap_mode_runs():
    """EF wire composes with the stale-by-one overlap schedule."""
    cfg = _cfg(sync_every=1, overlap_sync=True, wire_dtype="int8",
               wire_block=128)
    sess = _session(cfg)
    batches = jnp.broadcast_to(_targets(), (6, 1, N, 4))
    logs = sess.run_rounds(batches, jnp.zeros((N, 1)))
    assert np.asarray(logs["gates"]).all()
    assert np.isfinite(np.asarray(sess.state.params["x"])).all()
    assert sess.state.wire is not None


def test_direct_engine_api_honours_wire_dtype():
    """The deprecated tuple API (no threaded SwarmState.wire) must still
    quantize — never a silent f32 no-op while reporting a compressed
    schedule. Without carried state it falls back to a zero reference per
    call (stateless quantization); the advanced reference is returned so
    callers CAN thread it."""
    from repro.core.engine import SwarmEngine
    rng = np.random.default_rng(6)
    params = {"x": jnp.asarray(rng.normal(0, 1, (N, 64)), jnp.float32)}
    _, eval_fn = _toy_fns()
    outs = {}
    for wd in ("f32", "int8"):
        eng = SwarmEngine(_cfg(wire_dtype=wd, wire_block=128), None, eval_fn,
                          data_sizes=[1.0] * N)
        committed, log = jax.jit(eng.sync)(params, jnp.zeros((N, 1)))
        outs[wd] = np.asarray(committed["x"])
        assert ("wire" in log) == (wd == "int8")
    assert np.abs(outs["int8"] - outs["f32"]).max() > 0   # quantized for real
    assert np.abs(outs["int8"] - outs["f32"]).max() < 3.0 / 127 * 4


def test_session_surfaces_schedule_and_bytes():
    """The trace-time choice and predicted bytes are session attributes —
    what the logs and benchmarks report."""
    sess = _session(_cfg(topology="ring", merge="fisher"))
    s = sess.sync_schedule
    assert s.name == "ring_topo_ppermute" and s.simulated
    assert sess.payload_params == 4
    assert sess.predicted_sync_bytes == pytest.approx(4 * 4 * 4)
    assert "ring_topo_ppermute" in s.describe(sess.payload_params)


# ---------------------------------------------------------------------------
# histo smoke: convergence non-regression under the quantized wire
# ---------------------------------------------------------------------------

def test_histo_smoke_with_int8_wire_non_regression():
    """The paper's histo swarm loop with an int8 EF wire tracks the f32 loop:
    same gates trajectory shape, merged-metric within a small band."""
    from repro.data import make_histo_dataset, paper_splits, shard_to_nodes
    from repro.experiments.histo import (HistoExperimentConfig,
                                         _make_model_fns, _train_loop)

    def run(wd):
        ecfg = HistoExperimentConfig(
            n_train=120, n_test=24, steps=4, image_size=16, batch_size=8,
            noise=0.6, growth=4, stem=8, feat_dim=32, hidden=16, n_blocks=1,
            layers_per_block=2, seed=5,
            swarm=SwarmConfig(n_nodes=4, sync_every=2, topology="full",
                              merge="fedavg", lora_only=False,
                              val_threshold=0.8, gate_metric="auc",
                              wire_dtype=wd, wire_block=128))
        images, labels = make_histo_dataset(ecfg.n_train,
                                            size=ecfg.image_size,
                                            noise=ecfg.noise, seed=ecfg.seed)
        shards = shard_to_nodes(images, labels,
                                paper_splits(ecfg.n_train, ecfg.fractions),
                                seed=ecfg.seed)
        train_step, _, _ = _make_model_fns(ecfg)
        params, sync_log = _train_loop(ecfg, train_step, shards,
                                       swarm_cfg=ecfg.swarm)
        return params, sync_log

    p8, log8 = run("int8")
    pf, logf = run("f32")
    assert len(log8) == len(logf) > 0
    for s8, sf in zip(log8, logf):
        assert all(np.isfinite(s8["metric_merged"]))
        m8 = np.mean(s8["metric_merged"])
        mf = np.mean(sf["metric_merged"])
        assert m8 >= mf - 0.05   # quantized sync must not collapse the gate
    for a, b in zip(jax.tree.leaves(p8[0]), jax.tree.leaves(pf[0])):
        assert np.isfinite(np.asarray(a)).all()
