"""Cross-path consistency: model modules vs kernels vs hand oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.models.attention import attention, init_attention
from repro.models.moe import init_moe, moe
from repro.models.transformer import layer_windows


def test_module_attention_matches_flash_kernel():
    """The jnp attention module (dry-run path) == the Pallas flash kernel."""
    from repro.kernels.flash_attention import flash_attention
    cfg = ModelConfig(name="t", d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, vocab_size=10, rope_theta=1e4)
    key = jax.random.key(0)
    p = init_attention(key, cfg)
    b, s = 2, 128
    x = jax.random.normal(jax.random.key(1), (b, s, 128), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    for window in (0, 32):
        y_mod, _ = attention(p, x, cfg, positions=positions, window=window)
        # rebuild q/k/v exactly as the module does, run the kernel on them
        from repro.models.layers import linear, apply_rope
        q = apply_rope(linear(p["q"], x).reshape(b, s, 4, 32), positions, 1e4)
        k = apply_rope(linear(p["k"], x).reshape(b, s, 2, 32), positions, 1e4)
        v = linear(p["v"], x).reshape(b, s, 2, 32)
        out = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              window=window, bq=64, bk=64, interpret=True)
        y_kern = linear(p["o"], out.transpose(0, 2, 1, 3).reshape(b, s, -1))
        np.testing.assert_allclose(np.asarray(y_mod), np.asarray(y_kern),
                                   rtol=2e-4, atol=2e-4)


def test_vocab_padding_masked_and_argmax_valid():
    cfg = ModelConfig(name="t", n_layers=1, d_model=64, n_heads=2,
                      n_kv_heads=2, d_ff=128, vocab_size=100, vocab_pad_to=64)
    assert cfg.padded_vocab == 128
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    from repro.models.transformer import forward_lm
    logits, _, _ = forward_lm(params, cfg, jnp.ones((1, 8), jnp.int32))
    assert logits.shape[-1] == 128
    pad = np.asarray(logits[..., 100:])
    assert (pad < -1e29).all(), "padding columns must be masked"
    assert int(jnp.argmax(logits, -1).max()) < 100


@pytest.mark.parametrize("seed", range(3))
def test_moe_matches_dense_expert_oracle(seed):
    """Top-1 routing with ample capacity == manually routing every token."""
    cfg = ModelConfig(name="t", family="moe", d_model=32, n_experts=4,
                      top_k=1, d_ff_expert=64, capacity_factor=8.0,
                      vocab_size=10, router_aux_coef=0.0)
    p = init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 10), (2, 16, 32), jnp.float32)
    y, aux = moe(p, x, cfg)

    # oracle: per-token argmax expert, run its FFN densely
    logits = x.reshape(-1, 32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    eid = jnp.argmax(probs, -1)
    xt = x.reshape(-1, 32)
    outs = []
    for t in range(xt.shape[0]):
        e = int(eid[t])
        h = jax.nn.silu(xt[t] @ p["experts"]["gate"]["w"][e]) * (
            xt[t] @ p["experts"]["up"]["w"][e])
        outs.append(h @ p["experts"]["down"]["w"][e])
    want = jnp.stack(outs).reshape(2, 16, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens_gracefully():
    """Tiny capacity: output stays finite and dropped tokens contribute 0."""
    cfg = ModelConfig(name="t", family="moe", d_model=16, n_experts=2,
                      top_k=2, d_ff_expert=32, capacity_factor=0.1,
                      vocab_size=10)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 32, 16), jnp.float32)
    y, aux = moe(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux))
    # with cf=8 nothing drops; outputs must differ (capacity actually binds)
    y_full, _ = moe(p, x, cfg.replace(capacity_factor=8.0))
    assert float(jnp.abs(y - y_full).max()) > 1e-6


def test_layer_windows_patterns():
    cfg = ModelConfig(name="t", n_layers=8, sliding_window=128, attn_every=4,
                      n_heads=2, n_kv_heads=2, vocab_size=10)
    w = layer_windows(cfg)
    assert list(w) == [0, 128, 128, 128, 0, 128, 128, 128]
    cfg2 = cfg.replace(attn_every=0)
    assert (layer_windows(cfg2) == 128).all()
    cfg3 = cfg.replace(sliding_window=0)
    assert (layer_windows(cfg3) == 0).all()


def test_gqa_grouping_math():
    """GQA with g groups == full MHA when KV heads are replicated g times."""
    from repro.kernels.ref import attention_ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (1, 4, 32, 16)), jnp.float32)
    kv = jnp.asarray(rng.normal(0, 1, (1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 32, 16)), jnp.float32)
    gqa = attention_ref(q, kv, v)
    mha = attention_ref(q, jnp.repeat(kv, 2, 1), jnp.repeat(v, 2, 1))
    np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha), rtol=1e-5,
                               atol=1e-6)
