"""Heterogeneous swarm (ISSUE 10): adapter-only ``payload="lora"`` mode.

Pins the tentpole semantics end to end on the engine backend:

  * `comms.payload_mode` / `split_payload_at_sync` validation and the lora
    payload-class tagging of every picked schedule,
  * `lora.flatten_payload` / `unflatten_payload` — THE sole adapter
    flatten implementation (swarmlint SWL004 `adapter_flatten`),
  * the model zoo (`models.zoo`): heterogeneous frozen backbones around one
    shared LoRA'd head, structurally identical payload rows,
  * zoo closure lists through `zoo_vstep`/`zoo_veval` — per-node dispatch
    with the stacked-state contract, zero retraces across join/leave,
  * the fairness gate (`SwarmConfig.fairness_floor`): worst-active-site
    metric floor ANDed into the commit gate like quorum,
  * committed-adapter parity vs the numpy mixing oracle,
  * checkpoint round-trips of the adapter-only state (incl. the int8 EF
    wire residuals) bit-identically, with cfg-mismatch rejection,
  * the scenario grid (`experiments.scenarios`): biased-label partitions,
    synthetic augmentation, and the BENCH_hetero row contract.

The multi-device HLO bytes / mesh-wire checks live in tests/test_hetero_spmd.py.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.core import comms
from repro.core import topology as topo
from repro.core.engine import zoo_veval, zoo_vstep
from repro.core.lora import flatten_payload, inject_lora, unflatten_payload
from repro.core.session import SwarmSession
from repro.experiments import scenarios
from repro.models import zoo

N = 4


def _cfg(**kw):
    kw.setdefault("n_nodes", N)
    kw.setdefault("sync_every", 2)
    kw.setdefault("merge", "fedavg")
    kw.setdefault("topology", "full")
    kw.setdefault("lora_only", False)
    kw.setdefault("val_threshold", 0.0)
    return SwarmConfig(**kw)


# ---------------------------------------------------------------------------
# payload mode plumbing
# ---------------------------------------------------------------------------

def test_payload_mode_validation():
    assert comms.payload_mode(_cfg()) == "full"
    assert comms.payload_mode(_cfg(payload="lora")) == "lora"
    with pytest.raises(ValueError, match="unknown payload mode"):
        comms.payload_mode(_cfg(payload="int8"))


def test_split_payload_at_sync_semantics():
    """lora_only still means "only adapters cross the wire" — but in
    payload="lora" mode there is nothing to carve out at sync time."""
    assert not comms.split_payload_at_sync(_cfg())
    assert comms.split_payload_at_sync(_cfg(lora_only=True))
    assert not comms.split_payload_at_sync(_cfg(lora_only=True,
                                                payload="lora"))
    assert not comms.split_payload_at_sync(_cfg(payload="lora"))


def test_every_candidate_schedule_carries_the_payload_class():
    for cfg in (_cfg(payload="lora"), _cfg(lora_only=True)):
        for s in comms.candidate_schedules(cfg):
            assert s.payload == "lora", s.name
            assert "/lora" in s.describe(), s.describe()
    for s in comms.candidate_schedules(_cfg()):
        assert s.payload == "full", s.name
        assert "/lora" not in s.describe()


# ---------------------------------------------------------------------------
# the sole adapter flatten implementation
# ---------------------------------------------------------------------------

def _lora_params():
    base = {"attn": {"w": jnp.arange(12.0).reshape(4, 3)},
            "mlp": {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))}}
    return inject_lora(base, jax.random.PRNGKey(0), rank=2, targets="attn")


def test_flatten_payload_roundtrip():
    params = _lora_params()
    flat = flatten_payload(params)
    assert sorted(flat) == ["attn/lora_A", "attn/lora_B", "attn/lora_scale"]
    assert flat["attn/lora_A"].shape == (4, 2)
    # substitution: unflatten writes the payload rows back into the template
    bumped = {k: v + 1.0 for k, v in flat.items()}
    full = unflatten_payload(bumped, params)
    np.testing.assert_array_equal(np.asarray(full["attn"]["lora_A"]),
                                  np.asarray(params["attn"]["lora_A"]) + 1.0)
    # non-payload leaves come straight from the template
    np.testing.assert_array_equal(np.asarray(full["attn"]["w"]),
                                  np.asarray(params["attn"]["w"]))


def test_flatten_payload_custom_select_and_errors():
    params = _lora_params()
    flat = flatten_payload(params, lambda p: p.startswith("mlp/"))
    assert sorted(flat) == ["mlp/b", "mlp/w"]
    with pytest.raises(ValueError, match="no leaf matched"):
        flatten_payload(params, lambda p: False)
    with pytest.raises(ValueError, match="not present in"):
        unflatten_payload({"nope/w": jnp.zeros(())}, params)


def test_adapter_flatten_is_sole_impl_registered():
    """The swarmlint SWL004 registry guards the single implementation; the
    repo tree must be clean of rogue copies (the fixture corpus in
    tests/lint_fixtures/swl004_adapter_flatten.py proves the positive)."""
    from repro.analysis.lint import run_paths
    from repro.analysis.rules import SOLE_IMPLS
    spec = {s.name: s for s in SOLE_IMPLS}["adapter_flatten"]
    assert spec.allowed == "src/repro/core/lora.py"
    assert run_paths(["src"], rules=["SWL004"]) == []


# ---------------------------------------------------------------------------
# the model zoo
# ---------------------------------------------------------------------------

_PAYLOAD_KEYS = ["head/out/b", "head/out/w", "head/proj/lora_A",
                 "head/proj/lora_B", "head/proj/lora_scale"]


def _tiny_zoo(n=N):
    return zoo.build_zoo(jax.random.PRNGKey(0), n, image_size=16,
                         feat_dim=8, hidden=8, rank=2)


def test_zoo_payload_rows_are_structurally_identical():
    nodes = _tiny_zoo()
    assert [nd.family for nd in nodes] == list(zoo.DEFAULT_FAMILIES)
    payloads = [nd.payload() for nd in nodes]
    for p in payloads:
        assert sorted(p) == _PAYLOAD_KEYS
    # one shared head key: every node's payload row starts identical, and
    # the frozen backbones (never in the payload) differ per family
    for p in payloads[1:]:
        for k in p:
            np.testing.assert_array_equal(np.asarray(p[k]),
                                          np.asarray(payloads[0][k]))
    structs = {str(jax.tree.structure(nd.template["backbone"]))
               for nd in nodes}
    assert len(structs) > 1, "zoo backbones should be heterogeneous"


def test_zoo_apply_emits_logits_through_each_backbone():
    nodes = _tiny_zoo()
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (5, 16, 16, 3)),
                    jnp.float32)
    for nd in nodes:
        logits = nd.apply(nd.payload(), x)
        assert logits.shape == (5, 3)
        assert np.isfinite(np.asarray(logits)).all()


def test_zoo_grads_flow_only_through_the_payload():
    nd = _tiny_zoo(1)[0]
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (4, 16, 16, 3)),
                    jnp.float32)

    def loss(payload):
        return jnp.sum(nd.apply(payload, x) ** 2)

    g = jax.grad(loss)(nd.payload())
    # the trainable surface is exactly the payload; gradients reach it
    assert sorted(g) == _PAYLOAD_KEYS
    assert float(jnp.abs(g["head/out/b"]).max()) > 0
    assert float(jnp.abs(g["head/proj/lora_B"]).max()) > 0


def test_zoo_vstep_rejects_mixed_tuple_forms():
    def three(p, o, b, s):
        return p, o, {}

    def four(p, o, b, s):
        return p, o, {}, p

    p = {"x": np.zeros((2, 3))}
    with pytest.raises(ValueError, match="3-tuple vs"):
        zoo_vstep([three, four])(p, p, np.zeros((2, 1)), 0)
    out = zoo_veval([lambda p, v: jnp.asarray(0.25),
                     lambda p, v: jnp.asarray(0.75)])(p, np.zeros((2, 1)))
    np.testing.assert_allclose(np.asarray(out), [0.25, 0.75])


# ---------------------------------------------------------------------------
# zoo sessions: closures + adapter-only state on the engine backend
# ---------------------------------------------------------------------------

def _payload_session(cfg, nodes=None, metric_vals=None, trace_log=None,
                     decay=0.01, seed=0):
    """payload="lora" session over the tiny zoo with decay-toward-zero
    train closures (payload-only dynamics keep oracles analytic)."""
    nodes = nodes or _tiny_zoo(cfg.n_nodes)

    def make(i):
        def step(p, o, b, s):
            if trace_log is not None:
                trace_log.append(i)
            return ({k: v * (1.0 - decay) for k, v in p.items()}, o,
                    {"loss": 0.0 * jnp.sum(p["head/out/w"])})

        def ev(p, v):
            c = 1.0 if metric_vals is None else metric_vals[i]
            return c - 0.0 * jnp.sum(p["head/out/w"])

        return step, ev

    fns = [make(i) for i in range(cfg.n_nodes)]
    payloads = [nd.payload() for nd in nodes]
    return SwarmSession(cfg, [f[0] for f in fns], [f[1] for f in fns],
                        params=payloads, data_sizes=[10.0 * (i + 1) for i in
                                                     range(cfg.n_nodes)],
                        seed=seed)


def _batches(cfg, t=None):
    return jnp.zeros(((t or cfg.sync_every), cfg.n_nodes, 1))


def _val(cfg):
    return jnp.zeros((cfg.n_nodes, 1))


def test_payload_lora_session_zero_retrace_across_membership():
    cfg = _cfg(payload="lora", wire_dtype="int8", wire_block=128,
               topology="ring")
    trace_log = []
    sess = _payload_session(cfg, trace_log=trace_log)
    assert sess.sync_schedule.payload == "lora"
    assert sess.payload_params == sum(
        int(v.size) for v in _tiny_zoo(1)[0].payload().values())
    sess.round(_batches(cfg), _val(cfg))       # the one and only trace
    warm = len(trace_log)
    assert warm >= cfg.n_nodes                 # every closure traced
    sess.leave(2)
    sess.round(_batches(cfg), _val(cfg))
    sess.join(2)
    sess.leave(0)
    out = sess.round(_batches(cfg), _val(cfg))
    assert len(trace_log) == warm, "membership flips must not retrace"
    gates = np.asarray(out["gates"])
    assert not gates[0] and gates[1]           # inactive node never commits
    # the int8 EF wire rides SwarmState next to the flat payload
    assert sess.state.wire is not None
    assert sorted(sess.state.params) == _PAYLOAD_KEYS


def test_fairness_floor_gates_on_worst_active_site():
    metric_vals = [0.2, 0.4, 0.6, 0.8]
    cfg = _cfg(payload="lora", fairness_floor=0.3)
    sess = _payload_session(cfg, metric_vals=metric_vals)
    before = jax.tree.map(np.asarray, sess.state.params)
    out = sess.round(_batches(cfg), _val(cfg))
    # site 0's merged metric (0.2) is under the floor: the WHOLE swarm
    # holds its locals — params advance by local steps only, no commit
    assert not bool(np.asarray(out["fairness_ok"]))
    assert np.asarray(out["worst_site"]) == pytest.approx(0.2)
    assert not np.asarray(out["gates"]).any()
    # inactive sites never drag the min: with site 0 gone the worst
    # ACTIVE site (0.4) clears the floor and the commit lands
    sess.leave(0)
    out2 = sess.round(_batches(cfg), _val(cfg))
    assert bool(np.asarray(out2["fairness_ok"]))
    assert np.asarray(out2["worst_site"]) == pytest.approx(0.4)
    assert np.asarray(out2["gates"])[1:].all()
    del before


def test_fairness_floor_disabled_and_validated():
    cfg = _cfg(payload="lora")
    sess = _payload_session(cfg)
    out = sess.round(_batches(cfg), _val(cfg))
    assert "fairness_ok" not in out and "worst_site" not in out
    with pytest.raises(ValueError, match="fairness_floor"):
        _payload_session(_cfg(payload="lora", fairness_floor=1.5))


def test_fairness_floor_composes_with_quorum():
    cfg = _cfg(payload="lora", fairness_floor=0.3, quorum=4)
    sess = _payload_session(cfg, metric_vals=[0.5] * N)
    out = sess.round(_batches(cfg), _val(cfg))
    assert bool(np.asarray(out["fairness_ok"]))
    assert bool(np.asarray(out["quorum_ok"]))
    assert np.asarray(out["gates"]).all()
    sess.leave(3)                              # below quorum, floor still ok
    out2 = sess.round(_batches(cfg), _val(cfg))
    assert bool(np.asarray(out2["fairness_ok"]))
    assert not bool(np.asarray(out2["quorum_ok"]))
    assert not np.asarray(out2["gates"]).any()


def test_committed_adapters_match_numpy_mixing_oracle():
    """Identity local steps + accepting gates: one round commits exactly
    W @ payload_rows for every flat payload leaf (numpy host oracle)."""
    for topology in ("full", "ring"):
        cfg = _cfg(payload="lora", topology=topology, sync_every=1)
        sess = _payload_session(cfg, decay=0.0)
        start = {k: np.asarray(v).copy()
                 for k, v in sess.state.params.items()}
        sizes = [10.0 * (i + 1) for i in range(N)]
        W = topo.build_matrix(
            topology, N,
            weights=topo.fedavg_weights(sizes) if topology == "full" else None)
        out = sess.round(_batches(cfg), _val(cfg))
        assert np.asarray(out["gates"]).all()
        for k, v in sess.state.params.items():
            got = np.asarray(v)
            want = np.tensordot(W, start[k], axes=(1, 0))
            np.testing.assert_allclose(got, want, atol=1e-6, err_msg=k)


def test_payload_lora_checkpoint_bit_identical():
    """save → restore → continue == never stopping, for the flat adapter
    state AND the int8 EF wire residuals (ISSUE 10 satellite)."""
    cfg = _cfg(payload="lora", wire_dtype="int8", wire_block=128,
               topology="ring")

    def run(rounds, resume_at=None, path=None):
        sess = _payload_session(cfg)
        for r in range(rounds):
            if resume_at is not None and r == resume_at:
                sess.save(path)
                sess = SwarmSession.restore(
                    path, cfg, sess.train_step_fn, sess.eval_fn,
                    params=[nd.payload() for nd in _tiny_zoo()],
                    data_sizes=[10.0 * (i + 1) for i in range(N)], seed=0)
            sess.round(_batches(cfg), _val(cfg))
        return sess

    path = os.path.join(tempfile.mkdtemp(), "hetero.msgpack")
    ref = run(4)
    got = run(4, resume_at=2, path=path)
    for k in ref.state.params:
        np.testing.assert_array_equal(np.asarray(got.state.params[k]),
                                      np.asarray(ref.state.params[k]))
    for a, b in zip(jax.tree.leaves(got.state.wire),
                    jax.tree.leaves(ref.state.wire)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_payload_mode_mismatch_rejected():
    cfg = _cfg(payload="lora")
    sess = _payload_session(cfg)
    path = os.path.join(tempfile.mkdtemp(), "hetero_mismatch.msgpack")
    sess.save(path)
    other = _payload_session(_cfg())           # payload="full" session
    with pytest.raises(ValueError, match="payload"):
        other.load(path)


def test_payload_lora_and_zoo_need_a_compiled_backend():
    nodes = _tiny_zoo()
    payloads = [nd.payload() for nd in nodes]
    with pytest.raises(ValueError, match='payload="lora"'):
        SwarmSession(_cfg(payload="lora"), lambda p, o, b, s: (p, o, {}),
                     lambda p, v: 1.0, params=payloads, backend="host")
    fns = [lambda p, o, b, s: (p, o, {})] * N
    with pytest.raises(ValueError, match="engine-backend"):
        SwarmSession(_cfg(), fns, lambda p, v: 1.0, params=payloads,
                     backend="host")


def test_zoo_closure_list_length_must_match_n_nodes():
    nodes = _tiny_zoo()
    payloads = [nd.payload() for nd in nodes]
    fns = [lambda p, o, b, s: (p, o, {})] * (N - 1)
    with pytest.raises(ValueError, match="one closure per node"):
        SwarmSession(_cfg(payload="lora"), fns, lambda p, v: 1.0,
                     params=payloads)


# ---------------------------------------------------------------------------
# scenario grid
# ---------------------------------------------------------------------------

def test_scenario_grid_shape():
    grid = scenarios.scenario_grid()
    assert len(grid) >= 4
    names = [s.name for s in grid]
    assert len(set(names)) == len(names)
    parts = {s.partition for s in grid}
    assert {"label_skew", "label_synth", "dirichlet"} <= parts


def _corpus(n=240):
    from repro.data import make_histo_dataset
    return make_histo_dataset(n, size=16, noise=1.1,
                              class_probs=(0.5, 0.3, 0.2), seed=0)


def test_build_shards_label_skew_biases_labels():
    images, labels = _corpus()
    scn = next(s for s in scenarios.scenario_grid()
               if s.partition == "label_skew")
    shards, n_synth = scenarios.build_shards(scn, images, labels, N)
    assert n_synth == [0] * N
    assert sum(len(y) for _, y in shards) <= len(labels)
    for i, (_, y) in enumerate(shards):
        counts = np.bincount(y, minlength=3)
        assert counts.argmax() == i % 3, (i, counts)


def test_build_shards_synth_augments_starved_classes():
    images, labels = _corpus()
    scn = next(s for s in scenarios.scenario_grid()
               if s.partition == "label_synth")
    skew = next(s for s in scenarios.scenario_grid()
                if s.partition == "label_skew")
    shards, n_synth = scenarios.build_shards(scn, images, labels, N)
    plain, _ = scenarios.build_shards(skew, images, labels, N)
    assert all(k > 0 for k in n_synth)
    for i, ((_, y), (_, y0)) in enumerate(zip(shards, plain)):
        assert len(y) == len(y0) + n_synth[i]
        # the synthetic tail inverts the skew: site i's starved classes
        # gain share relative to the un-augmented shard
        starved = [c for c in range(3) if c != i % 3]
        frac = lambda yy: np.isin(yy, starved).mean()
        assert frac(y) > frac(y0), (i, frac(y), frac(y0))


def test_build_shards_dirichlet_floors_starved_sites():
    images, labels = _corpus()
    scn = next(s for s in scenarios.scenario_grid()
               if s.partition == "dirichlet")
    shards, _ = scenarios.build_shards(scn, images, labels, N)
    assert all(len(y) >= 8 for _, y in shards)
    with pytest.raises(ValueError, match="unknown partition"):
        scenarios.build_shards(
            scenarios.Scenario("x", "bogus"), images, labels, N)


@pytest.fixture(scope="module")
def scenario_row():
    """One fast biased-label cell end-to-end — the BENCH_hetero row."""
    rcfg = scenarios.ScenarioRunConfig(
        n_train=96, n_test=48, feat_dim=8, hidden=8, steps=8, batch_size=4,
        swarm=SwarmConfig(
            n_nodes=4, sync_every=4, topology="ring", merge="fedavg",
            payload="lora", wire_dtype="int8", wire_block=128,
            val_threshold=0.0, gate_metric="auc", fairness_floor=0.05))
    scn = next(s for s in scenarios.scenario_grid()
               if s.partition == "label_skew")
    return scenarios.run_scenario(scn, rcfg)


def test_scenario_row_contract(scenario_row):
    row = scenario_row
    for key in ("scenario", "families", "schedule", "payload_class",
                "payload_params", "wire_bytes_per_sync",
                "full_f32_bytes_per_sync", "wire_fraction_of_full",
                "retraces", "per_site", "site_auc_spread", "worst_site_auc",
                "oracle", "gates_last", "fairness_ok_last"):
        assert key in row, key
    assert row["payload_class"] == "lora"
    assert len(row["per_site"]) == 4
    assert len(set(row["families"])) == 4
    assert all("auc" in r and "sensitivity" in r for r in row["per_site"])
    assert row["site_auc_spread"] >= 0


def test_scenario_row_zero_retraces(scenario_row):
    assert scenario_row["retraces"] == 0


def test_scenario_row_wire_under_five_percent_of_full(scenario_row):
    """The headline acceptance ratio at the cost-model level: adapter-only
    int8 sync ≤ 5% of the full-payload f32 bytes (HLO-measured twin lives
    in tests/test_hetero_spmd.py)."""
    assert scenario_row["wire_fraction_of_full"] <= 0.05


# ---------------------------------------------------------------------------
# the fused head kernel dispatcher
# ---------------------------------------------------------------------------

def test_lora_apply_matches_unfused_form():
    from repro.kernels.lora_matmul import lora_apply, lora_matmul
    rng = np.random.default_rng(0)
    # zoo-head shapes: nothing tileable — the dispatcher must fall back
    x = jnp.asarray(rng.normal(0, 1, (5, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (8, 6)), jnp.float32)
    a = jnp.asarray(rng.normal(0, 1, (8, 2)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (2, 6)), jnp.float32)
    got = np.asarray(lora_apply(x, w, a, b, 0.5))
    want = np.asarray(x @ w + 0.5 * (x @ a) @ b)
    np.testing.assert_allclose(got, want, atol=1e-5)
    # MXU-tileable shapes: parity with the fused kernel (interpret mode)
    xt = jnp.asarray(rng.normal(0, 1, (128, 512)), jnp.float32)
    wt = jnp.asarray(rng.normal(0, 0.1, (512, 128)), jnp.float32)
    at = jnp.asarray(rng.normal(0, 0.1, (512, 4)), jnp.float32)
    bt = jnp.asarray(rng.normal(0, 0.1, (4, 128)), jnp.float32)
    fused = np.asarray(lora_matmul(xt, wt, at, bt, 2.0, interpret=True))
    unfused = np.asarray(lora_apply(xt, wt, at, bt, 2.0, interpret=True))
    np.testing.assert_allclose(fused, unfused, atol=2e-4)
