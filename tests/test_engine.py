"""The jitted stacked swarm engine: one compiled round must behave exactly
like the host-simulated `SwarmLearner` loop it replaces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.core import merge_impl as merge_lib
from repro.core.engine import SwarmEngine
from repro.core.swarm import NodeState, SwarmLearner

N = 4


def _toy_fns():
    """Traceable toy quadratic: each node descends toward its batch target."""
    def train_step(params, opt_state, batch, step):
        g = params["x"] - batch
        return {"x": params["x"] - 0.1 * g}, opt_state, {"loss": jnp.sum(g * g)}

    def eval_fn(params, val):
        return 1.0 - 0.0 * jnp.sum(params["x"])  # always accept, in-graph

    return train_step, eval_fn


def _cfg(**kw):
    kw.setdefault("n_nodes", N)
    kw.setdefault("sync_every", 2)
    kw.setdefault("merge", "fedavg")
    kw.setdefault("topology", "full")
    kw.setdefault("lora_only", False)
    kw.setdefault("val_threshold", 0.0)
    return SwarmConfig(**kw)


def _targets():
    return jnp.asarray([np.full((4,), t, np.float32) for t in range(N)])


def test_engine_matches_swarm_learner_toy():
    """run_rounds == the SwarmLearner loop on the toy quadratic model."""
    train_step, eval_fn = _toy_fns()
    cfg = _cfg()
    targets = _targets()
    rounds, t = 3, cfg.sync_every

    nodes = [NodeState(params={"x": jnp.zeros((4,))}, opt_state=None,
                       data_size=100 * (i + 1)) for i in range(N)]
    sw = SwarmLearner(cfg, train_step, eval_fn, nodes)
    for _ in range(rounds):
        for _ in range(t):
            sw.local_steps(list(targets))
        assert sw.maybe_sync([1] * N) is not None

    eng = SwarmEngine(cfg, train_step, eval_fn,
                      data_sizes=[100 * (i + 1) for i in range(N)])
    batches = jnp.broadcast_to(targets, (rounds, t, N, 4))
    params, _, _, logs = eng.run_rounds({"x": jnp.zeros((N, 4))}, None,
                                        batches, jnp.zeros((N, 1)), None, 0)
    assert np.asarray(logs["gates"]).all()
    want = np.stack([np.asarray(n.params["x"]) for n in sw.nodes])
    np.testing.assert_allclose(np.asarray(params["x"]), want,
                               rtol=1e-5, atol=1e-6)


def test_engine_gate_rejects_per_node():
    """Nodes whose local metric beats the merged metric keep their params."""
    train_step, _ = _toy_fns()

    def eval_fn(params, val):  # lower params -> better metric
        return 1.0 - 0.1 * jnp.mean(params["x"])

    cfg = _cfg(val_threshold=1.0)
    eng = SwarmEngine(cfg, train_step, eval_fn, data_sizes=[1] * N)
    params = {"x": jnp.asarray([np.full((4,), i, np.float32)
                                for i in range(N)])}
    committed, log = jax.jit(eng.sync)(params, jnp.zeros((N, 1)))
    gates = np.asarray(log["gates"])
    # merged mean = 1.5 -> metric 0.85; locals 1.0, 0.9, 0.8, 0.7
    assert gates.tolist() == [False, False, True, True]
    out = np.asarray(committed["x"])
    np.testing.assert_allclose(out[0], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[1], 1.0, atol=1e-6)
    np.testing.assert_allclose(out[2], 1.5, rtol=1e-6)
    np.testing.assert_allclose(out[3], 1.5, rtol=1e-6)


def test_engine_active_mask_excludes_and_freezes_node():
    train_step, eval_fn = _toy_fns()
    cfg = _cfg()
    eng = SwarmEngine(cfg, train_step, eval_fn, data_sizes=[1] * N)
    params = {"x": jnp.asarray([np.full((4,), i, np.float32)
                                for i in range(N)])}
    active = jnp.asarray([True, True, False, True])
    committed, log = jax.jit(eng.sync)(params, jnp.zeros((N, 1)), active)
    gates = np.asarray(log["gates"])
    assert not gates[2] and gates[[0, 1, 3]].all()
    out = np.asarray(committed["x"])
    np.testing.assert_allclose(out[2], 2.0)             # absent: frozen
    np.testing.assert_allclose(out[[0, 1, 3]], 4.0 / 3,  # mean over active
                               rtol=1e-5, atol=1e-6)


def test_engine_lora_only_commit_keeps_base():
    from repro.core.lora import inject_lora
    rng = np.random.default_rng(0)
    base = {"attn": {"q": {"w": jnp.asarray(rng.normal(0, 1, (8, 8)),
                                            jnp.float32)}}}
    trees = [inject_lora(jax.tree.map(lambda x: x + i, base),
                         jax.random.key(i), rank=2) for i in range(N)]
    stacked = merge_lib.stack_params(trees)

    def eval_any(params, val):
        return 1.0 - 0.0 * jnp.sum(params["attn"]["q"]["w"])

    eng = SwarmEngine(_cfg(lora_only=True), None, eval_any,
                      data_sizes=[1] * N)
    committed, log = jax.jit(eng.sync)(stacked, jnp.zeros((N, 1)))
    assert np.asarray(log["gates"]).all()
    # base leaves pass through bit-exactly; adapters hit the fused mean
    np.testing.assert_array_equal(np.asarray(committed["attn"]["q"]["w"]),
                                  np.asarray(stacked["attn"]["q"]["w"]))
    a = np.asarray(stacked["attn"]["q"]["lora_A"])
    np.testing.assert_allclose(np.asarray(committed["attn"]["q"]["lora_A"]),
                               np.tile(a.mean(0), (N, 1, 1)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("seed", range(4))
def test_macro_auc_traced_matches_host(seed):
    """The engine's in-graph gate metric == the host macro AUC, including
    tie handling, padding masks, and absent classes."""
    from repro.metrics import macro_auc, macro_auc_traced
    rng = np.random.default_rng(seed)
    v, pad = 37, 11
    probs = np.round(rng.random((v, 3)), 1)          # coarse -> many ties
    labels = rng.integers(0, 3 if seed % 2 else 2, v)  # even seeds: no class 2
    probs_p = np.concatenate([probs, np.zeros((pad, 3))])
    labels_p = np.concatenate([labels, np.zeros(pad, np.int64)])
    mask = np.arange(v + pad) < v
    got = float(macro_auc_traced(jnp.asarray(probs_p), jnp.asarray(labels_p),
                                 jnp.asarray(mask)))
    assert abs(got - macro_auc(probs, labels)) < 1e-5


@pytest.mark.parametrize("seed", range(4))
def test_macro_auc_sorted_matches_pairwise(seed):
    """The sort-based traced AUC == the old O(V²) pairwise form on small
    inputs (many ties forced by coarse rounding, random padding masks)."""
    from repro.metrics import _macro_auc_pairwise, macro_auc_traced
    rng = np.random.default_rng(100 + seed)
    v = 29
    probs = np.round(rng.random((v, 4)), 1)
    labels = rng.integers(0, 4, v)
    mask = rng.random(v) > 0.2
    got = float(macro_auc_traced(jnp.asarray(probs), jnp.asarray(labels),
                                 jnp.asarray(mask)))
    want = float(_macro_auc_pairwise(jnp.asarray(probs), jnp.asarray(labels),
                                     jnp.asarray(mask)))
    assert abs(got - want) < 1e-6


def test_macro_auc_traced_degenerate_single_class():
    """All-one-class labels: every one-vs-rest AUC is degenerate -> 0.5,
    matching the host metric (and not NaN)."""
    from repro.metrics import macro_auc, macro_auc_traced
    rng = np.random.default_rng(0)
    probs = rng.random((20, 3))
    labels = np.full(20, 1)
    got = float(macro_auc_traced(jnp.asarray(probs), jnp.asarray(labels)))
    assert got == pytest.approx(macro_auc(probs, labels)) == 0.5
    # fully-masked input: no class present -> 0.0, bit-matching the old
    # pairwise traced form (the engine's active mask handles such nodes)
    from repro.metrics import _macro_auc_pairwise
    mask = jnp.zeros(20, bool)
    got = float(macro_auc_traced(jnp.asarray(probs), jnp.asarray(labels),
                                 mask))
    assert got == float(_macro_auc_pairwise(jnp.asarray(probs),
                                            jnp.asarray(labels), mask)) == 0.0


def test_macro_auc_traced_randomized_large():
    """Acceptance: sort-based AUC within 1e-6 of the host metric on
    randomized inputs big enough that the pairwise form would be O(V²)."""
    from repro.metrics import macro_auc, macro_auc_traced
    rng = np.random.default_rng(7)
    v = 2500
    probs = rng.random((v, 3)).astype(np.float32)
    labels = rng.integers(0, 3, v)
    got = float(macro_auc_traced(jnp.asarray(probs), jnp.asarray(labels)))
    assert abs(got - macro_auc(probs, labels)) < 1e-6


def test_engine_run_rounds_reaches_consensus():
    """Full-topology fedavg commit pulls all nodes onto one iterate."""
    train_step, eval_fn = _toy_fns()
    cfg = _cfg(sync_every=1)
    eng = SwarmEngine(cfg, train_step, eval_fn, data_sizes=[1] * N)
    batches = jnp.broadcast_to(_targets(), (5, 1, N, 4))
    params, _, _, logs = eng.run_rounds({"x": jnp.zeros((N, 4))}, None,
                                        batches, jnp.zeros((N, 1)), None, 0)
    out = np.asarray(params["x"])
    for i in range(1, N):
        np.testing.assert_allclose(out[i], out[0], rtol=1e-5, atol=1e-6)


def test_engine_round_runs_local_steps_then_sync():
    """engine.round advances exactly T local steps before the gated commit."""
    train_step, eval_fn = _toy_fns()
    cfg = _cfg(sync_every=3, merge="mean")
    eng = SwarmEngine(cfg, train_step, eval_fn, data_sizes=[1] * N)
    batches = jnp.broadcast_to(_targets(), (3, N, 4))
    params, _, out = eng.round({"x": jnp.zeros((N, 4))}, None, batches,
                               jnp.zeros((N, 1)), None, 0)
    assert out["train"]["loss"].shape == (3, N)
    # 3 gradient steps toward target i: x = i * (1 - 0.9^3), then full-mean
    iterate = np.arange(N) * (1 - 0.9 ** 3)
    np.testing.assert_allclose(np.asarray(params["x"]),
                               np.tile(iterate.mean(), (N, 4)),
                               rtol=1e-5, atol=1e-6)
