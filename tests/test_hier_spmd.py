"""Hierarchical two-level swarm comms (ISSUE 7): pod-delegate q8 schedules.

All checks need >1 device arranged as a 2x2 ("pod", "node") mesh, so they
run in ONE subprocess with XLA_FLAGS forcing 4 host devices (same pattern as
test_mesh_wire_spmd), each printing an ``OK <tag>`` marker the tests assert
on. Pins the acceptance criteria:

  * the hierarchical fedavg/fisher pod-delegate schedules settle to their
    numpy oracles (intra-pod weighted reduce -> delegate int8 EF pod ring ->
    Wp mix -> intra-pod gather), raw and through the full gated session,
  * `pick_schedule` selects hierarchical iff the configured cross-pod
    per-byte cost dominates (both directions; 1-D meshes never offer it),
  * HLO-measured cross-pod bytes of hierarchical int8 fedavg are <= 0.35x
    the flat ring-q8 schedule, and match the per-link-class prediction,
  * the per-pod EF residual pytree checkpoints bit-identically and restored
    leaves are re-placed onto the 2-D NamedSharding templates,
  * flat q8 schedules keep running unchanged over the joint axis tuple.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.spmd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH=SRC)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


_CHECKS = """
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import SwarmConfig
from repro.core import comms, gossip
from repro.core.engine import SwarmEngine
from repro.core.session import SwarmSession
from repro.core.topology import ring_matrix
from repro.launch import hlo_stats
from repro.launch.mesh import make_two_level_swarm_mesh

mesh, axis = make_two_level_swarm_mesh(2, 2)
K, PER, N, WB = 2, 2, 4, 128
rng = np.random.default_rng(0)
Wp = jnp.asarray(ring_matrix(K, 0.7), jnp.float32)   # asymmetric pod mixing

# --- raw hierarchical schedules settle to their numpy oracles -------------
# D=700 is NOT a multiple of the per_pod*wire_block delegate grid (256), so
# the pad/unpad path is exercised.
Dp = 700
w0p = jnp.asarray(rng.normal(0, 1, (N, Dp)), jnp.float32)
xp = {"w": w0p}
fishp = {"w": jnp.asarray(np.abs(rng.normal(1, 0.3, (N, Dp))), jnp.float32)}
wvec = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)

def pod_mix(vals):  # [K, D] pod aggregates -> [N, D] per-node outputs
    out = np.asarray(Wp) @ np.asarray(vals)
    return np.repeat(out, PER, axis=0)

wnp, th = np.asarray(wvec), np.asarray(w0p)
favg = np.stack([(wnp[:2] @ th[:2]) / wnp[:2].sum(),
                 (wnp[2:] @ th[2:]) / wnp[2:].sum()])
fedavg_want = pod_mix(favg)
fnp, eps = np.asarray(fishp["w"]), 1e-8
num = np.stack([((fnp + eps) * th)[:2].sum(0), ((fnp + eps) * th)[2:].sum(0)])
den = np.stack([(fnp + eps)[:2].sum(0), (fnp + eps)[2:].sum(0)])
fisher_want = pod_mix(num) / np.maximum(pod_mix(den), 1e-30)

cases = [
    ("hier_fedavg_ring_q8", fedavg_want,
     lambda w: gossip.hier_fedavg_ring_q8(xp, wvec, Wp, w, mesh, axis,
                                          wire_block=WB)),
    ("hier_fisher_ring_q8", fisher_want,
     lambda w: gossip.hier_fisher_ring_q8(xp, fishp, Wp, w, mesh, axis,
                                          wire_block=WB)),
]
for sched, want, fn in cases:
    wire = gossip.init_mesh_wire(sched, xp, n_shards=N, wire_block=WB,
                                 mesh_shape=(K, PER))
    assert set(wire) == {"ref", "left"}, sched   # fwd-only ring at K=2
    jfn = jax.jit(fn)
    for _ in range(6):
        merged, wire = jfn(wire)
    err = np.abs(np.asarray(merged["w"]) - want).max()
    assert err < 1e-5, (sched, err)
print("OK hier_parity")

# --- cost model picks hierarchical iff cross-pod cost dominates -----------
for merge, flat_want, hier_want in [
        ("fedavg", "ring_ppermute", "hier_fedavg_ring_q8"),
        ("fisher", "ring_topo_ppermute", "hier_fisher_ring_q8")]:
    for cross, want in [(1.0, flat_want), (5.0, flat_want),
                        (6.0, hier_want), (10.0, hier_want)]:
        cfg = SwarmConfig(n_nodes=N, topology="ring", merge=merge,
                          lora_only=False, wire_dtype="int8", wire_block=WB,
                          cross_pod_cost=cross)
        eng = SwarmEngine(cfg, None, None, data_sizes=[1.0] * N,
                          backend="gossip", mesh=mesh, axis=axis)
        assert eng.sync_schedule.name == want, (merge, cross,
                                                eng.sync_schedule.name)
# a 1-D mesh never offers the hierarchical schedules, however costly DCN is
flat_mesh = jax.make_mesh((4,), ("node",), devices=jax.devices()[:4])
cfg1d = SwarmConfig(n_nodes=N, topology="ring", merge="fedavg",
                    lora_only=False, wire_dtype="int8", wire_block=WB,
                    cross_pod_cost=100.0)
eng1d = SwarmEngine(cfg1d, None, None, data_sizes=[1.0] * N,
                    backend="gossip", mesh=flat_mesh, axis="node")
assert eng1d.sync_schedule.name == "ring_ppermute", eng1d.sync_schedule.name
print("OK pick_directions")

# --- session-level settled commit == numpy oracle on the 2x2 mesh ---------
def id_step(p, o, b, s):
    return p, o, {"loss": 0.0 * jnp.sum(p["w"])}

def eval_fn(p, v):
    return 1.0 - 0.0 * jnp.sum(p["w"])

D = 1024                      # multiple of per_pod*WB: exact HLO byte math
w0 = jnp.asarray(rng.normal(0, 1, (N, D)), jnp.float32)
batches = jnp.zeros((1, N, 1))
val = jnp.zeros((N, 1))
sizes = [1.0, 2.0, 3.0, 4.0]

def settled_commit(merge, want_sched):
    mk = lambda thr: SwarmConfig(
        n_nodes=N, sync_every=1, topology="ring", merge=merge,
        lora_only=False, val_threshold=thr, self_weight=0.7,
        wire_dtype="int8", wire_block=WB, cross_pod_cost=10.0)
    kw = dict(params={"w": w0.copy()}, stacked=True, data_sizes=sizes,
              backend="gossip", mesh=mesh, axis=axis)
    sa = SwarmSession(mk(1.5), id_step, eval_fn, **kw)
    assert sa.sync_schedule.name == want_sched, sa.sync_schedule.name
    for _ in range(6):
        out = sa.round(batches, val)
        assert not np.asarray(out["gates"]).any()
    sb = SwarmSession(mk(0.0), id_step, eval_fn, **kw)
    sb.load_state(sa.state)
    out = sb.round(batches, val)
    assert np.asarray(out["gates"]).all()
    return sb, np.asarray(sb.state.params["w"])

snp, thd = np.asarray(sizes), np.asarray(w0)
pavg = np.stack([(snp[:2] @ thd[:2]) / snp[:2].sum(),
                 (snp[2:] @ thd[2:]) / snp[2:].sum()])
sess_a, got = settled_commit("fedavg", "hier_fedavg_ring_q8")
want = np.repeat(np.asarray(ring_matrix(K, 0.7)) @ pavg, PER, axis=0)
err = np.abs(got - want).max()
assert err < 1e-5, err
# zero strategy stats -> eps floor -> uniform pod means, same Wp mix
_, gotf = settled_commit("fisher", "hier_fisher_ring_q8")
pmean = np.stack([thd[:2].mean(0), thd[2:].mean(0)])
wantf = np.repeat(np.asarray(ring_matrix(K, 0.7)) @ pmean, PER, axis=0)
errf = np.abs(gotf - wantf).max()
assert errf < 1e-5, errf
# the session surfaces the per-link-class prediction
plb = sess_a.predicted_link_bytes
assert plb["intra"] == 8 * D and plb["cross"] == 0.5 * D * (1 + 4 / WB), plb
print("OK session_parity")

# --- HLO bytes per link class: cross <= 0.35x flat ring q8 ----------------
pod_of = hlo_stats.pod_device_map(K, PER)
x = {"w": w0}
wv4 = jnp.full((N,), 0.25, jnp.float32)
hwire = gossip.init_mesh_wire("hier_fedavg_ring_q8", x, n_shards=N,
                              wire_block=WB, mesh_shape=(K, PER))
hfn = jax.jit(lambda t, w: gossip.hier_fedavg_ring_q8(
    t, wv4, Wp, w, mesh, axis, wire_block=WB))
hb = hlo_stats.collective_bytes_by_link(
    hfn.lower(x, hwire).compile().as_text(), pod_of)
W4 = jnp.asarray(ring_matrix(N, 0.5), jnp.float32)
fwire = gossip.init_mesh_wire("ring_ppermute", x, n_shards=N, wire_block=WB)
ffn = jax.jit(lambda t, w: gossip.ring_rows_gossip_q8(t, W4, w, mesh, axis,
                                                      wire_block=WB))
fb = hlo_stats.collective_bytes_by_link(
    ffn.lower(x, fwire).compile().as_text(), pod_of)
# flat ring ppermutes mix intra-pod and pod-spanning pairs in ONE
# instruction -> the whole payload prices as cross (DCN-bound)
assert fb["intra"] == 0 and fb["cross"] == 2 * D * 1 + 2 * (D // WB) * 4, fb
# hier: cross is exactly the predicted delegate-chunk q+scale bytes ...
assert hb["cross"] == D // 2 * 1 + (D // 2) // WB * 4, hb
# ... intra is the psum + all_gather payload (within one small all-reduce
# of the predicted 2*D f32: the scalar pod-mass reduction)
pred = comms.pick_schedule(
    SwarmConfig(n_nodes=N, topology="ring", merge="fedavg", lora_only=False,
                wire_dtype="int8", wire_block=WB, cross_pod_cost=10.0),
    mesh_shape=(K, PER)).bytes_by_link_class(D)
assert hb["cross"] == pred["cross"], (hb, pred)
assert abs(hb["intra"] - pred["intra"]) / pred["intra"] < 0.01, (hb, pred)
ratio = hb["cross"] / fb["cross"]
assert ratio <= 0.35, (hb, fb)
print(f"OK hlo_link_bytes ratio={ratio:.3f}")

# --- checkpoint: per-pod EF residual round-trips bit-identically ----------
def decay_step(p, o, b, s):
    return {"w": p["w"] * 0.999}, o, {"loss": 0.0 * jnp.sum(p["w"])}

ccfg = SwarmConfig(n_nodes=N, sync_every=1, topology="ring", merge="fisher",
                   lora_only=False, val_threshold=0.0, wire_dtype="int8",
                   wire_block=WB, cross_pod_cost=10.0)
ckw = dict(stacked=True, backend="gossip", mesh=mesh, axis=axis,
           data_sizes=[1.0] * N)
ref_sess = SwarmSession(ccfg, decay_step, eval_fn, params={"w": w0.copy()},
                        **ckw)
assert ref_sess.sync_schedule.name == "hier_fisher_ring_q8"
for _ in range(4):
    ref_sess.round(batches, val)
s1 = SwarmSession(ccfg, decay_step, eval_fn, params={"w": w0.copy()}, **ckw)
for _ in range(2):
    s1.round(batches, val)
path = os.path.join(tempfile.mkdtemp(), "hier_wire.msgpack")
s1.save(path)
s2 = SwarmSession(ccfg, decay_step, eval_fn, params={"w": w0.copy()}, **ckw)
s2.round(batches, val)   # state leaves now carry the 2-D NamedSharding
s2.load(path)
# restored leaves are re-placed onto the 2-D NamedSharding templates
for leaf in [s2.state.params["w"], s2.state.wire["ref"]["num"]["w"]]:
    sh = leaf.sharding
    assert isinstance(sh, jax.sharding.NamedSharding), sh
    assert set(sh.mesh.axis_names) == {"pod", "node"}, sh
# ... bit-identically
for a, b in zip(jax.tree.leaves(s2.state.wire),
                jax.tree.leaves(s1.state.wire)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for _ in range(2):
    s2.round(batches, val)
np.testing.assert_array_equal(np.asarray(s2.state.params["w"]),
                              np.asarray(ref_sess.state.params["w"]))
for a, b in zip(jax.tree.leaves(s2.state.wire),
                jax.tree.leaves(ref_sess.state.wire)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK hier_checkpoint")

# --- flat schedules still run over the joint ("pod", "node") axis ---------
want_flat = np.asarray(W4) @ np.asarray(w0)
wire = gossip.init_mesh_wire("ring_ppermute", x, n_shards=N, wire_block=WB)
jfn = jax.jit(lambda w: gossip.ring_rows_gossip_q8(x, W4, w, mesh, axis,
                                                   wire_block=WB))
for _ in range(6):
    merged, wire = jfn(wire)
assert np.abs(np.asarray(merged["w"]) - want_flat).max() < 1e-5
print("OK flat_on_two_level")
"""


@pytest.fixture(scope="module")
def spmd_out():
    return _run(_CHECKS)  # module scope: the subprocess runs once


def test_hier_schedules_match_numpy_oracles(spmd_out):
    """Raw hierarchical fedavg/fisher settle to the pod-aggregate + Wp-mix
    numpy oracle <= 1e-5 on a payload that exercises the delegate-grid
    padding path; the K=2 wire is forward-only ({"ref", "left"})."""
    assert "OK hier_parity" in spmd_out


def test_pick_schedule_cross_cost_both_directions(spmd_out):
    """Hierarchical is picked iff cross_pod_cost dominates (flat at <= 5x,
    hierarchical at >= 6x for wire_block=128), for both merges, end-to-end
    through SwarmEngine; a 1-D mesh never offers hierarchical."""
    assert "OK pick_directions" in spmd_out


def test_session_committed_parity_on_two_level_mesh(spmd_out):
    """backend="gossip" on the 2x2 mesh with dominant cross-pod cost: the
    gated session commits params <= 1e-5 of the numpy oracle after EF
    settling, and surfaces the per-link-class byte prediction — the
    headline acceptance check."""
    assert "OK session_parity" in spmd_out


def test_hier_cross_pod_bytes_shrink(spmd_out):
    """HLO-measured cross-pod bytes of hierarchical int8 fedavg <= 0.35x
    flat ring-q8 (flat pod-spanning ppermutes price entirely as cross), and
    the measured intra/cross split matches SyncSchedule.bytes_by_link_class."""
    assert "OK hlo_link_bytes" in spmd_out


def test_hier_wire_checkpoint_and_resharding(spmd_out):
    """session.save/restore round-trips the per-pod EF residual pytree
    bit-identically, re-places restored leaves onto the 2-D NamedSharding
    templates, and resumed training matches never-stopping (ISSUE 7
    satellite)."""
    assert "OK hier_checkpoint" in spmd_out


def test_flat_schedules_run_over_axis_tuple(spmd_out):
    """The flat ring q8 schedule is unchanged on the two-level mesh: the
    joint ("pod", "node") axis tuple behaves as one 4-way gossip axis."""
    assert "OK flat_on_two_level" in spmd_out
