"""SwarmSession: the backend-agnostic API over one SwarmState pytree.

Pins the redesign's acceptance criteria:
  * session drivers == the legacy SwarmEngine/SwarmLearner paths,
  * join→leave→rejoin mid-run reuses the compiled round (ZERO retraces,
    asserted via a trace counter in the train step's python body),
  * ring/dynamic fisher & gradmatch merges match the numpy host oracle
    (topology-restricted per-row ratio) to fused-kernel tolerance,
  * checkpoint/resume round-trips the FULL state (params, opt state,
    strategy stats, membership, rng, counters) — continuing from a restore
    is bit-identical to never having stopped,
  * checkpoint keys no longer collide for pytrees whose paths used to
    serialize identically (dict key "0" vs sequence index 0, "a/b" vs a→b),
  * the gate_metric knob selects traced macro-F1 / sensitivity / accuracy
    matching their host numpy oracles,
  * the opt-in 4-tuple train step feeds exact squared gradients into the
    fisher accumulators (true-Fisher hook).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.core import merge_impl as merge_lib
from repro.core import topology as topo
from repro.core.engine import SwarmEngine, active_weights
from repro.core.session import SwarmSession, SwarmState

N = 4


def _toy_fns():
    def train_step(params, opt_state, batch, step):
        g = params["x"] - batch
        return {"x": params["x"] - 0.1 * g}, opt_state, {"loss": jnp.sum(g * g)}

    def eval_fn(params, val):
        return 1.0 - 0.0 * jnp.sum(params["x"])  # always accept, in-graph

    return train_step, eval_fn


def _cfg(**kw):
    kw.setdefault("n_nodes", N)
    kw.setdefault("sync_every", 2)
    kw.setdefault("merge", "fedavg")
    kw.setdefault("topology", "full")
    kw.setdefault("lora_only", False)
    kw.setdefault("val_threshold", 0.0)
    return SwarmConfig(**kw)


def _targets():
    return jnp.asarray([np.full((4,), t, np.float32) for t in range(N)])


def _session(cfg, train_step=None, eval_fn=None, **kw):
    ts, ef = _toy_fns()
    kw.setdefault("params", {"x": jnp.zeros((4,))})
    kw.setdefault("data_sizes", [100 * (i + 1) for i in range(N)])
    return SwarmSession(cfg, train_step or ts, eval_fn or ef, **kw)


# ---------------------------------------------------------------------------
# session == legacy engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("merge", ["fedavg", "fisher"])
def test_session_matches_legacy_engine(merge):
    """run_rounds through the SwarmState API == the legacy tuple API."""
    train_step, eval_fn = _toy_fns()
    cfg = _cfg(merge=merge)
    batches = jnp.broadcast_to(_targets(), (3, 2, N, 4))
    sizes = [100 * (i + 1) for i in range(N)]

    eng = SwarmEngine(cfg, train_step, eval_fn, data_sizes=sizes)
    want, _, _, legacy_logs = eng.run_rounds(
        {"x": jnp.zeros((N, 4))}, None, batches, jnp.zeros((N, 1)), None, 0)

    sess = _session(cfg)
    logs = sess.run_rounds(batches, jnp.zeros((N, 1)))
    np.testing.assert_allclose(np.asarray(sess.state.params["x"]),
                               np.asarray(want["x"]), rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(logs["gates"]),
                                  np.asarray(legacy_logs["gates"]))
    assert int(sess.state.round) == 3 and int(sess.state.step) == 6


def test_session_overlap_mode_runs():
    """The stale-by-one double-buffered schedule works through the session."""
    cfg = _cfg(sync_every=1, overlap_sync=True)
    sess = _session(cfg)
    batches = jnp.broadcast_to(_targets(), (6, 1, N, 4))
    logs = sess.run_rounds(batches, jnp.zeros((N, 1)))
    assert np.asarray(logs["gates"]).all()
    assert np.isfinite(np.asarray(sess.state.params["x"])).all()


# ---------------------------------------------------------------------------
# dynamic membership: zero retraces (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_membership_changes_trigger_zero_retraces():
    """join → leave → rejoin between rounds AND between run_rounds calls
    compiles the round exactly once: the traced-topology mixing matrix makes
    membership pure runtime data."""
    base_step, eval_fn = _toy_fns()
    traces = []

    def counting_step(p, o, b, s):
        traces.append(1)  # python body executes only while tracing
        return base_step(p, o, b, s)

    sess = _session(_cfg(topology="dynamic"), counting_step, eval_fn)
    batches = jnp.broadcast_to(_targets(), (2, N, 4))
    rbatches = jnp.broadcast_to(_targets(), (2, 2, N, 4))
    val = jnp.zeros((N, 1))

    sess.round(batches, val)
    round_traces = len(traces)
    assert round_traces >= 1

    sess.leave(2)                       # leave
    out = sess.round(batches, val)
    assert not np.asarray(out["gates"])[2]
    sess.leave(1)                       # second leave, different mask
    sess.round(batches, val)
    sess.join(1)
    sess.join(2)                        # rejoin
    out = sess.round(batches, val)
    assert np.asarray(out["gates"]).all()
    assert len(traces) == round_traces, "membership change retraced round()"

    sess.run_rounds(rbatches, val)      # separate driver: one new trace
    rounds_traces = len(traces)
    sess.leave(3)                       # ... reused across membership changes
    logs = sess.run_rounds(rbatches, val)
    assert not np.asarray(logs["gates"])[:, 3].any()
    sess.join(3)
    sess.run_rounds(rbatches, val)
    assert len(traces) == rounds_traces, "membership change retraced run_rounds()"


def test_left_node_trains_locally_and_rejoins():
    """A departed node keeps training on its own shard but is excluded from
    every merge (no sends, no receives); on rejoin it merges again."""
    sess = _session(_cfg(sync_every=1, topology="dynamic"))
    batches = jnp.broadcast_to(_targets(), (1, N, 4))
    val = jnp.zeros((N, 1))
    sess.round(batches, val)
    sess.leave(2)
    x2 = float(sess.state.params["x"][2, 0])
    for _ in range(2):
        out = sess.round(batches, val)
        assert not np.asarray(out["gates"])[2]
        # pure local descent toward target 2.0, untouched by any merge
        x2 = x2 + 0.1 * (2.0 - x2)
        np.testing.assert_allclose(np.asarray(sess.state.params["x"][2]),
                                   np.full(4, x2, np.float32), rtol=1e-6)
    sess.join(2)
    out = sess.round(batches, val)
    assert np.asarray(out["gates"])[2]
    # back in the swarm: node 2's params snap to the consensus merge again
    np.testing.assert_allclose(np.asarray(sess.state.params["x"][2]),
                               np.asarray(sess.state.params["x"][0]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# topology-restricted weighted merges (ring/dynamic fisher & gradmatch)
# ---------------------------------------------------------------------------

def _topo_oracle(x, mass, rows, eps):
    """numpy ground truth: out[i] = Σ_j rows[ij](m_j+eps)x_j / Σ_j rows[ij](m_j+eps)."""
    ff = mass + eps
    num = rows @ (ff * x)
    den = rows @ ff
    return num / np.maximum(den, 1e-30)


@pytest.mark.parametrize("method", ["fisher", "gradmatch"])
@pytest.mark.parametrize("topology", ["ring", "dynamic"])
def test_topology_restricted_weighted_merge_matches_oracle(method, topology):
    """Engine sync for ring/dynamic fisher/gradmatch == the per-row
    neighbour-restricted numpy oracle, to fused-kernel tolerance; the
    departed node is exactly excluded (not eps-suppressed)."""
    rng = np.random.default_rng(0)
    params = {"x": jnp.asarray(rng.normal(0, 1, (N, 6)), jnp.float32)}
    stats = {"x": jnp.asarray(np.abs(rng.normal(1, 0.5, (N, 6))), jnp.float32)}
    _, eval_fn = _toy_fns()
    sizes = [100 * (i + 1) for i in range(N)]
    cfg = _cfg(merge=method, topology=topology)
    eng = SwarmEngine(cfg, None, eval_fn, data_sizes=sizes)
    active = jnp.asarray([True, True, False, True])
    committed, log = jax.jit(eng.sync)(params, jnp.zeros((N, 1)), active,
                                       stats)
    assert not np.asarray(log["gates"])[2]

    a = np.array([True, True, False, True])
    W = topo.dynamic_matrix(topo.build_matrix(topology, N), a)
    w = active_weights(sizes, a)
    strategy = merge_lib.get_strategy(cfg)
    mass = np.asarray(strategy.finalize_mass(stats, jnp.asarray(a))["x"])
    rows = np.asarray(strategy.topo_rows(jnp.asarray(W, jnp.float32),
                                         jnp.asarray(w, jnp.float32)))
    want = _topo_oracle(np.asarray(params["x"]), mass, rows, strategy.eps)
    got = np.asarray(committed["x"])
    np.testing.assert_array_equal(got[2], np.asarray(params["x"])[2])
    for i in (0, 1, 3):
        np.testing.assert_allclose(got[i], want[i], rtol=2e-4, atol=2e-5)


def test_ring_fisher_only_uses_graph_neighbours():
    """A node two hops away contributes nothing to a ring fisher merge."""
    _, eval_fn = _toy_fns()
    params = {"x": jnp.asarray([[0.0], [0.0], [100.0], [0.0]], jnp.float32)}
    stats = {"x": jnp.ones((N, 1), jnp.float32)}
    eng = SwarmEngine(_cfg(merge="fisher", topology="ring"), None, eval_fn,
                      data_sizes=[1] * N)
    committed, _ = jax.jit(eng.sync)(params, jnp.zeros((N, 1)), None, stats)
    # node 0's ring neighbours are 1 and 3 — node 2's huge params must not
    # leak in (a global merge would put ~25 here)
    assert abs(float(committed["x"][0, 0])) < 1e-3


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("merge", ["fedavg", "fisher"])
def test_checkpoint_resume_is_bit_identical(tmp_path, merge):
    """save → restore → continue == never stopping (params, stats, rng,
    counters, membership all round-trip through checkpointing.io)."""
    cfg = _cfg(merge=merge, topology="dynamic")
    batches = jnp.broadcast_to(_targets(), (2, N, 4))
    val = jnp.zeros((N, 1))
    path = str(tmp_path / "sess.msgpack")

    ref = _session(cfg)
    ref.leave(3)
    for _ in range(4):
        ref.round(batches, val)

    sess = _session(cfg)
    sess.leave(3)
    for _ in range(2):
        sess.round(batches, val)
    sess.save(path)

    resumed = SwarmSession.restore(path, cfg, *_toy_fns(),
                                   params={"x": jnp.zeros((4,))},
                                   data_sizes=[100 * (i + 1)
                                               for i in range(N)])
    assert int(resumed.state.round) == 2 and int(resumed.state.step) == 4
    np.testing.assert_array_equal(np.asarray(resumed.state.active),
                                  [True, True, True, False])
    for _ in range(2):
        resumed.round(batches, val)
    np.testing.assert_array_equal(np.asarray(resumed.state.params["x"]),
                                  np.asarray(ref.state.params["x"]))
    np.testing.assert_array_equal(np.asarray(resumed.state.rng),
                                  np.asarray(ref.state.rng))
    if merge == "fisher":
        np.testing.assert_array_equal(np.asarray(resumed.state.stats["x"]),
                                      np.asarray(ref.state.stats["x"]))


def test_restore_rejects_mismatched_cfg(tmp_path):
    path = str(tmp_path / "sess.msgpack")
    _session(_cfg()).save(path)
    with pytest.raises(ValueError, match="cfg mismatch"):
        _session(_cfg(merge="fisher")).load(path)


def test_checkpoint_key_collisions_fixed(tmp_path):
    """Pytree paths that used to serialize identically (dict key "0" vs
    sequence index 0; dict key "a/b" vs nested a→b) now round-trip."""
    from repro.checkpointing import load_pytree, save_pytree
    tree = {
        "d": {"0": jnp.asarray([1.0]), "1": jnp.asarray([2.0])},
        "l": [jnp.asarray([3.0]), jnp.asarray([4.0])],
        "a/b": jnp.asarray([5.0]),
        "a": {"b": jnp.asarray([6.0])},
    }
    path = str(tmp_path / "tree.msgpack")
    save_pytree(path, tree)
    out = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for want, got in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_checkpoint_legacy_keys_still_load(tmp_path):
    """Old checkpoints ("/"-joined key format) remain readable."""
    import msgpack
    tree = {"a": {"b": jnp.asarray([1.5, 2.5])}}
    path = str(tmp_path / "legacy.msgpack")
    arr = np.asarray(tree["a"]["b"])
    payload = {"leaves": {"a/b": {"dtype": str(arr.dtype),
                                  "shape": list(arr.shape),
                                  "data": arr.tobytes()}},
               "metadata": {}}
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    from repro.checkpointing import load_pytree
    out = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["a"]["b"]), arr)


# ---------------------------------------------------------------------------
# host backend
# ---------------------------------------------------------------------------

def test_host_backend_matches_engine_backend():
    """The same toy schedule through backend="host" (SwarmLearner loop)
    and the compiled engine backend lands on the same params — including
    after a leave(): on BOTH backends a departed node that still receives
    batches keeps training locally and is only excluded from merges."""
    cfg = _cfg(topology="dynamic")
    targets = list(_targets())
    host = _session(cfg, backend="host")
    eng = _session(cfg)
    ebatches = jnp.broadcast_to(_targets(), (2, N, 4))
    val = jnp.zeros((N, 1))
    for sess in (host, eng):
        sess.round([targets, targets] if sess is host else ebatches,
                   [1] * N if sess is host else val)
        sess.leave(3)
        sess.round([targets, targets] if sess is host else ebatches,
                   [1] * N if sess is host else val)
        sess.join(3)
        sess.round([targets, targets] if sess is host else ebatches,
                   [1] * N if sess is host else val)
    np.testing.assert_allclose(
        np.asarray(host.state.params["x"]),
        np.asarray(eng.state.params["x"]), rtol=1e-5, atol=1e-6)
    assert int(host.state.round) == int(eng.state.round) == 3


def test_host_backend_checkpoint_roundtrip(tmp_path):
    cfg = _cfg(merge="fisher")
    sess = _session(cfg, backend="host")
    targets = list(_targets())
    sess.round([targets, targets], [1] * N)
    sess.leave(1)
    path = str(tmp_path / "host.msgpack")
    sess.save(path)
    restored = SwarmSession.restore(
        path, cfg, *_toy_fns(), backend="host",
        params={"x": jnp.zeros((4,))},
        data_sizes=[100 * (i + 1) for i in range(N)])
    np.testing.assert_array_equal(restored.active, [True, False, True, True])
    np.testing.assert_array_equal(
        np.asarray(restored.state.params["x"]),
        np.asarray(sess.state.params["x"]))
    np.testing.assert_array_equal(
        np.asarray(restored.state.stats["x"]),
        np.asarray(sess.state.stats["x"]))


# ---------------------------------------------------------------------------
# gate metrics beyond AUC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(3))
def test_traced_gate_metrics_match_host_oracles(seed):
    """Traced macro-F1 / sensitivity / accuracy == the numpy confusion-stats
    oracles, including padding masks and absent classes."""
    from repro.metrics import (accuracy, accuracy_traced, confusion_stats,
                               macro_f1_traced, sensitivity_traced)
    rng = np.random.default_rng(seed)
    v, pad = 41, 7
    probs = rng.random((v, 3)).astype(np.float32)
    labels = rng.integers(0, 3 if seed % 2 else 2, v)  # even seeds: no class 2
    preds = probs.argmax(-1)
    want = confusion_stats(preds, labels, 3)
    probs_p = np.concatenate([probs, np.zeros((pad, 3), np.float32)])
    labels_p = np.concatenate([labels, np.zeros(pad, np.int64)])
    mask = np.arange(v + pad) < v
    args = (jnp.asarray(probs_p), jnp.asarray(labels_p), jnp.asarray(mask))
    assert float(macro_f1_traced(*args)) == pytest.approx(want["f1"], abs=1e-6)
    assert float(sensitivity_traced(*args)) == pytest.approx(
        want["sensitivity"], abs=1e-6)
    assert float(accuracy_traced(*args)) == pytest.approx(
        accuracy(preds, labels), abs=1e-6)


def test_gate_metric_knob_selects_traced_metric():
    from repro.metrics import (accuracy_traced, gate_metric_fn,
                               macro_auc_traced, macro_f1_traced,
                               sensitivity_traced)
    assert gate_metric_fn("auc") is macro_auc_traced
    assert gate_metric_fn("f1") is macro_f1_traced
    assert gate_metric_fn("sensitivity") is sensitivity_traced
    assert gate_metric_fn("accuracy") is accuracy_traced
    with pytest.raises(ValueError, match="unknown gate_metric"):
        gate_metric_fn("bleu")


def test_histo_loop_with_f1_gate_runs():
    """The gate_metric knob drives the histo swarm loop end-to-end."""
    from repro.data import make_histo_dataset, paper_splits, shard_to_nodes
    from repro.experiments.histo import (HistoExperimentConfig,
                                         _make_model_fns, _train_loop)
    ecfg = HistoExperimentConfig(
        n_train=120, n_test=24, steps=4, image_size=16, batch_size=8,
        noise=0.6, growth=4, stem=8, feat_dim=32, hidden=16, n_blocks=1,
        layers_per_block=2, seed=5,
        swarm=SwarmConfig(n_nodes=4, sync_every=2, topology="full",
                          merge="fedavg", lora_only=False, val_threshold=0.8,
                          gate_metric="f1"))
    images, labels = make_histo_dataset(ecfg.n_train, size=ecfg.image_size,
                                        noise=ecfg.noise, seed=ecfg.seed)
    shards = shard_to_nodes(images, labels,
                            paper_splits(ecfg.n_train, ecfg.fractions),
                            seed=ecfg.seed)
    train_step, _, _ = _make_model_fns(ecfg)
    params, sync_log = _train_loop(ecfg, train_step, shards,
                                   swarm_cfg=ecfg.swarm)
    assert len(params) == 4 and sync_log
    for s in sync_log:
        assert all(0.0 <= m <= 1.0 for m in s["metric_local"])


# ---------------------------------------------------------------------------
# true-Fisher accumulation hook
# ---------------------------------------------------------------------------

def test_four_tuple_train_step_accumulates_exact_grad_squares():
    """A train step returning (params, opt, metrics, grads) feeds F ← γF + g²
    (exact squared gradients) instead of the Δθ² proxy — engine path."""
    decay = 0.5

    def grad_step(p, o, b, s):
        g = p["x"] - b
        return {"x": p["x"] - 0.1 * g}, o, {"loss": jnp.sum(g * g)}, {"x": g}

    _, eval_fn = _toy_fns()
    cfg = _cfg(merge="fisher", fisher_decay=decay)
    eng = SwarmEngine(cfg, grad_step, eval_fn, data_sizes=[1] * N)
    batches = jnp.broadcast_to(_targets(), (2, N, 4))
    p0 = {"x": jnp.zeros((N, 4))}
    _, _, stats, _ = jax.jit(eng.local_steps)(p0, None, batches, 0,
                                              eng.init_stats(p0))
    t = np.stack([np.full(4, float(i), np.float32) for i in range(N)])
    # g0 = -t; θ1 = 0.1t; g1 = -0.9t  ->  F = γ·g0² + g1²
    want = decay * t ** 2 + (0.9 * t) ** 2
    np.testing.assert_allclose(np.asarray(stats["x"]), want, rtol=1e-5)


def test_four_tuple_train_step_host_path():
    """Same hook through the SwarmLearner (host) loop."""
    from repro.core.swarm import NodeState, SwarmLearner
    decay = 0.5

    def grad_step(p, o, b, s):
        g = p["x"] - b
        return {"x": p["x"] - 0.1 * g}, o, {"loss": float(jnp.sum(g * g))}, \
            {"x": g}

    nodes = [NodeState(params={"x": jnp.zeros((4,))}, opt_state=None,
                       data_size=100) for _ in range(N)]
    sw = SwarmLearner(_cfg(merge="fisher", fisher_decay=decay),
                      grad_step, lambda p, v: 1.0, nodes)
    targets = list(_targets())
    for _ in range(2):
        sw.local_steps(targets)
    t = np.full(4, 3.0, np.float32)  # node 3's target
    want = decay * t ** 2 + (0.9 * t) ** 2
    np.testing.assert_allclose(np.asarray(nodes[3].fisher_stats["x"]), want,
                               rtol=1e-5)
