"""Gradient accumulation (microbatching) == full-batch step, exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config, smoke_variant
from repro.launch.train import init_train_state, make_train_step
from repro.models import build_model


@pytest.fixture(scope="module")
def full_step_state():
    """Model, init state, batch, and the full-batch reference step — shared
    so every accum setting compiles only its own microbatched step."""
    cfg = smoke_variant(get_config("minicpm-2b"))
    model = build_model(cfg)
    params, opt = init_train_state(model, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
    }
    full = jax.jit(make_train_step(model, TrainConfig(lr=1e-3, remat=False)))
    p1, _, m1 = full(params, opt, batch)
    return model, params, opt, batch, p1, m1


@pytest.mark.parametrize("accum", [2, 4])
def test_accum_matches_full_batch(accum, full_step_state):
    model, params, opt, batch, p1, m1 = full_step_state
    micro = jax.jit(make_train_step(
        model, TrainConfig(lr=1e-3, remat=False, accum_steps=accum)))
    p2, _, m2 = micro(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)
