"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_merge import fused_merge, fused_merge_tree
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused_merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,dtype", [
    (2, 512, jnp.float32), (4, 1000, jnp.float32), (8, 4096, jnp.float32),
    (4, 777, jnp.float32),          # non-multiple of block
    (4, 2048, jnp.bfloat16),
])
def test_fused_merge_sweep(n, d, dtype):
    x = jnp.asarray(RNG.normal(0, 1, (n, d))).astype(dtype)
    w = jnp.asarray(RNG.dirichlet(np.ones(n)), jnp.float32)
    for gate, self_idx in [(True, 0), (False, n - 1)]:
        got = fused_merge(x, w, self_idx, gate, block=512, interpret=True)
        want = ref.fused_merge_ref(x, w, self_idx, gate)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))


def test_fused_merge_tree():
    tree = {"a": jnp.ones((4, 3, 5)), "b": {"c": jnp.arange(4 * 7.).reshape(4, 7)},
            "skip": None}
    w = jnp.asarray([0.25] * 4)
    out = fused_merge_tree(tree, w, 1, True, block=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out["a"]), np.ones((3, 5)), rtol=1e-6)
    want = np.asarray(jnp.arange(4 * 7.).reshape(4, 7).mean(0))
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), want, rtol=1e-6)
    assert out["skip"] is None


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_merge_all_parity_with_merge_impl(dtype):
    """All-nodes fused commit == mix + where for every gate pattern, incl.
    None (lora_only) leaves and non-multiple-of-block D."""
    from repro.core.merge_impl import mix
    from repro.kernels.fused_merge import fused_merge_all

    rng = np.random.default_rng(0)
    n = 4
    shapes = [(6, 9), (300,), (3, 5, 2)]   # 54 / 300 / 30 elems vs block 128
    tree = {f"l{i}": jnp.asarray(rng.normal(0, 1, (n,) + s)).astype(dtype)
            for i, s in enumerate(shapes)}
    tree["skip"] = None
    W = jnp.asarray(rng.dirichlet(np.ones(n), size=n), jnp.float32)
    mixed = mix({k: v for k, v in tree.items() if v is not None}, W)
    for gates in ([True] * 4, [True, False, False, True], [False] * 4):
        g = np.asarray(gates)
        out = fused_merge_tree(tree, W, None, jnp.asarray(g),
                               block=128, interpret=True)
        assert out["skip"] is None
        for k, v in mixed.items():
            gb = g.reshape((n,) + (1,) * (v.ndim - 1))
            want = np.where(gb, np.asarray(v, np.float32),
                            np.asarray(tree[k], np.float32))
            np.testing.assert_allclose(np.asarray(out[k], np.float32), want,
                                       **_tol(dtype))


@pytest.mark.parametrize("seed", range(3))
def test_fused_merge_all_rows_match_per_node_oracle(seed):
    """out[i] of the all-nodes kernel == the per-node reference for row i."""
    from repro.kernels.fused_merge import fused_merge_all

    rng = np.random.default_rng(seed)
    n, d = 4, 777
    x = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    W = jnp.asarray(rng.dirichlet(np.ones(n), size=n), jnp.float32)
    gates = jnp.asarray(rng.random(n) > 0.5)
    out = fused_merge_all(x, W, gates, block=256, interpret=True)
    for i in range(n):
        want = ref.fused_merge_ref(x, W[i], i, bool(gates[i]))
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_auto_block_respects_vmem_budget_at_n64():
    """Regression (ISSUE 4 satellite): block sizing must account for N and
    the extra importance stream — the old fixed 16k block wanted
    (2·64+1)·16384·4 ≈ 8.5 MB of VMEM for a 64-node fisher commit."""
    from repro.kernels.fused_merge import (DEFAULT_BLOCK, VMEM_BUDGET,
                                           auto_block)
    for n, streams in [(4, 1), (4, 2), (64, 1), (64, 2), (256, 2)]:
        b = auto_block(n, streams)
        assert b % 128 == 0 and b >= 128
        assert (streams * n + 1) * b * 4 <= VMEM_BUDGET or b == 128
    assert auto_block(4, 1) == DEFAULT_BLOCK          # small swarms keep 16k
    assert (2 * 64 + 1) * auto_block(64, 2) * 4 <= VMEM_BUDGET


def test_fused_merge_all_n64_weighted_matches_oracle():
    """The importance-weighted commit at N=64 (auto-shrunk block) is still
    exact vs the unfused ratio."""
    from repro.kernels.fused_merge import fused_merge_all
    rng = np.random.default_rng(7)
    n, d = 64, 3000
    x = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    f = jnp.asarray(np.abs(rng.normal(1, 0.4, (n, d))), jnp.float32)
    W = jnp.asarray(rng.dirichlet(np.ones(n), size=n), jnp.float32)
    gates = jnp.asarray(rng.random(n) > 0.3)
    out = fused_merge_all(x, W, gates, f, interpret=True)
    num = np.asarray(W) @ (np.asarray(f) * np.asarray(x))
    den = np.asarray(W) @ np.asarray(f)
    want = np.where(np.asarray(gates)[:, None], num / den, np.asarray(x))
    np.testing.assert_allclose(np.asarray(out), want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("wire_dtype", ["int8", "bf16", "f32"])
@pytest.mark.parametrize("use_imp", [False, True])
def test_fused_quant_merge_matches_xla_oracle(wire_dtype, use_imp):
    """Quantize→merge→dequantize kernel == the `core.comms` XLA ground
    truth: same EF reference advance, same merged rows, exact local params
    on rejected rows."""
    from repro.core import comms
    from repro.kernels.fused_merge import fused_quant_merge_all
    rng = np.random.default_rng(11)
    n, d, wb = 4, 1500, 128
    x = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    ref = jnp.asarray(rng.normal(0, 0.5, (n, d)), jnp.float32)
    W = jnp.asarray(rng.dirichlet(np.ones(n), size=n), jnp.float32)
    gates = jnp.asarray([1, 0, 1, 1])
    imp = (jnp.asarray(np.abs(rng.normal(1, 0.4, (n, d))), jnp.float32)
           if use_imp else None)
    got, new_ref = fused_quant_merge_all(x, ref, W, gates, imp,
                                         wire_dtype=wire_dtype,
                                         wire_block=wb, interpret=True)
    eff = np.asarray(comms.wire_effective({"x": x}, {"x": ref},
                                          wire_dtype, wb)["x"])
    np.testing.assert_allclose(np.asarray(new_ref), eff, rtol=1e-6, atol=1e-6)
    if use_imp:
        merged = (np.asarray(W) @ (np.asarray(imp) * eff)
                  / np.maximum(np.asarray(W) @ np.asarray(imp), 1e-30))
    else:
        merged = np.asarray(W) @ eff
    g = np.asarray(gates).astype(bool)[:, None]
    want = np.where(g, merged, np.asarray(x))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)
    # rejected rows keep EXACT f32 locals — no wire round-trip on the keep
    np.testing.assert_array_equal(np.asarray(got)[1], np.asarray(x)[1])


def test_fused_quant_merge_tree_structural_tuples():
    """A params tree whose structure contains tuples must not be confused
    with the per-leaf (committed, reference) pairs."""
    from repro.core import comms
    from repro.kernels.fused_merge import fused_quant_merge_tree
    tree = {"layers": (jnp.full((4, 8), 1.0), jnp.full((4, 8), 2.0))}
    wire = comms.init_wire(tree)
    W = jnp.full((4, 4), 0.25, jnp.float32)
    committed, new_wire = fused_quant_merge_tree(
        tree, wire, W, jnp.ones(4, jnp.int32), wire_dtype="int8",
        wire_block=128, interpret=True)
    np.testing.assert_allclose(np.asarray(committed["layers"][0]), 1.0,
                               rtol=1e-2)
    np.testing.assert_allclose(np.asarray(committed["layers"][1]), 2.0,
                               rtol=1e-2)
    assert new_wire["layers"][1].shape == (4, 8)


def test_fused_quant_merge_tree_none_leaves():
    from repro.core import comms
    from repro.kernels.fused_merge import fused_quant_merge_tree
    rng = np.random.default_rng(12)
    tree = {"a": jnp.asarray(rng.normal(0, 1, (4, 6, 9)), jnp.float32),
            "skip": None}
    wire = comms.init_wire(tree)
    W = jnp.full((4, 4), 0.25, jnp.float32)
    committed, new_wire = fused_quant_merge_tree(
        tree, wire, W, jnp.ones(4, jnp.int32), wire_dtype="int8",
        wire_block=128, interpret=True)
    assert committed["skip"] is None and new_wire["skip"] is None
    assert committed["a"].shape == (4, 6, 9)
    assert new_wire["a"].shape == (4, 6, 9)


# property: merge with identity row == self row regardless of gate
@pytest.mark.parametrize("seed", range(5))
def test_fused_merge_identity_property(seed):
    rng = np.random.default_rng(seed)
    n, d = 4, 513
    x = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    i = seed % n
    w = jnp.zeros((n,), jnp.float32).at[i].set(1.0)
    got = fused_merge(x, w, i, True, block=256, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x[i]), rtol=1e-6)


# ---------------------------------------------------------------------------
# lora_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,r,dtype", [
    (128, 256, 128, 8, jnp.float32),
    (256, 512, 384, 16, jnp.float32),
    (128, 1024, 256, 64, jnp.float32),
    (256, 256, 256, 16, jnp.bfloat16),
])
def test_lora_matmul_sweep(m, k, n, r, dtype):
    x = jnp.asarray(RNG.normal(0, 1, (m, k))).astype(dtype)
    w = jnp.asarray(RNG.normal(0, 1, (k, n)) / np.sqrt(k)).astype(dtype)
    a = jnp.asarray(RNG.normal(0, 1, (k, r)) / np.sqrt(k)).astype(dtype)
    b = jnp.asarray(RNG.normal(0, 1, (r, n)) / np.sqrt(r)).astype(dtype)
    got = lora_matmul(x, w, a, b, 1.5, bm=128, bn=128, bk=128, interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, 1.5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_lora_matmul_zero_b_is_base_matmul():
    m = k = n = 128
    x = jnp.asarray(RNG.normal(0, 1, (m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(0, 1, (k, n)), jnp.float32)
    a = jnp.asarray(RNG.normal(0, 1, (k, 8)), jnp.float32)
    b = jnp.zeros((8, n), jnp.float32)
    got = lora_matmul(x, w, a, b, 99.0, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,s,d,causal,window", [
    (1, 4, 4, 128, 64, True, 0),       # MHA causal
    (2, 4, 2, 256, 64, True, 0),       # GQA
    (1, 8, 2, 256, 64, True, 64),      # GQA + sliding window
    (1, 4, 1, 128, 128, True, 0),      # MQA
    (2, 2, 2, 128, 64, False, 0),      # bidirectional (encoder)
])
def test_flash_attention_sweep(b, h, hkv, s, d, causal, window):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    b, h, s, d = 1, 2, 128, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, d))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (b, h, s, d))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, d))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("seed", range(3))
def test_flash_window_equals_masked_full(seed):
    """Sliding window == full attention when window >= seq (property)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64)), jnp.float32)
    a = flash_attention(q, k, v, window=128, bq=64, bk=64, interpret=True)
    b = flash_attention(q, k, v, window=0, bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 32, 16, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 4, 64, 128, 64),   # mamba2-370m-like state size
    (2, 96, 2, 32, 8, 32),      # seq not a multiple of 64
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(0.1, 0.05, (b, s, h))), jnp.float32)
    alog = jnp.asarray(np.log(np.linspace(1, 8, h)), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, n)), jnp.float32)
    yk, stk = ssd_scan(x, dt, alog, bm, cm, chunk=chunk, interpret=True)
    yr, str_ = ref.ssd_scan_ref(x, dt, alog, bm, cm)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(str_),
                               rtol=1e-4, atol=1e-4)


def test_ssd_matches_model_module():
    """Kernel == the model's chunked jnp implementation (same math, two paths)."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 1, 128, 2, 32, 16
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(0.1, 0.05, (b, s, h))), jnp.float32)
    alog = jnp.asarray(np.log(np.linspace(1, 4, h)), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 0.5, (b, s, 1, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(0, 0.5, (b, s, 1, n)), jnp.float32)
    ym, stm = ssd_chunked(x, dt, alog, bm, cm, chunk=32)
    bmh = jnp.repeat(bm, h, axis=2)
    cmh = jnp.repeat(cm, h, axis=2)
    yk, stk = ssd_scan(x, dt, alog, bmh, cmh, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ym), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(stm), rtol=1e-4, atol=1e-4)
