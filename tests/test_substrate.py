"""Substrate tests: optimizer, schedules, early stopping, data pipeline,
metrics, checkpointing, LoRA."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.lora import (inject_lora, merge_lora_into_base, payload_bytes,
                             split_adapters, combine)
from repro.checkpointing import load_pytree, save_pytree, load_metadata
from repro.data import (batches, dirichlet_shards, make_histo_dataset,
                        make_lm_stream, paper_splits, shard_to_nodes)
from repro.metrics import (binary_auc, classify_report, davies_bouldin,
                           macro_auc)
from repro.models import build_model
from repro.optim import (EarlyStopper, adamw_init, adamw_update,
                         clip_by_global_norm, global_norm, make_schedule)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    tc = TrainConfig(lr=0.1, weight_decay=0.0, grad_clip=0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}
        params, state = adamw_update(params, grads, state, tc, 0.1)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adamw_weight_decay_shrinks_params():
    tc = TrainConfig(lr=0.1, weight_decay=0.5, grad_clip=0)
    params = {"x": jnp.asarray([10.0])}
    state = adamw_init(params)
    params, _ = adamw_update(params, {"x": jnp.zeros(1)}, state, tc, 0.1)
    assert float(params["x"][0]) < 10.0


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)


@pytest.mark.parametrize("kind", ["cosine", "wsd", "const"])
def test_schedules_shape(kind):
    tc = TrainConfig(lr=1e-3, warmup_steps=10, max_steps=100, schedule=kind)
    sched = make_schedule(tc)
    lrs = np.asarray([float(sched(s)) for s in range(100)])
    if kind != "const":
        assert lrs[0] < lrs[9]                 # warmup rises
    assert lrs.max() <= 1e-3 + 1e-9
    if kind == "cosine":
        assert lrs[-1] < lrs[50] < lrs[11]     # monotone decay after warmup
    if kind == "wsd":
        stable = lrs[15:85]
        assert np.allclose(stable, 1e-3)       # plateau
        assert lrs[-1] < 1e-3 * 0.95           # final decay kicks in


def test_early_stopper_patience():
    es = EarlyStopper(patience=3, mode="max")
    assert not es.update(0.5)
    assert not es.update(0.6)
    for m in (0.55, 0.58, 0.59):
        stopped = es.update(m)
    assert stopped and es.best == 0.6


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_paper_splits():
    assert paper_splits(10_000) == [1000, 3000, 3000, 3000]


def test_shard_to_nodes_disjoint_and_sized():
    x, y = make_histo_dataset(500, size=8, seed=0)
    shards = shard_to_nodes(x, y, paper_splits(500), seed=1)
    assert [len(s[1]) for s in shards] == [50, 150, 150, 150]
    # disjoint: total class counts match
    all_y = np.concatenate([s[1] for s in shards])
    assert len(all_y) == 500


def test_class_bias_sharding_skews_distribution():
    x, y = make_histo_dataset(900, size=8, seed=0)
    shards = shard_to_nodes(x, y, [300, 300, 300], seed=1,
                            class_bias=[[10, 1, 1], [1, 10, 1], [1, 1, 10]])
    for i, (_, sy) in enumerate(shards):
        counts = np.bincount(sy, minlength=3)
        assert counts.argmax() == i


def test_dirichlet_shards_partition():
    x, y = make_histo_dataset(400, size=8, seed=0)
    shards = dirichlet_shards(x, y, 4, alpha=0.5, seed=0)
    assert sum(len(s[1]) for s in shards) == 400


def test_batches_and_augment_shapes():
    x, y = make_histo_dataset(100, size=16, seed=0)
    rng = np.random.default_rng(0)
    bs = list(batches(x, y, 16, rng))
    assert len(bs) == 6
    assert bs[0][0].shape == (16, 16, 16, 3)


def test_lm_stream_labels_shifted():
    d = make_lm_stream(4, 32, 100, seed=0)
    np.testing.assert_array_equal(d["tokens"][:, 1:], d["labels"][:, :-1])


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_auc_known_values():
    assert binary_auc(np.array([0.1, 0.9]), np.array([0, 1])) == 1.0
    assert binary_auc(np.array([0.9, 0.1]), np.array([0, 1])) == 0.0
    assert binary_auc(np.array([0.5, 0.5]), np.array([0, 1])) == 0.5


def test_macro_auc_perfect():
    probs = np.eye(3)[np.array([0, 1, 2, 0, 1, 2])] * 0.8 + 0.1
    labels = np.array([0, 1, 2, 0, 1, 2])
    assert macro_auc(probs, labels) == 1.0


def test_davies_bouldin_orders_cluster_quality():
    rng = np.random.default_rng(0)
    labels = np.repeat([0, 1, 2], 50)
    centers = np.eye(3) * 10
    tight = centers[labels] + rng.normal(0, 0.1, (150, 3))
    loose = centers[labels] + rng.normal(0, 3.0, (150, 3))
    assert davies_bouldin(tight, labels) < davies_bouldin(loose, labels)


def test_classify_report_keys():
    rng = np.random.default_rng(0)
    probs = rng.dirichlet(np.ones(3), 100)
    labels = rng.integers(0, 3, 100)
    rep = classify_report(probs, labels)
    for k in ("auc", "accuracy", "sensitivity", "specificity", "f1",
              "per_class_recall"):
        assert k in rep


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=100)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    path = os.path.join(tmp_path, "node0.msgpack")
    save_pytree(path, params, metadata={"step": 42, "arch": "t"})
    like = jax.tree.map(jnp.zeros_like, params)
    restored = load_pytree(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert load_metadata(path)["step"] == 42


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "x.msgpack")
    save_pytree(path, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        load_pytree(path, {"a": jnp.ones((4,))})


# ---------------------------------------------------------------------------
# LoRA
# ---------------------------------------------------------------------------

def test_lora_identity_at_init_and_mergeable():
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=100)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    lp = inject_lora(params, jax.random.key(1), rank=4)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    l0 = float(model.loss_fn(params, batch, remat=False)[0])
    l1 = float(model.loss_fn(lp, batch, remat=False)[0])
    assert abs(l0 - l1) < 1e-5
    l2 = float(model.loss_fn(merge_lora_into_base(lp), batch, remat=False)[0])
    assert abs(l0 - l2) < 1e-4


def test_lora_payload_fraction_small():
    cfg = ModelConfig(name="t", n_layers=4, d_model=256, n_heads=4,
                      n_kv_heads=4, d_ff=1024, vocab_size=5000)
    model = build_model(cfg)
    lp = inject_lora(model.init(jax.random.key(0)), jax.random.key(1), rank=8)
    frac = payload_bytes(lp, True) / payload_bytes(lp, False)
    assert frac < 0.10  # the paper's communication-efficiency claim


def test_split_combine_inverse():
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab_size=100)
    model = build_model(cfg)
    lp = inject_lora(model.init(jax.random.key(0)), jax.random.key(1), rank=4)
    ad, base = split_adapters(lp)
    rt = combine(ad, base)
    for a, b in zip(jax.tree.leaves(lp), jax.tree.leaves(rt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
