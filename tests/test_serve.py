"""Serving plane (PR 8): continuous-batching consensus engine + hot-swap.

Pins the tentpole's acceptance criteria:
  * the vmapped ensemble engine reproduces the host-loop ``generate``
    reference token for token (single request, identical-replica consensus),
  * continuous batching is isolation-preserving: a request's tokens are
    identical whether it runs alone or co-batched with strangers at other
    depths (per-lane cache_pos + masked commits),
  * compiles are bounded by the bucket grid: steady-state serving adds ZERO
    traces (trace-counter idiom),
  * hot-swap under load: decode ticks interleaved with a checkpoint ingest
    (a) never retrace, (b) feed every request exactly one param version,
    (c) leave the live ensemble bit-identical to the ``session.save``
    checkpoint, and (d) drop no in-flight request,
  * ``load_checkpoint_params`` restores only the params subtree and rejects
    node-count mismatches.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.configs.base import SwarmConfig
from repro.core.session import SwarmSession, load_checkpoint_params
from repro.launch.serve import generate
from repro.models import Model, build_model
from repro.serve import (AGG_MODES, BucketPolicy, HotSwapSlot, RequestQueue,
                         ServeEngine, aggregate_logits)

N = 3
V = 16


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _smoke_model():
    cfg = smoke_variant(get_config("minicpm-2b")).replace(vocab_size=64)
    return build_model(cfg)


def _stacked_params(model, n=N, seed=0):
    return jax.vmap(model.init)(jax.random.split(jax.random.key(seed), n))


def _toy_model():
    """Constant-logits model: argmax(params['x']) regardless of input — the
    emitted token IS the param version, which makes hot-swap pinning
    directly observable. The cache records every written token so the
    masked-commit path is exercised too."""

    def decode(params, tokens, caches, cache_pos):
        b, s = tokens.shape
        written = jax.lax.dynamic_update_slice_in_dim(
            caches["written"], tokens, cache_pos, axis=1)
        logits = jnp.broadcast_to(params["x"][None, None, :], (b, s, V))
        return logits, {"written": written}

    return Model(
        cfg=None,
        init=lambda key: {"x": jax.random.normal(key, (V,))},
        loss_fn=None,
        decode=decode,
        init_cache=lambda b, max_len: {"written": jnp.zeros((b, max_len),
                                                            jnp.int32)})


def _peaked(token: int, n=N):
    """Stacked toy params whose every node argmaxes to ``token``."""
    x = np.zeros((n, V), np.float32)
    x[:, token] = 5.0
    return {"x": jnp.asarray(x)}


def _toy_session_fns():
    def train_step(params, opt_state, batch, step):
        return {"x": params["x"] + batch}, opt_state, {"loss": jnp.sum(batch)}

    def eval_fn(params, val):
        return 1.0 - 0.0 * jnp.sum(params["x"])

    return train_step, eval_fn


def _cfg(**kw):
    kw.setdefault("n_nodes", N)
    kw.setdefault("sync_every", 1)
    kw.setdefault("merge", "mean")
    kw.setdefault("topology", "full")
    return SwarmConfig(**kw)


# ---------------------------------------------------------------------------
# bucket policy + queue
# ---------------------------------------------------------------------------

def test_bucket_policy():
    p = BucketPolicy(batch_buckets=(1, 2, 4), seq_buckets=(8, 16))
    assert p.batch_bucket(1) == 1 and p.batch_bucket(3) == 4
    assert p.seq_bucket(8) == 8 and p.seq_bucket(9) == 16
    with pytest.raises(ValueError):
        p.batch_bucket(5)
    with pytest.raises(ValueError):
        p.seq_bucket(17)
    padded, length = p.pad_prompt(np.arange(1, 6))
    assert padded.shape == (8,) and length == 5
    assert padded[:5].tolist() == [1, 2, 3, 4, 5] and not padded[5:].any()
    with pytest.raises(ValueError):
        BucketPolicy(batch_buckets=(4, 2))       # must be sorted


def test_queue_fifo_and_validation():
    q = RequestQueue()
    a = q.submit([1, 2], 4)
    b = q.submit([3], 4)
    assert len(q) == 2 and q.pop() is a and q.pop() is b
    with pytest.raises(ValueError):
        q.submit([], 4)
    with pytest.raises(ValueError):
        q.submit([1], 0)


# ---------------------------------------------------------------------------
# aggregation modes vs a numpy oracle
# ---------------------------------------------------------------------------

def test_aggregate_modes_match_numpy_oracle():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(5, 3, 11)).astype(np.float32)
    out = {m: np.asarray(aggregate_logits(jnp.asarray(logits), m, top_k=2))
           for m in AGG_MODES}
    votes = logits.argmax(-1)                                   # [N, B]
    assert (out["per_node"] == votes).all()
    # consensus: strict majority wins; with all-distinct votes the highest
    # mean-probability candidate does
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    for b in range(3):
        counts = np.bincount(votes[:, b], minlength=11).astype(np.float64)
        counts += probs.mean(0)[b] / 6.0
        assert (out["consensus"][:, b] == counts.argmax()).all()
        assert (out["average"][:, b] == probs.mean(0)[b].argmax()).all()
        top2 = np.argsort(-probs.max(-1)[:, b])[:2]
        assert (out["topk"][:, b] == probs[top2, b].mean(0).argmax()).all()


def test_consensus_majority_beats_confidence():
    """Two peaked nodes out-vote one extremely confident dissenter."""
    logits = np.zeros((3, 1, V), np.float32)
    logits[0, 0, 3] = 2.0
    logits[1, 0, 3] = 2.0
    logits[2, 0, 9] = 50.0
    out = np.asarray(aggregate_logits(jnp.asarray(logits), "consensus"))
    assert (out == 3).all()


# ---------------------------------------------------------------------------
# engine vs the host-loop generate reference
# ---------------------------------------------------------------------------

def test_engine_matches_host_generate():
    """Identical-replica consensus through the continuous engine == the
    seed's host-loop greedy decode, token for token (padded-bucket prefill
    is exact for position-indexed caches)."""
    model = _smoke_model()
    params1 = _stacked_params(model, n=1)
    eng = ServeEngine(
        model, jax.tree.map(lambda x: jnp.concatenate([x] * N), params1),
        mode="consensus", max_len=32, max_slots=2,
        policy=BucketPolicy(batch_buckets=(1, 2), seq_buckets=(8, 16)))
    prompt = np.arange(1, 8) % 64
    req = eng.submit(prompt, max_new=6)
    eng.drain()
    ref = np.asarray(generate(model, jax.tree.map(lambda x: x[0], params1),
                              jnp.asarray(prompt)[None], 6, 32))[0]
    assert req.tokens == ref.tolist()
    # consensus of identical replicas: every node carries the same stream
    assert all((v == v[0]).all() for v in req.node_tokens)


def test_continuous_batching_is_isolation_preserving():
    """Requests co-batched at different depths (staggered admission, mixed
    prompt lengths) produce exactly the tokens they produce alone."""
    model = _smoke_model()
    params = _stacked_params(model)
    policy = BucketPolicy(batch_buckets=(1, 2, 4), seq_buckets=(8, 16))
    prompts = [np.arange(1, 1 + n) % 64 for n in (5, 9, 3, 7)]

    solo = []
    for p in prompts:
        eng = ServeEngine(model, params, mode="average", max_len=32,
                          max_slots=1,
                          policy=BucketPolicy(batch_buckets=(1,),
                                              seq_buckets=(8, 16)))
        req = eng.submit(p, max_new=5)
        eng.drain()
        solo.append(req.tokens)

    eng = ServeEngine(model, params, mode="average", max_len=32, max_slots=4,
                      policy=policy)
    first = [eng.submit(p, max_new=5) for p in prompts[:2]]
    eng.step()                       # stagger: two requests mid-flight ...
    later = [eng.submit(p, max_new=5) for p in prompts[2:]]
    eng.drain()                      # ... before the other two are admitted
    got = [r.tokens for r in first + later]
    assert got == solo


def test_steady_state_serving_never_retraces():
    """Compiles are bounded by the bucket grid: a second wave of requests
    through already-warm shapes adds zero traces."""
    model = _smoke_model()
    eng = ServeEngine(model, _stacked_params(model), max_len=32, max_slots=2,
                      policy=BucketPolicy(batch_buckets=(1, 2),
                                          seq_buckets=(8,)))
    for _ in range(2):
        for n in (4, 6, 5):
            eng.submit(np.arange(1, 1 + n), max_new=4)
        eng.drain()
        warm = dict(eng.trace_counts)
    assert dict(eng.trace_counts) == warm
    assert all(v == 1 for v in eng.trace_counts.values())


# ---------------------------------------------------------------------------
# hot-swap
# ---------------------------------------------------------------------------

def test_hot_swap_slot_is_double_buffered():
    slot = HotSwapSlot(_peaked(3))
    assert slot.version == 0 and slot.versions == (0,)
    v1 = slot.publish(_peaked(9))
    assert (slot.version, slot.versions) == (1, (0, 1))
    assert np.asarray(slot.live["x"]).argmax(-1).tolist() == [9] * N
    slot.retire(pinned=[0])          # old version still pinned -> kept
    assert slot.versions == (0, 1)
    slot.retire(pinned=[])           # drained -> dropped; live survives
    assert slot.versions == (v1,)
    with pytest.raises(ValueError):
        slot.publish({"x": jnp.zeros((N, V + 1))})
    with pytest.raises(ValueError):
        slot.publish({"y": slot.live["x"]})


def test_hot_swap_under_load(tmp_path):
    """The PR 8 invariant triple, under a real mid-flight swap from a real
    ``session.save`` checkpoint of a still-usable training session."""
    model = _toy_model()
    eng = ServeEngine(model, _peaked(3), mode="consensus", max_len=32,
                      max_slots=2,
                      policy=BucketPolicy(batch_buckets=(1, 2),
                                          seq_buckets=(8,)))
    # warm every (kind, shape) this test will touch — decode at both batch
    # buckets, prefill at both table widths — then snapshot traces
    eng.submit([1, 2, 3], max_new=3)
    eng.drain()
    eng.submit([1, 2, 3], max_new=3)
    eng.submit([1, 2], max_new=3)
    eng.drain()
    warm = dict(eng.trace_counts)

    old = eng.submit([1, 2, 3, 4], max_new=6)
    eng.step()                                   # old request mid-flight
    assert eng.live_count == 1

    # a training swarm whose params now peak at token 9, checkpointed
    train_step, eval_fn = _toy_session_fns()
    sess = SwarmSession(_cfg(), train_step, eval_fn, params=_peaked(9),
                        stacked=True)
    ckpt = str(tmp_path / "swarm.msgpack")
    sess.save(ckpt)
    v1 = eng.ingest_checkpoint(ckpt)
    assert v1 == 1 and eng.slot.versions == (0, 1)

    new = eng.submit([5, 6], max_new=4)
    eng.step()                                   # two versions in flight
    assert eng.live_count == 2
    done = eng.drain()

    # (d) no dropped in-flight requests
    assert {r.rid for r in done} == {old.rid, new.rid}
    assert len(old.tokens) == 6 and len(new.tokens) == 4
    # (b) exactly one param version per request: the toy model emits its
    # params' argmax, so every token names the version that produced it
    assert old.param_version == 0 and old.tokens == [3] * 6
    assert new.param_version == 1 and new.tokens == [9] * 4
    # (a) the swap and the two-version transition window never retraced
    assert dict(eng.trace_counts) == warm
    # (c) live ensemble bit-identical to the ingested checkpoint
    want = load_checkpoint_params(ckpt, _peaked(0), expect_nodes=N)
    assert jax.tree.map(lambda a, b: bool((np.asarray(a) == np.asarray(b))
                                          .all()), eng.slot.live, want) \
        == jax.tree.map(lambda a: True, want)
    # old buffer retired once its last request drained
    assert eng.slot.versions == (1,)


def test_load_checkpoint_params(tmp_path):
    train_step, eval_fn = _toy_session_fns()
    sess = SwarmSession(_cfg(), train_step, eval_fn, params=_peaked(7),
                        stacked=True)
    path = str(tmp_path / "ck.msgpack")
    sess.save(path)
    got = load_checkpoint_params(path, _peaked(0), expect_nodes=N)
    assert (np.asarray(got["x"]) == np.asarray(sess.state.params["x"])).all()
    with pytest.raises(ValueError, match="n_nodes"):
        load_checkpoint_params(path, _peaked(0), expect_nodes=N + 1)


# ---------------------------------------------------------------------------
# engine guard rails
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# degradation under faults (ISSUE 9): deadlines, backpressure, lane crashes
# ---------------------------------------------------------------------------

def _per_node_peaked(peaks):
    """Toy params where node i argmaxes to token peaks[i][0] with logit
    peaks[i][1] — distinct lanes make consensus re-formation observable."""
    x = np.zeros((len(peaks), V), np.float32)
    for i, (tok, height) in enumerate(peaks):
        x[i, tok] = height
    return {"x": jnp.asarray(x)}


def test_status_lifecycle_pending_live_done():
    eng = ServeEngine(_toy_model(), _peaked(3), max_len=32, max_slots=1,
                      policy=BucketPolicy(batch_buckets=(1,),
                                          seq_buckets=(8,)))
    req = eng.submit([1, 2], max_new=3)
    assert req.status == "pending" and not req.done
    eng.step()
    assert req.status == "live" and not req.done
    eng.drain()
    assert req.status == "done" and req.done and req.finish_t is not None


def test_bounded_queue_rejects_with_explicit_backpressure():
    eng = ServeEngine(_toy_model(), _peaked(3), max_len=32, max_slots=1,
                      policy=BucketPolicy(batch_buckets=(1,),
                                          seq_buckets=(8,)),
                      max_pending=1)
    ok = eng.submit([1, 2], max_new=2)
    rej = [eng.submit([3, 4], max_new=2) for _ in range(2)]
    # over-limit submits are terminal immediately: never enqueued, never
    # admitted, already in the completed ledger
    assert all(r.status == "rejected" and r.done for r in rej)
    assert all(r.finish_t == r.submit_t and r.tokens == [] for r in rej)
    assert len(eng.queue) == 1 and [r.rid for r in eng.completed] \
        == [r.rid for r in rej]
    done = eng.drain()
    assert [r.rid for r in done] == [ok.rid] and ok.status == "done"
    with pytest.raises(ValueError):
        RequestQueue(max_pending=0)


def test_deadline_expires_queued_request_before_admission():
    t = [0.0]
    eng = ServeEngine(_toy_model(), _peaked(3), max_len=32, max_slots=1,
                      policy=BucketPolicy(batch_buckets=(1,),
                                          seq_buckets=(8,)),
                      now=lambda: t[0])
    req = eng.submit([1, 2], max_new=4, deadline_s=1.0)
    t[0] = 2.0                         # budget elapses while still queued
    done = eng.step()
    assert [r.rid for r in done] == [req.rid]
    assert req.status == "deadline_exceeded" and req.done
    assert req.tokens == [] and req.admit_t is None and req.finish_t == 2.0
    assert len(eng.queue) == 0 and eng.live_count == 0
    with pytest.raises(ValueError):
        eng.submit([1], max_new=1, deadline_s=0.0)


def test_deadline_expires_mid_decode_and_frees_the_slot():
    t = [0.0]
    eng = ServeEngine(_toy_model(), _peaked(3), max_len=32, max_slots=1,
                      policy=BucketPolicy(batch_buckets=(1,),
                                          seq_buckets=(8,)),
                      now=lambda: t[0])
    req = eng.submit([1, 2], max_new=10, deadline_s=1.0)
    eng.step()
    assert req.status == "live" and len(req.tokens) >= 1
    emitted = len(req.tokens)
    t[0] = 1.5                         # budget elapses mid-decode
    done = eng.step()
    assert [r.rid for r in done] == [req.rid]
    assert req.status == "deadline_exceeded"
    assert len(req.tokens) == emitted  # already-emitted tokens are kept
    assert eng.live_count == 0         # the lane freed for new work
    nxt = eng.submit([3, 4], max_new=2)
    eng.drain()
    assert nxt.status == "done"


def test_drain_timeout_names_stuck_work():
    eng = ServeEngine(_toy_model(), _peaked(3), max_len=64, max_slots=1,
                      policy=BucketPolicy(batch_buckets=(1,),
                                          seq_buckets=(8,)))
    live = eng.submit([1, 2], max_new=50)
    queued = eng.submit([3, 4], max_new=50)
    with pytest.raises(TimeoutError) as exc:
        eng.drain(max_ticks=3)
    msg = str(exc.value)
    assert f"(0, {live.rid})" in msg and str(queued.rid) in msg


def test_node_crash_reaggregates_consensus_mid_flight_without_retraces():
    """fail_node mid-request: the in-flight consensus re-forms over the
    surviving ensemble lanes on the very next dispatch — no retrace, no
    drop, and restore_node re-admits the lane the same way."""
    eng = ServeEngine(_toy_model(),
                      _per_node_peaked([(3, 5.0), (3, 5.0), (9, 4.0)]),
                      mode="consensus", max_len=32, max_slots=1,
                      policy=BucketPolicy(batch_buckets=(1,),
                                          seq_buckets=(8,)))
    eng.submit([1, 2, 3], max_new=2)   # warm every (kind, shape)
    eng.drain()
    warm = dict(eng.trace_counts)

    req = eng.submit([1, 2, 3], max_new=6)
    eng.step()                         # 2 tokens under full membership
    assert req.tokens == [3, 3]        # majority out-votes the dissenter
    eng.fail_node(0)
    eng.fail_node(1)                   # only the token-9 lane survives
    assert eng.node_mask.tolist() == [False, False, True]
    eng.step()
    eng.restore_node(0)                # recovery: 3-lane vs 9-lane tie is
    eng.drain()                        # broken by node 0's taller peak
    assert req.status == "done" and req.tokens == [3, 3, 9, 3, 3, 3]
    assert dict(eng.trace_counts) == warm      # mask flips never retrace


def test_node_mask_guard_rails():
    eng = ServeEngine(_toy_model(), _peaked(3), max_len=32, max_slots=1,
                      policy=BucketPolicy(batch_buckets=(1,),
                                          seq_buckets=(8,)))
    with pytest.raises(ValueError, match="at least one"):
        eng.set_node_mask([False] * N)
    with pytest.raises(ValueError, match="entries"):
        eng.set_node_mask([True] * (N + 1))
    mask = eng.node_mask
    mask[0] = False                    # property returns a copy
    assert eng.node_mask.all()


def test_engine_rejects_oversized_work():
    model = _toy_model()
    eng = ServeEngine(model, _peaked(1), max_len=10,
                      policy=BucketPolicy(batch_buckets=(1,),
                                          seq_buckets=(8,)), max_slots=1)
    with pytest.raises(ValueError):
        eng.submit(np.arange(9), max_new=1)      # no seq bucket fits
    with pytest.raises(ValueError):
        eng.submit(np.arange(8), max_new=3)      # cache overflow
    with pytest.raises(ValueError):
        ServeEngine(model, _peaked(1), max_slots=4,
                    policy=BucketPolicy(batch_buckets=(1, 2)))
    with pytest.raises(ValueError):
        ServeEngine(model, _peaked(1), mode="vote")
