"""Property-style tests for the P2P-SL core (the paper's invariants).

`hypothesis` is not installable in this offline container; the same invariants
are asserted over seed-swept random instances instead.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.core import topology as topo
from repro.core.merge_impl import (fisher_merge, gradmatch_merge, mix,
                                   stack_params, unstack_params)
from repro.core.swarm import (SwarmLearner, NodeState, gate_decisions,
                              gated_commit, mixing_matrix, propose_merge)

SEEDS = range(8)


def _rand_tree(rng, n_nodes):
    mk = lambda *s: jnp.asarray(rng.normal(0, 1, (n_nodes, *s)), jnp.float32)
    return {"w": mk(8, 16), "b": mk(16), "nested": {"v": mk(4, 4, 2)}}


# ---------------------------------------------------------------------------
# topology properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 3, 4, 8, 16])
def test_mixing_matrices_row_stochastic(n):
    for W in (topo.ring_matrix(n, 0.5), topo.full_matrix(n),
              topo.full_matrix(n, list(range(1, n + 1)))):
        assert np.allclose(W.sum(1), 1.0)
        assert (W >= 0).all()


@pytest.mark.parametrize("n", [4, 8])
def test_ring_is_doubly_stochastic_with_positive_gap(n):
    W = topo.ring_matrix(n, 0.5)
    assert np.allclose(W.sum(0), 1.0)
    assert 0.0 < topo.spectral_gap(W) <= 1.0


@pytest.mark.parametrize("seed", SEEDS)
def test_dynamic_matrix_isolates_absent_nodes(seed):
    rng = np.random.default_rng(seed)
    n = 5
    active = rng.random(n) > 0.4
    active[0] = True  # at least one active
    W = topo.dynamic_matrix(topo.full_matrix(n, rng.random(n) + 0.1), active)
    assert np.allclose(W.sum(1), 1.0)
    for i in np.flatnonzero(~active):
        row = np.zeros(n); row[i] = 1.0
        assert np.allclose(W[i], row)          # absent node keeps its params
        assert np.allclose(W[active][:, i], 0)  # nobody reads from it


def test_fedavg_weights_closed_form():
    w = topo.fedavg_weights([1000, 3000, 3000, 3000])
    assert np.allclose(w, [0.1, 0.3, 0.3, 0.3])
    with pytest.raises(ValueError):
        topo.fedavg_weights([0, 0])


# ---------------------------------------------------------------------------
# merge properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_mix_preserves_global_mean_doubly_stochastic(seed):
    """Gossip with a doubly-stochastic W preserves the parameter average."""
    rng = np.random.default_rng(seed)
    st = _rand_tree(rng, 4)
    out = mix(st, topo.ring_matrix(4, 0.3))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a.mean(0)), np.asarray(b.mean(0)),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_gossip_contracts_to_consensus(seed):
    """Repeated ring gossip converges to the mean at the spectral-gap rate."""
    rng = np.random.default_rng(seed)
    st = _rand_tree(rng, 8)
    W = topo.ring_matrix(8, 0.5)
    gap = topo.spectral_gap(W)
    disagreement = lambda t: max(
        float(jnp.abs(x - x.mean(0, keepdims=True)).max())
        for x in jax.tree.leaves(t))
    d0 = disagreement(st)
    cur = st
    for _ in range(60):
        cur = mix(cur, W)
    assert disagreement(cur) < d0 * (1 - gap) ** 30  # generous bound


@pytest.mark.parametrize("seed", SEEDS)
def test_fedavg_mix_equals_closed_form(seed):
    rng = np.random.default_rng(seed)
    st = _rand_tree(rng, 4)
    sizes = rng.integers(100, 1000, 4)
    W = topo.full_matrix(4, sizes)
    out = mix(st, W)
    w = sizes / sizes.sum()
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        want = np.tensordot(w, np.asarray(a), axes=(0, 0))
        for i in range(4):
            np.testing.assert_allclose(np.asarray(b[i]), want, rtol=1e-5,
                                       atol=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_fisher_merge_interpolates(seed):
    """Equal Fishers -> plain mean; one-hot Fisher -> that node's params."""
    rng = np.random.default_rng(seed)
    st = _rand_tree(rng, 3)
    ones = jax.tree.map(jnp.ones_like, st)
    out = fisher_merge(st, ones)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(b[0]), np.asarray(a.mean(0)),
                                   rtol=1e-5, atol=1e-5)
    hot = jax.tree.map(
        lambda x: jnp.zeros_like(x).at[1].set(1.0), st)
    out = fisher_merge(st, hot, eps=1e-12)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(b[0]), np.asarray(a[1]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed", SEEDS)
def test_gradmatch_reduces_to_fedavg_with_equal_fishers(seed):
    rng = np.random.default_rng(seed)
    st = _rand_tree(rng, 4)
    ones = jax.tree.map(jnp.ones_like, st)
    w = jnp.asarray(rng.dirichlet(np.ones(4)), jnp.float32)
    out = gradmatch_merge(st, ones, weights=w)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        want = np.tensordot(np.asarray(w), np.asarray(a), axes=(0, 0))
        np.testing.assert_allclose(np.asarray(b[0]), want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# gating (the paper's 80% validation-acceptance rule)
# ---------------------------------------------------------------------------

def test_gate_decisions_relative_and_absolute():
    merged = jnp.asarray([0.9, 0.5, 0.79, 0.81])
    local = jnp.asarray([1.0, 1.0, 1.0, 1.0])
    rel = np.asarray(gate_decisions(merged, local, 0.8, "relative"))
    assert rel.tolist() == [True, False, False, True]
    ab = np.asarray(gate_decisions(merged, local, 0.8, "absolute"))
    assert ab.tolist() == [True, False, False, True]


@pytest.mark.parametrize("seed", SEEDS)
def test_gated_commit_selects_per_node(seed):
    rng = np.random.default_rng(seed)
    local = _rand_tree(rng, 4)
    cand = jax.tree.map(lambda x: x + 100.0, local)
    gates = jnp.asarray(rng.random(4) > 0.5)
    out = gated_commit(cand, local, gates)
    for lo, o in zip(jax.tree.leaves(local), jax.tree.leaves(out)):
        for i, g in enumerate(np.asarray(gates)):
            want = np.asarray(lo[i]) + (100.0 if g else 0.0)
            np.testing.assert_allclose(np.asarray(o[i]), want, rtol=1e-6)


def test_propose_merge_lora_only_leaves_base_untouched():
    from repro.core.lora import inject_lora
    rng = np.random.default_rng(0)
    base = {"attn": {"q": {"w": jnp.asarray(rng.normal(0, 1, (16, 16)),
                                            jnp.float32)}}}
    trees = [inject_lora(jax.tree.map(lambda x: x + i, base),
                         jax.random.key(i), rank=4) for i in range(3)]
    st = stack_params(trees)
    cfg = SwarmConfig(n_nodes=3, lora_only=True, merge="fedavg", topology="full")
    W = mixing_matrix(cfg, [1, 1, 1])
    cand = propose_merge(st, cfg, W)
    # base weights unchanged per node, adapters averaged
    np.testing.assert_allclose(np.asarray(cand["attn"]["q"]["w"]),
                               np.asarray(st["attn"]["q"]["w"]))
    a = np.asarray(st["attn"]["q"]["lora_A"])
    # atol floor: the merge contracts in f32 (N·eps·max|θ| ≈ 3·1.2e-7), so
    # elements produced by cancellation can't satisfy a pure rtol vs the
    # numpy pairwise mean; base-leaf passthrough above stays bit-exact.
    np.testing.assert_allclose(np.asarray(cand["attn"]["q"]["lora_A"]),
                               np.tile(a.mean(0), (3, 1, 1)), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end SwarmLearner behaviour (toy quadratic "model")
# ---------------------------------------------------------------------------

def _toy_learner(sync_every=2, merge="fedavg", threshold=0.0):
    """Nodes descend toward different targets; swarm pulls them together."""
    targets = [jnp.full((4,), t, jnp.float32) for t in (0.0, 1.0, 2.0, 3.0)]

    def train_step(params, opt_state, batch, step):
        i = batch
        g = params["x"] - targets[i]
        return {"x": params["x"] - 0.1 * g}, opt_state, {"loss": float(jnp.sum(g**2))}

    def eval_fn(params, val):
        return 1.0  # always accept (threshold tested separately)

    nodes = [NodeState(params={"x": jnp.zeros((4,))}, opt_state=None,
                       data_size=100 * (i + 1)) for i in range(4)]
    cfg = SwarmConfig(n_nodes=4, sync_every=sync_every, merge=merge,
                      topology="full", lora_only=False, val_threshold=threshold)
    return SwarmLearner(cfg, train_step, eval_fn, nodes)


def test_swarm_learner_syncs_to_weighted_mean():
    sw = _toy_learner()
    for _ in range(2):
        sw.local_steps([0, 1, 2, 3])
    log = sw.sync([1, 1, 1, 1])
    assert all(log["gates"])
    xs = [np.asarray(n.params["x"]) for n in sw.nodes]
    for x in xs[1:]:
        np.testing.assert_allclose(x, xs[0], rtol=1e-5, atol=1e-6)


def test_swarm_learner_dynamic_membership():
    sw = _toy_learner()
    sw.set_active(2, False)
    for _ in range(2):
        sw.local_steps([0, 1, None, 3])
    x2_before = np.asarray(sw.nodes[2].params["x"]).copy()
    log = sw.sync([1, 1, None, 1])
    assert log["gates"][2] is False or log["gates"][2] == 0
    np.testing.assert_allclose(np.asarray(sw.nodes[2].params["x"]), x2_before)


@pytest.mark.parametrize("merge", ["fisher", "gradmatch"])
def test_inactive_node_excluded_from_weighted_merges(merge):
    """Regression: a departed node's (huge) Fisher mass and dataset weight
    must not leak into fisher/gradmatch merges — zero + renormalize over the
    active membership."""
    nodes = []
    for i in range(4):
        params = {"x": jnp.full((8,), float(i), jnp.float32)}
        nodes.append(NodeState(
            params=params, opt_state=None, data_size=100,
            fisher=jax.tree.map(
                lambda t: jnp.full_like(t, 1e6 if i == 2 else 1.0), params)))
    cfg = SwarmConfig(n_nodes=4, sync_every=1, merge=merge, topology="full",
                      lora_only=False, val_threshold=0.0)
    sw = SwarmLearner(cfg, lambda p, o, b, s: (p, o, {}),
                      lambda p, v: 1.0, nodes)
    sw.set_active(2, False)
    sw.step = 1
    log = sw.sync([1, 1, None, 1])
    assert not log["gates"][2]
    # active nodes merge to mean(0, 1, 3); node 2 (params=2, fisher=1e6)
    # would drag the result toward 2.0 if it leaked in
    for i in (0, 1, 3):
        np.testing.assert_allclose(np.asarray(sw.nodes[i].params["x"]),
                                   np.full(8, 4.0 / 3), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sw.nodes[2].params["x"]),
                               np.full(8, 2.0))


def test_swarm_learner_gate_rejects_bad_merges():
    sw = _toy_learner()
    # eval_fn returning lower metric for merged candidate -> reject
    calls = {"n": 0}

    def eval_fn(params, val):
        calls["n"] += 1
        return 0.1 if calls["n"] % 2 == 0 else 1.0  # merged evaluated second

    sw.eval_fn = eval_fn
    sw.cfg = SwarmConfig(n_nodes=4, sync_every=2, merge="fedavg",
                         topology="full", lora_only=False, val_threshold=0.8)
    for _ in range(2):
        sw.local_steps([0, 1, 2, 3])
    before = [np.asarray(n.params["x"]).copy() for n in sw.nodes]
    log = sw.sync([1, 1, 1, 1])
    assert not any(log["gates"])
    for b, n in zip(before, sw.nodes):
        np.testing.assert_allclose(np.asarray(n.params["x"]), b)
