"""Launch-layer units: HLO collective parser, roofline terms, input specs,
analytic FLOP model, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES_BY_NAME, get_config, smoke_variant
from repro.launch import hlo_stats


# ---------------------------------------------------------------------------
# collective-bytes parser
# ---------------------------------------------------------------------------

def test_parser_simple_ops():
    txt = """
      %ag.3 = bf16[2,1024,128]{2,1,0} all-gather(%x), dims={0}
      %ar = f32[16,4096]{1,0} all-reduce(%y), to_apply=%add
      %cp = f32[8,8]{1,0} collective-permute(%z)
      %rs = bf16[64]{0} reduce-scatter(%w)
      %a2a = f32[4,4]{1,0} all-to-all(%v)
    """
    cb = hlo_stats.collective_bytes(txt)
    assert cb["all-gather"] == 2 * 1024 * 128 * 2
    assert cb["all-reduce"] == 16 * 4096 * 4
    assert cb["collective-permute"] == 8 * 8 * 4
    assert cb["reduce-scatter"] == 64 * 2
    assert cb["all-to-all"] == 4 * 4 * 4
    assert cb["count"] == 5


def test_parser_tuple_result_and_async():
    txt = """
      %all-reduce = (f32[768,2304]{1,0}, f32[2304]{0}, /*index=5*/f32[10,14]{1,0}) all-reduce(%a, %b, %c)
      %ag.1 = bf16[4,128]{1,0} all-gather-start(%x)
      %agd = bf16[4,128]{1,0} all-gather-done(%ag.1)
      %trap.all-reduce.5 = f32[8]{0} add(%p, %q)
    """
    cb = hlo_stats.collective_bytes(txt)
    assert cb["all-reduce"] == (768 * 2304 + 2304 + 10 * 14) * 4
    assert cb["all-gather"] == 4 * 128 * 2   # start only, done skipped
    assert cb["count"] == 2


@pytest.mark.spmd
def test_parser_on_real_compiled_module():
    """An actual psum lowering must be visible to the parser."""
    import subprocess, sys, os, textwrap
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_stats import collective_bytes
        mesh = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
        x = jax.ShapeDtypeStruct((16, 8), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data")))
        c = jax.jit(lambda a: a.sum(0, keepdims=True) * 1.0 +
                    jax.lax.with_sharding_constraint(
                        a, NamedSharding(mesh, P())).mean()).lower(x).compile()
        cb = collective_bytes(c.as_text())
        assert cb["total"] > 0, c.as_text()
        print("OK")
    """)
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

def test_roofline_terms_and_dominant():
    r = hlo_stats.Roofline(arch="a", shape="s", mesh="m", chips=256,
                           hlo_flops=197e12, hlo_bytes=819e9,
                           coll_bytes=50e9, model_flops=100e12)
    assert r.compute_s == pytest.approx(1 / 256)
    assert r.memory_s == pytest.approx(1 / 256)
    assert r.collective_s == pytest.approx(1.0)
    assert r.dominant == "collective"
    assert r.useful_ratio == pytest.approx(100 / 197)


# ---------------------------------------------------------------------------
# analytic FLOP model
# ---------------------------------------------------------------------------

def test_model_flops_kinds():
    from repro.launch.dryrun import model_flops_analytic  # noqa: E402  (sets XLA_FLAGS; ok in-process)
    cfg = get_config("deepseek-coder-33b")
    tr = model_flops_analytic(cfg, SHAPES_BY_NAME["train_4k"])
    pf = model_flops_analytic(cfg, SHAPES_BY_NAME["prefill_32k"])
    dc = model_flops_analytic(cfg, SHAPES_BY_NAME["decode_32k"])
    n = cfg.param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert dc == pytest.approx(2 * n * 128)


def test_moe_active_params_lower_than_total():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    assert cfg.active_param_count() < cfg.param_count()
    # ≈ 6.6B active vs 42B total (order of magnitude)
    assert 4e9 < cfg.active_param_count() < 10e9
    assert 35e9 < cfg.param_count() < 50e9


def test_smoke_variants_within_limits():
    for name in ("command-r-plus-104b", "phi3.5-moe-42b-a6.6b", "hymba-1.5b"):
        cfg = smoke_variant(get_config(name))
        assert cfg.n_layers <= 2 and cfg.d_model <= 512
        assert cfg.n_experts <= 4
        assert cfg.family == get_config(name).family


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.mark.spmd
def test_param_specs_divisibility_fallback():
    import subprocess, sys, os, textwrap
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import param_specs
        mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices())
        tree = {"attn": {"q": {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32)}},
                "mlp": {"up": {"w": jax.ShapeDtypeStruct((64, 130), jnp.float32)}}}
        specs = param_specs(tree, mesh)
        assert specs["attn"]["q"]["w"] == P("data", "model")
        # 130 % 4 != 0 -> model axis dropped on that dim
        assert specs["mlp"]["up"]["w"] == P("data", None)
        print("OK")
    """)
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr


def test_adapt_for_shape_swa():
    from repro.configs import adapt_for_shape
    cfg = get_config("deepseek-coder-33b")
    long = adapt_for_shape(cfg, SHAPES_BY_NAME["long_500k"])
    assert long.sliding_window == 4096      # dense arch gets SWA for 500k
    tr = adapt_for_shape(cfg, SHAPES_BY_NAME["train_4k"])
    assert tr.sliding_window == 0
    ssm = adapt_for_shape(get_config("mamba2-370m"), SHAPES_BY_NAME["long_500k"])
    assert ssm.sliding_window == 0          # attention-free: native path
