"""Integration test: the paper's experimental protocol end-to-end (tiny)."""
import numpy as np

from repro.experiments.histo import HistoExperimentConfig, run_experiment


def test_histo_protocol_tiny():
    cfg = HistoExperimentConfig(n_train=240, n_test=120, steps=20,
                                image_size=16, batch_size=8, noise=0.6,
                                seed=0)
    r = run_experiment(cfg)
    # structure
    assert len(r["local"]) == 4 and len(r["swarm"]) == 4
    for rep in [r["centralized"]] + r["local"] + r["swarm"]:
        assert 0.0 <= rep["auc"] <= 1.0
        assert np.isfinite(rep["dbi"])
    assert r["config"]["sizes"][0] < r["config"]["sizes"][1]
    # sync happened and produced gates
    assert r["sync_log"], "no gossip rounds logged"
    assert all(len(s["gates"]) == 4 for s in r["sync_log"])


def test_histo_scarcity_downsampling():
    cfg = HistoExperimentConfig(n_train=240, n_test=60, steps=4,
                                image_size=16, batch_size=8,
                                scarcity={3: 0.25}, seed=1)
    r = run_experiment(cfg)
    sizes = r["config"]["sizes"]
    assert sizes[3] < sizes[2]  # node 3 down-sampled
