"""Integration test: the paper's experimental protocol end-to-end (tiny).

One session-scoped experiment run is shared by every asserting test — the
engine compiles the swarm round once and the assertions read the cached
result. The full-size protocol stays reachable via ``benchmarks/run.py``.
"""
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.experiments.histo import HistoExperimentConfig, run_experiment

TINY = dict(n_train=160, n_test=64, steps=6, image_size=16, batch_size=8,
            noise=0.6, growth=4, stem=8, feat_dim=32, hidden=16,
            n_blocks=1, layers_per_block=2)


@pytest.fixture(scope="session")
def tiny_result():
    cfg = HistoExperimentConfig(
        seed=0,
        swarm=SwarmConfig(n_nodes=4, sync_every=3, topology="full",
                          merge="fedavg", lora_only=False, val_threshold=0.8),
        **TINY)
    return run_experiment(cfg)


def test_histo_protocol_structure(tiny_result):
    r = tiny_result
    assert len(r["local"]) == 4 and len(r["swarm"]) == 4
    for rep in [r["centralized"]] + r["local"] + r["swarm"]:
        assert 0.0 <= rep["auc"] <= 1.0
        assert np.isfinite(rep["dbi"])
    assert r["config"]["sizes"][0] < r["config"]["sizes"][1]


def test_histo_sync_rounds_logged(tiny_result):
    r = tiny_result
    assert r["sync_log"], "no gossip rounds logged"
    assert all(len(s["gates"]) == 4 for s in r["sync_log"])
    for s in r["sync_log"]:
        assert len(s["metric_local"]) == 4 and len(s["metric_merged"]) == 4
        assert all(0.0 <= m <= 1.0 for m in s["metric_local"])


def test_histo_scarcity_downsampling():
    cfg = HistoExperimentConfig(scarcity={3: 0.25}, seed=1,
                                **dict(TINY, steps=2, n_test=32))
    r = run_experiment(cfg)
    sizes = r["config"]["sizes"]
    assert sizes[3] < sizes[2]  # node 3 down-sampled
