"""Chaos plane on the mesh (ISSUE 9): fault plans against the q8 gossip
backend.

All checks need >1 device, so they run in ONE subprocess with XLA_FLAGS
forcing 4 host devices (same pattern as test_mesh_wire_spmd), each printing
an ``OK <tag>`` marker the tests assert on. Pins the gossip half of the
acceptance criteria:

  * crash → EF quarantine → rejoin on the sharded int8 wire settles back to
    the fault-free numpy oracle (committed params ≤ 1e-5),
  * a preempt event mid-fault-plan (``session.save`` → fresh session →
    restore) leaves params AND every mesh-wire leaf bit-identical to the
    uninterrupted run,
  * the ``quorum`` degradation policy closes every gate on the gossip
    backend when membership dips below the floor, and reopens on recovery,
  * a whole plan (crash / straggle / drop / corrupt-degraded) replays
    against the compiled gossip round with ZERO retraces.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.spmd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH=SRC)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


_CHECKS = """
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import SwarmConfig
from repro.core.session import SwarmSession
from repro.faults import FaultPlan, run_plan
from repro.faults import oracle

mesh = jax.make_mesh((4,), ("node",), devices=jax.devices()[:4])
N, D, WB = 4, 640, 128
rng = np.random.default_rng(0)
w0 = jnp.asarray(rng.normal(0, 1, (N, D)), jnp.float32)

def id_step(p, o, b, s):
    return p, o, {"loss": 0.0 * jnp.sum(p["w"])}

def decay_step(p, o, b, s):
    return {"w": p["w"] * 0.999}, o, {"loss": 0.0 * jnp.sum(p["w"])}

def eval_fn(p, v):
    return 1.0 - 0.0 * jnp.sum(p["w"])

batches = jnp.zeros((1, N, 1))
val = jnp.zeros((N, 1))

def mk_cfg(thr, **kw):
    kw.setdefault("topology", "ring")
    kw.setdefault("merge", "fisher")
    return SwarmConfig(n_nodes=N, sync_every=1, lora_only=False,
                      val_threshold=thr, wire_dtype="int8", wire_block=WB,
                      **kw)

GKW = dict(stacked=True, backend="gossip", mesh=mesh, axis="node",
           data_sizes=[1.0] * N)

# --- crash -> EF quarantine -> rejoin settles to the fault-free oracle ---
# Phase 1 (reject gates, metric 1.0 < 1.5): params frozen at w0 while the
# mesh wire telescopes THROUGH the fault — the rejoin's full-mesh
# quarantine restarts the residual, which re-contracts over the remaining
# rounds. Phase 2: same state, accepting gates, one committed round — must
# land on the uncompressed numpy merge of w0 within the settled bound.
for topo, merge in [("ring", "fisher"), ("full", "fedavg")]:
    sa = SwarmSession(mk_cfg(1.5, topology=topo, merge=merge), id_step,
                      eval_fn, params={"w": w0.copy()}, **GKW)
    plan = FaultPlan(n_nodes=N, n_rounds=9, seed=0).crash(1, at=1, rejoin=3)
    sa, logs = run_plan(sa, plan, batches, val)
    assert not any(l["gates"].any() for l in logs), (topo, merge)
    np.testing.assert_array_equal(np.asarray(sa.state.params["w"]),
                                  np.asarray(w0))    # reject gates held
    assert sa.active.all()                           # node 1 rejoined
    sb = SwarmSession(mk_cfg(0.0, topology=topo, merge=merge), id_step,
                      eval_fn, params={"w": w0.copy()}, **GKW)
    sb.load_state(sa.state)
    out = sb.round(batches, val)
    assert np.asarray(out["gates"]).all()
    want = oracle.merge_candidate(np.asarray(w0), np.ones(N, bool),
                                  merge=merge, topology=topo,
                                  data_sizes=[1.0] * N)
    err = np.abs(np.asarray(sb.state.params["w"]) - want).max()
    assert err < 1e-5, (topo, merge, err)
print("OK crash_rejoin_parity")

# --- quarantine_wire on gossip resets the WHOLE mesh wire ----------------
sq = SwarmSession(mk_cfg(1.5), id_step, eval_fn, params={"w": w0.copy()},
                  **GKW)
sq.round(batches, val)
assert any(np.asarray(x).any() for x in jax.tree.leaves(sq.state.wire))
sq.quarantine_wire(2)      # gossip: neighbour replicas must track ref ->
                           # per-node surgery is unsafe, the reset is total
assert not any(np.asarray(x).any() for x in jax.tree.leaves(sq.state.wire))
print("OK mesh_quarantine")

# --- preempt mid-plan: save -> rebuild -> restore == uninterrupted -------
tmp = tempfile.mkdtemp()
def run(plan):
    sess = SwarmSession(mk_cfg(0.0), decay_step, eval_fn,
                        params={"w": w0.copy()}, **GKW)
    mk = lambda: SwarmSession(mk_cfg(0.0), decay_step, eval_fn,
                              params={"w": w0.copy()}, **GKW)
    return run_plan(sess, plan, batches, val, make_session=mk,
                    checkpoint_path=os.path.join(tmp, "preempt.msgpack"))

base = FaultPlan(n_nodes=N, n_rounds=6, seed=0).crash(2, at=1, rejoin=4)
ra, la = run(base)
rb, lb = run(base.preempt(at=3))
np.testing.assert_array_equal(np.asarray(ra.state.params["w"]),
                              np.asarray(rb.state.params["w"]))
for x, y in zip(jax.tree.leaves(ra.state.wire), jax.tree.leaves(rb.state.wire)):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
assert [l["gates"].tolist() for l in la] == [l["gates"].tolist() for l in lb]
print("OK preempt_bit_identity")

# --- quorum degradation on the gossip backend ----------------------------
sp = SwarmSession(mk_cfg(0.0, quorum=3), id_step, eval_fn,
                  params={"w": w0.copy()}, **GKW)
sp.set_active([True, False, False, True])    # 2 alive < quorum 3
out = sp.round(batches, val)
assert not np.asarray(out["gates"]).any() and not bool(out["quorum_ok"])
np.testing.assert_array_equal(np.asarray(sp.state.params["w"]),
                              np.asarray(w0))    # the round held locals
sp.set_active([True, True, False, True])     # recovery: 3 alive
out = sp.round(batches, val)
assert bool(out["quorum_ok"])
assert np.asarray(out["gates"]).tolist() == [True, True, False, True]
print("OK gossip_quorum")

# --- a whole plan replays against ONE compiled gossip round --------------
traces = []
def counting_step(p, o, b, s):
    traces.append(1)         # python body: appends only at trace time
    return p, o, {"loss": 0.0 * jnp.sum(p["w"])}

sc = SwarmSession(mk_cfg(1.5, quorum=2), counting_step, eval_fn,
                  params={"w": w0.copy()}, **GKW)
sc.round(batches, val)       # warm the one trace membership swings reuse
warm = len(traces)
plan = (FaultPlan(n_nodes=N, n_rounds=8, seed=3)
        .crash(1, at=1, rejoin=3)
        .straggle(3, at=4, rounds=1)
        .drop(0, at=5)
        .corrupt(2, at=6))   # no in-graph wire on gossip -> lowers to drop
run_plan(sc, plan, batches, val)
assert len(traces) == warm, (warm, len(traces))
print("OK gossip_zero_retrace")
"""


@pytest.fixture(scope="module")
def spmd_out():
    return _run(_CHECKS)  # module scope: the subprocess runs once


def test_gossip_crash_rejoin_settles_to_oracle(spmd_out):
    """q8 gossip backend: crash → full-mesh EF quarantine → rejoin, then an
    accepting commit ≤ 1e-5 of the fault-free numpy merge (ISSUE 9
    satellite, both ring/fisher and full/fedavg schedules)."""
    assert "OK crash_rejoin_parity" in spmd_out


def test_gossip_quarantine_resets_whole_mesh_wire(spmd_out):
    """On the mesh wire, quarantine is total: neighbour replicas must stay
    bit-identical to senders' references, so no per-node surgery."""
    assert "OK mesh_quarantine" in spmd_out


def test_preempt_mid_plan_is_bit_identical(spmd_out):
    """save → fresh session → restore in the middle of a fault plan leaves
    params and every mesh-wire leaf bit-identical to never stopping."""
    assert "OK preempt_bit_identity" in spmd_out


def test_gossip_quorum_holds_and_recovers(spmd_out):
    """Below-quorum membership closes every gate (locals held exactly);
    recovery reopens them — all in-graph on the runtime mask."""
    assert "OK gossip_quorum" in spmd_out


def test_gossip_plan_replays_with_zero_retraces(spmd_out):
    """crash / straggle / drop / corrupt-degraded across 8 rounds reuse the
    single warm compiled round — no retrace, no structure churn."""
    assert "OK gossip_zero_retrace" in spmd_out
