"""Heterogeneous swarm on the mesh (ISSUE 10): adapter-only wire at scale.

Multi-device checks for ``payload="lora"`` run in ONE subprocess with
XLA_FLAGS forcing 4 host devices (same pattern as test_mesh_wire_spmd),
each printing an ``OK <tag>`` marker the tests assert on. Pins the
acceptance criteria:

  * HLO-measured collective bytes of the adapter-only int8 sync are ≤ 5%
    of syncing the full model state in f32 (the headline wire shrink),
  * committed adapters ≤ 1e-5 of the numpy ring-mixing oracle after the
    int8 EF wire settles (gossip backend, flat payload state),
  * save → restore → continue is bit-identical for the flat adapter state
    AND the mesh EF wire residuals,
  * the cost model tags the gossip-backend lora schedule with the lora
    payload class,
  * per-node closure lists (the model zoo) are rejected on the gossip
    backend even when a real mesh is supplied — the frozen-backbone
    closures are an engine-backend construct.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.spmd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH=SRC)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


_CHECKS = """
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import SwarmConfig
from repro.core import gossip
from repro.core.session import SwarmSession
from repro.core.topology import build_matrix
from repro.launch import hlo_stats
from repro.models import zoo

mesh = jax.make_mesh((4,), ("node",), devices=jax.devices()[:4])
N, WB = 4, 128
nodes = zoo.build_zoo(jax.random.PRNGKey(0), N, image_size=16,
                      feat_dim=128, hidden=128, rank=2)
payload0 = {k: jnp.stack([nd.payload()[k] for nd in nodes])
            for k in nodes[0].payload()}
p_payload = sum(int(v[0].size) for v in payload0.values())
full_tree = jax.tree.map(
    lambda t: jnp.broadcast_to(t, (N,) + t.shape), nodes[0].template)
p_full = sum(int(l.size) for l in jax.tree.leaves(full_tree)) // N
assert p_full > 20 * p_payload, (p_full, p_payload)
Wring = build_matrix("ring", N)

# --- HLO bytes: adapter-only int8 sync vs full-state f32 sync -------------
wire = gossip.init_mesh_wire("ring_ppermute", payload0, n_shards=N,
                             wire_block=WB)
q8fn = jax.jit(lambda t, w: gossip.ring_rows_gossip_q8(
    t, Wring, w, mesh, "node", wire_block=WB))
f32fn = jax.jit(lambda t: gossip.ring_rows_gossip(t, Wring, mesh, "node"))
cq = hlo_stats.collective_bytes(
    q8fn.lower(payload0, wire).compile().as_text())
cf = hlo_stats.collective_bytes(f32fn.lower(full_tree).compile().as_text())
ratio = cq["total"] / cf["total"]
# ISSUE 10 acceptance: adapter-only int8 moves ≤ 5% of full-payload f32
assert ratio <= 0.05, (ratio, cq, cf)
assert cq["all-gather"] == 0 and cq["all-reduce"] == 0, cq
print(f"OK hetero_bytes ratio={ratio:.4f} p_full={p_full} "
      f"p_payload={p_payload}")

# --- settled commit: adapters match the numpy ring-mixing oracle ----------
def id_step(p, o, b, s):
    return p, o, {"loss": 0.0 * jnp.sum(p["head/out/w"])}

def eval_fn(p, v):
    return 1.0 - 0.0 * jnp.sum(p["head/out/w"])

batches = jnp.zeros((1, N, 1))
val = jnp.zeros((N, 1))

def mk(thr):
    return SwarmConfig(n_nodes=N, sync_every=1, topology="ring",
                       merge="fedavg", payload="lora", lora_only=False,
                       val_threshold=thr, wire_dtype="int8", wire_block=WB)

# perturb the shared-init rows so the mix is non-trivial per node
rng = np.random.default_rng(0)
pstart = {k: v + jnp.asarray(rng.normal(0, 0.05, v.shape), v.dtype)
          for k, v in payload0.items()}
kw = dict(params=pstart, stacked=True, data_sizes=[1.0] * N,
          backend="gossip", mesh=mesh, axis="node")
sa = SwarmSession(mk(1.5), id_step, eval_fn, **kw)
assert sa.sync_schedule.payload == "lora", sa.sync_schedule.describe()
assert sa.payload_params == p_payload, (sa.payload_params, p_payload)
for _ in range(6):
    out = sa.round(batches, val)
    assert not np.asarray(out["gates"]).any()
sb = SwarmSession(mk(0.0), id_step, eval_fn, **kw)
sb.load_state(sa.state)
out = sb.round(batches, val)
assert np.asarray(out["gates"]).all()
W = np.asarray(Wring)
for k, v in sb.state.params.items():
    got = np.asarray(v)
    want = np.tensordot(W, np.asarray(pstart[k]), axes=(1, 0))
    err = np.abs(got - want).max()
    assert err < 1e-5, (k, err)
print("OK adapter_parity")

# --- checkpoint: save -> restore -> continue == never stopping ------------
def decay_step(p, o, b, s):
    return ({k: v * 0.999 for k, v in p.items()}, o,
            {"loss": 0.0 * jnp.sum(p["head/out/w"])})

ccfg = mk(0.0)
ref = SwarmSession(ccfg, decay_step, eval_fn, **kw)
for _ in range(4):
    ref.round(batches, val)
s1 = SwarmSession(ccfg, decay_step, eval_fn, **kw)
for _ in range(2):
    s1.round(batches, val)
path = os.path.join(tempfile.mkdtemp(), "hetero_mesh.msgpack")
s1.save(path)
s2 = SwarmSession.restore(path, ccfg, decay_step, eval_fn, **kw)
for _ in range(2):
    s2.round(batches, val)
for k in ref.state.params:
    np.testing.assert_array_equal(np.asarray(s2.state.params[k]),
                                  np.asarray(ref.state.params[k]))
for a, b in zip(jax.tree.leaves(s2.state.wire),
                jax.tree.leaves(ref.state.wire)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK checkpoint")

# --- the model zoo is engine-backend only, even with a real mesh ----------
step_fns = [id_step] * N
eval_fns = [eval_fn] * N
try:
    SwarmSession(mk(0.0), step_fns, eval_fns,
                 params=[nd.payload() for nd in nodes],
                 data_sizes=[1.0] * N, backend="gossip", mesh=mesh,
                 axis="node")
except ValueError as e:
    assert "engine-backend only" in str(e), e
    print("OK zoo_gossip_rejected")
"""


@pytest.fixture(scope="module")
def spmd_out():
    return _run(_CHECKS)  # module scope: the subprocess runs once


def test_adapter_int8_bytes_under_five_percent_of_full_f32(spmd_out):
    """ISSUE 10 acceptance: HLO-measured collective bytes of the adapter-only
    int8 sync ≤ 5% of syncing the full model state in f32."""
    assert "OK hetero_bytes" in spmd_out


def test_committed_adapters_match_ring_oracle_on_mesh(spmd_out):
    """Committed flat-payload adapters ≤ 1e-5 of the numpy ring-W oracle
    after the mesh int8 EF wire settles."""
    assert "OK adapter_parity" in spmd_out


def test_lora_payload_mesh_checkpoint_bit_identical(spmd_out):
    """save → restore → continue equals never stopping, for the flat adapter
    state and the mesh EF wire residuals (ISSUE 10 satellite)."""
    assert "OK checkpoint" in spmd_out


def test_zoo_closures_rejected_on_gossip_backend(spmd_out):
    """Per-node closure lists (the model zoo) stay engine-backend only even
    when a real mesh is supplied."""
    assert "OK zoo_gossip_rejected" in spmd_out
