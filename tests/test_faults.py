"""Chaos plane: deterministic fault injection + graceful degradation.

Pins the acceptance criteria of the faults subsystem:
  * `faults.retry.with_retry` — bounded attempts, exponential backoff,
    timeout budget, exception routing (RetryError wrap vs raise_last);
  * `FaultPlan` — declarative, validated, seeded; lowering produces the
    exact membership/corruption/rejoin/preempt matrices;
  * `comms.payload_checksum` + `faults.signals.flip_payload_bits` — any
    injected bit flip is detected, deterministically per (seed, round);
  * checkpoint durability — atomic writes (a failed save never tears the
    previous checkpoint), truncated/corrupt files raise a clear error,
    transient IO errors are retried;
  * degradation policies — corrupt-wire senders are quarantined for the
    round (reject-and-keep-local), sub-quorum rounds hold every node's
    locals (engine AND host backends), crash→rejoin resets the EF wire;
  * parity — every FaultPlan kind runs to completion with no hang and the
    committed params match the float64 numpy oracle (`faults.oracle`):
    full-trajectory ≤2e-5 on the f32 engine, settled ≤1e-5 on the int8
    EF wire; preempt-and-restore is bit-identical to the uninterrupted
    twin; the whole plan replays against ONE compiled round (zero
    retraces across crash/straggle/drop/corrupt).
"""
import dataclasses

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

import repro.faults.oracle as oracle
from repro.checkpointing import io as ckpt_io
from repro.checkpointing import load_pytree, save_pytree
from repro.configs.base import SwarmConfig
from repro.core import comms
from repro.core.session import SwarmSession
from repro.faults import (FaultEvent, FaultPlan, RetryError, flip_payload_bits,
                          idle_signals, run_plan, with_retry)
from repro.faults.signals import FaultSignals, plan_key

N = 4


# ---------------------------------------------------------------------------
# toy session plumbing (same dynamics the oracle replicates)
# ---------------------------------------------------------------------------

def _pull_step(p, o, b, s):
    """x ← x + 0.1·(target − x): the oracle's linear local step."""
    g = p["x"] - b
    return {"x": p["x"] - 0.1 * g}, o, {"loss": jnp.sum(g * g)}


def _id_step(p, o, b, s):
    return p, o, {"loss": 0.0 * jnp.sum(p["x"])}


def _accept_eval(p, v):
    return 1.0 - 0.0 * jnp.sum(p["x"])


def _cfg(**kw):
    kw.setdefault("n_nodes", N)
    kw.setdefault("sync_every", 2)
    kw.setdefault("merge", "fedavg")
    kw.setdefault("topology", "full")
    kw.setdefault("lora_only", False)
    kw.setdefault("val_threshold", 0.0)
    return SwarmConfig(**kw)


def _targets(d=8):
    return jnp.asarray([np.full((d,), t, np.float32) for t in range(N)])


def _session(cfg, train_step=_pull_step, eval_fn=_accept_eval, *,
             params=None, sizes=None, **kw):
    params = {"x": jnp.zeros((8,))} if params is None else params
    sizes = [1.0, 2.0, 3.0, 4.0] if sizes is None else sizes
    return SwarmSession(cfg, train_step, eval_fn, params=params,
                        data_sizes=sizes, **kw)


# ---------------------------------------------------------------------------
# retry helper
# ---------------------------------------------------------------------------

def test_retry_transient_success_and_backoff_schedule():
    delays, calls = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    got = with_retry(flaky, attempts=5, base_delay=0.02, backoff=2.0,
                     sleep=delays.append)
    assert got == "ok" and len(calls) == 3
    assert delays == [0.02, 0.04]          # base · backoff^attempt


def test_retry_exhaustion_wraps_in_retryerror():
    boom = OSError("disk on fire")

    def always_fails():
        raise boom

    with pytest.raises(RetryError, match="3 attempt") as exc_info:
        with_retry(always_fails, attempts=3, sleep=lambda s: None,
                   describe="checkpoint write")
    assert exc_info.value.last_exception is boom
    assert exc_info.value.__cause__ is boom
    assert "checkpoint write" in str(exc_info.value)


def test_retry_raise_last_surfaces_original_type():
    def missing():
        raise FileNotFoundError("no such checkpoint")

    with pytest.raises(FileNotFoundError):
        with_retry(missing, attempts=2, sleep=lambda s: None, raise_last=True)


def test_retry_timeout_budget_stops_early():
    clock = {"t": 0.0}

    def tick():
        return clock["t"]

    def sleep(s):
        clock["t"] += s

    def always_fails():
        clock["t"] += 0.5
        raise OSError("slow failure")

    with pytest.raises(RetryError):
        with_retry(always_fails, attempts=100, base_delay=0.4, backoff=1.0,
                   timeout=1.0, sleep=sleep, clock=tick)
    # each attempt burns 0.5 s + 0.4 s backoff: the 1.0 s budget admits at
    # most two attempts, nowhere near the 100-attempt bound
    assert clock["t"] < 2.5


def test_retry_unlisted_exception_propagates_immediately():
    delays, calls = [], []

    def typo():
        calls.append(1)
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        with_retry(typo, attempts=5, retry_on=(OSError,), sleep=delays.append)
    assert len(calls) == 1 and delays == []


def test_retry_validates_attempts():
    with pytest.raises(ValueError):
        with_retry(lambda: 1, attempts=0)


# ---------------------------------------------------------------------------
# FaultPlan: declarative grammar + lowering
# ---------------------------------------------------------------------------

def test_plan_builders_validate():
    plan = FaultPlan(N, 6)
    with pytest.raises(ValueError):
        plan.crash(7, at=0)                     # node out of range
    with pytest.raises(ValueError):
        plan.crash(0, at=6)                     # round out of range
    with pytest.raises(ValueError):
        plan.crash(0, at=3, rejoin=3)           # rejoin must be later
    with pytest.raises(ValueError):
        plan.straggle(0, at=1, rounds=0)
    with pytest.raises(ValueError):
        FaultPlan(0, 6)
    with pytest.raises(ValueError):
        FaultPlan(N, 6, events=(FaultEvent("meteor", 0, 0),))


def test_plan_builders_are_pure():
    base = FaultPlan(N, 6)
    withcrash = base.crash(1, at=2)
    assert base.events == () and len(withcrash.events) == 1


def test_plan_lowering_windows():
    plan = (FaultPlan(N, 6, seed=5)
            .crash(1, at=1, rejoin=3)     # out rounds 1-2, back at 3
            .straggle(3, at=2, rounds=2)  # out rounds 2-3
            .drop(0, at=4)                # out round 4 only
            .corrupt(2, at=5)
            .preempt(at=3))
    low = plan.lower(corrupt_in_graph=True)
    want_active = np.ones((6, N), bool)
    want_active[1:3, 1] = False
    want_active[2:4, 3] = False
    want_active[4, 0] = False
    np.testing.assert_array_equal(low.active, want_active)
    want_corrupt = np.zeros((6, N), bool)
    want_corrupt[5, 2] = True
    np.testing.assert_array_equal(low.corrupt, want_corrupt)
    # rejoin = first active round after an absence
    assert low.rejoin[3, 1] and low.rejoin[4, 3] and low.rejoin[5, 0]
    assert low.rejoin.sum() == 3
    np.testing.assert_array_equal(low.preempt,
                                  np.arange(6) == 3)
    # without in-graph support, corruption lowers to a drop
    low2 = plan.lower(corrupt_in_graph=False)
    assert not low2.corrupt.any()
    assert not low2.active[5, 2]


def test_crash_without_rejoin_is_permanent():
    low = FaultPlan(N, 5).crash(2, at=1).lower()
    np.testing.assert_array_equal(low.active[:, 2],
                                  [True, False, False, False, False])


# ---------------------------------------------------------------------------
# checksum + deterministic bit flips
# ---------------------------------------------------------------------------

def _payload(seed=0, d=32):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(0, 1, (N, d)), jnp.float32),
            "b": jnp.asarray(rng.normal(0, 1, (N, 2, d)), jnp.float32),
            "none": None}


def test_checksum_localizes_a_single_bit_flip():
    payload = _payload()
    before = np.asarray(comms.payload_checksum(payload))
    raw = np.asarray(payload["b"]).copy()
    raw_bits = raw.view(np.uint32)
    raw_bits[2, 1, 7] ^= np.uint32(1) << 3       # one bit, node 2
    after = np.asarray(comms.payload_checksum(
        dict(payload, b=jnp.asarray(raw))))
    changed = before != after
    np.testing.assert_array_equal(changed, [False, False, True, False])


def test_flip_payload_bits_is_targeted_and_deterministic():
    payload = _payload()
    corrupt = jnp.asarray([False, True, False, True])
    key = plan_key(seed=9, round_index=4)
    out1 = flip_payload_bits(payload, corrupt, key)
    out2 = flip_payload_bits(payload, corrupt, key)
    for leaf_name in ("a", "b"):
        x, y = np.asarray(payload[leaf_name]), np.asarray(out1[leaf_name])
        np.testing.assert_array_equal(x[0], y[0])       # clean rows intact
        np.testing.assert_array_equal(x[2], y[2])
        assert (x[1] != y[1]).any() and (x[3] != y[3]).any()
        assert np.isfinite(y).all()                     # mantissa-only flips
        np.testing.assert_array_equal(y, np.asarray(out2[leaf_name]))
    assert out1["none"] is None
    # every injected flip is caught by the checksum
    ok = np.asarray(comms.payload_checksum(payload)) == np.asarray(
        comms.payload_checksum(out1))
    np.testing.assert_array_equal(ok, ~np.asarray(corrupt))


def test_idle_signals_flip_nothing():
    payload = _payload()
    sig = idle_signals(N)
    out = flip_payload_bits(payload, sig.corrupt, sig.key)
    for name in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(payload[name]),
                                      np.asarray(out[name]))


# ---------------------------------------------------------------------------
# checkpoint durability (atomic write + clear corruption errors + retry)
# ---------------------------------------------------------------------------

def _tree():
    return {"x": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}


def test_failed_save_never_tears_the_previous_checkpoint(tmp_path,
                                                         monkeypatch):
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, _tree(), metadata={"v": 1})

    def broken_replace(src, dst):
        raise OSError("simulated rename failure")

    monkeypatch.setattr(ckpt_io.os, "replace", broken_replace)
    with pytest.raises(RetryError):
        save_pytree(path, {"x": jnp.zeros((3, 4))}, metadata={"v": 2})
    monkeypatch.undo()
    # old checkpoint intact, no temp-file litter
    assert ckpt_io.load_metadata(path) == {"v": 1}
    np.testing.assert_array_equal(
        np.asarray(load_pytree(path, _tree())["x"]),
        np.asarray(_tree()["x"]))
    assert [p.name for p in tmp_path.iterdir()] == ["ckpt.msgpack"]


def test_transient_save_failure_is_retried(tmp_path, monkeypatch):
    path = str(tmp_path / "ckpt.msgpack")
    real_replace = ckpt_io.os.replace
    fails = {"left": 2}

    def flaky_replace(src, dst):
        if fails["left"] > 0:
            fails["left"] -= 1
            raise OSError("transient")
        return real_replace(src, dst)

    monkeypatch.setattr(ckpt_io.os, "replace", flaky_replace)
    save_pytree(path, _tree(), metadata={"v": 3})
    assert fails["left"] == 0
    assert ckpt_io.load_metadata(path) == {"v": 3}


def test_truncated_checkpoint_raises_clear_error(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, _tree())
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[:len(blob) // 2])
    with pytest.raises(ValueError, match="corrupt or truncated"):
        load_pytree(path, _tree())
    with pytest.raises(ValueError, match="corrupt or truncated"):
        ckpt_io.load_metadata(path)


def test_non_checkpoint_msgpack_raises_clear_error(tmp_path):
    path = str(tmp_path / "notckpt.msgpack")
    open(path, "wb").write(msgpack.packb([1, 2, 3]))
    with pytest.raises(ValueError, match="corrupt or truncated"):
        load_pytree(path, _tree())


def test_missing_checkpoint_raises_file_not_found(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_pytree(str(tmp_path / "nope.msgpack"), _tree())


# ---------------------------------------------------------------------------
# fault trajectories match the numpy oracle (f32 engine, every plan kind)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("merge,topology", [
    ("fedavg", "full"), ("fedavg", "ring"),
    ("fisher", "full"), ("fisher", "ring"),
    ("gradmatch", "full"),
])
def test_fault_trajectory_matches_oracle(merge, topology):
    """crash+rejoin / straggle / drop against the float64 oracle: the full
    committed-params trajectory, every round, ≤2e-5."""
    plan = (FaultPlan(N, 7)
            .crash(1, at=1, rejoin=3)
            .straggle(3, at=4, rounds=1)
            .drop(0, at=5))
    cfg = _cfg(merge=merge, topology=topology)
    sess = _session(cfg)
    targets = _targets()
    batches = jnp.broadcast_to(targets, (cfg.sync_every, N, 8))
    traj = []
    _, logs = run_plan(sess, plan, batches, jnp.zeros((N, 1)),
                       on_round=lambda r, lg: traj.append(
                           np.asarray(sess.state.params["x"]).copy()))
    assert all(not lg["gates"][~lg["active"]].any() for lg in logs)
    want = oracle.simulate(
        np.zeros((N, 8)), np.asarray(targets), plan.lower().active,
        merge=merge, topology=topology, lr=0.1,
        steps_per_round=cfg.sync_every, data_sizes=[1.0, 2.0, 3.0, 4.0],
        fisher_decay=cfg.fisher_decay)
    assert len(traj) == plan.n_rounds
    for r, (got, exp) in enumerate(zip(traj, want)):
        np.testing.assert_allclose(got, exp, atol=2e-5,
                                   err_msg=f"round {r} diverged from oracle")


def test_quorum_holds_locals_engine_and_recovers():
    """Sub-quorum membership: local training continues, every gate closes,
    nobody commits; the first round back at quorum merges again."""
    cfg = _cfg(quorum=3, sync_every=1)
    sess = _session(cfg, train_step=_id_step,
                    params={"x": _targets()}, stacked=True)
    batches = jnp.zeros((1, N, 8))
    val = jnp.zeros((N, 1))
    x0 = np.asarray(sess.state.params["x"]).copy()
    sess.set_active([True, True, False, False])      # 2 < quorum
    out = sess.round(batches, val)
    assert not bool(out["quorum_ok"])
    assert not np.asarray(out["gates"]).any()
    np.testing.assert_array_equal(np.asarray(sess.state.params["x"]), x0)
    sess.join(2)                                     # 3 == quorum
    out = sess.round(batches, val)
    assert bool(out["quorum_ok"])
    np.testing.assert_array_equal(np.asarray(out["gates"]),
                                  [True, True, True, False])
    want = oracle.commit(x0, oracle.merge_candidate(
        x0, [1, 1, 1, 0], merge="fedavg", topology="full",
        data_sizes=[1.0, 2.0, 3.0, 4.0]), [1, 1, 1, 0], quorum=3)
    np.testing.assert_allclose(np.asarray(sess.state.params["x"]), want,
                               atol=2e-6)


def test_quorum_rejects_unsatisfiable_config():
    with pytest.raises(ValueError, match="quorum"):
        _session(_cfg(quorum=N + 1))


def test_quorum_holds_locals_host_backend():
    def train_step(p, o, b, s):
        return p, o, {"loss": 0.0}

    def eval_fn(p, v):
        return 1.0

    cfg = _cfg(quorum=3, sync_every=1)
    sess = SwarmSession(cfg, train_step, eval_fn,
                        params=[{"x": np.full(4, float(i))} for i in range(N)],
                        data_sizes=[1.0] * N, backend="host")
    sess.set_active([True, True, False, False])
    batches = [[np.zeros(4)] * N]
    log = sess.round(batches, [np.zeros(1)] * N)
    assert log["quorum_ok"] is False
    assert not any(log["gates"])
    for i, p in enumerate(sess.node_params):         # everyone kept locals
        np.testing.assert_array_equal(np.asarray(p["x"]), np.full(4, float(i)))
    sess.join(2)
    log = sess.round(batches, [np.zeros(1)] * N)
    assert log["quorum_ok"] is True
    assert log["gates"][:3] == [True, True, True]


# ---------------------------------------------------------------------------
# int8 EF wire: corrupt quarantine + crash→rejoin settled parity
# ---------------------------------------------------------------------------

def _settled_int8_state(merge, topology, *, plan=None, rounds=6, x0=None):
    """Phase 1 of the two-phase settle idiom: reject-gate rounds
    (val_threshold 1.5 > any relative metric) freeze the params while the
    EF wire telescopes onto them — optionally under a fault plan."""
    cfg = _cfg(merge=merge, topology=topology, sync_every=1,
               val_threshold=1.5, wire_dtype="int8", wire_block=128)
    rng = np.random.default_rng(11)
    x0 = (rng.normal(0, 1, (N, 128)).astype(np.float32)
          if x0 is None else np.asarray(x0))
    sess = _session(cfg, train_step=_id_step, params={"x": jnp.asarray(x0)},
                    stacked=True)
    batches = jnp.zeros((1, N, 8))
    val = jnp.zeros((N, 1))
    if plan is not None:
        sess, logs = run_plan(sess, plan, batches, val)
        assert not any(lg["gates"].any() for lg in logs)
    else:
        for _ in range(rounds):
            out = sess.round(batches, val)
            assert not np.asarray(out["gates"]).any()
    state = sess.state
    np.testing.assert_array_equal(np.asarray(state.params["x"]), x0)
    return cfg, state, x0                            # params never moved


@pytest.mark.parametrize("merge,topology", [("fedavg", "full"),
                                            ("fisher", "ring")])
def test_int8_crash_rejoin_settled_parity(merge, topology):
    """crash → rejoin (EF quarantine) on the quantized wire: after the
    residual re-settles, one accepting round commits ≤1e-5 of the numpy
    oracle — the rejoined node's stale reference never poisons the merge."""
    plan = FaultPlan(N, 8).crash(1, at=1, rejoin=2)   # 6 settle rounds after
    cfg, state, x0 = _settled_int8_state(merge, topology, plan=plan)
    accept = _session(dataclasses.replace(cfg, val_threshold=0.0),
                      train_step=_id_step, params={"x": jnp.zeros((N, 128))},
                      stacked=True)
    accept.load_state(state)
    out = accept.round(jnp.zeros((1, N, 8)), jnp.zeros((N, 1)))
    assert np.asarray(out["gates"]).all()
    want = oracle.commit(x0, oracle.merge_candidate(
        x0, np.ones(N, bool), merge=merge, topology=topology,
        data_sizes=[1.0, 2.0, 3.0, 4.0]), np.ones(N, bool))
    np.testing.assert_allclose(np.asarray(accept.state.params["x"]), want,
                               atol=1e-5)


def test_corrupt_wire_quarantines_sender_and_matches_oracle():
    """An injected bit flip is detected (wire_ok), the sender is excluded
    from the merge AND keeps its own locals, and the survivors' commit
    matches the oracle merge over the clean membership ≤1e-5."""
    cfg, state, x0 = _settled_int8_state("fedavg", "full", rounds=6)
    accept = _session(dataclasses.replace(cfg, val_threshold=0.0),
                      train_step=_id_step, params={"x": jnp.zeros((N, 128))},
                      stacked=True)
    accept.load_state(state)
    faults = FaultSignals(corrupt=jnp.asarray([False, False, True, False]),
                          key=plan_key(seed=7, round_index=0))
    out = accept.round(jnp.zeros((1, N, 8)), jnp.zeros((N, 1)), faults=faults)
    np.testing.assert_array_equal(np.asarray(out["wire_ok"]),
                                  [True, True, False, True])
    np.testing.assert_array_equal(np.asarray(out["gates"]),
                                  [True, True, False, True])
    got = np.asarray(accept.state.params["x"])
    clean = np.asarray([True, True, False, True])
    want = oracle.commit(x0, oracle.merge_candidate(
        x0, clean, merge="fedavg", topology="full",
        data_sizes=[1.0, 2.0, 3.0, 4.0]), clean)
    np.testing.assert_allclose(got, want, atol=1e-5)
    np.testing.assert_array_equal(got[2], x0[2])     # sender kept locals


def test_faults_rejected_off_the_wire_path():
    sess = _session(_cfg())                          # f32: no wire state
    sig = idle_signals(N)
    with pytest.raises(ValueError, match="corrupt-wire injection"):
        sess.round(jnp.zeros((2, N, 8)), jnp.zeros((N, 1)), faults=sig)


# ---------------------------------------------------------------------------
# zero retraces + preempt bit-identity
# ---------------------------------------------------------------------------

def test_whole_plan_replays_against_one_compiled_round():
    """crash, straggle, drop, AND corrupt across 8 rounds: the trace count
    after round 0 never moves again — every fault is runtime data."""
    traces = []

    def counting_step(p, o, b, s):
        traces.append(1)
        return _id_step(p, o, b, s)

    cfg = _cfg(sync_every=1, val_threshold=1.5, wire_dtype="int8",
               wire_block=128, quorum=2)
    sess = _session(cfg, train_step=counting_step,
                    params={"x": _targets(128)}, stacked=True)
    batches = jnp.zeros((1, N, 8))
    val = jnp.zeros((N, 1))
    sess.round(batches, val, faults=idle_signals(N))  # compile once
    warm = len(traces)
    plan = (FaultPlan(N, 8, seed=1)
            .crash(1, at=1, rejoin=3)
            .straggle(3, at=2, rounds=2)
            .drop(0, at=5)
            .corrupt(2, at=6))
    run_plan(sess, plan, batches, val)
    assert len(traces) == warm, "a fault event retraced the round"


def test_preempt_restore_is_bit_identical(tmp_path):
    """preempt-and-restore mid-plan (save → fresh session → load) == the
    uninterrupted twin, bit for bit — params, EF wire, rng, counters."""
    def make(cfg):
        return lambda: _session(cfg, params={"x": jnp.zeros((N, 128))},
                                stacked=True)

    def run(with_preempt):
        cfg = _cfg(sync_every=1, wire_dtype="int8", wire_block=128)
        plan = FaultPlan(N, 6).crash(2, at=1, rejoin=4)
        if with_preempt:
            plan = plan.preempt(at=3)
        sess = make(cfg)()
        targets = _targets(128)
        batches = jnp.broadcast_to(targets, (1, N, 128))
        sess, logs = run_plan(sess, plan, batches, jnp.zeros((N, 1)),
                              make_session=make(cfg),
                              checkpoint_path=str(tmp_path / "preempt.msgpack"))
        return sess.state, logs

    a, logs_a = run(with_preempt=True)
    b, logs_b = run(with_preempt=False)
    assert any(lg["preempted"] for lg in logs_a)
    np.testing.assert_array_equal(np.asarray(a.params["x"]),
                                  np.asarray(b.params["x"]))
    np.testing.assert_array_equal(np.asarray(a.wire["x"]),
                                  np.asarray(b.wire["x"]))
    np.testing.assert_array_equal(np.asarray(a.rng), np.asarray(b.rng))
    assert int(a.round) == int(b.round) and int(a.step) == int(b.step)
    for la, lb in zip(logs_a, logs_b):
        np.testing.assert_array_equal(la["gates"], lb["gates"])


def test_run_plan_requires_preempt_plumbing():
    sess = _session(_cfg())
    plan = FaultPlan(N, 3).preempt(at=1)
    with pytest.raises(ValueError, match="preempt"):
        run_plan(sess, plan, jnp.zeros((2, N, 8)), jnp.zeros((N, 1)))


def test_run_plan_checks_node_count():
    sess = _session(_cfg())
    with pytest.raises(ValueError, match="nodes"):
        run_plan(sess, FaultPlan(N + 1, 3), jnp.zeros((2, N, 8)),
                 jnp.zeros((N, 1)))
