from repro.data.synthetic import (  # noqa: F401
    augment, batches, dirichlet_shards, macenko_normalize, make_histo_dataset,
    make_lm_stream, paper_splits, shard_to_nodes,
)
