"""Synthetic data generation (repro-band-2 gate: the paper's 10k-image
histopathology corpus is private; we simulate a statistically analogous one).

Histopathology images are class-conditional random textures: each of the 3
classes has a distinct spatial frequency / color signature plus per-image
noise, giving a learnable but non-trivial 3-way problem whose difficulty is
tuned so a small DenseNet lands in the paper's observed AUC band (~0.6-0.75)
within a few epochs. Augmentations reproduce §4.1: random rotations (±15°
approximated by ±1 90°-steps + shear noise), horizontal flips, color jitter
(±0.1). Macenko stain normalization is approximated by per-channel
standardization to a reference stain vector.

LM streams (for the 10 assigned architectures) are Zipf-sampled token
sequences with per-node topic bias, so swarm experiments on LM archs also see
heterogeneous shards.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# histopathology-like images
# ---------------------------------------------------------------------------

_STAIN_REF = np.array([0.65, 0.70, 0.29])  # H&E-ish reference channel weights


def _class_texture(rng, size: int, cls: int) -> np.ndarray:
    """Distinct spatial-frequency signature per class."""
    freq = [2, 5, 9][cls]
    phase = rng.uniform(0, 2 * np.pi, (2,))
    xx, yy = np.meshgrid(np.linspace(0, 2 * np.pi, size),
                         np.linspace(0, 2 * np.pi, size))
    base = np.sin(freq * xx + phase[0]) * np.cos(freq * yy + phase[1])
    blobs = rng.normal(0, 1, (size // 8, size // 8))
    blobs = np.kron(blobs, np.ones((8, 8)))[:size, :size]
    mix = [0.7, 0.5, 0.3][cls]
    return mix * base + (1 - mix) * blobs


def make_histo_dataset(n: int, *, size: int = 32, n_classes: int = 3,
                       class_probs: Optional[Sequence[float]] = None,
                       noise: float = 0.8, seed: int = 0):
    """Returns (images [N,H,W,3] float32, labels [N] int32)."""
    rng = np.random.default_rng(seed)
    probs = (np.full(n_classes, 1.0 / n_classes)
             if class_probs is None else np.asarray(class_probs, float))
    probs = probs / probs.sum()
    labels = rng.choice(n_classes, size=n, p=probs).astype(np.int32)
    images = np.empty((n, size, size, 3), np.float32)
    for i, y in enumerate(labels):
        tex = _class_texture(rng, size, int(y))
        chan_w = _STAIN_REF * (1.0 + 0.3 * np.eye(3)[y % 3])
        img = tex[..., None] * chan_w[None, None, :]
        img = img + noise * rng.normal(0, 1, (size, size, 3))
        images[i] = img
    return macenko_normalize(images), labels


def macenko_normalize(images: np.ndarray) -> np.ndarray:
    """Approximate Macenko stain normalization: per-channel standardization
    against the reference stain vector (the paper's preprocessing)."""
    mu = images.mean(axis=(1, 2), keepdims=True)
    sd = images.std(axis=(1, 2), keepdims=True) + 1e-6
    return ((images - mu) / sd * _STAIN_REF[None, None, None, :]).astype(np.float32)


def augment(images: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Paper §4.1: rotations (±15° ≈ k90 + jitter), h-flips, color jitter ±0.1."""
    out = images.copy()
    n = len(out)
    flip = rng.random(n) < 0.5
    out[flip] = out[flip, :, ::-1]
    rot = rng.integers(0, 4, n)
    for k in range(1, 4):
        idx = rot == k
        out[idx] = np.rot90(out[idx], k=k, axes=(1, 2))
    jitter = 1.0 + rng.uniform(-0.1, 0.1, (n, 1, 1, 3)).astype(np.float32)
    return out * jitter


# ---------------------------------------------------------------------------
# node sharding — the paper's imbalance scenarios
# ---------------------------------------------------------------------------

def paper_splits(n_total: int, fractions=(0.10, 0.30, 0.30, 0.30)) -> List[int]:
    """§4.1 federated-average unbalanced split: 10/30/30/30."""
    sizes = [int(round(f * n_total)) for f in fractions]
    sizes[-1] = n_total - sum(sizes[:-1])
    return sizes


def shard_to_nodes(images, labels, sizes: Sequence[int], *, seed: int = 0,
                   class_bias: Optional[Sequence[Sequence[float]]] = None):
    """Partition a dataset into per-node shards, optionally class-biased.

    class_bias[i] = unnormalized class sampling weights for node i — the
    paper's "biased data allocations".
    """
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(labels))
    images, labels = images[order], labels[order]
    shards = []
    pool = np.ones(len(labels), bool)
    for i, sz in enumerate(sizes):
        idx_pool = np.flatnonzero(pool)
        if class_bias is not None:
            w = np.asarray(class_bias[i], float)[labels[idx_pool]]
            w = w / w.sum()
            pick = rng.choice(idx_pool, size=min(sz, len(idx_pool)),
                              replace=False, p=w)
        else:
            pick = idx_pool[:sz]
        pool[pick] = False
        shards.append((images[pick], labels[pick]))
    return shards


def dirichlet_shards(images, labels, n_nodes: int, alpha: float = 0.5,
                     seed: int = 0):
    """Standard non-IID federated benchmark sharding (Dirichlet over classes)."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    node_of = np.empty(len(labels), np.int32)
    for c in range(n_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_nodes)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for node, part in enumerate(np.split(idx, cuts)):
            node_of[part] = node
    return [(images[node_of == i], labels[node_of == i]) for i in range(n_nodes)]


def batches(images, labels, batch_size: int, rng: np.random.Generator,
            *, augment_data: bool = True):
    """One epoch of shuffled minibatches (drops remainder)."""
    order = rng.permutation(len(labels))
    for start in range(0, len(order) - batch_size + 1, batch_size):
        idx = order[start:start + batch_size]
        x = images[idx]
        if augment_data:
            x = augment(x, rng)
        yield x, labels[idx]


# ---------------------------------------------------------------------------
# LM token streams (assigned-architecture training)
# ---------------------------------------------------------------------------

def make_lm_stream(n_seqs: int, seq_len: int, vocab: int, *, seed: int = 0,
                   topic_bias: float = 0.0, n_topics: int = 8):
    """Zipf token sequences; topic_bias>0 skews each node toward one topic."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    base = 1.0 / ranks ** 1.1
    topic = seed % n_topics
    boost = np.ones(vocab)
    span = vocab // n_topics
    boost[topic * span:(topic + 1) * span] += topic_bias * 10
    p = base * boost
    p /= p.sum()
    toks = rng.choice(vocab, size=(n_seqs, seq_len + 1), p=p).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
