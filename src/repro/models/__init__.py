"""Unified model API over all six assigned families.

``build_model(cfg)`` returns a :class:`Model` bundle of pure functions with a
single batch convention, so the trainer / server / dry-run / swarm layers are
architecture-agnostic:

  train: batch = {tokens, labels[, patch_embeds | frames]}
  decode: (params, tokens[B,1], caches, cache_pos) -> (logits, new_caches)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cnn  # noqa: F401  (paper's model, used by examples)
from repro.models.encdec import (
    decode_step as _encdec_decode, forward_encdec, init_encdec, make_encdec_cache,
)
from repro.models.layers import softmax_xent
from repro.models.transformer import (
    forward_lm, init_lm, make_lm_cache, project_frontend,
)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Any], Any]
    loss_fn: Callable[..., Any]           # (params, batch, remat) -> (loss, metrics)
    decode: Callable[..., Any]            # (params, tokens, caches, cache_pos)
    init_cache: Callable[..., Any]        # (batch_size, max_len) -> caches
    prefill: Optional[Callable[..., Any]] = None


def _lm_model(cfg: ModelConfig) -> Model:
    def loss_fn(params, batch, remat=True):
        logits, aux, _ = forward_lm(params, cfg, batch["tokens"], remat=remat)
        xent = softmax_xent(logits, batch["labels"], batch.get("mask"))
        return xent + aux, {"xent": xent, "aux": aux}

    def prefill(params, batch, caches):
        logits, _, caches = forward_lm(
            params, cfg, batch["tokens"], caches=caches,
            cache_pos=jnp.int32(0), remat=False)
        return logits[:, -1:], caches

    def decode(params, tokens, caches, cache_pos):
        logits, _, caches = forward_lm(
            params, cfg, tokens, caches=caches, cache_pos=cache_pos, remat=False)
        return logits, caches

    return Model(cfg, lambda key: init_lm(key, cfg), loss_fn, decode,
                 lambda b, m: make_lm_cache(cfg, b, m), prefill)


def _vlm_model(cfg: ModelConfig) -> Model:
    """LM backbone consuming stub patch embeddings + text tokens."""

    def _embeds(params, batch):
        from repro.models.layers import dtype_of, embed
        tok = embed(params["embed"], batch["tokens"], dtype_of(cfg.compute_dtype))
        patches = project_frontend(params, cfg, batch["patch_embeds"].astype(tok.dtype))
        return jnp.concatenate([patches, tok], axis=1)

    def loss_fn(params, batch, remat=True):
        x = _embeds(params, batch)
        logits, aux, _ = forward_lm(params, cfg, embeds=x, remat=remat)
        txt_logits = logits[:, cfg.n_patches:]
        xent = softmax_xent(txt_logits, batch["labels"], batch.get("mask"))
        return xent + aux, {"xent": xent, "aux": aux}

    def prefill(params, batch, caches):
        x = _embeds(params, batch)
        logits, _, caches = forward_lm(params, cfg, embeds=x, caches=caches,
                                       cache_pos=jnp.int32(0), remat=False)
        return logits[:, -1:], caches

    def decode(params, tokens, caches, cache_pos):
        logits, _, caches = forward_lm(params, cfg, tokens, caches=caches,
                                       cache_pos=cache_pos, remat=False)
        return logits, caches

    return Model(cfg, lambda key: init_lm(key, cfg), loss_fn, decode,
                 lambda b, m: make_lm_cache(cfg, b, m), prefill)


def _encdec_model(cfg: ModelConfig) -> Model:
    def loss_fn(params, batch, remat=True):
        logits, aux = forward_encdec(params, cfg, batch["frames"],
                                     batch["tokens"], remat=remat)
        xent = softmax_xent(logits, batch["labels"], batch.get("mask"))
        return xent + aux, {"xent": xent, "aux": aux}

    def decode(params, tokens, caches, cache_pos):
        logits, _, caches = _encdec_decode(params, cfg, tokens, caches, cache_pos)
        return logits, caches

    return Model(cfg, lambda key: init_encdec(key, cfg), loss_fn, decode,
                 lambda b, m: make_encdec_cache(cfg, b, m))


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encdec:
        return _encdec_model(cfg)
    if cfg.family == "vlm":
        return _vlm_model(cfg)
    return _lm_model(cfg)
