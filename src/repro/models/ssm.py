"""Mamba-2 (SSD — state-space duality) block, chunked, pure jnp.

Follows the SSD formulation of arXiv:2405.21060: within a chunk the recurrence
is materialized as a decay-masked quadratic form (MXU-friendly); across chunks
a short scan carries the [H, P, N] state. The Pallas ``ssd_scan`` kernel in
``repro.kernels`` implements the same chunked schedule with explicit VMEM
tiling; this module is the lowering/oracle path.

Decode keeps an O(1) recurrent state — this is what makes ``long_500k``
natively sub-quadratic for the ssm/hybrid families.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, dtype_of, init_linear, linear
from repro.sharding.rules import logical_shard


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    h = cfg.n_ssm_heads
    pdim = di // h
    n = cfg.ssm_state
    g = cfg.ssm_groups
    return di, h, pdim, n, g


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    di, h, pdim, n, g = _dims(cfg)
    conv_dim = di + 2 * g * n
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return {
        # z (gate), x, B, C, dt in one projection
        "in_proj": init_linear(ks[0], d, 2 * di + 2 * g * n + h, cfg),
        "conv": {
            "w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim)) * 0.1).astype(dtype),
            "b": jnp.zeros((conv_dim,), dtype),
        },
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": init_linear(ks[2], di, d, cfg),
    }


def make_ssm_state(cfg: ModelConfig, batch: int, dtype):
    di, h, pdim, n, g = _dims(cfg)
    conv_dim = di + 2 * g * n
    return {
        "ssd": jnp.zeros((batch, h, pdim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    }


def _split_proj(cfg, zxbcdt):
    di, h, pdim, n, g = _dims(cfg)
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1
    )
    return z, xin, bmat, cmat, dt


def _causal_conv(conv_p, u, prefix=None):
    """Depthwise causal conv. u [B,S,C]; prefix [B,W-1,C] for decode."""
    w = conv_p["w"].astype(u.dtype)          # [W, C]
    width = w.shape[0]
    if prefix is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = prefix.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)  # [B, S+W-1, C]
    out = sum(full[:, i : i + u.shape[1]] * w[i] for i in range(width))
    out = out + conv_p["b"].astype(u.dtype)
    return jax.nn.silu(out), full[:, -(width - 1):]


def _gated_norm(scale, y, z, eps):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x, dt, a_log, bmat, cmat, chunk: int):
    """Chunked SSD scan (pure jnp oracle).

    x    [B,S,H,P]   inputs per head
    dt   [B,S,H]     softplus'd step sizes
    a_log[H]         -exp(a_log) is the decay rate
    bmat [B,S,G,N]   input->state projection
    cmat [B,S,G,N]   state->output projection
    Returns y [B,S,H,P], final_state [B,H,P,N].
    """
    b, s, h, pdim = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    nc = s // chunk
    dtype = x.dtype

    # per-step log decay
    dA = dt * (-jnp.exp(a_log.astype(jnp.float32)))       # [B,S,H] (<0)
    xdt = x * dt[..., None].astype(dtype)                  # weight input by dt

    def ch(t):  # reshape into chunks
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc, dAc = ch(xdt), ch(dA)
    bc = jnp.repeat(ch(bmat), rep, axis=3)                 # [B,nc,L,H,N]
    cc = jnp.repeat(ch(cmat), rep, axis=3)

    cum = jnp.cumsum(dAc, axis=2)                          # [B,nc,L,H]
    # intra-chunk: decay-masked quadratic attention
    # L_mat[i,j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,L,L,H]
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # mask BEFORE exp: exp of masked (positive) entries overflows and poisons
    # the backward pass with inf*0 = NaN
    lmat = jnp.exp(jnp.where(causal, diff, -1e30))
    scores = jnp.einsum("bclhn,bcmhn->bclmh", cc.astype(jnp.float32), bc.astype(jnp.float32))
    y_diag = jnp.einsum("bclmh,bclmh,bcmhp->bclhp", scores, lmat,
                        xc.astype(jnp.float32))

    # chunk-final states: sum_j exp(cum_last - cum_j) * B_j ⊗ x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [B,nc,L,H]
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn",
                        bc.astype(jnp.float32), decay_to_end, xc.astype(jnp.float32))

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [B,nc,H]

    def step(carry, inp):
        st_in, dec = inp                                    # [B,H,P,N], [B,H]
        new = carry * dec[:, :, None, None] + st_in
        return new, carry                                   # emit state BEFORE chunk

    init = jnp.zeros((b, h, pdim, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # [B,nc,H,P,N]

    # inter-chunk contribution: C_t · (decay to t) · S_prev
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp",
                       cc.astype(jnp.float32), jnp.exp(cum), prev_states)

    y = (y_diag + y_off).reshape(b, s, h, pdim).astype(dtype)
    return y, final


def ssm_block(p, x, cfg: ModelConfig, *, state=None):
    """Full mamba2 mixer. x [B,S,D] -> (y [B,S,D], new_state or None)."""
    b, s, d = x.shape
    di, h, pdim, n, g = _dims(cfg)
    zxbcdt = linear(p["in_proj"], x)
    z, xin, bmat, cmat, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    decode = state is not None and s == 1
    conv_prefix = state["conv"] if decode else None
    conv_out, new_conv = _causal_conv(p["conv"], conv_in, conv_prefix)
    xin, bmat, cmat = jnp.split(conv_out, [di, di + g * n], axis=-1)

    xh = xin.reshape(b, s, h, pdim)
    xh = logical_shard(xh, "batch", "seq", "ff", None)
    bm = bmat.reshape(b, s, g, n)
    cm = cmat.reshape(b, s, g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]

    if decode:
        # O(1) recurrent update
        dA = jnp.exp(dtv[:, 0] * (-jnp.exp(p["A_log"])))           # [B,H]
        bm1 = jnp.repeat(bm[:, 0], h // g, axis=1)                 # [B,H,N]
        cm1 = jnp.repeat(cm[:, 0], h // g, axis=1)
        xdt = (xh[:, 0] * dtv[:, 0, :, None]).astype(jnp.float32)  # [B,H,P]
        new_ssd = state["ssd"] * dA[:, :, None, None] + \
            jnp.einsum("bhp,bhn->bhpn", xdt, bm1.astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", new_ssd, cm1.astype(jnp.float32))
        y = y[:, None].astype(x.dtype)                              # [B,1,H,P]
        new_state = {"ssd": new_ssd, "conv": new_conv}
    else:
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        if pad:
            padded = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
            xh, bm, cm, dtv = padded(xh), padded(bm), padded(cm), padded(dtv)
        y, final = ssd_chunked(xh, dtv, p["A_log"], bm, cm, chunk)
        y = y[:, :s]
        new_state = {"ssd": final, "conv": new_conv} if state is not None else None

    y = y + xh[:, :s] * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = _gated_norm(p["norm_scale"], y, z, cfg.norm_eps)
    return linear(p["out_proj"], y.astype(x.dtype)), new_state
