"""Top-k Mixture-of-Experts with capacity-based scatter/gather dispatch.

Dispatch uses index scatter (memory traffic), NOT one-hot matmuls, so the
compiled FLOP count stays ≈ top_k × a dense MLP — this matters for the
roofline's MODEL_FLOPS/HLO_FLOPs "useful compute" ratio. Experts carry a
leading E axis sharded over the `model` mesh axis (expert parallelism);
with tokens sharded over `data`, GSPMD inserts the all-to-all exchange.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, dtype_of
from repro.sharding.rules import axis_size, logical_shard


def init_moe(key, cfg: ModelConfig):
    d = cfg.d_model
    fe = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)

    def expert_mat(k, i, o):
        return (jax.random.normal(k, (e, i, o)) / jnp.sqrt(i)).astype(dtype)

    return {
        "router": {"w": dense_init(ks[0], d, e, jnp.float32)},
        "experts": {
            "gate": {"w": expert_mat(ks[1], d, fe)},
            "up": {"w": expert_mat(ks[2], d, fe)},
            "down": {"w": expert_mat(ks[3], fe, d)},
        },
    }


def moe(p, x, cfg: ModelConfig):
    """x [B,S,D] -> (y [B,S,D], aux_loss scalar).

    Dispatch positions are computed PER BATCH ROW (per-group capacity): the
    running-count cumsum stays independent across the data-sharded batch axis,
    so GSPMD never has to serialize a global scan across shards (measured:
    a global-cumsum dispatch made granite-moe 17x more collective-bound).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s

    # --- routing (fp32 for stability) ---
    logits = x.astype(jnp.float32) @ p["router"]["w"]            # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # [B,S,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary loss (Switch-style) ---
    me = jnp.mean(probs.reshape(t, e), axis=0)                    # [E]
    assign = jax.nn.one_hot(expert_ids[..., 0].reshape(t), e, dtype=jnp.float32)
    ce = jnp.mean(assign, axis=0)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef

    # --- per-group capacity + position within (group, expert) ---
    cap_g = int(max(1, round(s * k / e * cfg.capacity_factor)))
    flat_ids = expert_ids.reshape(b, s * k)                       # group-major
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)         # [B,S*k,E]
    pos = jnp.cumsum(onehot, axis=1) - 1                          # per group
    pos = jnp.take_along_axis(pos, flat_ids[..., None], axis=2)[..., 0]
    keep = pos < cap_g
    pos = jnp.where(keep, pos, cap_g)                              # OOB -> dropped
    # global slot: group g owns rows [g*cap_g, (g+1)*cap_g) of each expert
    grp = jnp.arange(b, dtype=jnp.int32)[:, None]
    slot = grp * cap_g + pos                                       # [B, S*k]
    cap = b * cap_g

    # --- dispatch: scatter tokens to [E, C, D] buffers ---
    flat_ids = flat_ids.reshape(t * k)
    slot = slot.reshape(t * k)
    keep = keep.reshape(t * k)
    gate_flat = gate_vals.reshape(t * k)
    src = jnp.repeat(x.reshape(t, d), k, axis=0)                   # [T*k, D]
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_ids, jnp.where(keep, slot, cap)].add(src, mode="drop")
    # slot dim is batch-major (group g owns a contiguous slab) -> shard it over
    # data; experts over model. The scatter across both = the MoE all-to-all.
    # When n_experts ∤ model-axis (e.g. granite's 40 over 16), experts stay
    # replicated over model and slots shard over data only. (Measured
    # alternative — slots over (data×model) — removes the 16x FLOP redundancy
    # but the scatter across a model-sharded destination costs 7x more in
    # resharding collectives than the redundant compute: EXPERIMENTS §Perf.)
    e_div = axis_size("experts") > 1 and e % axis_size("experts") == 0
    # two-step dispatch: (1) the data-dependent SCATTER lands in a buffer
    # whose slot dim is data-sharded and expert dim replicated — fully local
    # (group-major slots); (2) a DENSE reshard moves experts onto the model
    # axis for the FFN — that is the MoE all-to-all, and GSPMD lowers dense
    # reshards efficiently (a scatter straight into a model-sharded dest
    # replicates the whole buffer instead: 203s vs 13s collective on phi3.5).
    buf = logical_shard(buf, None, "batch", None)
    if e_div:
        buf = logical_shard(buf, "experts", "batch", None)

    # --- expert FFN (batched over E) ---
    w = p["experts"]
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w["gate"]["w"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, w["up"]["w"].astype(x.dtype))
    h = g * u
    out = jnp.einsum("ecf,efd->ecd", h, w["down"]["w"].astype(x.dtype))
    if e_div:
        out = logical_shard(out, "experts", "batch", None)
    out = logical_shard(out, None, "batch", None)  # a2a back before gather

    # --- combine: gather back, weight by gates ---
    got = out[flat_ids, jnp.where(keep, slot, cap - 1)]            # [T*k, D]
    got = got * (keep[:, None] * gate_flat[:, None]).astype(x.dtype)
    y = got.reshape(t, k, d).sum(axis=1)
    return y.reshape(b, s, d), aux
