"""Shared pure-JAX layer primitives for the model zoo.

Everything is functional: ``init_*`` builds a param sub-pytree (nested dict of
jnp arrays), the matching apply function consumes it. Params carry no framework
wrapper so the swarm merge layer (core/) can treat any model uniformly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def init_linear(key, in_dim, out_dim, cfg: ModelConfig, bias: Optional[bool] = None):
    dtype = dtype_of(cfg.param_dtype)
    use_bias = cfg.use_bias if bias is None else bias
    p = {"w": dense_init(key, in_dim, out_dim, dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "lora_A" in p:  # LoRA adapter (injected by repro.core.lora)
        scale = p["lora_scale"].astype(x.dtype)
        y = y + ((x @ p["lora_A"].astype(x.dtype)) @ p["lora_B"].astype(x.dtype)) * scale
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_norm(dim: int, cfg: ModelConfig):
    return {"scale": jnp.ones((dim,), dtype_of(cfg.param_dtype))}


def rmsnorm(p, x, eps: float = 1e-5):
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(orig)


def init_embedding(key, vocab: int, dim: int, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    return {"table": (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)}


def embed(p, ids, compute_dtype):
    return p["table"].astype(compute_dtype)[ids]


def unembed(p, x):
    return x @ p["table"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim//2]


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {
            "gate": init_linear(ks[0], d, f, cfg),
            "up": init_linear(ks[1], d, f, cfg),
            "down": init_linear(ks[2], f, d, cfg),
        }
    return {
        "up": init_linear(ks[0], d, f, cfg),
        "down": init_linear(ks[1], f, d, cfg),
    }


def mlp(p, x, cfg: ModelConfig):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
    elif cfg.activation == "sq_relu":  # nemotron-4: squared ReLU
        h = jnp.square(jax.nn.relu(linear(p["up"], x)))
    else:
        h = jax.nn.gelu(linear(p["up"], x))
    return linear(p["down"], h)


# ---------------------------------------------------------------------------
# losses / misc
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Token-mean cross entropy. logits [..., V]; labels int [...]."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def count_params(params) -> int:
    return int(sum(p.size for p in jax.tree_util.tree_leaves(params)))
