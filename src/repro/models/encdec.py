"""Encoder-decoder transformer (SeamlessM4T-style audio family).

The modality frontend (mel-spectrogram + conv feature extractor) is a STUB per
the assignment carve-out: the model consumes precomputed frame embeddings
[B, S_enc, frontend_dim]. The encoder is bidirectional; the decoder has cached
causal self-attention + cross-attention to the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import attention, init_attention, make_cache
from repro.models.layers import (
    dtype_of, embed, init_embedding, init_linear, init_mlp, init_norm, linear,
    mlp, rmsnorm,
)
from repro.sharding.rules import logical_shard


def _init_enc_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": init_norm(cfg.d_model, cfg),
        "attn": init_attention(ks[0], cfg),
        "mlp_norm": init_norm(cfg.d_model, cfg),
        "mlp": init_mlp(ks[1], cfg),
    }


def _init_dec_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "attn_norm": init_norm(cfg.d_model, cfg),
        "attn": init_attention(ks[0], cfg),
        "cross_norm": init_norm(cfg.d_model, cfg),
        "cross": init_attention(ks[1], cfg),
        "mlp_norm": init_norm(cfg.d_model, cfg),
        "mlp": init_mlp(ks[2], cfg),
    }


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    ek = jax.random.split(ks[0], cfg.n_enc_layers)
    dk = jax.random.split(ks[1], cfg.n_layers)
    return {
        "frontend_proj": init_linear(ks[2], cfg.frontend_dim, cfg.d_model, cfg, bias=True),
        "enc_layers": jax.vmap(lambda k: _init_enc_block(k, cfg))(ek),
        "enc_norm": init_norm(cfg.d_model, cfg),
        "embed": init_embedding(ks[3], cfg.padded_vocab, cfg.d_model, cfg),
        "dec_layers": jax.vmap(lambda k: _init_dec_block(k, cfg))(dk),
        "final_norm": init_norm(cfg.d_model, cfg),
        "lm_head": {"w": init_linear(ks[4], cfg.d_model, cfg.padded_vocab, cfg, bias=False)["w"]},
    }


def encode(params, cfg: ModelConfig, frames):
    """frames [B, S_enc, frontend_dim] -> enc_out [B, S_enc, D]."""
    x = linear(params["frontend_proj"], frames.astype(dtype_of(cfg.compute_dtype)))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = logical_shard(x, "batch", "res_seq", "embed")

    def body(h, lp):
        a = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, _ = attention(lp["attn"], a, cfg, positions=positions, causal=False)
        h = h + a
        m = rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
        return h + mlp(lp["mlp"], m, cfg), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=cfg.n_enc_layers if cfg.unroll_layers else 1)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def make_encdec_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = dtype_of(cfg.compute_dtype)
    self_cache = jax.vmap(lambda _: make_cache(cfg, batch, max_len, dtype))(
        jnp.arange(cfg.n_layers))
    return {
        "self": self_cache,
        "enc_out": jnp.zeros((batch, cfg.enc_seq_len, cfg.d_model), dtype),
    }


def decode_step(params, cfg: ModelConfig, tokens, caches, cache_pos, *,
                enc_out=None, remat: bool = False):
    """Decoder forward. tokens [B,S]; caches from make_encdec_cache (or None
    for teacher-forced training with enc_out supplied)."""
    compute_dtype = dtype_of(cfg.compute_dtype)
    x = embed(params["embed"], tokens, compute_dtype)
    b, s = x.shape[:2]
    if enc_out is None:
        enc_out = caches["enc_out"].astype(compute_dtype)
    if cache_pos is not None:
        positions = cache_pos + jnp.arange(s, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc_positions = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None], (b, enc_out.shape[1]))
    x = logical_shard(x, "batch", "res_seq", "embed")
    self_caches = caches["self"] if caches is not None else None

    def body(carry, inp):
        h = carry
        lp, cache = inp
        a = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, kv = attention(lp["attn"], a, cfg, positions=positions,
                          cache=cache if cache else None, cache_pos=cache_pos)
        h = h + a
        c = rmsnorm(lp["cross_norm"], h, cfg.norm_eps)
        c, _ = attention(lp["cross"], c, cfg, positions=positions,
                         kv_x=enc_out, kv_positions=enc_positions, causal=False)
        h = h + c
        m = rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + mlp(lp["mlp"], m, cfg)
        return h, (kv if kv is not None else {})

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (params["dec_layers"], self_caches if self_caches is not None else {})
    x, new_self = jax.lax.scan(body, x, xs,
                               unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["lm_head"]["w"].astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.float32(-1e30).astype(logits.dtype), logits)
    logits = logical_shard(logits, "batch", "seq", "vocab")
    new_caches = None
    if caches is not None:
        new_caches = {"self": new_self, "enc_out": caches["enc_out"]}
    return logits, jnp.float32(0.0), new_caches


def forward_encdec(params, cfg: ModelConfig, frames, tokens, *, remat=False):
    """Teacher-forced training forward: (logits, aux)."""
    enc_out = encode(params, cfg, frames)
    logits, aux, _ = decode_step(params, cfg, tokens, None, None,
                                 enc_out=enc_out, remat=remat)
    return logits, aux
