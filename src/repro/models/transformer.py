"""Decoder-only LM assembly covering dense / moe / ssm / hybrid / vlm families.

Layers are **stacked and scanned** (`jax.lax.scan` over a leading L axis) so
that 60+-layer production configs lower and compile quickly for the 80-way
dry-run matrix. Mixed per-layer attention windows (sliding-window layers with
periodic full-attention layers, à la Hymba) are carried as a scanned int array.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import attention, init_attention, make_cache
from repro.models.layers import (
    dtype_of, embed, init_embedding, init_mlp, init_norm, mlp, rmsnorm, unembed,
    init_linear, linear,
)
from repro.models.moe import init_moe, moe
from repro.models.ssm import init_ssm, make_ssm_state, ssm_block
from repro.sharding.rules import constrain_block_params, logical_shard


# ---------------------------------------------------------------------------
# per-layer block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    fam = cfg.family
    if fam == "ssm":
        return {"ssm_norm": init_norm(cfg.d_model, cfg), "ssm": init_ssm(ks[0], cfg)}
    p = {
        "attn_norm": init_norm(cfg.d_model, cfg),
        "attn": init_attention(ks[0], cfg),
        "mlp_norm": init_norm(cfg.d_model, cfg),
    }
    if fam == "moe":
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    if fam == "hybrid":
        # Hymba-style parallel heads: attn ∥ ssm within the same block,
        # combined with learnable per-branch output scales (β).
        p["ssm"] = init_ssm(ks[2], cfg)
        p["beta_attn"] = jnp.ones((cfg.d_model,), dtype_of(cfg.param_dtype))
        p["beta_ssm"] = jnp.ones((cfg.d_model,), dtype_of(cfg.param_dtype))
    return p


def block_apply(p, x, cfg: ModelConfig, *, positions, window, cache, cache_pos):
    """One residual block. cache is {} (train/prefill) or the layer's state."""
    aux = jnp.float32(0.0)
    new_cache = {}
    has_cache = bool(cache)
    fam = cfg.family

    if fam == "ssm":
        h = rmsnorm(p["ssm_norm"], x, cfg.norm_eps)
        y, st = ssm_block(p["ssm"], h, cfg,
                          state={"ssd": cache["ssd"], "conv": cache["conv"]} if has_cache else None)
        if has_cache:
            new_cache = st
        return x + y, aux, new_cache

    h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    kv_cache = {"k": cache["k"], "v": cache["v"]} if has_cache else None
    a, kv = attention(p["attn"], h, cfg, positions=positions, window=window,
                      cache=kv_cache, cache_pos=cache_pos)
    if fam == "hybrid":
        s, st = ssm_block(p["ssm"], h, cfg,
                          state={"ssd": cache["ssd"], "conv": cache["conv"]} if has_cache else None)
        mix = 0.5 * (a * p["beta_attn"].astype(a.dtype)
                     + s * p["beta_ssm"].astype(a.dtype))
        x = x + mix
        if has_cache:
            new_cache = dict(st)
    else:
        x = x + a
    if has_cache:
        new_cache.update(kv)

    h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if fam == "moe":
        y, aux = moe(p["moe"], h, cfg)
    else:
        y = mlp(p["mlp"], h, cfg)
    return x + y, aux, new_cache


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention window (0 = full attention)."""
    w = np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    if cfg.sliding_window and cfg.attn_every:
        w[:: cfg.attn_every] = 0  # periodic global-attention layers
    return w


def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    lkeys = jax.random.split(ks[0], cfg.n_layers)
    emb_key = "embed_tied" if cfg.tie_embeddings else "embed"
    params = {
        emb_key: init_embedding(ks[1], cfg.padded_vocab, cfg.d_model, cfg),
        "layers": jax.vmap(lambda k: init_block(k, cfg))(lkeys),
        "final_norm": init_norm(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": init_linear(ks[2], cfg.d_model, cfg.padded_vocab, cfg, bias=False)["w"]}
    if cfg.frontend_dim:  # vlm projector (frontend itself is a stub)
        pk = jax.random.split(ks[3], 2)
        params["projector"] = {
            "fc1": init_linear(pk[0], cfg.frontend_dim, cfg.d_model, cfg, bias=True),
            "fc2": init_linear(pk[1], cfg.d_model, cfg.d_model, cfg, bias=True),
        }
    return params


def make_lm_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked [L, ...] decode state for scan-over-layers."""
    dtype = dtype_of(cfg.compute_dtype)

    def one(_):
        c = {}
        if cfg.family != "ssm":
            c.update(make_cache(cfg, batch, max_len, dtype))
        if cfg.family in ("ssm", "hybrid"):
            c.update(make_ssm_state(cfg, batch, dtype))
        return c

    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def project_frontend(params, cfg: ModelConfig, feats):
    """VLM/audio stub embeddings -> d_model via 2-layer MLP projector."""
    h = jax.nn.gelu(linear(params["projector"]["fc1"], feats))
    return linear(params["projector"]["fc2"], h)


def forward_lm(
    params,
    cfg: ModelConfig,
    tokens=None,            # [B,S] int32
    *,
    embeds=None,            # [B,S,D] pre-embedded (vlm prefix path)
    caches=None,            # stacked decode state or None
    cache_pos=None,         # scalar int32 write offset (decode)
    remat: bool = False,
):
    """Returns (logits [B,S,V], aux scalar, new_caches)."""
    compute_dtype = dtype_of(cfg.compute_dtype)
    emb_p = params["embed_tied"] if cfg.tie_embeddings else params["embed"]
    if embeds is None:
        x = embed(emb_p, tokens, compute_dtype)
    else:
        x = embeds.astype(compute_dtype)
    b, s = x.shape[:2]
    if cache_pos is not None:
        positions = cache_pos + jnp.arange(s, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    x = logical_shard(x, "batch", "res_seq", "embed")

    windows = jnp.asarray(layer_windows(cfg))

    def body(carry, inp):
        h, aux = carry
        lp, win, cache = inp
        # pinning layer params here makes their scan-accumulated GRADIENTS
        # inherit the same sharding (w_s_c transposes to the cotangent)
        lp = constrain_block_params(lp)
        h, aux_i, new_cache = block_apply(
            lp, h, cfg, positions=positions, window=win,
            cache=cache, cache_pos=cache_pos)
        # Megatron-SP residual pin: saved (remat) activations shard over the
        # model axis via the sequence dim — 16x less live memory at TP=16
        h = logical_shard(h, "batch", "res_seq", "embed")
        return (h, aux + aux_i), new_cache

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (params["layers"], windows, caches if caches is not None else {})
    unroll = cfg.n_layers if cfg.unroll_layers else 1
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)), xs,
                                        unroll=unroll)
    if caches is None:
        new_caches = None

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed_tied"], x)
    else:
        logits = x @ params["lm_head"]["w"].astype(x.dtype)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if cfg.padded_vocab != cfg.vocab_size:  # mask the padding columns
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.float32(-1e30).astype(logits.dtype), logits)
    logits = logical_shard(logits, "batch", "seq", "vocab")
    return logits, aux, new_caches
