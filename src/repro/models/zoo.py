"""Model zoo: heterogeneous frozen backbones + ONE shared LoRA'd head.

The paper's hospital sites are *unlike*: different compute budgets, different
(possibly pre-trained) feature extractors. The heterogeneous swarm keeps each
site's backbone frozen and local — it never crosses the wire — and shares
only a small common head: a LoRA-adapted projection over a ``feat_dim``
feature interface plus the decoder layer. That shared payload is the entire
swarm state in ``cfg.payload = "lora"`` mode (docs/heterogeneous.md):

  node i state row = flatten_payload({"backbone": bb_i, "head": head},
                                     payload_select)
                   = {"head/out/b", "head/out/w",
                      "head/proj/lora_A", "head/proj/lora_B",
                      "head/proj/lora_scale"}

Every backbone family must emit ``feat_dim`` features; the payload pytree is
then structurally identical across nodes, so it stacks, merges, quantizes,
and checkpoints exactly like a homogeneous swarm — at the adapter-only wire
cost. The frozen ``proj`` base weight stays local (it is the per-site
feature calibration LoRA adapts); the decoder ``out`` layer crosses raw.

Backbones reuse in-tree families: DenseNet-lite encoders (`models.cnn`, the
paper's own architecture at two scales) and MLP stacks (a structurally
different pytree, proving the wire contract really is backbone-agnostic).
The head projection runs through `kernels.lora_matmul.lora_apply`, so on TPU
with tileable dims the shared payload hits the fused base+LoRA MXU kernel.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.lora import (flatten_payload, inject_lora, is_adapter_path,
                             unflatten_payload)
from repro.kernels.lora_matmul import lora_apply
from repro.models.cnn import forward_cnn, init_cnn


# ---------------------------------------------------------------------------
# backbone families (frozen, local, architecture-specific)
# ---------------------------------------------------------------------------

def _cnn_features(params, images):
    """DenseNet-lite features: the penultimate activation (its fc1 width is
    built as ``feat_dim`` below, so the feature interface lines up)."""
    return forward_cnn(params, images, return_features=True)[1]


def _init_mlp(key, *, image_size: int, feat_dim: int, widths):
    d = image_size * image_size * 3
    layers = []
    for w_out in tuple(widths) + (feat_dim,):
        key, k = jax.random.split(key)
        layers.append({"w": jax.random.normal(k, (d, w_out))
                       * jnp.sqrt(2.0 / d),
                       "b": jnp.zeros((w_out,))})
        d = w_out
    return {"layers": layers}


def _mlp_features(params, images):
    x = images.reshape(images.shape[0], -1)
    for layer in params["layers"]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    return x


def build_backbone(family: str, key, *, image_size: int, feat_dim: int):
    """``(frozen_params, features_fn)`` for one zoo family.

    ``features_fn(params, images [B,H,W,3]) -> [B, feat_dim]`` — the one
    interface every family must honour for the shared head to compose.
    """
    if family == "densenet_s":
        return (init_cnn(key, None, growth=4, stem=8, n_blocks=2,
                         layers_per_block=2, feat_dim=24, hidden=feat_dim),
                _cnn_features)
    if family == "densenet_w":
        return (init_cnn(key, None, growth=8, stem=16, n_blocks=2,
                         layers_per_block=3, feat_dim=40, hidden=feat_dim),
                _cnn_features)
    if family == "mlp_deep":
        return (_init_mlp(key, image_size=image_size, feat_dim=feat_dim,
                          widths=(64, 64)), _mlp_features)
    if family == "mlp_wide":
        return (_init_mlp(key, image_size=image_size, feat_dim=feat_dim,
                          widths=(128,)), _mlp_features)
    raise ValueError(f"unknown zoo family {family!r} "
                     f"(choose from {DEFAULT_FAMILIES})")


DEFAULT_FAMILIES = ("densenet_s", "densenet_w", "mlp_deep", "mlp_wide")


# ---------------------------------------------------------------------------
# the shared head (what crosses the wire)
# ---------------------------------------------------------------------------

def init_head(key, *, feat_dim: int, hidden: int = 32, n_classes: int = 3,
              rank: int = 4, alpha: float = 8.0):
    """Shared head: LoRA'd projection (frozen base w) + raw decoder layer.

    Initialized from ONE key shared across the swarm, so every node's
    payload row starts identical (the warm-start the paper attributes to
    shared pre-training)."""
    k1, k2, k3 = jax.random.split(key, 3)
    head = {
        "proj": {"w": jax.random.normal(k1, (feat_dim, hidden))
                 * jnp.sqrt(2.0 / feat_dim)},
        "out": {"w": jax.random.normal(k2, (hidden, n_classes))
                * jnp.sqrt(2.0 / hidden),
                "b": jnp.zeros((n_classes,))},
    }
    return inject_lora(head, k3, rank=rank, alpha=alpha, targets="proj")


def payload_select(path: str) -> bool:
    """The wire membership rule: LoRA adapters + the decoder ``out`` layer.

    The frozen ``proj`` base weight and every backbone leaf stay local."""
    return is_adapter_path(path) or path.startswith("head/out/")


def head_forward(head, feats):
    """``feats [B, feat_dim] -> logits [B, n_classes]`` through the fused
    base+LoRA matmul (`lora_apply` dispatches kernel vs unfused by shape)."""
    p = head["proj"]
    z = lora_apply(feats, p["w"], p["lora_A"], p["lora_B"], p["lora_scale"])
    z = jax.nn.relu(z)
    return z @ head["out"]["w"] + head["out"]["b"]


# ---------------------------------------------------------------------------
# zoo assembly
# ---------------------------------------------------------------------------

@dataclass
class ZooNode:
    """One heterogeneous site: frozen full-params template + features fn.

    ``template`` holds the node's backbone and the head's frozen base; the
    adapter payload rows are written into it at apply time. Everything here
    is closure state — only the flat payload dict is swarm state.
    """

    family: str
    template: Any
    features: Callable

    def payload(self):
        """This node's wire payload (flat path-keyed dict, sorted)."""
        return flatten_payload(self.template, payload_select)

    def apply(self, payload, images):
        """logits for ``images`` under ``payload`` (grads flow through the
        payload leaves only — the frozen-backbone fine-tuning contract)."""
        full = unflatten_payload(payload, self.template)
        feats = self.features(full["backbone"], images)
        return head_forward(full["head"], feats)


def build_zoo(key, n_nodes: int, *, families: Optional[Sequence[str]] = None,
              image_size: int = 16, feat_dim: int = 32, hidden: int = 32,
              n_classes: int = 3, rank: int = 4,
              alpha: float = 8.0) -> List[ZooNode]:
    """N heterogeneous nodes around one shared head.

    ``families`` cycles over :data:`DEFAULT_FAMILIES` by default, so a
    4-node swarm gets four distinct backbone architectures."""
    fams = tuple(families) if families else DEFAULT_FAMILIES
    keys = jax.random.split(key, n_nodes + 1)
    head = init_head(keys[-1], feat_dim=feat_dim, hidden=hidden,
                     n_classes=n_classes, rank=rank, alpha=alpha)
    nodes = []
    for i in range(n_nodes):
        fam = fams[i % len(fams)]
        backbone, feats = build_backbone(fam, keys[i],
                                         image_size=image_size,
                                         feat_dim=feat_dim)
        nodes.append(ZooNode(family=fam,
                             template={"backbone": backbone, "head": head},
                             features=feats))
    return nodes
