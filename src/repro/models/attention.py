"""Grouped-query attention with RoPE, sliding-window masking and KV cache.

Used by every attention-bearing family (dense, moe, hybrid, vlm, audio).
Pure jnp by default (this is the path the multi-pod dry-run lowers); the
Pallas flash kernel in ``repro.kernels`` is an opt-in drop-in for TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, init_linear, linear
from repro.sharding.rules import axis_size, logical_shard

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, *, n_heads=None, n_kv_heads=None):
    nh = n_heads or cfg.n_heads
    nkv = n_kv_heads or cfg.n_kv_heads
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "q": init_linear(ks[0], d, nh * hd, cfg),
        "k": init_linear(ks[1], d, nkv * hd, cfg),
        "v": init_linear(ks[2], d, nkv * hd, cfg),
        "o": init_linear(ks[3], nh * hd, d, cfg),
    }


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype, *, n_kv_heads=None):
    nkv = n_kv_heads or cfg.n_kv_heads
    shape = (batch, max_len, nkv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _gqa_scores(q, k):
    """q [B,S,nh,hd], k [B,T,nkv,hd] -> scores [B,nkv,g,S,T] (g = nh // nkv)."""
    b, s, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(b, s, nkv, g, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _gqa_out(probs, v):
    """probs [B,nkv,g,S,T], v [B,T,nkv,hd] -> [B,S,nh,hd]."""
    b, nkv, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, nkv * g, -1)


def attention(
    p,
    x,
    cfg: ModelConfig,
    *,
    positions,                      # [B, S] query positions
    causal: bool = True,
    window: int = 0,                # 0 = full
    cache: Optional[dict] = None,   # decode: KV cache dict
    cache_pos=None,                 # [] scalar — write offset into cache
    kv_x=None,                      # cross-attn: encoder output
    kv_positions=None,
    n_heads=None,
    n_kv_heads=None,
):
    """Returns (output [B,S,D], updated_cache)."""
    nh = n_heads or cfg.n_heads
    nkv = n_kv_heads or cfg.n_kv_heads
    hd = cfg.head_dim
    b, s, _ = x.shape

    q = linear(p["q"], x).reshape(b, s, nh, hd)
    src = x if kv_x is None else kv_x
    k = linear(p["k"], src).reshape(b, src.shape[1], nkv, hd)
    v = linear(p["v"], src).reshape(b, src.shape[1], nkv, hd)

    is_cross = kv_x is not None
    if not is_cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        kp = positions if kv_positions is None else kv_positions
        k = apply_rope(k, kp, cfg.rope_theta)

    # Sharding scheme: head-parallel when the KV heads divide the model axis
    # (k/v/q all sharded on heads, zero attention collectives); otherwise
    # sequence-parallel on the query side (q/scores sharded over model via
    # the q-seq dim, k/v replicated over model and all-gathered per layer) —
    # this stays even for ANY head count, incl. GQA kv=8 on a 16-way axis.
    msize = axis_size("heads")
    head_parallel = msize > 1 and nkv % msize == 0
    if head_parallel:
        q = logical_shard(q, "batch", "seq", "heads", None)
        k = logical_shard(k, "batch", "seq", "kv_heads", None)
        v = logical_shard(v, "batch", "seq", "kv_heads", None)
    elif s > 1:
        q = logical_shard(q, "batch", "attn_seq", None, None)
        k = logical_shard(k, "batch", None, None, None)
        v = logical_shard(v, "batch", None, None, None)

    if cache is not None and not is_cross:
        # decode: align the new K/V with the CACHE's layout before the
        # in-place update — otherwise GSPMD reshards (re-gathers) the whole
        # multi-GB cache every step to match the unconstrained update
        kv_div = axis_size("kv_heads") > 1 and nkv % axis_size("kv_heads") == 0
        hd_div = axis_size("head_dim") > 1 and hd % axis_size("head_dim") == 0
        if s == 1 and kv_div:  # single-token decode only (prefill conflicts
            k = logical_shard(k, "batch", None, "kv_heads", None)  # with the
            v = logical_shard(v, "batch", None, "kv_heads", None)  # seq path)
        elif s == 1 and hd_div:
            k = logical_shard(k, "batch", None, None, "head_dim")
            v = logical_shard(v, "batch", None, None, "head_dim")
            # q must match, or GSPMD all-gathers the whole cache per layer
            # to run the scores contraction unsharded (137 GB/step on 104B)
            q = logical_shard(q, "batch", None, None, "head_dim")
        # append this step's K/V at cache_pos, attend over prefix
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        cache = {"k": k_cache, "v": v_cache}
        k, v = k_cache.astype(x.dtype), v_cache.astype(x.dtype)
        key_positions = jnp.arange(k.shape[1])[None, :]  # [1, T]
    else:
        key_positions = (positions if kv_positions is None else kv_positions)
        if key_positions.ndim == 1:
            key_positions = key_positions[None, :]

    scores = _gqa_scores(q, k)  # [B,nkv,g,S,T]
    if head_parallel:
        scores = logical_shard(scores, "batch", "kv_heads", None, None, None)
    elif s > 1:
        scores = logical_shard(scores, "batch", None, None, "attn_seq", None)
    qpos = positions[:, None, None, :, None]          # [B,1,1,S,1]
    kpos = key_positions[:, None, None, None, :]      # [B,1,1,1,T]
    if causal and not is_cross:
        # `window` may be a traced per-layer scalar (scan-over-layers); 0 = full
        w = jnp.asarray(window, jnp.int32)
        w_eff = jnp.where(w > 0, w, jnp.int32(2**30))
        mask = (kpos <= qpos) & (kpos > qpos - w_eff)
    else:
        mask = jnp.ones(scores.shape[-2:], bool)[None, None, None, :, :]
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v)  # [B,S,nh,hd]
    if head_parallel:
        out = logical_shard(out, "batch", "seq", "heads", None)
    elif s > 1:
        out = logical_shard(out, "batch", "attn_seq", None, None)
    y = linear(p["o"], out.reshape(b, s, nh * hd))
    return y, cache
