"""The paper's own diagnostic model: DenseNet-style encoder + classifier head.

Faithful to §3.3 of the paper: input images pass through **four encoder
modules of four layers each**, pooled to a feature vector (the paper reports
1152 features into the head), then FC(→512)+BN+ReLU, then FC(512→3)+BN with a
sigmoid applied at the loss. TorchXRayVision's pre-trained weights are not
available offline; we reproduce the *architecture* and treat "pre-trained"
as a warm-start option (`init_cnn(..., pretrained_key=...)` reuses a shared
seed across nodes — all swarm nodes start from the same backbone, exactly the
effect pre-training has on the swarm experiment).

BatchNorm note: implemented in batch-statistics mode (no running averages) to
stay purely functional; with the paper's batch size (32) this is the standard
train-mode behaviour. Recorded as a simplification in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in)


def conv2d(w, x, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm(p, x, eps=1e-5):
    axes = tuple(range(x.ndim - 1))
    mu = jnp.mean(x, axes, keepdims=True)
    var = jnp.var(x, axes, keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    return xn * p["scale"] + p["bias"]


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def init_cnn(key, cfg: ModelConfig, *, growth=32, stem=64, n_blocks=4,
             layers_per_block=4, feat_dim=1152, hidden=512, n_classes=3):
    """DenseNet-lite: n_blocks dense blocks × layers_per_block conv layers."""
    ks = iter(jax.random.split(key, 2 + n_blocks * (layers_per_block + 1) + 4))
    params = {"stem": {"w": _conv_init(next(ks), 7, 7, 3, stem), "bn": _bn_init(stem)}}
    c = stem
    blocks = []
    for b in range(n_blocks):
        layers = []
        for _ in range(layers_per_block):
            layers.append({"bn": _bn_init(c), "w": _conv_init(next(ks), 3, 3, c, growth)})
            c += growth
        trans_out = c // 2 if b < n_blocks - 1 else feat_dim
        blocks.append({
            "layers": layers,
            "trans": {"bn": _bn_init(c), "w": _conv_init(next(ks), 1, 1, c, trans_out)},
        })
        c = trans_out
    params["blocks"] = blocks
    params["head"] = {
        "fc1": {"w": jax.random.normal(next(ks), (feat_dim, hidden)) * jnp.sqrt(2.0 / feat_dim),
                "b": jnp.zeros((hidden,)), "bn": _bn_init(hidden)},
        "fc2": {"w": jax.random.normal(next(ks), (hidden, n_classes)) * jnp.sqrt(2.0 / hidden),
                "b": jnp.zeros((n_classes,)), "bn": _bn_init(n_classes)},
    }
    return params


def forward_cnn(params, images, *, return_features=False):
    """images [B,H,W,3] -> logits [B,3] (sigmoid applied at the loss)."""
    x = conv2d(params["stem"]["w"], images, stride=2)
    x = jax.nn.relu(batchnorm(params["stem"]["bn"], x))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for block in params["blocks"]:
        for layer in block["layers"]:
            h = jax.nn.relu(batchnorm(layer["bn"], x))
            h = conv2d(layer["w"], h)
            x = jnp.concatenate([x, h], axis=-1)  # dense connectivity
        x = jax.nn.relu(batchnorm(block["trans"]["bn"], x))
        x = conv2d(block["trans"]["w"], x)
        if min(x.shape[1], x.shape[2]) >= 2:  # keep ≥1×1 for small test images
            x = jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 2, 2, 1),
                                      (1, 2, 2, 1), "VALID") / 4.0
    feats = jnp.mean(x, axis=(1, 2))  # global average pool -> [B, feat_dim]
    h = params["head"]["fc1"]
    z = feats @ h["w"] + h["b"]
    z = jax.nn.relu(batchnorm(h["bn"], z))
    penultimate = z
    h = params["head"]["fc2"]
    logits = batchnorm(h["bn"], z @ h["w"] + h["b"])
    if return_features:
        return logits, penultimate
    return logits


def bce_loss(logits, labels_onehot):
    """Paper head uses sigmoid -> multi-label BCE over the 3 classes."""
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(labels_onehot * logp + (1 - labels_onehot) * lognp)
