"""Evaluation metrics in pure numpy (no sklearn offline): AUC via
Mann-Whitney U, sensitivity/specificity/F1 (paper §4), Davies-Bouldin index
(paper §4.3 embedding-quality claim), and per-class recall.

`macro_auc_traced` is the jax-traceable twin of `macro_auc` used for the
swarm engine's in-graph validation gate — same value up to f32, no host
round-trip. It uses the sort-based (rank-sum) Mann-Whitney formulation,
O(C·V log V), so gating scales past a few thousand validation samples per
node; the old O(V²) pairwise form is kept as `_macro_auc_pairwise` (the
small-input cross-check oracle).

`gate_metric_fn(name)` maps the `SwarmConfig.gate_metric` knob to a traced
gate metric: "auc" | "accuracy" | "f1" | "sensitivity" — each with a host
numpy oracle in this module (`macro_auc` / `accuracy` / `confusion_stats`)."""
from __future__ import annotations

import numpy as np


def binary_auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Rank-based AUC (ties averaged) — equivalent to Mann-Whitney U / (n+ n-)."""
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels).astype(bool)
    n_pos, n_neg = labels.sum(), (~labels).sum()
    if n_pos == 0 or n_neg == 0:
        return 0.5
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty(len(scores), np.float64)
    sorted_scores = scores[order]
    ranks[order] = np.arange(1, len(scores) + 1)
    # average ranks over ties
    uniq, inv, counts = np.unique(sorted_scores, return_inverse=True,
                                  return_counts=True)
    cum = np.cumsum(counts)
    avg_rank = (cum - (counts - 1) / 2.0)
    ranks[order] = avg_rank[inv]
    u = ranks[labels].sum() - n_pos * (n_pos + 1) / 2.0
    return float(u / (n_pos * n_neg))


def macro_auc(probs: np.ndarray, labels: np.ndarray) -> float:
    """One-vs-rest macro AUC for multiclass probs [N, C]."""
    cs = [binary_auc(probs[:, c], labels == c)
          for c in range(probs.shape[1]) if (labels == c).any()]
    return float(np.mean(cs)) if cs else 0.5


def macro_auc_traced(probs, labels, valid=None):
    """Jax-traceable one-vs-rest macro AUC over [V, C] probs.

    Sort-based Mann-Whitney: AUC_c = (Σ ranks⁺ − n⁺(n⁺+1)/2) / (n⁺n⁻) with
    average ranks over ties (identical to `macro_auc` and to the pairwise
    half-credit form, up to f32) — computed fully in-graph so the swarm gate
    needs no host sync, at O(V log V) per class instead of O(V²).
    `valid` masks padded rows (per-node validation sets differ in size and
    are padded to a common V for the vmapped engine eval): masked scores are
    pushed to +inf, past every valid score, so valid ranks are undisturbed.
    """
    import jax
    import jax.numpy as jnp

    probs = jnp.asarray(probs)
    labels = jnp.asarray(labels)
    v = (jnp.ones(labels.shape, bool) if valid is None
         else jnp.asarray(valid).astype(bool))
    classes = jnp.arange(probs.shape[1])

    def one_class(scores, c):
        s = jnp.where(v, scores.astype(jnp.float32), jnp.inf)
        pos = (labels == c) & v
        neg = (labels != c) & v
        ss = jnp.sort(s)
        lo = jnp.searchsorted(ss, s, side="left")    # count of strictly-less
        hi = jnp.searchsorted(ss, s, side="right")   # count of less-or-equal
        # average 1-based rank over the tie group occupying ranks lo+1..hi
        rank = 0.5 * (lo + hi + 1).astype(jnp.float32)
        n_pos = pos.sum().astype(jnp.float32)
        n_neg = neg.sum().astype(jnp.float32)
        u = jnp.sum(jnp.where(pos, rank, 0.0)) - n_pos * (n_pos + 1.0) / 2.0
        n_pairs = n_pos * n_neg
        auc = jnp.where(n_pairs > 0, u / jnp.maximum(n_pairs, 1.0), 0.5)
        return auc, n_pos > 0

    aucs, present = jax.vmap(one_class, in_axes=(1, 0))(probs, classes)
    present = present.astype(jnp.float32)
    return jnp.sum(aucs * present) / jnp.maximum(present.sum(), 1.0)


def _macro_auc_pairwise(probs, labels, valid=None):
    """The original O(V²) pairwise traced AUC (ties get half credit) — kept
    as an independent oracle for `macro_auc_traced` on small inputs."""
    import jax.numpy as jnp

    probs = jnp.asarray(probs)
    labels = jnp.asarray(labels)
    v = (jnp.ones(labels.shape, bool) if valid is None
         else jnp.asarray(valid).astype(bool))

    def one_class(c):
        s = probs[:, c]
        pos = (labels == c) & v
        neg = (labels != c) & v
        pair = pos[:, None] & neg[None, :]
        diff = s[:, None] - s[None, :]
        wins = jnp.where(diff > 0, 1.0, 0.0) + jnp.where(diff == 0, 0.5, 0.0)
        u = jnp.sum(jnp.where(pair, wins, 0.0))
        n_pairs = pos.sum() * neg.sum()
        auc = jnp.where(n_pairs > 0, u / jnp.maximum(n_pairs, 1), 0.5)
        return auc, pos.sum() > 0

    aucs, present = zip(*[one_class(c) for c in range(probs.shape[1])])
    aucs = jnp.stack(aucs)
    present = jnp.stack(present).astype(jnp.float32)
    return jnp.sum(aucs * present) / jnp.maximum(present.sum(), 1.0)


def _confusion_traced(probs, labels, valid=None):
    """Per-class (tp, fn, fp, tn) counts from argmax predictions, in-graph.

    Mirrors :func:`confusion_stats` exactly (all C classes enter the macro
    average; ``max(count, 1)`` denominators guard absent classes) so the
    traced gate metrics agree with the host oracles bit-for-bit up to f32.
    ``valid`` masks padded validation rows (vmapped engine eval).
    """
    import jax.numpy as jnp

    probs = jnp.asarray(probs)
    labels = jnp.asarray(labels)
    v = (jnp.ones(labels.shape, bool) if valid is None
         else jnp.asarray(valid).astype(bool))
    preds = jnp.argmax(probs, axis=-1)
    classes = jnp.arange(probs.shape[1])
    is_c = labels[None, :] == classes[:, None]       # [C, V]
    pred_c = preds[None, :] == classes[:, None]
    vf = v[None, :]
    tp = jnp.sum(pred_c & is_c & vf, axis=1).astype(jnp.float32)
    fn = jnp.sum(~pred_c & is_c & vf, axis=1).astype(jnp.float32)
    fp = jnp.sum(pred_c & ~is_c & vf, axis=1).astype(jnp.float32)
    tn = jnp.sum(~pred_c & ~is_c & vf, axis=1).astype(jnp.float32)
    return tp, fn, fp, tn


def sensitivity_traced(probs, labels, valid=None):
    """Traced macro sensitivity (recall) — the host oracle is
    ``confusion_stats(...)['sensitivity']``."""
    import jax.numpy as jnp

    tp, fn, _, _ = _confusion_traced(probs, labels, valid)
    return jnp.mean(tp / jnp.maximum(tp + fn, 1.0))


def macro_f1_traced(probs, labels, valid=None):
    """Traced macro F1 — the host oracle is ``confusion_stats(...)['f1']``."""
    import jax.numpy as jnp

    tp, fn, fp, _ = _confusion_traced(probs, labels, valid)
    se = tp / jnp.maximum(tp + fn, 1.0)
    pr = tp / jnp.maximum(tp + fp, 1.0)
    return jnp.mean(2.0 * pr * se / jnp.maximum(pr + se, 1e-12))


def accuracy_traced(probs, labels, valid=None):
    """Traced accuracy over valid rows (host oracle: :func:`accuracy`)."""
    import jax.numpy as jnp

    probs = jnp.asarray(probs)
    labels = jnp.asarray(labels)
    v = (jnp.ones(labels.shape, bool) if valid is None
         else jnp.asarray(valid).astype(bool))
    hit = (jnp.argmax(probs, axis=-1) == labels) & v
    return hit.sum() / jnp.maximum(v.sum(), 1.0)


GATE_METRICS = {
    "auc": macro_auc_traced,
    "accuracy": accuracy_traced,
    "f1": macro_f1_traced,
    "sensitivity": sensitivity_traced,
}


def gate_metric_fn(name: str):
    """The traced validation-gate metric for `SwarmConfig.gate_metric`:
    ``fn(probs [V, C], labels [V], valid [V]) -> scalar in [0, 1]``."""
    try:
        return GATE_METRICS[name]
    except KeyError:
        raise ValueError(f"unknown gate_metric {name!r}; "
                         f"choose from {sorted(GATE_METRICS)}") from None


def confusion_stats(preds: np.ndarray, labels: np.ndarray, n_classes: int):
    """Macro-averaged sensitivity / specificity / F1 + per-class recall."""
    sens, spec, f1s, recalls = [], [], [], []
    for c in range(n_classes):
        tp = np.sum((preds == c) & (labels == c))
        fn = np.sum((preds != c) & (labels == c))
        fp = np.sum((preds == c) & (labels != c))
        tn = np.sum((preds != c) & (labels != c))
        se = tp / max(tp + fn, 1)
        sp = tn / max(tn + fp, 1)
        pr = tp / max(tp + fp, 1)
        f1 = 2 * pr * se / max(pr + se, 1e-12)
        sens.append(se); spec.append(sp); f1s.append(f1); recalls.append(se)
    return {
        "sensitivity": float(np.mean(sens)),
        "specificity": float(np.mean(spec)),
        "f1": float(np.mean(f1s)),
        "per_class_recall": [float(r) for r in recalls],
    }


def accuracy(preds: np.ndarray, labels: np.ndarray) -> float:
    return float(np.mean(preds == labels))


def davies_bouldin(embeddings: np.ndarray, labels: np.ndarray) -> float:
    """DBI (lower = tighter clusters) — paper reports 15% lower for swarm."""
    embeddings = np.asarray(embeddings, np.float64)
    classes = np.unique(labels)
    cents, scatters = [], []
    for c in classes:
        e = embeddings[labels == c]
        mu = e.mean(0)
        cents.append(mu)
        scatters.append(np.mean(np.linalg.norm(e - mu, axis=1)))
    k = len(classes)
    if k < 2:
        return 0.0
    cents = np.stack(cents)
    db = 0.0
    for i in range(k):
        ratios = [
            (scatters[i] + scatters[j]) / max(np.linalg.norm(cents[i] - cents[j]), 1e-12)
            for j in range(k) if j != i
        ]
        db += max(ratios)
    return float(db / k)


def classify_report(probs: np.ndarray, labels: np.ndarray) -> dict:
    preds = probs.argmax(-1)
    rep = {"auc": macro_auc(probs, labels), "accuracy": accuracy(preds, labels)}
    rep.update(confusion_stats(preds, labels, probs.shape[1]))
    return rep
