"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Deliberately naive implementations — independent of the kernel code paths and
of the model modules, so a bug can't hide in shared code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_merge_ref(stacked, weights, self_idx, gate):
    """stacked [N, D]; weights [N]; gate scalar bool.
    out [D] = gate ? Σ_j w_j θ_j : θ_self   (fp32 accumulation)."""
    merged = jnp.einsum("n,nd->d", weights.astype(jnp.float32),
                        stacked.astype(jnp.float32))
    keep = stacked[self_idx].astype(jnp.float32)
    return jnp.where(gate, merged, keep).astype(stacked.dtype)


def lora_matmul_ref(x, w, a, b, scale):
    """y = x @ W + scale * (x @ A) @ B, fp32 accumulation."""
    xf = x.astype(jnp.float32)
    y = xf @ w.astype(jnp.float32)
    y = y + scale * (xf @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    return y.astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0):
    """q [B,H,S,D], k/v [B,Hkv,T,D] (GQA: H multiple of Hkv). Softmax in fp32."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask = kpos <= qpos
        if window > 0:
            mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)


def ssd_scan_ref(x, dt, a_log, bmat, cmat):
    """Exact sequential SSD recurrence (the slow oracle).

    x [B,S,H,P]; dt [B,S,H] (already softplus'd); a_log [H];
    bmat/cmat [B,S,H,N] (groups pre-broadcast to heads).
    Returns y [B,S,H,P], final state [B,H,P,N].
    """
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    decay = jnp.exp(dt * (-jnp.exp(a_log.astype(jnp.float32))))  # [B,S,H]
    xdt = x.astype(jnp.float32) * dt[..., None]

    def step(state, inp):
        xt, dct, bt, ct = inp  # [B,H,P], [B,H], [B,H,N], [B,H,N]
        state = state * dct[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(xdt, 1, 0), jnp.moveaxis(decay, 1, 0),
          jnp.moveaxis(bmat.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cmat.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
