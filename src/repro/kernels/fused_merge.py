"""Pallas TPU kernel: fused swarm merge + validation gate.

The gossip commit applies  out = gate ? Σ_j w_j θ_j : θ_self  over every
parameter shard. Done naively (XLA) this materializes the weighted sum and the
select as separate HBM round-trips over the full model (multi-GB). The kernel
fuses contraction-over-nodes and gating into ONE VMEM pass: each grid step
streams an [N, BLOCK] tile from HBM, reduces over N on the VPU, applies the
gate, writes BLOCK back. Memory-bound by design — (N+1)·BLOCK bytes moved per
BLOCK produced, the roofline minimum for this op.

Two entry points:

  * ``fused_merge``      — one node's commit:   [N, D] → [D]
  * ``fused_merge_all``  — the whole swarm's commit in one launch:
                           [N, D] → [N, D] with a full mixing matrix W [N, N]
                           and per-node gate bits. Grid order is
                           (d-blocks, nodes) so the [N, BLOCK] input tile is
                           fetched once per d-block and reused for all N output
                           rows — (N + N)·BLOCK bytes per column block, still
                           the roofline minimum.

``fused_merge_all`` optionally takes per-element importance weights
``imp [N, D]`` (diagonal Fisher mass). The merged row then becomes the
normalized importance-weighted mean

    out[i] = gate_i ?  Σ_j W[i,j]·imp[j]⊙θ_j / Σ_j W[i,j]·imp[j]  :  θ_i

which covers fisher merging (W = 1) and gradient matching (W rows = dataset
weights; the gradmatch correction collapses algebraically to this ratio) in
the same single VMEM pass — (2N + N)·BLOCK bytes per column block instead of
the ~6N·BLOCK an unfused numerator/denominator/select chain moves.

``fused_merge_tree`` maps either entry point leaf-wise over a stacked param
pytree (2-D ``weights`` selects the all-nodes form, ``imp=`` a matching
importance pytree); the host-simulated swarm engine commits through it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16_384  # 4 nodes × 16k × 4B = 256 KiB VMEM working set

# VMEM working-set budget for auto block sizing: ~16 MB/core total, leave
# room for double buffering + compiler scratch.
VMEM_BUDGET = 4 * 1024 * 1024


def auto_block(n: int, streams: int, *, out_rows: int = 1,
               block: int = DEFAULT_BLOCK, budget: int = VMEM_BUDGET,
               align: int = 128) -> int:
    """Largest tile width whose VMEM working set fits the budget.

    A grid step holds ``streams`` input tiles of [N, block] plus ``out_rows``
    output rows of [block] — (streams·N + out_rows)·block·4 bytes. The old
    fixed DEFAULT_BLOCK ignored both N and the extra importance stream, so a
    64-node fisher commit wanted (2·64+1)·16384·4 ≈ 8.5 MB of VMEM per step.
    Returns min(requested block, budget-derived cap), multiple of ``align``
    (lane width), floored at ``align``.
    """
    rows = streams * n + out_rows
    cap = budget // (rows * 4)
    return min(block, max(align, cap // align * align))


def _merge_kernel(x_ref, w_ref, gate_ref, self_idx_ref, o_ref):
    """x [N, B] tile; w [N]; gate/self_idx scalars (SMEM); o [B] tile."""
    x = x_ref[...].astype(jnp.float32)              # [N, B]
    w = w_ref[...].astype(jnp.float32)              # [N]
    merged = jnp.einsum("n,nb->b", w, x)
    self_row = jax.lax.dynamic_index_in_dim(x, self_idx_ref[0], axis=0,
                                            keepdims=False)
    gate = gate_ref[0] != 0
    o_ref[...] = jnp.where(gate, merged, self_row).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_merge(stacked, weights, self_idx, gate, *, block: int = DEFAULT_BLOCK,
                interpret: bool = False):
    """stacked [N, D] → merged-or-kept [D].

    weights: [N] mixing row for this node; gate: scalar bool (validation
    acceptance); self_idx: this node's row. D is padded to a block multiple.
    """
    n, d = stacked.shape
    block = min(auto_block(n, 1, block=block), max(128, d))
    pad = (-d) % block
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    dp = d + pad
    grid = (dp // block,)

    out = pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights.astype(jnp.float32),
      jnp.asarray(gate, jnp.int32).reshape(1),
      jnp.asarray(self_idx, jnp.int32).reshape(1))
    return out[:d]


def _merge_all_kernel(x_ref, w_ref, g_ref, o_ref):
    """x [N, B] tile (all nodes); w [1, N] mixing row of node i; g [1];
    o [1, B] — node i's committed slice. Grid is (d-blocks, nodes)."""
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)              # [N, B]
    w = w_ref[...].astype(jnp.float32)[0]           # [N]
    merged = jnp.einsum("n,nb->b", w, x)
    self_row = jax.lax.dynamic_index_in_dim(x, i, axis=0, keepdims=False)
    gate = g_ref[0] != 0
    o_ref[...] = jnp.where(gate, merged, self_row)[None].astype(o_ref.dtype)


def _merge_all_imp_kernel(x_ref, f_ref, w_ref, g_ref, o_ref):
    """Importance-weighted form: x/f [N, B] tiles; w [1, N] row of node i;
    g [1]; o [1, B].  merged = Σ_j w_j f_j x_j / Σ_j w_j f_j  per element."""
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)              # [N, B]
    f = f_ref[...].astype(jnp.float32)              # [N, B]
    w = w_ref[...].astype(jnp.float32)[0]           # [N]
    wf = f * w[:, None]
    num = jnp.einsum("nb,nb->b", wf, x)
    den = wf.sum(0)
    merged = num / jnp.maximum(den, 1e-30)
    self_row = jax.lax.dynamic_index_in_dim(x, i, axis=0, keepdims=False)
    gate = g_ref[0] != 0
    o_ref[...] = jnp.where(gate, merged, self_row)[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_merge_all(stacked, W, gates, imp=None, *, block: int = DEFAULT_BLOCK,
                    interpret: bool = False):
    """stacked [N, D] → committed [N, D]:  out[i] = gate[i] ? Σ_j W[i,j] θ_j : θ_i.

    W: [N, N] row-stochastic mixing matrix; gates: [N] acceptance bits. The
    node axis is the innermost grid dimension, so each [N, BLOCK] tile is
    loaded once and serves every node's output row.

    imp: optional [N, D] per-element importance weights — switches to the
    normalized weighted merge  Σ_j W[i,j]·imp[j]⊙θ_j / Σ_j W[i,j]·imp[j]
    (fisher / gradmatch commits), still one pass over the tile.

    The tile width is auto-capped so the VMEM working set — one [N, BLOCK]
    tile per input stream (two with ``imp``) plus the output row — fits
    `VMEM_BUDGET` regardless of swarm size N (see :func:`auto_block`).
    """
    n, d = stacked.shape
    block = min(auto_block(n, 1 if imp is None else 2, block=block),
                max(128, d))
    pad = (-d) % block
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        if imp is not None:
            imp = jnp.pad(imp, ((0, 0), (0, pad)))
    dp = d + pad

    tile_spec = pl.BlockSpec((n, block), lambda j, i: (0, j))
    operands = [stacked]
    in_specs = [tile_spec]
    if imp is not None:  # same tiling, one extra [N, B] importance stream
        operands.append(jnp.asarray(imp, jnp.float32))
        in_specs.append(tile_spec)
    operands += [jnp.asarray(W, jnp.float32),
                 jnp.asarray(gates).astype(jnp.int32)]
    in_specs += [pl.BlockSpec((1, n), lambda j, i: (i, 0)),
                 pl.BlockSpec((1,), lambda j, i: (i,))]

    out = pl.pallas_call(
        _merge_all_kernel if imp is None else _merge_all_imp_kernel,
        grid=(dp // block, n),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block), lambda j, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, dp), stacked.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :d]


# ---------------------------------------------------------------------------
# quantized-wire commit: quantize -> merge -> dequantize in one VMEM pass
# ---------------------------------------------------------------------------
# The per-block round-trip is `core.comms.quant_dequant_block` — the ONE
# shared implementation (kernels import core.comms; no second quantization
# body anywhere), so the fused commit can never silently diverge from the
# XLA ground truth the candidate (gate) path computes. The import is lazy:
# `repro.core.__init__` imports the engine, which imports this module, so a
# module-level import back into the package would be init-order-sensitive.

def _quant_block(v, wire_dtype: str, wire_block: int):
    from repro.core.comms import quant_dequant_block
    return quant_dequant_block(v, wire_dtype, wire_block)


def _quant_merge_kernel(x_ref, r_ref, w_ref, g_ref, o_ref, ro_ref, *,
                        wire_dtype, wire_block):
    """x (local params) / r (wire reference θ̂): [N, B] tiles; w: [N, N];
    g: [N]; outputs: o committed [N, B], ro new reference [N, B].

    One VMEM pass per column block: quantize the EF delta v = x − θ̂ (per-
    wire-block int8 scales or bf16 cast), advance the reference, contract
    every node's mixing row against the dequantized payload, gate-select
    against the EXACT local row — the wire round-trip, merge, and gate never
    touch HBM between each other."""
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    rp = r + _quant_block(x - r, wire_dtype, wire_block)
    w = w_ref[...].astype(jnp.float32)                      # [N, N]
    merged = jax.lax.dot(w, rp, precision=jax.lax.Precision.HIGHEST)
    g = g_ref[...] != 0                                     # [N]
    o_ref[...] = jnp.where(g[:, None], merged, x).astype(o_ref.dtype)
    ro_ref[...] = rp


def _quant_merge_imp_kernel(x_ref, r_ref, f_ref, w_ref, g_ref, o_ref, ro_ref,
                            *, wire_dtype, wire_block):
    """Importance-weighted form: merged = W·(imp⊙θ̂') / W·imp per element
    (fisher / gradmatch / topology-restricted rows), same single pass."""
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    rp = r + _quant_block(x - r, wire_dtype, wire_block)
    f = f_ref[...].astype(jnp.float32)                      # [N, B]
    w = w_ref[...].astype(jnp.float32)                      # [N, N]
    hi = jax.lax.Precision.HIGHEST
    num = jax.lax.dot(w, f * rp, precision=hi)
    den = jax.lax.dot(w, f, precision=hi)
    merged = num / jnp.maximum(den, 1e-30)
    g = g_ref[...] != 0
    o_ref[...] = jnp.where(g[:, None], merged, x).astype(o_ref.dtype)
    ro_ref[...] = rp


@functools.partial(jax.jit, static_argnames=("wire_dtype", "wire_block",
                                             "block", "interpret"))
def fused_quant_merge_all(stacked, wire_ref, W, gates, imp=None, *,
                          wire_dtype: str = "int8", wire_block: int = 512,
                          block: int = DEFAULT_BLOCK, interpret: bool = False):
    """Quantized-wire commit: [N, D] params + [N, D] wire reference →
    (committed [N, D], new reference [N, D]).

    Fuses the error-feedback wire round-trip (quantize the delta against the
    reference copy θ̂, per-``wire_block`` scales, dequantize), the mixing-row
    (optionally importance-weighted) contraction, and the validation gate
    into one VMEM pass per column block — the wire-compressed sibling of
    :func:`fused_merge_all`. Rejected rows keep the EXACT f32 local params;
    the reference always advances (the wire traffic happened either way).

    The tile is sized by :func:`auto_block` counting every stream — params,
    reference, optional importance in; committed + reference out — then
    aligned down to a ``wire_block`` multiple so in-kernel scale blocks land
    on the same global grid as the XLA ground truth (`core.comms`).
    """
    n, d = stacked.shape
    streams = 2 if imp is None else 3
    block = auto_block(n, streams, out_rows=2 * n, block=block,
                       align=wire_block)
    block = max(wire_block, block // wire_block * wire_block)
    # don't pad small leaves (lora_scale, biases) out to the full tile —
    # cap at d rounded up to the wire-block grid
    block = min(block, -(-d // wire_block) * wire_block)
    pad = (-d) % block
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        wire_ref = jnp.pad(wire_ref, ((0, 0), (0, pad)))
        if imp is not None:
            imp = jnp.pad(imp, ((0, 0), (0, pad)))
    dp = d + pad

    tile = pl.BlockSpec((n, block), lambda j: (0, j))
    operands = [stacked, jnp.asarray(wire_ref, jnp.float32)]
    in_specs = [tile, tile]
    if imp is not None:
        operands.append(jnp.asarray(imp, jnp.float32))
        in_specs.append(tile)
    operands += [jnp.asarray(W, jnp.float32),
                 jnp.asarray(gates).astype(jnp.int32)]
    in_specs += [pl.BlockSpec((n, n), lambda j: (0, 0)),
                 pl.BlockSpec((n,), lambda j: (0,))]

    kern = functools.partial(
        _quant_merge_kernel if imp is None else _quant_merge_imp_kernel,
        wire_dtype=wire_dtype, wire_block=wire_block)
    committed, new_ref = pl.pallas_call(
        kern,
        grid=(dp // block,),
        in_specs=in_specs,
        out_specs=(tile, tile),
        out_shape=(jax.ShapeDtypeStruct((n, dp), stacked.dtype),
                   jax.ShapeDtypeStruct((n, dp), jnp.float32)),
        interpret=interpret,
    )(*operands)
    return committed[:, :d], new_ref[:, :d]


def fused_quant_merge_tree(stacked_tree, wire_tree, W, gates, imp=None, **kw):
    """Leaf-wise :func:`fused_quant_merge_all` over stacked pytrees.

    Returns ``(committed_tree, new_wire_tree)``; None leaves (non-payload
    when lora_only sync is active) pass through as None in both. Flattens
    explicitly so params trees containing structural tuples can't be
    confused with the per-leaf (committed, reference) pairs."""
    nones = lambda v: v is None
    xs, treedef = jax.tree_util.tree_flatten(stacked_tree, is_leaf=nones)
    rs = jax.tree_util.tree_flatten(wire_tree, is_leaf=nones)[0]
    fs = ([None] * len(xs) if imp is None
          else jax.tree_util.tree_flatten(imp, is_leaf=nones)[0])

    committed, new_wire = [], []
    for x, r, f in zip(xs, rs, fs):
        if x is None:
            committed.append(None)
            new_wire.append(None)
            continue
        n = x.shape[0]
        c, nr = fused_quant_merge_all(
            x.reshape(n, -1), jnp.asarray(r, jnp.float32).reshape(n, -1),
            W, gates, None if f is None else jnp.asarray(f).reshape(n, -1),
            **kw)
        committed.append(c.reshape(x.shape))
        new_wire.append(nr.reshape(x.shape))
    return (jax.tree_util.tree_unflatten(treedef, committed),
            jax.tree_util.tree_unflatten(treedef, new_wire))


def fused_merge_tree(stacked_tree, weights, self_idx, gate, imp=None, **kw):
    """Apply the kernel leaf-wise over a stacked param pytree.

    weights [N] + scalar gate → one node's view ([D]-shaped leaves);
    weights [N, N] + gate [N] → the all-nodes commit (stacked leaves preserved;
    ``self_idx`` is ignored — each row is its own self). ``imp``: optional
    pytree of per-element importance weights matching ``stacked_tree``
    (fisher/gradmatch; all-nodes form only).
    """
    all_nodes = jnp.ndim(weights) == 2

    def one(x, f=None):
        if x is None:
            return None
        n = x.shape[0]
        flat = x.reshape(n, -1)
        if all_nodes:
            fflat = None if f is None else jnp.asarray(f).reshape(n, -1)
            return fused_merge_all(flat, weights, gate, fflat,
                                   **kw).reshape(x.shape)
        return fused_merge(flat, weights, self_idx, gate, **kw).reshape(x.shape[1:])

    if imp is None:
        return jax.tree.map(one, stacked_tree, is_leaf=lambda v: v is None)
    return jax.tree.map(one, stacked_tree, imp, is_leaf=lambda v: v is None)
