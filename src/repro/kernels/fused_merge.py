"""Pallas TPU kernel: fused swarm merge + validation gate.

The gossip commit applies  out = gate ? Σ_j w_j θ_j : θ_self  over every
parameter shard. Done naively (XLA) this materializes the weighted sum and the
select as separate HBM round-trips over the full model (multi-GB). The kernel
fuses contraction-over-nodes and gating into ONE VMEM pass: each grid step
streams an [N, BLOCK] tile from HBM, reduces over N on the VPU, applies the
gate, writes BLOCK back. Memory-bound by design — (N+1)·BLOCK bytes moved per
BLOCK produced, the roofline minimum for this op.

Two entry points:

  * ``fused_merge``      — one node's commit:   [N, D] → [D]
  * ``fused_merge_all``  — the whole swarm's commit in one launch:
                           [N, D] → [N, D] with a full mixing matrix W [N, N]
                           and per-node gate bits. Grid order is
                           (d-blocks, nodes) so the [N, BLOCK] input tile is
                           fetched once per d-block and reused for all N output
                           rows — (N + N)·BLOCK bytes per column block, still
                           the roofline minimum.

``fused_merge_all`` optionally takes per-element importance weights
``imp [N, D]`` (diagonal Fisher mass). The merged row then becomes the
normalized importance-weighted mean

    out[i] = gate_i ?  Σ_j W[i,j]·imp[j]⊙θ_j / Σ_j W[i,j]·imp[j]  :  θ_i

which covers fisher merging (W = 1) and gradient matching (W rows = dataset
weights; the gradmatch correction collapses algebraically to this ratio) in
the same single VMEM pass — (2N + N)·BLOCK bytes per column block instead of
the ~6N·BLOCK an unfused numerator/denominator/select chain moves.

``fused_merge_tree`` maps either entry point leaf-wise over a stacked param
pytree (2-D ``weights`` selects the all-nodes form, ``imp=`` a matching
importance pytree); the host-simulated swarm engine commits through it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16_384  # 4 nodes × 16k × 4B = 256 KiB VMEM working set


def _merge_kernel(x_ref, w_ref, gate_ref, self_idx_ref, o_ref):
    """x [N, B] tile; w [N]; gate/self_idx scalars (SMEM); o [B] tile."""
    x = x_ref[...].astype(jnp.float32)              # [N, B]
    w = w_ref[...].astype(jnp.float32)              # [N]
    merged = jnp.einsum("n,nb->b", w, x)
    self_row = jax.lax.dynamic_index_in_dim(x, self_idx_ref[0], axis=0,
                                            keepdims=False)
    gate = gate_ref[0] != 0
    o_ref[...] = jnp.where(gate, merged, self_row).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_merge(stacked, weights, self_idx, gate, *, block: int = DEFAULT_BLOCK,
                interpret: bool = False):
    """stacked [N, D] → merged-or-kept [D].

    weights: [N] mixing row for this node; gate: scalar bool (validation
    acceptance); self_idx: this node's row. D is padded to a block multiple.
    """
    n, d = stacked.shape
    block = min(block, max(128, d))
    pad = (-d) % block
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    dp = d + pad
    grid = (dp // block,)

    out = pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights.astype(jnp.float32),
      jnp.asarray(gate, jnp.int32).reshape(1),
      jnp.asarray(self_idx, jnp.int32).reshape(1))
    return out[:d]


def _merge_all_kernel(x_ref, w_ref, g_ref, o_ref):
    """x [N, B] tile (all nodes); w [1, N] mixing row of node i; g [1];
    o [1, B] — node i's committed slice. Grid is (d-blocks, nodes)."""
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)              # [N, B]
    w = w_ref[...].astype(jnp.float32)[0]           # [N]
    merged = jnp.einsum("n,nb->b", w, x)
    self_row = jax.lax.dynamic_index_in_dim(x, i, axis=0, keepdims=False)
    gate = g_ref[0] != 0
    o_ref[...] = jnp.where(gate, merged, self_row)[None].astype(o_ref.dtype)


def _merge_all_imp_kernel(x_ref, f_ref, w_ref, g_ref, o_ref):
    """Importance-weighted form: x/f [N, B] tiles; w [1, N] row of node i;
    g [1]; o [1, B].  merged = Σ_j w_j f_j x_j / Σ_j w_j f_j  per element."""
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)              # [N, B]
    f = f_ref[...].astype(jnp.float32)              # [N, B]
    w = w_ref[...].astype(jnp.float32)[0]           # [N]
    wf = f * w[:, None]
    num = jnp.einsum("nb,nb->b", wf, x)
    den = wf.sum(0)
    merged = num / jnp.maximum(den, 1e-30)
    self_row = jax.lax.dynamic_index_in_dim(x, i, axis=0, keepdims=False)
    gate = g_ref[0] != 0
    o_ref[...] = jnp.where(gate, merged, self_row)[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_merge_all(stacked, W, gates, imp=None, *, block: int = DEFAULT_BLOCK,
                    interpret: bool = False):
    """stacked [N, D] → committed [N, D]:  out[i] = gate[i] ? Σ_j W[i,j] θ_j : θ_i.

    W: [N, N] row-stochastic mixing matrix; gates: [N] acceptance bits. The
    node axis is the innermost grid dimension, so each [N, BLOCK] tile is
    loaded once and serves every node's output row.

    imp: optional [N, D] per-element importance weights — switches to the
    normalized weighted merge  Σ_j W[i,j]·imp[j]⊙θ_j / Σ_j W[i,j]·imp[j]
    (fisher / gradmatch commits), still one pass over the tile.
    """
    n, d = stacked.shape
    block = min(block, max(128, d))
    pad = (-d) % block
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        if imp is not None:
            imp = jnp.pad(imp, ((0, 0), (0, pad)))
    dp = d + pad

    tile_spec = pl.BlockSpec((n, block), lambda j, i: (0, j))
    operands = [stacked]
    in_specs = [tile_spec]
    if imp is not None:  # same tiling, one extra [N, B] importance stream
        operands.append(jnp.asarray(imp, jnp.float32))
        in_specs.append(tile_spec)
    operands += [jnp.asarray(W, jnp.float32),
                 jnp.asarray(gates).astype(jnp.int32)]
    in_specs += [pl.BlockSpec((1, n), lambda j, i: (i, 0)),
                 pl.BlockSpec((1,), lambda j, i: (i,))]

    out = pl.pallas_call(
        _merge_all_kernel if imp is None else _merge_all_imp_kernel,
        grid=(dp // block, n),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block), lambda j, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, dp), stacked.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :d]


def fused_merge_tree(stacked_tree, weights, self_idx, gate, imp=None, **kw):
    """Apply the kernel leaf-wise over a stacked param pytree.

    weights [N] + scalar gate → one node's view ([D]-shaped leaves);
    weights [N, N] + gate [N] → the all-nodes commit (stacked leaves preserved;
    ``self_idx`` is ignored — each row is its own self). ``imp``: optional
    pytree of per-element importance weights matching ``stacked_tree``
    (fisher/gradmatch; all-nodes form only).
    """
    all_nodes = jnp.ndim(weights) == 2

    def one(x, f=None):
        if x is None:
            return None
        n = x.shape[0]
        flat = x.reshape(n, -1)
        if all_nodes:
            fflat = None if f is None else jnp.asarray(f).reshape(n, -1)
            return fused_merge_all(flat, weights, gate, fflat,
                                   **kw).reshape(x.shape)
        return fused_merge(flat, weights, self_idx, gate, **kw).reshape(x.shape[1:])

    if imp is None:
        return jax.tree.map(one, stacked_tree, is_leaf=lambda v: v is None)
    return jax.tree.map(one, stacked_tree, imp, is_leaf=lambda v: v is None)
