"""Pallas TPU kernel: block-tiled flash attention with GQA + sliding window.

TPU-native adaptation (HBM→VMEM streaming, MXU-aligned tiles):
  grid = (batch, kv_heads, q_blocks, kv_blocks), kv innermost & sequential.
  q tile [G, bq, D] (all G query heads of one KV group ride together so K/V
  tiles are loaded once per group — the GQA bandwidth win), k/v tiles [bk, D].
  Online softmax state (m, l, acc) lives in VMEM scratch across kv steps.
  Causal and sliding-window tiles that are fully masked are SKIPPED
  (pl.when on block bounds) — this is what makes the long_500k sliding-window
  variant sub-quadratic in compute, not just masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, n_kv: int, causal: bool, window: int,
                  scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # tile-level skip: causal (kv entirely in the future) or out of window
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window > 0:
        # newest key in tile must be > oldest query pos - window
        live = live & (k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [G, bq, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, D]
        s = jnp.einsum("gqd,kd->gqk", q, k) * scale  # [G, bq, bk]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = kpos <= qpos
            if window > 0:
                mask = mask & (kpos > qpos - window)
        s = jnp.where(mask[None], s, NEG_INF)

        m_prev = m_ref[...]                           # [G, bq]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
            "gqk,kd->gqd", p, v)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q [B,H,S,D], k/v [B,Hkv,T,D] → [B,H,S,D]. H % Hkv == 0."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    if h % hkv:
        raise ValueError("GQA requires n_heads % n_kv_heads == 0")
    g = h // hkv
    bq, bk = min(bq, s), min(bk, t)
    if s % bq or t % bk:
        raise ValueError(f"seq dims ({s},{t}) must divide tiles ({bq},{bk})")
    grid = (b, hkv, s // bq, t // bk)
    qg = q.reshape(b, hkv, g, s, d)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, n_kv=grid[3],
                          causal=causal, window=window,
                          scale=1.0 / (d ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, bq, d), lambda bi, hi, qi, ki: (bi, hi, 0, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, bq), jnp.float32),      # running max
            pltpu.VMEM((g, bq), jnp.float32),      # running denom
            pltpu.VMEM((g, bq, d), jnp.float32),   # output accumulator
        ],
        compiler_params=dict(),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(b, h, s, d)
