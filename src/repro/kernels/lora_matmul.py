"""Pallas TPU kernel: fused base + LoRA matmul.

  y = x @ W + scale · (x @ A) @ B        x:[M,K] W:[K,N] A:[K,r] B:[r,N]

The paper makes LoRA adapters the permanent exchange payload, so swarm
fine-tuning runs this everywhere. Unfused, XLA materializes xA [M,r] and
xA@B [M,N] through HBM; the kernel keeps both low-rank intermediates in VMEM
and accumulates them into the same MXU tile pass as the base matmul:

  grid (M/bm, N/bn, K/bk), K innermost (sequential). Scratch: acc [bm,bn]
  (base+total) and xa [bm,r] (low-rank running sum). On the last K step the
  r-rank correction xa @ B_tile lands on the MXU and the tile is written once.

Tile defaults are MXU-aligned (128 multiples); r stays whole (r ≤ 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lora_kernel(x_ref, w_ref, a_ref, b_ref, scale_ref, o_ref,
                 acc_ref, xa_ref, *, n_k: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...],
                           preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _finish():
        scale = scale_ref[0]
        low_rank = jnp.dot(xa_ref[...].astype(x.dtype), b_ref[...],
                           preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * low_rank).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def lora_matmul(x, w, a, b, scale, *, bm: int = 128, bn: int = 128,
                bk: int = 512, interpret: bool = False):
    m, k = x.shape
    _, n = w.shape
    r = a.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"dims ({m},{n},{k}) must divide tiles ({bm},{bn},{bk})")
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_lora_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b, jnp.asarray(scale, jnp.float32).reshape(1))


def lora_apply(x, w, a, b, scale, *, interpret=None):
    """LoRA'd linear ``x @ W + scale·(x @ A) @ B`` with automatic dispatch.

    On TPU with MXU-tileable dims this is the fused Pallas kernel above
    (both low-rank intermediates stay in VMEM); elsewhere — interpret mode,
    or dims a tile doesn't divide (the model-zoo heads are small and
    arbitrary) — it is the mathematically identical unfused XLA form with
    f32 accumulation. One call site per layer, one numeric contract.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = x.shape
    n = w.shape[1]
    tiled = all(d % min(t, d) == 0
                for d, t in ((m, 128), (n, 128), (k, 512)))
    if not interpret and tiled:
        return lora_matmul(x, w, a, b, scale)
    xf = x.astype(jnp.float32)
    wf, af, bf = (t.astype(jnp.float32) for t in (w, a, b))
    y = xf @ wf + jnp.asarray(scale, jnp.float32) * ((xf @ af) @ bf)
    return y.astype(x.dtype)
