"""Public jit'd wrappers over the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python) — that is the validation mode. On real
TPU hardware pass ``interpret=False`` (the default resolves by backend).
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.fused_merge import fused_merge, fused_merge_tree  # noqa: F401
from repro.kernels.lora_matmul import lora_matmul  # noqa: F401
from repro.kernels.ssd_scan import ssd_scan  # noqa: F401


def default_interpret() -> bool:
    """True when no TPU is attached (validation mode)."""
    return jax.default_backend() != "tpu"


def attention_op(q, k, v, *, causal=True, window=0, **kw):
    kw.setdefault("interpret", default_interpret())
    return flash_attention(q, k, v, causal=causal, window=window, **kw)


def merge_op(stacked, weights, self_idx, gate, **kw):
    kw.setdefault("interpret", default_interpret())
    return fused_merge(stacked, weights, self_idx, gate, **kw)


def lora_op(x, w, a, b, scale, **kw):
    kw.setdefault("interpret", default_interpret())
    return lora_matmul(x, w, a, b, scale, **kw)


def ssd_op(x, dt, a_log, bmat, cmat, **kw):
    kw.setdefault("interpret", default_interpret())
    return ssd_scan(x, dt, a_log, bmat, cmat, **kw)
