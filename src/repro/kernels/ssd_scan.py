"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the GPU reference
leans on warp-level parallel prefix; on TPU we express each chunk as dense
MXU work ([L,L] decay-masked quadratic + [N,P] state GEMMs) and carry the
inter-chunk recurrence in VMEM scratch across a SEQUENTIAL chunk grid axis —
HBM sees each token exactly once.

  grid = (B, H, n_chunks)  (chunks innermost, "arbitrary" semantics)
  per step: x [L,P], dt [L], B/C [L,N] tiles in VMEM; state scratch [N,P] f32.

  y_chunk = (C·Bᵀ ⊙ decay-mask) @ (x·dt)  +  (C ⊙ e^cum) @ state
  state  ← e^{cum_L} · state + (B ⊙ decay-to-end)ᵀ @ (x·dt)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, st_out_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)        # [L, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # [L]
    bmat = b_ref[0, 0, 0].astype(jnp.float32)     # [L, N]
    cmat = c_ref[0, 0, 0].astype(jnp.float32)     # [L, N]
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))  # scalar decay rate

    dA = dt * a                                    # [L] (≤ 0)
    cum = jnp.cumsum(dA)                           # [L]
    xdt = x * dt[:, None]                          # [L, P]

    # intra-chunk: decay-masked quadratic attention (MXU)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    scores = (cmat @ bmat.T) * lmat                # [L, L]
    y = scores @ xdt                               # [L, P]

    # inter-chunk: contribution of carried state, then state update
    state = state_ref[...]                         # [N, P]
    y = y + (cmat * jnp.exp(cum)[:, None]) @ state
    decay_to_end = jnp.exp(cum[-1] - cum)          # [L]
    state_ref[...] = (jnp.exp(cum[-1]) * state
                      + (bmat * decay_to_end[:, None]).T @ xdt)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        st_out_ref[0, 0] = state_ref[...].T.astype(st_out_ref.dtype)  # [P, N]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, bmat, cmat, *, chunk: int = 256,
             interpret: bool = False):
    """x [B,S,H,P]; dt [B,S,H] (softplus'd); a_log [H];
    bmat/cmat [B,S,H,N] (groups pre-broadcast). S % chunk == 0.
    Returns y [B,S,H,P], final_state [B,H,P,N]."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} must divide chunk {chunk}")
    nc = s // chunk
    # head-major chunked layout
    xc = jnp.moveaxis(x, 2, 1).reshape(b, h, nc, chunk, p)
    dtc = jnp.moveaxis(dt, 2, 1).reshape(b, h, nc, chunk)
    bc = jnp.moveaxis(bmat, 2, 1).reshape(b, h, nc, chunk, n)
    cc = jnp.moveaxis(cmat, 2, 1).reshape(b, h, nc, chunk, n)

    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, a_log.astype(jnp.float32), bc, cc)

    y = jnp.moveaxis(y.reshape(b, h, s, p), 1, 2)  # back to [B,S,H,P]
    return y, st
