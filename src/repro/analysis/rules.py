"""swarmlint rule registry.

Each rule is a class with an ``id`` (``SWL001``...), a ``severity``, a
one-line ``summary``, and a ``check(module, ctx)`` returning findings. Rules
are registered with the :func:`rule` decorator; ``lint.py`` drives them.

Everything here works on the stdlib ``ast`` only — no jax import, so the
linter runs in CI before any backend exists and stays fast enough for a
pre-commit hook.

Fixture snippets (tests/lint_fixtures/) opt into path-scoped rules with a
``# swarmlint: treat-as=<repo-relative-path>`` directive in their first
lines; the runner rewrites the module's *effective* path before rules see
it, so e.g. a donation fixture can masquerade as ``src/repro/core/engine.py``
without living there.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import textwrap
from typing import Dict, List, Optional, Set, Tuple

# ---------------------------------------------------------------------------
# core types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str          # repo-relative posix path (the REAL file, not treat-as)
    line: int
    rule: str          # "SWL001"
    severity: str      # "error" | "warning"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.severity}] {self.message}"


@dataclasses.dataclass
class Module:
    """One parsed source file."""
    path: str          # real repo-relative posix path
    rel: str           # effective path for rule scoping (treat-as directive)
    source: str
    tree: ast.Module
    lines: List[str]


class LintContext:
    """Shared cross-module state: the module set, and lazily-derived facts
    (mesh-axis registry, jit callgraph)."""

    def __init__(self, modules: List[Module], repo_root):
        self.modules = modules
        self.repo_root = repo_root
        self._axes: Optional[Tuple[Set[str], Optional[Finding]]] = None
        self._callgraph = None

    # -- SWL001: the declared axis registry -------------------------------
    def mesh_axes(self) -> Tuple[Set[str], Optional[Finding]]:
        """Parse MESH_AXES from launch/mesh.py (never imports it)."""
        if self._axes is not None:
            return self._axes
        rel = "src/repro/launch/mesh.py"
        src = None
        for m in self.modules:
            if m.rel == rel:
                src = m.tree
                break
        if src is None:
            p = self.repo_root / rel
            try:
                src = ast.parse(p.read_text())
            except OSError:
                self._axes = (set(), Finding(
                    rel, 1, "SWL001", "error",
                    "axis registry source missing: cannot read MESH_AXES"))
                return self._axes
        axes: Set[str] = set()
        err = None
        for node in ast.walk(src):
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "MESH_AXES"
                            for t in node.targets)):
                if isinstance(node.value, (ast.Tuple, ast.List)):
                    for elt in node.value.elts:
                        if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                            axes.add(elt.value)
        if not axes:
            err = Finding(rel, 1, "SWL001", "error",
                          "MESH_AXES registry not found in launch/mesh.py "
                          "(must be a literal tuple of axis-name strings)")
        self._axes = (axes, err)
        return self._axes


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

RULES: List[type] = []


def rule(cls):
    RULES.append(cls)
    return cls


class Rule:
    id = "SWL000"
    severity = "error"
    summary = ""

    def applies(self, module: Module) -> bool:
        return True

    def check(self, module: Module, ctx: LintContext) -> List[Finding]:
        raise NotImplementedError


def _is_test_file(rel: str) -> bool:
    return rel.rsplit("/", 1)[-1].startswith("test_")


def _attr_name(func) -> Optional[str]:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _dotted(node) -> str:
    """'jax.lax.psum' for an Attribute/Name chain ('' if not a pure chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# ---------------------------------------------------------------------------
# SWL001 — collective axis names must come from the declared registry
# ---------------------------------------------------------------------------

# collective -> index of the axis-name argument
_COLLECTIVES = {
    "psum": 1, "pmean": 1, "pmax": 1, "pmin": 1, "ppermute": 1,
    "all_gather": 1, "all_to_all": 1, "psum_scatter": 1,
    "reduce_scatter": 1, "axis_index": 0,
}
_MESH_CTORS = {"make_mesh": 1, "Mesh": 1}  # index of the axis-names tuple


@rule
class CollectiveAxisRule(Rule):
    id = "SWL001"
    severity = "error"
    summary = ("collective / mesh-construction axis names must come from the "
               "MESH_AXES registry in launch/mesh.py")

    # embedded-code strings (the subprocess-based SPMD tests build their
    # programs as string literals) get parsed and checked too
    _EMBED_HINT = re.compile(
        r"\b(make_mesh|Mesh|psum|ppermute|all_gather|all_to_all|"
        r"psum_scatter|reduce_scatter|axis_index)\b")

    def check(self, module: Module, ctx: LintContext) -> List[Finding]:
        axes, err = ctx.mesh_axes()
        if err is not None:
            # report the registry problem once, from the registry's own file
            return [err] if module.rel == err.path else []
        out: List[Finding] = []

        def bad_axis(expr) -> List[Tuple[int, str]]:
            """(line, name) for every literal axis name not in the registry."""
            hits = []
            if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
                if expr.value not in axes:
                    hits.append((expr.lineno, expr.value))
            elif isinstance(expr, (ast.Tuple, ast.List)):
                for e in expr.elts:
                    hits.extend(bad_axis(e))
            return hits

        def check_tree(tree, mapper, origin: str):
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                name = _attr_name(node.func)
                table = None
                if name in _COLLECTIVES:
                    table, kwname = _COLLECTIVES, "axis_name"
                elif name in _MESH_CTORS:
                    table, kwname = _MESH_CTORS, "axis_names"
                if table is None:
                    continue
                idx = table[name]
                cand = None
                if len(node.args) > idx:
                    cand = node.args[idx]
                else:
                    for kw in node.keywords:
                        if kw.arg == kwname:
                            cand = kw.value
                if cand is None:
                    continue
                for line, ax in bad_axis(cand):
                    out.append(Finding(
                        module.path, mapper(line), self.id, self.severity,
                        f"axis name {ax!r} in {name}(...){origin} is not in "
                        f"the MESH_AXES registry {tuple(sorted(axes))} "
                        "(launch/mesh.py)"))

        check_tree(module.tree, lambda line: line, "")
        for node in ast.walk(module.tree):
            if (isinstance(node, ast.Constant) and isinstance(node.value, str)
                    and "\n" in node.value
                    and self._EMBED_HINT.search(node.value)):
                dedented = textwrap.dedent(node.value)
                try:
                    embedded = ast.parse(dedented)
                except SyntaxError:
                    continue

                def mapper(line, _emb=dedented.splitlines(), _at=node.lineno):
                    # map the in-string line back onto the physical line by
                    # content (backslash continuations inside the string
                    # break simple offset arithmetic) so a noqa comment in
                    # the code string suppresses exactly its own finding
                    txt = (_emb[line - 1].strip()
                           if 0 < line <= len(_emb) else "")
                    if txt:
                        for j in range(_at - 1, len(module.lines)):
                            if module.lines[j].strip() == txt:
                                return j + 1
                    return _at

                check_tree(embedded, mapper, " [embedded code string]")

        # cross-file consistency: the logical->physical table may only map
        # onto registered mesh axes
        if module.rel == "src/repro/sharding/rules.py":
            for node in ast.walk(module.tree):
                if (isinstance(node, ast.Assign) and isinstance(node.value, ast.Dict)
                        and any(isinstance(t, ast.Name) and t.id == "DEFAULT_LOGICAL"
                                for t in node.targets)):
                    for v in node.value.values:
                        vals = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
                        for e in vals:
                            if (isinstance(e, ast.Constant)
                                    and isinstance(e.value, str)
                                    and e.value not in axes):
                                out.append(Finding(
                                    module.path, e.lineno, self.id, self.severity,
                                    f"DEFAULT_LOGICAL maps onto mesh axis "
                                    f"{e.value!r} which is not in MESH_AXES"))
        return out


# ---------------------------------------------------------------------------
# SWL002 — no host syncs in code reachable from a jit/shard_map entry point
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Fn:
    module: Module
    qual: str                 # "SwarmEngine._round" / "ring_all_reduce"
    name: str
    cls: Optional[str]
    node: ast.AST
    children: Dict[str, "_Fn"] = dataclasses.field(default_factory=dict)
    is_entry: bool = False


# attribute names too generic to resolve on a non-self receiver (dict.update
# vs EarlyStopper.update would otherwise alias)
_GENERIC_ATTRS = {
    "update", "get", "pop", "items", "keys", "values", "append", "extend",
    "copy", "astype", "reshape", "sum", "mean", "max", "min", "join",
    "split", "map", "leaves", "flatten", "read", "write", "init", "index",
    "count", "sort", "item", "tolist", "apply", "lower", "shape", "close",
}

_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize", "sharding"}


def _contains_static_source(expr) -> bool:
    """True if the expression reads only trace-time-static metadata
    (shape/dtype arithmetic, len(...), constants)."""
    for n in ast.walk(expr):
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            return True
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id == "len"):
            return True
    return isinstance(expr, ast.Constant)


class _CallGraph:
    """Name-resolution callgraph over the src/repro modules in this run.

    Deliberately approximate: bare names resolve module-locally first, then
    to a globally unique def; ``self.x()`` resolves inside the enclosing
    class; other attribute calls resolve only when the method name is
    globally unique and not a generic container-method name. Function
    references passed as call *arguments* (vmap/scan/tree.map bodies) count
    as edges too.
    """

    def __init__(self, modules: List[Module]):
        self.fns: List[_Fn] = []
        self.by_module: Dict[str, Dict[str, _Fn]] = {}
        self.by_name: Dict[str, List[_Fn]] = {}
        self.by_cls: Dict[Tuple[str, str], _Fn] = {}
        self.aliases: Dict[str, Dict[str, str]] = {}
        for m in modules:
            if not m.rel.startswith("src/repro") or _is_test_file(m.rel):
                continue
            self._collect(m)
        self._mark_entries()
        self.reachable: Dict[str, str] = {}  # qual -> entry qual
        self._propagate()

    # -- collection -------------------------------------------------------
    def _collect(self, m: Module):
        top: Dict[str, _Fn] = {}
        alias: Dict[str, str] = {}
        self.by_module[m.rel] = top
        self.aliases[m.rel] = alias

        for node in ast.walk(m.tree):
            if isinstance(node, ast.ImportFrom):
                for a in node.names:
                    if a.asname:
                        alias[a.asname] = a.name

        def visit(body, cls, parent: Optional[_Fn], prefix):
            for node in body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{m.rel}::{prefix}{node.name}"
                    fn = _Fn(m, qual, node.name, cls, node)
                    self.fns.append(fn)
                    self.by_name.setdefault(node.name, []).append(fn)
                    if parent is None and cls is None:
                        top[node.name] = fn
                    if parent is not None:
                        parent.children[node.name] = fn
                    if cls is not None and parent is None:
                        self.by_cls[(cls, node.name)] = fn
                    visit(node.body, cls, fn, prefix + node.name + ".")
                elif isinstance(node, ast.ClassDef):
                    visit(node.body, node.name, None, prefix + node.name + ".")
                elif isinstance(node, (ast.If, ast.Try, ast.With)):
                    visit(ast.iter_child_nodes(node), cls, parent, prefix)

        visit(m.tree.body, None, None, "")

    # -- entry points -----------------------------------------------------
    def _is_jit_expr(self, expr) -> bool:
        d = _dotted(expr)
        return d in ("jax.jit", "jit", "pjit", "jax.pjit")

    def _entry_wrappers(self, call: ast.Call) -> bool:
        """jax.jit(f) / shard_map(f, ...) / pl.pallas_call(kernel, ...)."""
        if self._is_jit_expr(call.func):
            return True
        name = _attr_name(call.func)
        return name in ("shard_map", "_shard_map", "pallas_call")

    def _mark_entries(self):
        for fn in self.fns:
            node = fn.node
            for dec in getattr(node, "decorator_list", []):
                if self._is_jit_expr(dec):
                    fn.is_entry = True
                elif (isinstance(dec, ast.Call)
                      and (_attr_name(dec.func) == "partial"
                           and dec.args and self._is_jit_expr(dec.args[0])
                           or self._is_jit_expr(dec.func))):
                    fn.is_entry = True
        # call-site wrapping: jax.jit(self._round, ...), shard_map(f, ...)
        for fn in self.fns:
            for call in self._calls_in(fn):
                if not self._entry_wrappers(call) or not call.args:
                    continue
                target = self._resolve(call.args[0], fn)
                if target is not None:
                    target.is_entry = True
        # module-level wrapping (round = jax.jit(_round)) — rare here but
        # cheap to support
        for m_rel, top in self.by_module.items():
            mod = next(m for m in self.fns if m.module.rel == m_rel).module \
                if any(f.module.rel == m_rel for f in self.fns) else None
            if mod is None:
                continue
            for node in ast.walk(mod.tree):
                if (isinstance(node, ast.Call) and self._entry_wrappers(node)
                        and node.args):
                    t = node.args[0]
                    if isinstance(t, ast.Name) and t.id in top:
                        top[t.id].is_entry = True

    # -- edges ------------------------------------------------------------
    def _calls_in(self, fn: _Fn):
        """Call nodes in fn's own body, not descending into nested defs."""
        out = []

        def walk(node):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if isinstance(child, ast.Call):
                    out.append(child)
                walk(child)

        walk(fn.node)
        return out

    def _resolve(self, expr, fn: _Fn) -> Optional[_Fn]:
        if isinstance(expr, ast.Name):
            name = self.aliases.get(fn.module.rel, {}).get(expr.id, expr.id)
            if expr.id in fn.children:
                return fn.children[expr.id]
            local = self.by_module.get(fn.module.rel, {})
            if name in local:
                return local[name]
            if expr.id in local:
                return local[expr.id]
            cands = self.by_name.get(name, [])
            return cands[0] if len(cands) == 1 else None
        if isinstance(expr, ast.Attribute):
            attr = expr.attr
            if isinstance(expr.value, ast.Name) and expr.value.id == "self":
                if fn.cls and (fn.cls, attr) in self.by_cls:
                    return self.by_cls[(fn.cls, attr)]
            if attr in _GENERIC_ATTRS:
                return None
            cands = self.by_name.get(attr, [])
            return cands[0] if len(cands) == 1 else None
        return None

    # higher-order caller -> positional slots holding function references.
    # Only these slots create edges: resolving EVERY Name argument would
    # alias data variables onto same-named host functions (a scan's xs named
    # `batches` is not a call to data.synthetic.batches).
    _HIGHER_ORDER = {
        "vmap": (0,), "pmap": (0,), "map": (0,), "tree_map": (0,),
        "scan": (0,), "shard_map": (0,), "_shard_map": (0,),
        "pallas_call": (0,), "partial": (0,), "grad": (0,),
        "value_and_grad": (0,), "checkpoint": (0,), "remat": (0,),
        "jit": (0,), "custom_vjp": (0,), "while_loop": (0, 1),
        "fori_loop": (2,), "cond": (1, 2), "switch": (1, 2, 3, 4),
    }

    def _edges(self, fn: _Fn) -> List[_Fn]:
        out = []
        for call in self._calls_in(fn):
            t = self._resolve(call.func, fn)
            if t is not None:
                out.append(t)
            # function references handed to vmap/scan/tree.map/shard_map etc.
            slots = self._HIGHER_ORDER.get(_attr_name(call.func) or "", ())
            for i in slots:
                if i < len(call.args) and isinstance(
                        call.args[i], (ast.Name, ast.Attribute)):
                    t = self._resolve(call.args[i], fn)
                    if t is not None:
                        out.append(t)
        out.extend(fn.children.values())  # nested defs run in fn's trace
        return out

    def _propagate(self):
        work = [(f, f.qual) for f in self.fns if f.is_entry]
        for fn, entry in work:
            if fn.qual in self.reachable:
                continue
            self.reachable[fn.qual] = entry
        queue = list(work)
        while queue:
            fn, entry = queue.pop()
            for nxt in self._edges(fn):
                if nxt.qual not in self.reachable:
                    self.reachable[nxt.qual] = entry
                    queue.append((nxt, entry))


@rule
class TraceHazardRule(Rule):
    id = "SWL002"
    severity = "error"
    summary = ("no host syncs (int()/float()/.item()/np.*/device_get) in "
               "functions reachable from a jax.jit / shard_map entry point")

    _NP_DTYPE_ATTRS = {"float32", "float64", "int32", "int64", "int8",
                       "uint8", "bool_", "dtype", "uint32"}

    def applies(self, module: Module) -> bool:
        return (module.rel.startswith("src/repro")
                and not _is_test_file(module.rel))

    def check(self, module: Module, ctx: LintContext) -> List[Finding]:
        if ctx._callgraph is None:
            ctx._callgraph = _CallGraph(ctx.modules)
        cg: _CallGraph = ctx._callgraph
        out: List[Finding] = []
        for fn in cg.fns:
            if fn.module is not module and fn.module.rel != module.rel:
                continue
            entry = cg.reachable.get(fn.qual)
            if entry is None:
                continue
            short = fn.qual.split("::")[-1]
            eshort = entry.split("::")[-1]
            where = (f"in jit-reachable '{short}'"
                     + ("" if entry == fn.qual else f" (entry: {eshort})"))
            for call in cg._calls_in(fn):
                f = call.func
                if isinstance(f, ast.Name) and f.id in ("int", "float",
                                                        "bool", "complex"):
                    if call.args and _contains_static_source(call.args[0]):
                        continue
                    out.append(Finding(
                        module.path, call.lineno, self.id, "error",
                        f"host sync {f.id}(...) {where} — forces a device "
                        "round-trip under trace; keep the value on-device or "
                        "hoist it out of the jitted path"))
                elif isinstance(f, ast.Attribute) and f.attr in ("item",
                                                                 "tolist"):
                    out.append(Finding(
                        module.path, call.lineno, self.id, "error",
                        f".{f.attr}() host sync {where}"))
                elif _dotted(f) in ("jax.device_get", "jax.block_until_ready"):
                    out.append(Finding(
                        module.path, call.lineno, self.id, "error",
                        f"{_dotted(f)}(...) host sync {where}"))
                elif (isinstance(f, ast.Attribute)
                      and isinstance(f.value, ast.Name)
                      and f.value.id in ("np", "numpy")
                      and f.attr not in self._NP_DTYPE_ATTRS):
                    out.append(Finding(
                        module.path, call.lineno, self.id, "warning",
                        f"host numpy call np.{f.attr}(...) {where} — runs at "
                        "trace time; fine only for trace-static data (then "
                        "suppress with a justification) — otherwise use jnp"))
        return out


# ---------------------------------------------------------------------------
# SWL003 — hot jitted round entry points must donate their buffers
# ---------------------------------------------------------------------------

_HOT_ENTRY_RE = re.compile(r"(^|_)(round|rounds|local)(_|$|s$)")
# serving plane (PR 8): decode/prefill/commit/swap-named jit entries mutate
# the slot cache table every tick — an undonated entry copies the whole
# ensemble KV cache per token
_SERVE_ENTRY_RE = re.compile(r"(^|_)(decode|prefill|commit|swap)(_|$)")
_SERVE_PREFIX = "src/repro/serve/"
_DONATE_KWS = {"donate_argnums", "donate_argnames"}


@rule
class DonationRule(Rule):
    id = "SWL003"
    severity = "error"
    summary = ("jitted round/run_rounds-class entry points in core/engine.py "
               "and core/session.py — and decode/prefill/commit-class entries "
               "in src/repro/serve/ — must declare donate_argnums")

    _TARGETS = ("src/repro/core/engine.py", "src/repro/core/session.py")

    def applies(self, module: Module) -> bool:
        return (module.rel in self._TARGETS
                or module.rel.startswith(_SERVE_PREFIX))

    def check(self, module: Module, ctx: LintContext) -> List[Finding]:
        out: List[Finding] = []
        hot_re = (_SERVE_ENTRY_RE if module.rel.startswith(_SERVE_PREFIX)
                  else _HOT_ENTRY_RE)

        klass = ("serve decode/commit-class" if module.rel.startswith(
            _SERVE_PREFIX) else "round-class")

        def hot(name: Optional[str]) -> bool:
            return bool(name) and bool(hot_re.search(name))

        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) and _dotted(node.func) in (
                    "jax.jit", "jit"):
                if not node.args:
                    continue
                tname = _attr_name(node.args[0])
                if hot(tname) and not any(k.arg in _DONATE_KWS
                                          for k in node.keywords):
                    out.append(Finding(
                        module.path, node.lineno, self.id, self.severity,
                        f"jax.jit({tname}) is a {klass} hot path but "
                        "declares no donate_argnums — its state buffers "
                        "will be copied on every call"))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    donated = False
                    is_jit = _dotted(dec) in ("jax.jit", "jit")
                    if (isinstance(dec, ast.Call)
                            and _attr_name(dec.func) == "partial"
                            and dec.args
                            and _dotted(dec.args[0]) in ("jax.jit", "jit")):
                        is_jit = True
                        donated = any(k.arg in _DONATE_KWS
                                      for k in dec.keywords)
                    elif isinstance(dec, ast.Call) and _dotted(dec.func) in (
                            "jax.jit", "jit"):
                        is_jit = True
                        donated = any(k.arg in _DONATE_KWS
                                      for k in dec.keywords)
                    if is_jit and hot(node.name) and not donated:
                        out.append(Finding(
                            module.path, node.lineno, self.id, self.severity,
                            f"@jit on {klass} '{node.name}' without "
                            "donate_argnums"))
        return out


# ---------------------------------------------------------------------------
# SWL004 — declared shared cores must have exactly one implementation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SoleImpl:
    """Declarative single-implementation contract.

    A scope (function/method or module toplevel) *implements* the core when
    it contains every listed signature element. Elements:
      ``constant:<value>``  — a numeric literal (e.g. the 127.0 q8 scale)
      ``call:<name>``       — a call whose terminal name matches
      ``string:<value>``    — an exact string literal (e.g. the "lora_"
                              adapter-path marker)
    """
    name: str
    allowed: str                 # the one repo-relative path allowed to host it
    signature: Tuple[str, ...]
    description: str


# the next shared core (hierarchical comms reducer, serve decode path) gets
# the same guarantee by appending one entry here
SOLE_IMPLS: Tuple[SoleImpl, ...] = (
    SoleImpl(
        name="quant_dequant_block",
        allowed="src/repro/core/comms.py",
        signature=("constant:127.0", "call:round"),
        description="int8 block-quantization core (scale-to-127 + round)"),
    SoleImpl(
        name="adapter_flatten",
        allowed="src/repro/core/lora.py",
        signature=("call:tree_flatten_with_path", "string:lora_"),
        description="adapter payload flatten/unflatten core (the path-keyed "
                    "flat dict the heterogeneous wire stacks; engine, "
                    "gossip, and kernel paths must share lora.flatten_"
                    "payload/unflatten_payload)"),
)


@rule
class SoleImplementationRule(Rule):
    id = "SWL004"
    severity = "error"
    summary = ("declared shared cores (sole_impl registry) may have exactly "
               "one implementation site")

    def applies(self, module: Module) -> bool:
        return (module.rel.startswith("src/")
                and not _is_test_file(module.rel))

    @staticmethod
    def _matches(scope_nodes, spec: SoleImpl) -> bool:
        need_const: Set[float] = set()
        need_call: Set[str] = set()
        need_str: Set[str] = set()
        for sig in spec.signature:
            kind, _, val = sig.partition(":")
            if kind == "constant":
                need_const.add(float(val))
            elif kind == "call":
                need_call.add(val)
            elif kind == "string":
                need_str.add(val)
        found_const: Set[float] = set()
        found_call: Set[str] = set()
        found_str: Set[str] = set()
        for n in scope_nodes:
            if isinstance(n, ast.Constant):
                if (isinstance(n.value, (int, float))
                        and not isinstance(n.value, bool)
                        and float(n.value) in need_const):
                    found_const.add(float(n.value))
                if isinstance(n.value, str) and n.value in need_str:
                    found_str.add(n.value)
            if isinstance(n, ast.Call):
                name = _attr_name(n.func)
                if name in need_call:
                    found_call.add(name)
        return (found_const == need_const and found_call == need_call
                and found_str == need_str)

    def check(self, module: Module, ctx: LintContext) -> List[Finding]:
        out: List[Finding] = []
        scopes: List[Tuple[str, int, list]] = []
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append((node.name, node.lineno, list(ast.walk(node))))
        for spec in SOLE_IMPLS:
            if module.rel == spec.allowed:
                continue
            for name, line, nodes in scopes:
                if self._matches(nodes, spec):
                    out.append(Finding(
                        module.path, line, self.id, self.severity,
                        f"'{name}' re-implements sole-impl core "
                        f"'{spec.name}' ({spec.description}); the only "
                        f"allowed implementation lives in {spec.allowed} — "
                        "delegate to it instead"))
        return out


# ---------------------------------------------------------------------------
# SWL005 — mesh-touching tests must carry the spmd pytest marker
# ---------------------------------------------------------------------------

_SPMD_TOKENS = {"Mesh", "shard_map", "make_mesh", "make_swarm_mesh",
                "make_production_mesh", "ppermute", "init_mesh_wire"}
# the subprocess-based SPMD tests hold their mesh code in string literals;
# \b keeps schedule names like "ring_ppermute" from matching
_SPMD_STR_RE = re.compile(
    r"\b(Mesh|shard_map|make_mesh|make_swarm_mesh|make_production_mesh|"
    r"init_mesh_wire|ppermute)\b")


def _idents(node) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def _string_tokens(node) -> Set[str]:
    """SPMD tokens inside string literals, excluding the docstring (prose
    *describing* ppermute behavior is not mesh-touching code)."""
    doc = None
    body = getattr(node, "body", None)
    if (body and isinstance(body[0], ast.Expr)
            and isinstance(body[0].value, ast.Constant)
            and isinstance(body[0].value.value, str)):
        doc = body[0].value
    out: Set[str] = set()
    for n in ast.walk(node):
        if (n is not doc and isinstance(n, ast.Constant)
                and isinstance(n.value, str)):
            out |= set(_SPMD_STR_RE.findall(n.value))
    return out


@rule
class SpmdMarkerRule(Rule):
    id = "SWL005"
    severity = "error"
    summary = ("tests touching Mesh/shard_map/ppermute must carry the spmd "
               "pytest marker (the CI shard split depends on it)")

    def applies(self, module: Module) -> bool:
        return module.rel.startswith("tests/") and _is_test_file(module.rel)

    def check(self, module: Module, ctx: LintContext) -> List[Finding]:
        # module-level pytestmark covers every test in the file
        for node in module.tree.body:
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "pytestmark"
                            for t in node.targets)
                    and "spmd" in _idents(node.value)):
                return []

        # helper closure: non-test module functions that touch the mesh
        helpers: Dict[str, Set[str]] = {}
        touching: Set[str] = set()
        for node in module.tree.body:
            if (isinstance(node, ast.FunctionDef)
                    and not node.name.startswith("test_")):
                ids = _idents(node)
                helpers[node.name] = ids
                if (ids & _SPMD_TOKENS) or _string_tokens(node):
                    touching.add(node.name)
        changed = True
        while changed:  # transitive within the module
            changed = False
            for name, ids in helpers.items():
                if name not in touching and ids & touching:
                    touching.add(name)
                    changed = True

        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.FunctionDef)
                    and node.name.startswith("test_")):
                continue
            marked = any("spmd" in _idents(d) for d in node.decorator_list)
            if marked:
                continue
            ids = _idents(node)
            hit = ((ids & _SPMD_TOKENS) or (ids & touching)
                   or _string_tokens(node))
            if hit:
                out.append(Finding(
                    module.path, node.lineno, self.id, self.severity,
                    f"'{node.name}' touches the mesh ({sorted(hit)[0]}) but "
                    "has no @pytest.mark.spmd marker — it would silently "
                    "land in the wrong CI shard"))
        return out


# ---------------------------------------------------------------------------
# SWL006 — Pallas block sizes must go through auto_block / a checked expr
# ---------------------------------------------------------------------------

_TILE_PARAM_RE = re.compile(r"^(block|chunk|b[qkmn])$")
_CHECK_FNS = {"min", "max", "auto_block"}


@rule
class PallasBlockRule(Rule):
    id = "SWL006"
    severity = "error"
    summary = ("kernels/: BlockSpec/VMEM shapes must not use bare int "
               "literals, and tile-size parameters must be bounded via "
               "auto_block/min or a divisibility check")

    def applies(self, module: Module) -> bool:
        return module.rel.startswith("src/repro/kernels/")

    def check(self, module: Module, ctx: LintContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                name = _attr_name(node.func)
                if name in ("BlockSpec", "VMEM") and node.args:
                    shape = node.args[0]
                    if isinstance(shape, (ast.Tuple, ast.List)):
                        for elt in shape.elts:
                            if (isinstance(elt, ast.Constant)
                                    and isinstance(elt.value, int)
                                    and elt.value > 1):
                                out.append(Finding(
                                    module.path, elt.lineno, self.id,
                                    self.severity,
                                    f"bare literal {elt.value} in {name} "
                                    "shape — size blocks via auto_block(...) "
                                    "or a checked budget expression (the "
                                    "N=64 VMEM overflow class of bug)"))
            elif isinstance(node, ast.FunctionDef):
                body_calls = [_attr_name(c.func) for c in ast.walk(node)
                              if isinstance(c, ast.Call)]
                if "pallas_call" not in body_calls:
                    continue
                checked: Set[str] = set()
                for n in ast.walk(node):
                    if isinstance(n, ast.Assign):
                        names = _idents(n.value)
                        if names & _CHECK_FNS:
                            for t in n.targets:
                                checked |= {x.id for x in ast.walk(t)
                                            if isinstance(x, ast.Name)}
                    if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod):
                        checked |= {x.id for x in ast.walk(n)
                                    if isinstance(x, ast.Name)}
                args = node.args
                for a in args.args + args.kwonlyargs:
                    if (_TILE_PARAM_RE.match(a.arg)
                            and a.arg not in checked):
                        out.append(Finding(
                            module.path, node.lineno, self.id, self.severity,
                            f"tile parameter '{a.arg}' of '{node.name}' is "
                            "used unchecked — rebind it through "
                            "auto_block/min or validate divisibility before "
                            "the pallas_call"))
        return out


# ---------------------------------------------------------------------------
# SWL007 — host-side retry loops must go through faults/retry.with_retry
# ---------------------------------------------------------------------------

@rule
class RetryLoopRule(Rule):
    id = "SWL007"
    severity = "error"
    summary = ("src/: hand-rolled retry loops (loop + exception handler + "
               "sleep) must delegate to repro.faults.retry.with_retry — the "
               "single home for attempt bounds, backoff, and timeout budgets")

    def applies(self, module: Module) -> bool:
        # retry.py IS the sanctioned implementation
        return (module.rel.startswith("src/repro/")
                and module.rel != "src/repro/faults/retry.py")

    def check(self, module: Module, ctx: LintContext) -> List[Finding]:
        out: List[Finding] = []
        flagged: Set[int] = set()
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.While, ast.For)):
                continue
            if node.lineno in flagged:  # nested loop already reported
                continue
            has_handler = False
            has_sleep = False
            for n in ast.walk(node):
                if isinstance(n, ast.Try) and n.handlers:
                    has_handler = True
                elif (isinstance(n, ast.Call)
                      and _attr_name(n.func) == "sleep"):
                    has_sleep = True
            if has_handler and has_sleep:
                flagged.update(x.lineno for x in ast.walk(node)
                               if isinstance(x, (ast.While, ast.For)))
                out.append(Finding(
                    module.path, node.lineno, self.id, self.severity,
                    "hand-rolled retry loop (loop + exception handler + "
                    "sleep) — use repro.faults.retry.with_retry, which owns "
                    "attempt bounds, exponential backoff, and the timeout "
                    "budget (and is what SWL007 exempts)"))
        return out
