"""swarmlint: JAX/SPMD-aware static analysis for this repo.

The framework's correctness now rests on invariants nothing in the type
system checks: collective axis names must match the declared mesh registry,
jitted hot paths must donate their buffers, traced code must not sync to the
host, quantization must have exactly one implementation, SPMD tests must be
marked for the CI shard split, and Pallas block sizes must go through a
checked VMEM budget. PRs 3-5 enforced a few of these with ad-hoc grep tests;
this package turns them into a real AST analysis pass.

Usage::

    python -m repro.analysis.lint src tests          # CI gate (exit 1 on
                                                     # any unsuppressed
                                                     # finding)
    python -m repro.analysis.lint --list-rules

Suppress a finding with ``# noqa: SWLxxx — <justification>`` on the flagged
line; a suppression without a justification is itself a finding (SWL000).

Pure stdlib on purpose: the linter never imports jax (it must run before any
backend exists, and must stay cheap enough for a pre-commit hook).

The package body intentionally imports nothing: ``python -m
repro.analysis.lint`` must not re-execute a module the package already
pulled in (runpy double-import). Import ``repro.analysis.lint`` /
``repro.analysis.rules`` directly for the API (``run_paths``, ``RULES``,
``Finding``).
"""
