"""swarmlint driver: file collection, noqa suppression, reporting, CLI.

    python -m repro.analysis.lint src tests

Exit code is 1 when any unsuppressed finding remains, 0 on a clean tree.
Suppression: ``# noqa: SWL002 — <justification>`` on the flagged line. A
suppression without a justification (or a blanket ``noqa`` naming no code)
is reported as SWL000, which cannot itself be suppressed — every silenced
finding carries its reason in the source.

``tests/lint_fixtures/`` is excluded from directory walks (its files violate
rules on purpose); passing a fixture file as an explicit path lints it.
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.rules import RULES, Finding, LintContext, Module

_EXCLUDED_PARTS = {"__pycache__", ".git", "lint_fixtures", ".bench",
                   ".pytest_cache"}

_NOQA_RE = re.compile(r"#\s*noqa(?P<colon>\s*:)?(?P<rest>[^#]*)",
                      re.IGNORECASE)
_CODES_RE = re.compile(
    r"^\s*(?P<codes>[A-Za-z]+\d+(?:\s*,\s*[A-Za-z]+\d+)*)(?P<just>.*)$")

_TREAT_AS_RE = re.compile(r"#\s*swarmlint:\s*treat-as=(\S+)")


def _repo_root() -> Path:
    return Path(__file__).resolve().parents[3]


def _collect_files(paths: Sequence[str], root: Path) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if not path.is_absolute():
            path = root / path
        if path.is_file():
            out.append(path)
        elif path.is_dir():
            for f in sorted(path.rglob("*.py")):
                if not _EXCLUDED_PARTS & set(f.parts):
                    out.append(f)
        else:
            raise FileNotFoundError(f"swarmlint: no such path: {p}")
    return out


def _parse(path: Path, root: Path) -> Tuple[Optional[Module], List[Finding]]:
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    try:
        source = path.read_text()
    except (OSError, UnicodeDecodeError) as e:
        return None, [Finding(rel, 1, "SWL000", "error",
                              f"unreadable source: {e}")]
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return None, [Finding(rel, e.lineno or 1, "SWL000", "error",
                              f"syntax error: {e.msg}")]
    effective = rel
    for line in source.splitlines()[:10]:
        m = _TREAT_AS_RE.search(line)
        if m:
            effective = m.group(1)
            break
    return Module(path=rel, rel=effective, source=source, tree=tree,
                  lines=source.splitlines()), []


def _noqa_map(module: Module) -> Tuple[Dict[int, Set[str]], List[Finding]]:
    """line -> suppressed SWL codes, plus SWL000 hygiene findings."""
    sup: Dict[int, Set[str]] = {}
    meta: List[Finding] = []
    for i, ln in enumerate(module.lines, 1):
        m = _NOQA_RE.search(ln)
        if not m:
            continue
        if m.group("colon") is None:
            meta.append(Finding(
                module.path, i, "SWL000", "error",
                "blanket noqa comment is not allowed — name the code and "
                "the reason: '# noqa: SWL002 — <why this is safe>'"))
            continue
        cm = _CODES_RE.match(m.group("rest"))
        if cm is None:
            continue  # documentation mention / malformed — not a suppression
        codes = {c.strip().upper() for c in cm.group("codes").split(",")}
        swl = {c for c in codes if c.startswith("SWL")}
        if not swl:
            continue  # some other linter's noqa — not ours to police
        if not cm.group("just").strip(" -—–:\t"):
            meta.append(Finding(
                module.path, i, "SWL000", "error",
                f"suppression of {'/'.join(sorted(swl))} without a "
                "justifying comment — say why the finding does not apply"))
        sup[i] = swl
    return sup, meta


def run_paths(paths: Sequence[str], *, rules: Optional[Sequence[str]] = None,
              respect_noqa: bool = True,
              root: Optional[Path] = None) -> List[Finding]:
    """Lint ``paths`` (files or directories); returns unsuppressed findings.

    ``rules``: optional allowlist of rule ids (e.g. ``["SWL004"]``).
    """
    findings, _ = _run(paths, rules=rules, respect_noqa=respect_noqa,
                       root=root)
    return findings


def _run(paths, *, rules=None, respect_noqa=True, root=None):
    root = root or _repo_root()
    modules: List[Module] = []
    findings: List[Finding] = []
    for f in _collect_files(paths, root):
        mod, errs = _parse(f, root)
        findings.extend(errs)
        if mod is not None:
            modules.append(mod)

    ctx = LintContext(modules, root)
    active = [cls() for cls in RULES
              if rules is None or cls.id in set(rules)]
    suppressed = 0
    for module in modules:
        sup, meta = _noqa_map(module)
        if respect_noqa:
            findings.extend(meta)  # SWL000: never suppressible
        for r in active:
            if not r.applies(module):
                continue
            for finding in r.check(module, ctx):
                if respect_noqa and finding.rule in sup.get(finding.line,
                                                            set()):
                    suppressed += 1
                    continue
                findings.append(finding)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, {"suppressed": suppressed, "files": len(modules)}


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="swarmlint: JAX/SPMD-aware static analysis for this repo")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to lint (default: src tests)")
    ap.add_argument("--rule", action="append", dest="rules", metavar="SWLxxx",
                    help="run only this rule (repeatable)")
    ap.add_argument("--no-noqa", action="store_true",
                    help="ignore noqa comments (report everything)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        print("SWL000 [error]   noqa hygiene: suppressions must name a code "
              "and carry a justification (built into the runner)")
        for cls in RULES:
            print(f"{cls.id} [{cls.severity:7s}] {cls.summary}")
        return 0

    findings, stats = _run(args.paths, rules=args.rules,
                           respect_noqa=not args.no_noqa)
    for f in findings:
        print(f.render())
    errors = sum(1 for f in findings if f.severity == "error")
    warnings = len(findings) - errors
    if findings:
        print(f"swarmlint: {errors} error(s), {warnings} warning(s) "
              f"({stats['suppressed']} suppressed) in {stats['files']} files")
        return 1
    print(f"swarmlint: clean — {stats['files']} files, "
          f"{stats['suppressed']} suppressed finding(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
