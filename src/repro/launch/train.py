"""Train-step builders: standard synchronous data-parallel (the centralized
baseline) and the swarm-parallel variant (the paper's technique as SPMD).

Swarm-parallel = ``jax.vmap`` of the local step over a leading node axis
(sharded over the mesh's gossip axis) — gradients never cross node slices —
plus a periodic gossip sync step built from `repro.core`.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig, TrainConfig
from repro.core.engine import SwarmEngine, gate_decisions, gated_commit
from repro.models import Model
from repro.optim import adamw_init, adamw_update, make_schedule


def make_train_step(model: Model, tc: TrainConfig,
                    grad_shardings=None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_shardings: optional pytree of NamedShardings matching params. Without
    it GSPMD leaves large gradient accumulators (e.g. the [V, d] embedding
    grad) replicated over the model axis — pinning grads to the param sharding
    removed ~25 GiB/device of f32 temp on command-r-104B (§Perf iteration 2).
    """
    schedule = make_schedule(tc)

    def grads_of(params, batch):
        def loss(p):
            return model.loss_fn(p, batch, remat=tc.remat)
        return jax.value_and_grad(loss, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if tc.accum_steps > 1:
            # microbatching: scan over [A, B/A, ...] slices accumulating f32
            # grads — live activation memory scales with B/A, not B
            a = tc.accum_steps
            micro = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]), batch)

            def body(carry, mb):
                acc, lsum = carry
                (l, _), g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda A, G: A + G.astype(jnp.float32) / a, acc, g)
                return (acc, lsum + l / a), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, l), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
            metrics = {"xent": l, "aux": jnp.float32(0.0)}
        else:
            (l, metrics), grads = grads_of(params, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        lr = schedule(opt_state["count"])
        params, opt_state = adamw_update(params, grads, opt_state, tc, lr)
        metrics = dict(metrics, loss=l, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch, remat=False)
        return dict(metrics, loss=loss)

    return eval_step


def init_train_state(model: Model, key):
    params = model.init(key)
    return params, adamw_init(params)


# ---------------------------------------------------------------------------
# swarm-parallel (SPMD) — the paper's technique on the mesh
# ---------------------------------------------------------------------------

def make_swarm_train_step(model: Model, tc: TrainConfig) -> Callable:
    """vmapped local step: stacked (params, opt_state) with leading node axis,
    batch [N, local_B, ...]. Gradient reduction stays within each node slice."""
    local = make_train_step(model, tc)
    return jax.vmap(local, in_axes=(0, 0, 0), out_axes=(0, 0, 0))


def make_swarm_sync_step(swarm_cfg: SwarmConfig, mesh, axis: str,
                         data_sizes, param_specs=None) -> Callable:
    """Gossip sync: propose (collective merge) + commit (validation-gated
    select), both delegating to the shared `SwarmEngine` gossip backend.

    Returns propose_fn(stacked_params) -> candidate. Ring topology uses
    ppermute (sparse P2P, the TPU-native schedule); full/fedavg uses psum;
    dynamic uses the all_gather mixing matrix with a runtime membership mask.
    """
    engine = SwarmEngine(swarm_cfg, None, None, data_sizes=data_sizes,
                         backend="gossip", mesh=mesh, axis=axis,
                         param_specs=param_specs)

    def propose(stacked_params, active=None, fishers=None, stats=None):
        candidate, _, _ = engine.propose(stacked_params, active=active,
                                         fishers=fishers, stats=stats)
        return candidate

    def commit(candidate, local_params, metric_merged, metric_local):
        gates = gate_decisions(metric_merged, metric_local,
                               swarm_cfg.val_threshold)
        return gated_commit(candidate, local_params, gates)

    return propose, commit


# ---------------------------------------------------------------------------
# CLI launcher:  python -m repro.launch.train --arch minicpm-2b --smoke ...
# ---------------------------------------------------------------------------

def main():
    import argparse
    import time

    from repro.checkpointing import save_json, save_pytree
    from repro.configs import get_config, smoke_variant
    from repro.core.lora import inject_lora
    from repro.data import make_lm_stream
    from repro.models import build_model
    from repro.optim import EarlyStopper

    ap = argparse.ArgumentParser(description="P2P-SL trainer")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family variant (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--swarm-nodes", type=int, default=0,
                    help="0 = plain training; N = P2P-SL with N nodes")
    ap.add_argument("--sync-every", type=int, default=10)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "full", "dynamic"])
    ap.add_argument("--merge", default="fedavg",
                    choices=["mean", "fedavg", "fisher", "gradmatch"])
    ap.add_argument("--lora", action="store_true",
                    help="LoRA-adapter-only peer payloads (paper §3.2)")
    ap.add_argument("--wire-dtype", default="f32",
                    choices=["f32", "bf16", "int8"],
                    help="sync wire compression (core.comms): int8 = "
                         "error-feedback quantized deltas")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", default="",
                    help="resume a swarm run from a session checkpoint "
                         "(session.msgpack written by --ckpt-dir)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if cfg.is_encdec or cfg.family == "vlm":
        raise SystemExit("CLI LM trainer supports decoder-only families; "
                         "use examples/ for vlm/audio drivers")
    model = build_model(cfg)
    tc = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                     max_steps=args.steps, remat=False)
    base_step = make_train_step(model, tc)
    n_nodes = max(args.swarm_nodes, 1)
    streams = [make_lm_stream(256, args.seq, cfg.vocab_size,
                              seed=args.seed + i, topic_bias=1.0)
               for i in range(n_nodes)]
    stopper = EarlyStopper(patience=5, mode="min")
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    final_step, sync_log = 0, []

    if not args.swarm_nodes:  # plain single-learner training
        jit_step = jax.jit(base_step)
        p = model.init(jax.random.key(args.seed))
        o = adamw_init(p)
        s = streams[0]
        for step in range(args.steps):
            idx = rng.integers(0, len(s["tokens"]), args.batch)
            p, o, m = jit_step(p, o, {k: jnp.asarray(v[idx])
                                      for k, v in s.items()})
            final_step = step + 1
            if step % 20 == 0 or step == args.steps - 1:
                loss = float(m["loss"])
                print(f"step {final_step:4d} loss={loss:.3f} "
                      f"({(time.time()-t0)/final_step:.2f}s/step)")
                if stopper.update(loss):
                    print("early stop (patience exhausted)")
                    break
        node_params = [p]
    else:  # P2P-SL: one SwarmSession, one compiled call per round
        from repro.core.session import SwarmSession

        ps = []
        for i in range(n_nodes):
            p = model.init(jax.random.key(args.seed))
            if args.lora:
                p = inject_lora(p, jax.random.key(args.seed + 1 + i), rank=8)
            ps.append(p)

        def train_step(params, opt_state, batch, step):
            return base_step(params, opt_state, batch)

        def eval_fn(params, val):
            loss, _ = model.loss_fn(params, val, remat=False)
            return 1.0 / (1.0 + loss)

        scfg = SwarmConfig(n_nodes=n_nodes, sync_every=args.sync_every,
                           topology=args.topology, merge=args.merge,
                           lora_only=args.lora, wire_dtype=args.wire_dtype)
        # fisher/gradmatch importance accumulators live inside the session's
        # SwarmState — estimation is in-graph, no host-side Fisher loop
        sess = SwarmSession(scfg, train_step, eval_fn, params=ps,
                            opt_state=[adamw_init(p) for p in ps],
                            seed=args.seed,
                            data_sizes=[len(s["tokens"]) for s in streams])
        print(f"sync schedule: "
              f"{sess.sync_schedule.describe(sess.payload_params)}")
        if args.resume:
            sess.load(args.resume)
            final_step = int(sess.state.step)
            print(f"resumed from {args.resume} at step {final_step} "
                  f"(round {int(sess.state.round)})")
        vals = {k: jnp.asarray(np.stack([s[k][:8] for s in streams]))
                for k in streams[0]}

        def draw(count):  # [count, N, B, S] stacked batch block
            # one index draw per node, shared by every key — tokens and
            # labels rows are paired within a sequence
            idx = [rng.integers(0, len(s["tokens"]), (count, args.batch))
                   for s in streams]
            return {k: jnp.asarray(np.stack([s[k][i] for s, i
                                             in zip(streams, idx)], axis=1))
                    for k in streams[0]}

        last_check = 0  # keep the old loop's every-20-steps stopper cadence
        while final_step < args.steps:
            t = min(max(args.sync_every, 1), args.steps - final_step)
            block = draw(t)
            if t == args.sync_every:  # full round: local steps + gated sync
                out = sess.round(block, vals)
                losses = np.asarray(out["train"]["loss"])[-1]
                gates = np.asarray(out["gates"]).astype(bool).tolist()
                sync_log.append({
                    "step": final_step + t, "gates": gates,
                    "metric_local": np.asarray(out["metric_local"]).tolist(),
                    "metric_merged": np.asarray(out["metric_merged"]).tolist()})
                extra = f" sync gates={gates}"
            else:  # remainder steps, no sync
                tm = sess.run_local(block)
                losses = np.asarray(tm["loss"])[-1]
                extra = ""
            final_step += t
            print(f"step {final_step:4d} loss={['%.3f' % l for l in losses]} "
                  f"({(time.time()-t0)/final_step:.2f}s/step){extra}")
            if final_step - last_check >= 20 or final_step >= args.steps:
                last_check = final_step
                if stopper.update(float(np.mean(losses))):
                    print("early stop (patience exhausted)")
                    break
        node_params = sess.node_params
        if args.ckpt_dir:  # full session state: checkpoint/resume round-trip
            sess.save(f"{args.ckpt_dir}/session.msgpack")

    if args.ckpt_dir:
        for i, p in enumerate(node_params):
            save_pytree(f"{args.ckpt_dir}/node{i}.msgpack", p,
                        metadata={"arch": cfg.name, "step": final_step})
        save_json(f"{args.ckpt_dir}/sync_log.json", sync_log)
        print(f"checkpoints -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
