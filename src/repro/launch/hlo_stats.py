"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips × peak)
memory term     = HLO_bytes / (chips × HBM bw)
collective term = collective_bytes / (chips × link bw)

``cost_analysis`` provides flops/bytes; collective bytes are parsed out of the
(post-SPMD-partitioning) HLO text by summing the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Matches the OP USE position (` all-reduce(`, ` all-gather-start(`, ...),
# not the instruction NAME (`%all-reduce.3 = ...`). Result types — possibly a
# tuple with /*index=k*/ comments — sit between `=` and the op keyword.
# `-done` variants are skipped (the `-start` already carries the bytes).
_OP_RE = re.compile(
    r"=\s*(.*?)\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(_COMMENT_RE.sub("", type_str)):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module (per device)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float = 0.0
    coll_detail: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # coll_bytes is already per-device (post-partition HLO result shapes)
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "useful_ratio": self.useful_ratio,
            "coll_detail": self.coll_detail,
        }


def roofline_from_compiled(compiled, *, arch, shape, mesh_name, chips,
                           model_flops=0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    mem = float(cost.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=mem,
                    coll_bytes=float(coll["total"]), model_flops=model_flops,
                    coll_detail=coll)
