"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips × peak)
memory term     = HLO_bytes / (chips × HBM bw)
collective term = collective_bytes / (chips × link bw)

``cost_analysis`` provides flops/bytes; collective bytes are parsed out of the
(post-SPMD-partitioning) HLO text by summing the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Matches the OP USE position (` all-reduce(`, ` all-gather-start(`, ...),
# not the instruction NAME (`%all-reduce.3 = ...`). Result types — possibly a
# tuple with /*index=k*/ comments — sit between `=` and the op keyword.
# `-done` variants are skipped (the `-start` already carries the bytes).
_OP_RE = re.compile(
    r"=\s*(.*?)\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(_COMMENT_RE.sub("", type_str)):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module (per device)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# --- per-link-class split (two-level ("pod", "node") meshes) ----------------
# A collective participates in exactly one link class: "intra" when every one
# of its device groups (or source→target pairs) stays inside a single pod,
# "cross" as soon as any group spans pods — a global collective over the
# joint axis is bounded by its slowest (DCN) hop, so its whole payload prices
# as cross. This mirrors the `core.comms` analytic convention (flat schedules
# on a 2-D mesh carry cross_factor = payload_factor).

# literal groups: replica_groups={{0,1},{2,3}}
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
# iota form: replica_groups=[2,2]<=[4] — reshape iota(4) to [2,2], rows are
# groups; an optional T(perm) transposes the iota source first
_IOTA_RE = re.compile(
    r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")


def _iota_list(src_dims, perm):
    """iota(prod(src_dims)) reshaped to src_dims, transposed by perm (or
    identity), flattened — pure-python strides."""
    total = 1
    for d in src_dims:
        total *= d
    if perm is None:
        return list(range(total))
    tshape = [src_dims[p] for p in perm]
    # row-major strides of the source shape
    strides = [1] * len(src_dims)
    for i in range(len(src_dims) - 2, -1, -1):
        strides[i] = strides[i + 1] * src_dims[i + 1]
    out = []
    for k in range(total):
        rem, tidx = k, []
        for d in reversed(tshape):
            tidx.append(rem % d)
            rem //= d
        tidx.reverse()
        out.append(sum(strides[perm[i]] * tidx[i] for i in range(len(perm))))
    return out


def _parse_groups(line: str):
    """Device groups of one collective instruction, or None if unparseable
    (an empty ``replica_groups={}`` means "all devices" and also maps to
    None — both conservatively classify as cross)."""
    m = _GROUPS_RE.search(line)
    if m:
        return [[int(x) for x in g.split(",") if x]
                for g in m.group(1).strip("{}").split("},{")]
    m = _IOTA_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        src = [int(x) for x in m.group(2).split(",")]
        perm = ([int(x) for x in m.group(3).split(",")]
                if m.group(3) else None)
        flat = _iota_list(src, perm)
        group_len = dims[-1]
        return [flat[i:i + group_len] for i in range(0, len(flat), group_len)]
    m = _PAIRS_RE.search(line)
    if m:
        return [[int(x) for x in g.split(",") if x]
                for g in m.group(1).strip("{}").split("},{")]
    return None


def pod_device_map(n_pods: int, per_pod: int) -> Dict[int, int]:
    """device id → pod id for the row-major ``(pod, node)`` mesh layout of
    `launch.mesh.make_two_level_swarm_mesh` (device p·per_pod + j ∈ pod p)."""
    return {p * per_pod + j: p
            for p in range(n_pods) for j in range(per_pod)}


def collective_bytes_by_link(hlo_text: str,
                             pod_of: Dict[int, int]) -> Dict[str, int]:
    """Split :func:`collective_bytes` per link class on a two-level mesh.

    ``pod_of`` maps device id → pod id (see :func:`pod_device_map`). An
    instruction whose every replica group / permute pair stays inside one
    pod counts as ``intra``; any pod-spanning group — or unparseable /
    unknown-device groups — counts as ``cross`` (unattributed traffic must
    never inflate the cheap class)."""
    out = {"intra": 0, "cross": 0, "count": 0}

    def one_pod(group) -> bool:
        pods = set()
        for d in group:
            if d not in pod_of:
                return False
            pods.add(pod_of[d])
        return len(pods) <= 1

    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        groups = _parse_groups(line)
        intra = groups is not None and all(one_pod(g) for g in groups)
        out["intra" if intra else "cross"] += _shape_bytes(m.group(1))
        out["count"] += 1
    out["total"] = out["intra"] + out["cross"]
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float = 0.0
    coll_detail: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # coll_bytes is already per-device (post-partition HLO result shapes)
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "useful_ratio": self.useful_ratio,
            "coll_detail": self.coll_detail,
        }


def roofline_from_compiled(compiled, *, arch, shape, mesh_name, chips,
                           model_flops=0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    mem = float(cost.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=mem,
                    coll_bytes=float(coll["total"]), model_flops=model_flops,
                    coll_detail=coll)
