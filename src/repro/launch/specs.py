"""ShapeDtypeStruct input stand-ins + sharding specs for every
(architecture × input-shape) pair — the dry-run's contract.

No device allocation happens here: everything is jax.ShapeDtypeStruct with a
NamedSharding attached, exactly the pattern the dry-run lowers against.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeConfig, adapt_for_shape
from repro.configs.base import ModelConfig
from repro.models import Model, build_model


def batch_axes(mesh: Mesh, profile: str = "default"):
    """Mesh axes usable for batch sharding (pod folds into data).

    profile "dp": the model axis joins the batch axes — used when a model is
    too small to amortize tensor parallelism (collective-bound roofline)."""
    base = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if profile == "dp":
        return base + ("model",)
    return base


def _div(dim: int, mesh: Mesh, axes) -> bool:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return dim % n == 0 and dim > 0


def sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                profile: str = "default"):
    """Training / prefill batch stand-ins."""
    b, s = shape.global_batch, shape.seq_len
    ba = batch_axes(mesh, profile)
    bspec = ba if _div(b, mesh, ba) else (ba[-1] if _div(b, mesh, ba[-1:]) else None)
    out = {
        "tokens": sds((b, s), jnp.int32, mesh, P(bspec, None)),
        "labels": sds((b, s), jnp.int32, mesh, P(bspec, None)),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = sds((b, cfg.n_patches, cfg.frontend_dim),
                                  jnp.float32, mesh, P(bspec, None, None))
    if cfg.is_encdec:
        out["frames"] = sds((b, cfg.enc_seq_len, cfg.frontend_dim),
                            jnp.float32, mesh, P(bspec, None, None))
        del out["tokens"]
        out["tokens"] = sds((b, s), jnp.int32, mesh, P(bspec, None))
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                profile: str = "default"):
    """Decode-state stand-ins, sharded to fit: batch→data axes; kv_heads→model
    when divisible, else head_dim→model; SSM heads→model; long-context
    (unshardable batch=1) shards the cache sequence axis over data instead."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        s += cfg.n_patches  # prefill writes patch+text K/V into the cache
    ba = batch_axes(mesh, profile)
    bspec = ba if _div(b, mesh, ba) else (ba[-1] if _div(b, mesh, ba[-1:]) else None)
    seq_spec = None
    if bspec is None and _div(s, mesh, ba):
        seq_spec = ba  # sequence-sharded decode (long_500k)
    kv_spec, hd_spec = None, None
    if profile != "dp" and _div(cfg.n_kv_heads, mesh, "model"):
        kv_spec = "model"
    elif profile != "dp" and _div(cfg.head_dim, mesh, "model"):
        hd_spec = "model"
    L = cfg.n_layers

    out = {}
    if cfg.family != "ssm":
        kv_shape = (L, b, s, cfg.n_kv_heads, cfg.head_dim)
        spec = P(None, bspec, seq_spec, kv_spec, hd_spec)
        dt = jnp.dtype(cfg.compute_dtype)
        out["k"] = sds(kv_shape, dt, mesh, spec)
        out["v"] = sds(kv_shape, dt, mesh, spec)
    if cfg.family in ("ssm", "hybrid"):
        di, h = cfg.d_inner, cfg.n_ssm_heads
        pdim = di // h
        g, n = cfg.ssm_groups, cfg.ssm_state
        h_spec = "model" if profile != "dp" and _div(h, mesh, "model") else None
        out["ssd"] = sds((L, b, h, pdim, n), jnp.float32, mesh,
                         P(None, bspec, h_spec, None, None))
        conv_dim = di + 2 * g * n
        cd_spec = "model" if profile != "dp" and _div(conv_dim, mesh, "model") else None
        out["conv"] = sds((L, b, cfg.conv_width - 1, conv_dim),
                          jnp.dtype(cfg.compute_dtype), mesh,
                          P(None, bspec, None, cd_spec))
    if cfg.is_encdec:
        enc = sds((b, cfg.enc_seq_len, cfg.d_model), jnp.dtype(cfg.compute_dtype),
                  mesh, P(bspec, None, None))
        return {"self": out, "enc_out": enc}
    return out


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       profile: str = "default"):
    b = shape.global_batch
    ba = batch_axes(mesh, profile)
    bspec = ba if _div(b, mesh, ba) else (ba[-1] if _div(b, mesh, ba[-1:]) else None)
    return sds((b, 1), jnp.int32, mesh, P(bspec, None))


def model_for(arch_cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Model, ModelConfig]:
    cfg = adapt_for_shape(arch_cfg, shape)
    return build_model(cfg), cfg


def param_shapes(model: Model):
    """Abstract param pytree (no allocation)."""
    return jax.eval_shape(model.init, jax.random.key(0))
