"""Serving primitives: batched greedy decode against a sharded KV cache / SSM state.

``make_serve_step`` is what the decode input shapes (decode_32k, long_500k)
lower in the dry-run: ONE new token per sequence against a seq_len-deep cache.
``make_logits_step`` is the raw-logits form the continuous-batching consensus
engine (``repro.serve``) vmaps over nodes and slots.

Jitted forms are cached per :class:`~repro.models.Model` (a frozen, hashable
bundle) via ``serve_step_for`` / ``prefill_step_for`` — ``generate`` used to
call ``jax.jit(make_serve_step(model))`` inside its body, discarding the
compile cache on every invocation.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import Model


def make_logits_step(model: Model) -> Callable:
    """(params, tokens [B,S], caches, cache_pos) -> (logits [B,S,V], caches).

    The raw decode primitive: one forward against the cache, no sampling.
    With S > 1 and cache_pos = 0 this doubles as prefill for position-indexed
    cache families (attention writes tokens 0..S-1 in place and the causal
    mask hides everything at or past the query position), which is how the
    serve engine keeps a single traced core for both phases.
    """

    def logits_step(params, tokens, caches, cache_pos):
        return model.decode(params, tokens, caches, cache_pos)

    return logits_step


def make_serve_step(model: Model) -> Callable:
    """(params, tokens [B,1], caches, cache_pos) -> (next_tokens [B,1], caches)."""
    logits_step = make_logits_step(model)

    def serve_step(params, tokens, caches, cache_pos):
        logits, caches = logits_step(params, tokens, caches, cache_pos)
        next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tokens, caches

    return serve_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch, caches):
        logits, caches = model.prefill(params, batch, caches)
        next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tokens, caches

    return prefill_step


@functools.lru_cache(maxsize=None)
def serve_step_for(model: Model) -> Callable:
    """Jitted ``make_serve_step``, cached per Model instance — params and
    caches are per-call arguments, so the cache holds no array state."""
    return jax.jit(make_serve_step(model))


@functools.lru_cache(maxsize=None)
def prefill_step_for(model: Model) -> Callable:
    return jax.jit(make_prefill_step(model))


def generate(model: Model, params, prompt_tokens, max_new: int, max_len: int):
    """Host-loop generation (examples/serving demo)."""
    b, s = prompt_tokens.shape
    caches = model.init_cache(b, max_len)
    serve_step = serve_step_for(model)
    if model.prefill is not None:
        tok, caches = prefill_step_for(model)(
            params, {"tokens": prompt_tokens}, caches)
    else:  # encdec and others: feed prompt token-by-token
        tok = prompt_tokens[:, :1]
        for i in range(s):
            tok, caches = serve_step(params, prompt_tokens[:, i:i + 1],
                                     caches, jnp.int32(i))
    out = [tok]
    for i in range(max_new - 1):
        tok, caches = serve_step(params, tok, caches, jnp.int32(s + i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
