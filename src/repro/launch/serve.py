"""Serving: batched greedy decode against a sharded KV cache / SSM state.

``make_serve_step`` is what the decode input shapes (decode_32k, long_500k)
lower in the dry-run: ONE new token per sequence against a seq_len-deep cache.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import Model


def make_serve_step(model: Model) -> Callable:
    """(params, tokens [B,1], caches, cache_pos) -> (next_tokens [B,1], caches)."""

    def serve_step(params, tokens, caches, cache_pos):
        logits, caches = model.decode(params, tokens, caches, cache_pos)
        next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tokens, caches

    return serve_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch, caches):
        logits, caches = model.prefill(params, batch, caches)
        next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tokens, caches

    return prefill_step


def generate(model: Model, params, prompt_tokens, max_new: int, max_len: int):
    """Host-loop generation (examples/serving demo)."""
    b, s = prompt_tokens.shape
    caches = model.init_cache(b, max_len)
    serve_step = jax.jit(make_serve_step(model))
    if model.prefill is not None:
        logits, caches = model.prefill(params, {"tokens": prompt_tokens}, caches)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    else:  # encdec and others: feed prompt token-by-token
        tok = prompt_tokens[:, :1]
        for i in range(s):
            tok, caches = serve_step(params, prompt_tokens[:, i:i + 1],
                                     caches, jnp.int32(i))
    out = [tok]
    for i in range(max_new - 1):
        tok, caches = serve_step(params, tok, caches, jnp.int32(s + i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
