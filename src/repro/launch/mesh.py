"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax import; smoke tests must keep
seeing 1 CPU device).

  single-pod: (16, 16)    axes ("data", "model")      — 256 chips (v5e pod)
  multi-pod:  (2, 16, 16) axes ("pod", "data", "model") — 512 chips

Swarm view: the P2P-SL gossip axis is `pod` on the multi-pod mesh (1 hospital
= 1 pod; gossip is the only cross-DCN traffic) and a factored `node` axis on
the single-pod swarm mesh.
"""
from __future__ import annotations

import numpy as np

# The single declared mesh-axis registry. Every axis name that appears in a
# collective call site or mesh construction anywhere in the repo must come
# from this tuple — `repro.analysis` (swarmlint SWL001) parses this constant
# at lint time and flags literal drift, so adding a new physical axis means
# adding it HERE first.
MESH_AXES = ("pod", "node", "data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_two_level_swarm_mesh(n_pods: int = 2, per_pod: int = 2):
    """Two-level swarm mesh: ``(n_pods, per_pod)`` over ``("pod", "node")``.

    The swarm axis is the AXIS TUPLE ``("pod", "node")`` — flat gossip
    schedules run over the joint axis unchanged, while the `core.comms`
    per-link-class cost model may lower to the hierarchical pod-delegate
    schedules (`core.gossip.hier_*_ring_q8`) that keep bulk traffic
    intra-pod. Devices are row-major: device ``p·per_pod + j`` is node ``j``
    of pod ``p`` (the layout `launch.hlo_stats.pod_device_map` assumes).
    Returns ``(mesh, ("pod", "node"))``.
    """
    import jax

    n = n_pods * per_pod
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before any "
            "jax import to simulate the two-level mesh on CPU")
    mesh = jax.make_mesh((n_pods, per_pod), ("pod", "node"),
                         devices=devs[:n])
    return mesh, ("pod", "node")


def make_swarm_mesh(n_nodes: int = 4, *, multi_pod: bool = False):
    """Swarm training mesh: leading `node` axis is the gossip axis.

    single-pod: (node, data, model) = (n, 16//n? , 16) — we factor the data
    axis of the production mesh into (node, data): same 256 chips.
    multi-pod: gossip over `pod` — (pod, data, model) = (2, 16, 16), i.e. the
    production mesh itself; swarm code treats `pod` as the node axis.
    """
    import jax

    if multi_pod:
        mesh = make_production_mesh(multi_pod=True)
        return mesh, "pod"
    if 16 % n_nodes:
        raise ValueError("n_nodes must divide 16 on the single-pod mesh")
    shape = (n_nodes, 16 // n_nodes, 16)
    devs = jax.devices()[: int(np.prod(shape))]
    return jax.make_mesh(shape, ("node", "data", "model"), devices=devs), "node"
