"""Multi-pod dry-run: lower + compile every (architecture × input-shape) pair
on the production meshes, prove memory fits, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun

Methodology notes
-----------------
* Layers are scanned (jax.lax.scan) in the compiled artifact — that is the
  production module and compiles in seconds even for 104B configs. XLA's
  cost_analysis counts a scan body ONCE, so per-step FLOPs/bytes/collective
  bytes are recovered by compiling two cheap reduced-depth variants and
  extrapolating linearly:  f(L) = overhead + L·body  (verified: attention
  window pattern is handled per-kind for mixed SWA/global models).
* cost_analysis and memory_analysis are PER-DEVICE on this backend
  (calibrated against a hand-counted matmul), so roofline terms divide by
  per-chip peak numbers directly.
"""
# The VERY FIRST lines — before ANY other import (jax locks device count):
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, INPUT_SHAPES, SHAPES_BY_NAME, TrainConfig,
                           adapt_for_shape, get_config)
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (batch_specs, cache_specs, decode_token_specs,
                                 model_for, param_shapes)
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.models.transformer import layer_windows
from repro.optim import adamw_init
from repro.sharding.rules import sharding_rules, shardings_for


def _sds_like(shapes, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


# Sharding profiles (§Perf hillclimbs). "dp": no tensor parallelism — the
# model axis becomes extra data parallelism, params FSDP over data only.
# Right for small models whose TP collectives dwarf their compute.
from repro.sharding.rules import DEFAULT_LOGICAL  # noqa: E402

PROFILES = {
    "default": DEFAULT_LOGICAL,
    "dp": {**{k: None for k in DEFAULT_LOGICAL},
           "batch": ("data", "model")},
    # zero3: dp activations + params/opt fully sharded over the whole grid
    "zero3": {**{k: None for k in DEFAULT_LOGICAL},
              "batch": ("data", "model")},
}

_PROFILE_FSDP = {"default": True, "dp": True, "zero3": ("data", "model")}


def _param_sds(model, mesh, profile="default"):
    pshapes = param_shapes(model)
    pshard = shardings_for(pshapes, mesh, logical=PROFILES[profile],
                           fsdp=_PROFILE_FSDP[profile])
    return _sds_like(pshapes, pshard), pshard


def _opt_sds(pshapes_sds, pshard, mesh):
    f32 = lambda tree: jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=sh),
        tree, pshard)
    return {"mu": f32(pshapes_sds), "nu": f32(pshapes_sds),
            "count": jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(mesh, P()))}


def build_lowering(cfg, shape, mesh, tc, profile: str = "default"):
    """Returns (fn, args) ready for jax.jit(fn).lower(*args)."""
    model = build_model(cfg)
    p_sds, pshard = _param_sds(model, mesh, profile)
    logical = PROFILES[profile]

    if shape.kind == "train":
        step = make_train_step(model, tc, grad_shardings=pshard)
        o_sds = _opt_sds(p_sds, pshard, mesh)
        b_sds = batch_specs(cfg, shape, mesh, profile)

        def fn(params, opt_state, batch):
            with sharding_rules(mesh, logical=logical):
                return step(params, opt_state, batch)

        return fn, (p_sds, o_sds, b_sds)

    if shape.kind == "prefill":
        b_sds = batch_specs(cfg, shape, mesh, profile)
        del b_sds["labels"]
        c_sds = cache_specs(cfg, shape, mesh, profile)
        if cfg.is_encdec:
            # enc-dec prefill = encode + first decoder step
            from repro.models.encdec import encode

            def fn(params, batch, caches):
                with sharding_rules(mesh, logical=logical):
                    enc_out = encode(params, cfg, batch["frames"])
                    caches = dict(caches, enc_out=enc_out)
                    from repro.launch.serve import make_serve_step as mss
                    return mss(model)(params, batch["tokens"][:, :1], caches,
                                      jnp.int32(0))
        else:
            prefill = make_prefill_step(model)

            def fn(params, batch, caches):
                with sharding_rules(mesh, logical=logical):
                    return prefill(params, batch, caches)

        return fn, (p_sds, b_sds, c_sds)

    # decode
    serve = make_serve_step(model)
    c_sds = cache_specs(cfg, shape, mesh, profile)
    t_sds = decode_token_specs(cfg, shape, mesh, profile)

    def fn(params, tokens, caches, pos):
        with sharding_rules(mesh, logical=logical):
            return serve(params, tokens, caches, pos)

    pos_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
    return fn, (p_sds, t_sds, c_sds, pos_sds)


def compile_pair(cfg, shape, mesh, tc, profile: str = "default"):
    fn, args = build_lowering(cfg, shape, mesh, tc, profile)
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    return compiled


def _stats(compiled):
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    coll = hlo_stats.collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]), "coll_detail": coll}


def _lin(o, b, n):
    return {k: o[k] + n * b[k] for k in ("flops", "bytes", "coll")}


def extrapolated_stats(arch_cfg, cfg, shape, mesh, tc, profile="default"):
    """f(L) = overhead + Σ_kind n_kind·body_kind via reduced-depth compiles."""
    pattern = (layer_windows(cfg) if cfg.family != "ssm" and not cfg.is_encdec
               else np.zeros(cfg.n_layers, np.int32))
    kinds = sorted(set(pattern.tolist()))
    # uniform-window 1-layer and 2-layer variants per window kind
    def variant(n_layers, window):
        upd = dict(n_layers=n_layers, unroll_layers=True,
                   sliding_window=int(window), attn_every=0)
        if cfg.is_encdec:
            upd["n_enc_layers"] = n_layers
        return cfg.replace(**upd)

    # L=2 / L=4 variants: GSPMD occasionally makes different layout choices
    # at L=1, which destabilizes the linear fit; 2->4 is representative.
    w0 = kinds[0]
    s2 = _stats(compile_pair(variant(2, w0), shape, mesh, tc, profile))
    s4 = _stats(compile_pair(variant(4, w0), shape, mesh, tc, profile))
    body0 = {k: max((s4[k] - s2[k]) / 2.0, 0.0) for k in ("flops", "bytes", "coll")}
    overhead = {k: max(s2[k] - 2 * body0[k], 0.0) for k in ("flops", "bytes", "coll")}
    bodies = {w0: body0}
    for w in kinds[1:]:
        s2w = _stats(compile_pair(variant(2, w), shape, mesh, tc, profile))
        bodies[w] = {k: max((s2w[k] - overhead[k]) / 2.0, 0.0)
                     for k in ("flops", "bytes", "coll")}
    total = dict(overhead)
    for w in kinds:
        n = int((pattern == w).sum())
        if cfg.is_encdec:
            pass  # enc scales with dec in variants; pattern uniform
        for k in total:
            total[k] += n * bodies[w][k]
    if cfg.is_encdec:
        # variants scaled enc+dec together: body covers one enc + one dec layer;
        # n_layers == n_enc_layers for seamless so the linear form is exact.
        pass
    return total, {"overhead": overhead, "bodies": {str(k): v for k, v in bodies.items()}}


def model_flops_analytic(cfg, shape):
    """MODEL_FLOPS: 6·N·D train / 2·N·D prefill / 2·N·B decode (N active).

    enc-dec: the encoder runs over enc_seq_len frames, the decoder over the
    shape's token count (prefill = a single decode step after encoding).
    """
    n = cfg.active_param_count()
    if cfg.is_encdec:
        # split params roughly by layer count (enc and dec layers are ~equal)
        n_enc = n * cfg.n_enc_layers / (cfg.n_enc_layers + cfg.n_layers)
        n_dec = n - n_enc
        b = shape.global_batch
        if shape.kind == "train":
            return 6.0 * (n_enc * b * cfg.enc_seq_len + n_dec * b * shape.seq_len)
        if shape.kind == "prefill":
            return 2.0 * (n_enc * b * cfg.enc_seq_len + n_dec * b)
        return 2.0 * n_dec * b
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence


def run_pair(arch_name, shape_name, multi_pod, tc, *, do_stats=True,
             profile: str = "default"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    shape = SHAPES_BY_NAME[shape_name]
    arch_cfg = get_config(arch_name)
    cfg = adapt_for_shape(arch_cfg, shape)

    t0 = time.time()
    compiled = compile_pair(cfg, shape, mesh, tc, profile)
    compile_s = time.time() - t0
    ma = compiled.memory_analysis()
    print(f"--- {arch_name} × {shape_name} × {mesh_name} ---")
    print(compiled.memory_analysis())   # proves it fits
    ca_ = compiled.cost_analysis()      # FLOPs/bytes for §Roofline
    ca_ = ca_[0] if isinstance(ca_, list) else ca_
    print({k: ca_[k] for k in ("flops", "bytes accessed") if k in ca_})
    mem = {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                     + ma.temp_size_in_bytes),
    }
    scanned = _stats(compiled)

    rec = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "compile_s": compile_s, "memory": mem,
        "scanned_stats": scanned, "status": "ok", "profile": profile,
        "model_flops_global": model_flops_analytic(cfg, shape),
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if do_stats:
        total, detail = extrapolated_stats(arch_cfg, cfg, shape, mesh, tc,
                                           profile)
        rec["per_device_stats"] = total
        rec["extrapolation"] = detail
        rl = hlo_stats.Roofline(
            arch=arch_name, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=total["flops"], hlo_bytes=total["bytes"],
            coll_bytes=total["coll"],
            model_flops=rec["model_flops_global"] / chips)
        rec["roofline"] = rl.row()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-stats", action="store_true",
                    help="compile-proof only (skip roofline extrapolation)")
    ap.add_argument("--profile", default="default", choices=list(PROFILES))
    ap.add_argument("--accum", type=int, default=1,
                    help="microbatch gradient-accumulation steps (train shapes)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = ([s.name for s in INPUT_SHAPES] if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    tc = TrainConfig(remat=True, accum_steps=args.accum)
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape}_{'multi' if multi else 'single'}"
                if args.profile != "default":
                    tag += f"_{args.profile}"
                if args.accum > 1:
                    tag += f"_accum{args.accum}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip] {tag} (exists)")
                    continue
                t0 = time.time()
                try:
                    # roofline stats only needed on the single-pod mesh
                    rec = run_pair(arch, shape, multi, tc,
                                   do_stats=(not multi and not args.no_stats),
                                   profile=args.profile)
                    dom = rec.get("roofline", {}).get("dominant", "-")
                    print(f"[ok]   {tag}  compile={rec['compile_s']:.1f}s "
                          f"peak/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                          f"dominant={dom}  ({time.time()-t0:.0f}s)")
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "FAIL", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures.append(tag)
                    print(f"[FAIL] {tag}: {e}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2, default=float)
    print(f"\n{len(failures)} failures: {failures}" if failures
          else "\nALL PAIRS LOWERED AND COMPILED")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
