"""The paper's experimental protocol (§4), reproducible end-to-end.

Builds the 4-node P2P-SL swarm over synthetic histopathology shards and
compares, exactly as the paper does:
  * centralized "full-data" baseline,
  * standalone (local-only) per-node models,
  * P2P-SL swarm-trained per-node models,
under the unbalanced 10/30/30/30 split and the 25%/5% scarcity trials,
reporting AUC / sensitivity / specificity / F1 on a shared held-out test set,
plus the embedding-quality (Davies-Bouldin) and minority-recall claims.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig, TrainConfig
from repro.core.swarm import NodeState, SwarmLearner
from repro.data import batches, make_histo_dataset, paper_splits, shard_to_nodes
from repro.metrics import classify_report, davies_bouldin
from repro.models.cnn import bce_loss, forward_cnn, init_cnn
from repro.optim import EarlyStopper, adamw_init, adamw_update, make_schedule


@dataclass
class HistoExperimentConfig:
    n_train: int = 2000
    n_test: int = 500
    image_size: int = 24
    noise: float = 1.1               # tuned so AUCs land in the paper's band
    class_probs: tuple = (0.5, 0.3, 0.2)  # imbalanced classes (minority = 2)
    fractions: tuple = (0.10, 0.30, 0.30, 0.30)
    scarcity: Optional[Dict[int, float]] = None  # e.g. {2: 0.25} / {3: 0.05}
    steps: int = 240
    batch_size: int = 16
    lr: float = 1e-3
    sync_every: int = 20             # ≈ paper's every-3-epochs cadence
    val_frac: float = 0.25
    seed: int = 0
    swarm: SwarmConfig = field(default_factory=lambda: SwarmConfig(
        n_nodes=4, sync_every=20, topology="full", merge="fedavg",
        lora_only=False, val_threshold=0.8))
    # small CNN (paper arch scaled to 24px inputs for CPU)
    growth: int = 8
    stem: int = 16
    feat_dim: int = 96
    hidden: int = 32


def _make_model_fns(ecfg: HistoExperimentConfig):
    tc = TrainConfig(lr=ecfg.lr, warmup_steps=20, max_steps=ecfg.steps,
                     weight_decay=1e-4, schedule="cosine")
    sched = make_schedule(tc)

    def loss(params, x, y):
        return bce_loss(forward_cnn(params, x), jax.nn.one_hot(y, 3))

    @jax.jit
    def train_step(params, opt_state, batch, step):
        x, y = batch
        l, g = jax.value_and_grad(loss)(params, jnp.asarray(x), jnp.asarray(y))
        params, opt_state = adamw_update(params, g, opt_state, tc,
                                         sched(opt_state["count"]))
        return params, opt_state, {"loss": l}

    @jax.jit
    def predict(params, x):
        return jax.nn.sigmoid(forward_cnn(params, jnp.asarray(x)))

    @jax.jit
    def features(params, x):
        _, f = forward_cnn(params, jnp.asarray(x), return_features=True)
        return f

    return train_step, predict, features


def _init_params(ecfg, key):
    return init_cnn(key, None, growth=ecfg.growth, stem=ecfg.stem,
                    feat_dim=ecfg.feat_dim, hidden=ecfg.hidden)


def _train_loop(ecfg, train_step, shards, *, swarm_cfg=None, log=None):
    """Train nodes (swarm if swarm_cfg else isolated). Returns node params."""
    key = jax.random.key(ecfg.seed + 42)   # shared init = warm-start effect
    _, predict, _ = _make_model_fns(ecfg)

    def eval_fn(params, val):
        x, y = val
        return classify_report(np.asarray(predict(params, x)), y)["auc"]

    nodes = []
    vals, trains = [], []
    for i, (x, y) in enumerate(shards):
        n_val = max(8, int(len(y) * ecfg.val_frac))
        vals.append((x[:n_val], y[:n_val]))
        trains.append((x[n_val:], y[n_val:]))
        params = _init_params(ecfg, key)
        nodes.append(NodeState(params=params, opt_state=adamw_init(params),
                               data_size=len(y)))

    cfg = swarm_cfg or SwarmConfig(n_nodes=len(shards), sync_every=10**9)
    sw = SwarmLearner(cfg, train_step, eval_fn, nodes)
    rngs = [np.random.default_rng(ecfg.seed * 100 + i) for i in range(len(shards))]
    iters = [iter(()) for _ in shards]
    for step in range(ecfg.steps):
        bs = []
        for i, (x, y) in enumerate(trains):
            try:
                b = next(iters[i])
            except StopIteration:
                iters[i] = batches(x, y, min(ecfg.batch_size, len(y)), rngs[i])
                b = next(iters[i])
            bs.append(b)
        sw.local_steps(bs)
        if swarm_cfg is not None:
            r = sw.maybe_sync(vals)
            if r and log is not None:
                log.append(r)
    return [n.params for n in nodes], sw.sync_log


def run_experiment(ecfg: HistoExperimentConfig) -> dict:
    """Full §4 protocol. Returns nested report dict."""
    images, labels = make_histo_dataset(
        ecfg.n_train, size=ecfg.image_size, noise=ecfg.noise,
        class_probs=ecfg.class_probs, seed=ecfg.seed)
    test_x, test_y = make_histo_dataset(
        ecfg.n_test, size=ecfg.image_size, noise=ecfg.noise,
        class_probs=ecfg.class_probs, seed=ecfg.seed + 999)

    sizes = paper_splits(ecfg.n_train, ecfg.fractions)
    shards = shard_to_nodes(images, labels, sizes, seed=ecfg.seed)
    if ecfg.scarcity:  # down-sample chosen nodes (the 25% / 5% trials)
        shards = [
            (x[: max(16, int(len(y) * ecfg.scarcity.get(i, 1.0)))],
             y[: max(16, int(len(y) * ecfg.scarcity.get(i, 1.0)))])
            for i, (x, y) in enumerate(shards)
        ]

    train_step, predict, features = _make_model_fns(ecfg)

    def report(params):
        probs = np.asarray(predict(params, test_x))
        rep = classify_report(probs, test_y)
        rep["dbi"] = davies_bouldin(np.asarray(features(params, test_x)), test_y)
        return rep

    # centralized full-data baseline
    key = jax.random.key(ecfg.seed + 42)
    params = _init_params(ecfg, key)
    opt = adamw_init(params)
    rng = np.random.default_rng(ecfg.seed)
    it = iter(())
    for step in range(ecfg.steps):
        try:
            b = next(it)
        except StopIteration:
            it = batches(images, labels, 32, rng)
            b = next(it)
        params, opt, _ = train_step(params, opt, b, step)
    central = report(params)

    # standalone local learners
    local_params, _ = _train_loop(ecfg, train_step, shards, swarm_cfg=None)
    local = [report(p) for p in local_params]

    # P2P-SL swarm
    swarm_params, sync_log = _train_loop(ecfg, train_step, shards,
                                         swarm_cfg=ecfg.swarm)
    swarm = [report(p) for p in swarm_params]

    out = {
        "config": {"sizes": [len(s[1]) for s in shards], "steps": ecfg.steps,
                   "sync_every": ecfg.swarm.sync_every,
                   "merge": ecfg.swarm.merge, "topology": ecfg.swarm.topology},
        "centralized": central,
        "local": local,
        "swarm": swarm,
        "sync_log": sync_log[-3:],
        "recovery": [  # fraction of centralized AUC recovered by swarm
            (s["auc"] - 0.5) / max(central["auc"] - 0.5, 1e-9) for s in swarm
        ],
    }
    return out


def summarize(result: dict) -> str:
    lines = ["node,setting,auc,sensitivity,specificity,f1,dbi"]
    c = result["centralized"]
    lines.append(f"-,centralized,{c['auc']:.4f},{c['sensitivity']:.2f},"
                 f"{c['specificity']:.2f},{c['f1']:.2f},{c['dbi']:.3f}")
    for i, (l, s) in enumerate(zip(result["local"], result["swarm"])):
        lines.append(f"{i},local,{l['auc']:.4f},{l['sensitivity']:.2f},"
                     f"{l['specificity']:.2f},{l['f1']:.2f},{l['dbi']:.3f}")
        lines.append(f"{i},swarm,{s['auc']:.4f},{s['sensitivity']:.2f},"
                     f"{s['specificity']:.2f},{s['f1']:.2f},{s['dbi']:.3f}")
    return "\n".join(lines)
