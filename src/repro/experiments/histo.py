"""The paper's experimental protocol (§4), reproducible end-to-end.

Builds the 4-node P2P-SL swarm over synthetic histopathology shards and
compares, exactly as the paper does:
  * centralized "full-data" baseline,
  * standalone (local-only) per-node models,
  * P2P-SL swarm-trained per-node models,
under the unbalanced 10/30/30/30 split and the 25%/5% scarcity trials,
reporting AUC / sensitivity / specificity / F1 on a shared held-out test set,
plus the embedding-quality (Davies-Bouldin) and minority-recall claims.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig, TrainConfig
from repro.core.session import SwarmSession
from repro.data import (augment, batches, make_histo_dataset, paper_splits,
                        shard_to_nodes)
from repro.metrics import classify_report, davies_bouldin, gate_metric_fn
from repro.models.cnn import bce_loss, forward_cnn, init_cnn
from repro.optim import EarlyStopper, adamw_init, adamw_update, make_schedule


@dataclass
class HistoExperimentConfig:
    n_train: int = 2000
    n_test: int = 500
    image_size: int = 24
    noise: float = 1.1               # tuned so AUCs land in the paper's band
    class_probs: tuple = (0.5, 0.3, 0.2)  # imbalanced classes (minority = 2)
    fractions: tuple = (0.10, 0.30, 0.30, 0.30)
    scarcity: Optional[Dict[int, float]] = None  # e.g. {2: 0.25} / {3: 0.05}
    steps: int = 240
    batch_size: int = 16
    lr: float = 1e-3
    sync_every: int = 20             # ≈ paper's every-3-epochs cadence
    val_frac: float = 0.25
    seed: int = 0
    swarm: SwarmConfig = field(default_factory=lambda: SwarmConfig(
        n_nodes=4, sync_every=20, topology="full", merge="fedavg",
        lora_only=False, val_threshold=0.8, gate_metric="auc"))
    # small CNN (paper arch scaled to 24px inputs for CPU)
    growth: int = 8
    stem: int = 16
    feat_dim: int = 96
    hidden: int = 32
    n_blocks: int = 4           # paper: 4 encoder modules × 4 layers; tests
    layers_per_block: int = 4   # shrink these to bound XLA compile time


def _make_model_fns(ecfg: HistoExperimentConfig):
    tc = TrainConfig(lr=ecfg.lr, warmup_steps=20, max_steps=ecfg.steps,
                     weight_decay=1e-4, schedule="cosine")
    sched = make_schedule(tc)

    def loss(params, x, y):
        return bce_loss(forward_cnn(params, x), jax.nn.one_hot(y, 3))

    @jax.jit
    def train_step(params, opt_state, batch, step):
        x, y = batch
        l, g = jax.value_and_grad(loss)(params, jnp.asarray(x), jnp.asarray(y))
        params, opt_state = adamw_update(params, g, opt_state, tc,
                                         sched(opt_state["count"]))
        return params, opt_state, {"loss": l}

    @jax.jit
    def predict(params, x):
        return jax.nn.sigmoid(forward_cnn(params, jnp.asarray(x)))

    @jax.jit
    def features(params, x):
        _, f = forward_cnn(params, jnp.asarray(x), return_features=True)
        return f

    return train_step, predict, features


def _init_params(ecfg, key):
    return init_cnn(key, None, growth=ecfg.growth, stem=ecfg.stem,
                    feat_dim=ecfg.feat_dim, hidden=ecfg.hidden,
                    n_blocks=ecfg.n_blocks,
                    layers_per_block=ecfg.layers_per_block)


def _batch_stream(ecfg, trains):
    """Precompute the per-node minibatch stream as stacked arrays.

    Returns (xs [steps, N, B, H, W, C], ys [steps, N, B]). Nodes whose shard
    can serve a full batch keep the exact per-node epoch iterators the host
    loop used (identical data order); a node with fewer than B samples — the
    extreme-scarcity trials — draws B samples with replacement per step
    instead of shrinking every other node's batch (vmap needs one B).
    """
    n = len(trains)
    bs = min(ecfg.batch_size, max(len(y) for _, y in trains))
    rngs = [np.random.default_rng(ecfg.seed * 100 + i) for i in range(n)]
    iters = [iter(()) for _ in range(n)]
    h = trains[0][0].shape[1]
    xs = np.empty((ecfg.steps, n, bs, h, h, 3), np.float32)
    ys = np.empty((ecfg.steps, n, bs), np.int32)
    for s in range(ecfg.steps):
        for i, (x, y) in enumerate(trains):
            if len(y) < bs:  # tiny shard: resample with replacement
                idx = rngs[i].integers(0, len(y), bs)
                xs[s, i], ys[s, i] = augment(x[idx], rngs[i]), y[idx]
                continue
            try:
                b = next(iters[i])
            except StopIteration:
                iters[i] = batches(x, y, bs, rngs[i])
                b = next(iters[i])
            xs[s, i], ys[s, i] = b
    return xs, ys


def _stack_vals(vals):
    """Pad per-node validation sets to a common length + validity mask."""
    n = len(vals)
    vmax = max(len(y) for _, y in vals)
    h = vals[0][0].shape[1]
    vx = np.zeros((n, vmax, h, h, 3), np.float32)
    vy = np.zeros((n, vmax), np.int32)
    vm = np.zeros((n, vmax), bool)
    for i, (x, y) in enumerate(vals):
        vx[i, :len(y)], vy[i, :len(y)], vm[i, :len(y)] = x, y, True
    return jnp.asarray(vx), jnp.asarray(vy), jnp.asarray(vm)


def _train_loop(ecfg, train_step, shards, *, swarm_cfg=None, log=None):
    """Train nodes (swarm if swarm_cfg else isolated). Returns node params.

    Runs on `SwarmSession` (engine backend): the whole sync round —
    `sync_every` vmapped local steps, the in-graph gate metric selected by
    ``swarm.gate_metric`` (sort-based AUC by default), fused Pallas commit —
    is one compiled program; `run_rounds` scans over rounds with zero host
    round-trips. The swarm config's merge method (including fisher/gradmatch
    with in-graph importance accumulation) and `overlap_sync` double-buffered
    rounds are handled entirely inside the session's compiled drivers.
    """
    key = jax.random.key(ecfg.seed + 42)   # shared init = warm-start effect
    n = len(shards)

    vals, trains = [], []
    for x, y in shards:
        n_val = max(8, int(len(y) * ecfg.val_frac))
        vals.append((x[:n_val], y[:n_val]))
        trains.append((x[n_val:], y[n_val:]))

    params = _init_params(ecfg, key)
    xs, ys = _batch_stream(ecfg, trains)
    val = _stack_vals(vals)

    cfg = swarm_cfg or SwarmConfig(n_nodes=n, sync_every=10**9,
                                   gate_metric="auc")
    metric = gate_metric_fn(cfg.gate_metric)

    def eval_fn(p, v):
        x, y, m = v
        return metric(jax.nn.sigmoid(forward_cnn(p, x)), y, m)

    sess = SwarmSession(cfg, train_step, eval_fn, params=params,
                        opt_state=adamw_init(params), seed=ecfg.seed,
                        data_sizes=[len(y) for _, y in shards])

    sync_log = []
    if swarm_cfg is None or cfg.sync_every > ecfg.steps:
        sess.run_local((jnp.asarray(xs), jnp.asarray(ys)))
    else:
        t = cfg.sync_every
        rounds = ecfg.steps // t
        head = (jnp.asarray(xs[:rounds * t]).reshape((rounds, t) + xs.shape[1:]),
                jnp.asarray(ys[:rounds * t]).reshape((rounds, t) + ys.shape[1:]))
        logs = sess.run_rounds(head, val)
        if ecfg.steps % t:
            sess.run_local((jnp.asarray(xs[rounds * t:]),
                            jnp.asarray(ys[rounds * t:])))
        gates = np.asarray(logs["gates"])
        ml = np.asarray(logs["metric_local"])
        mm = np.asarray(logs["metric_merged"])
        sync_log = [{"step": (r + 1) * t, "gates": gates[r].tolist(),
                     "metric_local": ml[r].tolist(),
                     "metric_merged": mm[r].tolist(),
                     "spectral_gap": sess.engine.spectral_gap}
                    for r in range(rounds)]
        if log is not None:
            log.extend(sync_log)
    return sess.node_params, sync_log


def run_experiment(ecfg: HistoExperimentConfig) -> dict:
    """Full §4 protocol. Returns nested report dict."""
    images, labels = make_histo_dataset(
        ecfg.n_train, size=ecfg.image_size, noise=ecfg.noise,
        class_probs=ecfg.class_probs, seed=ecfg.seed)
    test_x, test_y = make_histo_dataset(
        ecfg.n_test, size=ecfg.image_size, noise=ecfg.noise,
        class_probs=ecfg.class_probs, seed=ecfg.seed + 999)

    sizes = paper_splits(ecfg.n_train, ecfg.fractions)
    shards = shard_to_nodes(images, labels, sizes, seed=ecfg.seed)
    if ecfg.scarcity:  # down-sample chosen nodes (the 25% / 5% trials)
        shards = [
            (x[: max(16, int(len(y) * ecfg.scarcity.get(i, 1.0)))],
             y[: max(16, int(len(y) * ecfg.scarcity.get(i, 1.0)))])
            for i, (x, y) in enumerate(shards)
        ]

    train_step, predict, features = _make_model_fns(ecfg)

    def report(params):
        probs = np.asarray(predict(params, test_x))
        rep = classify_report(probs, test_y)
        rep["dbi"] = davies_bouldin(np.asarray(features(params, test_x)), test_y)
        return rep

    # centralized full-data baseline
    key = jax.random.key(ecfg.seed + 42)
    params = _init_params(ecfg, key)
    opt = adamw_init(params)
    rng = np.random.default_rng(ecfg.seed)
    it = iter(())
    for step in range(ecfg.steps):
        try:
            b = next(it)
        except StopIteration:
            it = batches(images, labels, 32, rng)
            b = next(it)
        params, opt, _ = train_step(params, opt, b, step)
    central = report(params)

    # standalone local learners
    local_params, _ = _train_loop(ecfg, train_step, shards, swarm_cfg=None)
    local = [report(p) for p in local_params]

    # P2P-SL swarm
    swarm_params, sync_log = _train_loop(ecfg, train_step, shards,
                                         swarm_cfg=ecfg.swarm)
    swarm = [report(p) for p in swarm_params]

    out = {
        "config": {"sizes": [len(s[1]) for s in shards], "steps": ecfg.steps,
                   "sync_every": ecfg.swarm.sync_every,
                   "merge": ecfg.swarm.merge, "topology": ecfg.swarm.topology},
        "centralized": central,
        "local": local,
        "swarm": swarm,
        "sync_log": sync_log[-3:],
        "recovery": [  # fraction of centralized AUC recovered by swarm
            (s["auc"] - 0.5) / max(central["auc"] - 0.5, 1e-9) for s in swarm
        ],
    }
    return out


def summarize(result: dict) -> str:
    lines = ["node,setting,auc,sensitivity,specificity,f1,dbi"]
    c = result["centralized"]
    lines.append(f"-,centralized,{c['auc']:.4f},{c['sensitivity']:.2f},"
                 f"{c['specificity']:.2f},{c['f1']:.2f},{c['dbi']:.3f}")
    for i, (l, s) in enumerate(zip(result["local"], result["swarm"])):
        lines.append(f"{i},local,{l['auc']:.4f},{l['sensitivity']:.2f},"
                     f"{l['specificity']:.2f},{l['f1']:.2f},{l['dbi']:.3f}")
        lines.append(f"{i},swarm,{s['auc']:.4f},{s['sensitivity']:.2f},"
                     f"{s['specificity']:.2f},{s['f1']:.2f},{s['dbi']:.3f}")
    return "\n".join(lines)
