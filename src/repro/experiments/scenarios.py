"""Scenario grid: non-IID partitions + synthetic augmentation over the
heterogeneous swarm.

The paper's sites hold imbalanced, *biased* data; the fairness literature on
swarm learning (PAPERS.md) shows per-site metrics must be measured, not
assumed, and the generative-augmentation line motivates letting label-starved
sites synthesize minority-class samples. This module turns those designs into
a reproducible grid:

  * :func:`scenario_grid` — named cells over partition strategies: iid, the
    paper's 10/30/30/30 unbalanced split, biased-label allocations
    (``class_bias``), biased labels + synthetic minority augmentation
    (`data.synthetic.make_histo_dataset` with skewed ``class_probs``), and
    Dirichlet non-IID sharding.
  * :func:`build_shards` — materializes one cell into per-node (x, y) shards.
  * :func:`run_scenario` — drives a ``payload="lora"`` model-zoo swarm
    (`models.zoo`, engine backend, int8 EF wire by default) through the
    cell and reports per-site test metrics, the spread between the best and
    worst site, a centralized single-model oracle trained on the pooled
    data with the same step budget, predicted wire bytes vs a full-payload
    f32 sync, retrace counters, and the fairness-gate log
    (``cfg.fairness_floor`` — docs/heterogeneous.md).

`benchmarks/run.py --only hetero_swarm` sweeps the grid and commits the
result as ``BENCH_hetero.json``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig, TrainConfig
from repro.core import comms
from repro.core.session import SwarmSession
from repro.data import (augment, batches, dirichlet_shards, make_histo_dataset,
                        paper_splits, shard_to_nodes)
from repro.metrics import classify_report, gate_metric_fn
from repro.models import zoo
from repro.models.cnn import bce_loss
from repro.optim import adamw_init, adamw_update, make_schedule


@dataclass(frozen=True)
class Scenario:
    """One grid cell: how the shared corpus lands on the N sites.

    partition:
      ``iid``            uniform random equal shards
      ``paper``          the paper's unbalanced 10/30/30/30 split
      ``label_skew``     biased-label allocation — site i oversamples class
                         i mod C by ``bias`` (the paper's "biased data
                         allocations")
      ``label_synth``    label_skew + each site augments its starved classes
                         with ``synth_frac``·|shard| synthetic samples drawn
                         from the generator with inverted class odds
      ``dirichlet``      Dirichlet(α) non-IID federated sharding
    """

    name: str
    partition: str
    bias: float = 8.0
    alpha: float = 0.3
    synth_frac: float = 0.5
    fractions: Tuple[float, ...] = (0.10, 0.30, 0.30, 0.30)


def scenario_grid(n_nodes: int = 4) -> List[Scenario]:
    """The benchmark grid — ≥4 cells, incl. the biased-label and
    synthetic-augmentation scenarios the source papers call for."""
    del n_nodes  # cells are partition strategies; N is a run_scenario knob
    return [
        Scenario("iid", "iid"),
        Scenario("paper_unbalanced", "paper"),
        Scenario("label_skew", "label_skew"),
        Scenario("label_skew_synth", "label_synth"),
        Scenario("dirichlet03", "dirichlet", alpha=0.3),
    ]


def _bias_rows(n_nodes: int, n_classes: int, bias: float) -> List[List[float]]:
    """class_bias rows: site i oversamples class i mod C by ``bias``×."""
    rows = []
    for i in range(n_nodes):
        row = [1.0] * n_classes
        row[i % n_classes] = float(bias)
        rows.append(row)
    return rows


def build_shards(scn: Scenario, images, labels, n_nodes: int, *,
                 seed: int = 0, n_classes: int = 3, image_size: int = 16,
                 noise: float = 1.1):
    """Materialize one grid cell into per-node shards.

    Returns ``(shards, n_synth)`` — shards is a list of N ``(x, y)`` pairs
    and ``n_synth[i]`` counts site i's synthetic-augmentation samples (all
    zero except in the ``label_synth`` cell).
    """
    n = len(labels)
    n_synth = [0] * n_nodes
    if scn.partition == "iid":
        shards = shard_to_nodes(images, labels, [n // n_nodes] * n_nodes,
                                seed=seed)
    elif scn.partition == "paper":
        shards = shard_to_nodes(images, labels,
                                paper_splits(n, scn.fractions), seed=seed)
    elif scn.partition in ("label_skew", "label_synth"):
        shards = shard_to_nodes(images, labels, [n // n_nodes] * n_nodes,
                                seed=seed,
                                class_bias=_bias_rows(n_nodes, n_classes,
                                                      scn.bias))
        if scn.partition == "label_synth":
            # generative augmentation for the non-IID problem: each site
            # synthesizes samples with INVERTED class odds (starved classes
            # oversampled), shrinking its label skew without sharing data
            out = []
            for i, (x, y) in enumerate(shards):
                inv = [1.0 / w for w in _bias_rows(n_nodes, n_classes,
                                                   scn.bias)[i]]
                k = max(4, int(len(y) * scn.synth_frac))
                sx, sy = make_histo_dataset(
                    k, size=image_size, n_classes=n_classes,
                    class_probs=inv, noise=noise, seed=seed * 1000 + 77 + i)
                out.append((np.concatenate([x, sx]),
                            np.concatenate([y, sy])))
                n_synth[i] = k
            shards = out
    elif scn.partition == "dirichlet":
        shards = dirichlet_shards(images, labels, n_nodes, alpha=scn.alpha,
                                  seed=seed)
        # a Dirichlet draw can starve a site entirely; float it on a few
        # global samples so every site can still train and validate
        shards = [(x, y) if len(y) >= 8 else (images[:8], labels[:8])
                  for x, y in shards]
    else:
        raise ValueError(f"unknown partition {scn.partition!r}")
    return shards, n_synth


@dataclass
class ScenarioRunConfig:
    """Run-scale knobs, sized so the whole grid smokes on CPU."""

    n_nodes: int = 4
    n_train: int = 320
    n_test: int = 160
    image_size: int = 16  # make_histo_dataset tiles 8×8 blobs — keep ≥16
    noise: float = 1.1
    class_probs: tuple = (0.5, 0.3, 0.2)
    feat_dim: int = 16
    hidden: int = 16
    lora_rank: int = 4
    steps: int = 24
    batch_size: int = 8
    lr: float = 3e-3
    val_frac: float = 0.25
    seed: int = 0
    swarm: SwarmConfig = field(default_factory=lambda: SwarmConfig(
        n_nodes=4, sync_every=6, topology="ring", merge="fedavg",
        payload="lora", wire_dtype="int8", wire_block=128,
        val_threshold=0.0, gate_metric="auc", fairness_floor=0.05))


def _zoo_closures(nodes, cfg: SwarmConfig, tc: TrainConfig, n_classes: int,
                  trace_log: list):
    """Per-node train/eval closures over the flat adapter payload.

    ``trace_log`` grows by one per TRACE of the train step (the python body
    runs only while tracing), so ``len(trace_log)`` deltas across rounds
    count retraces — the zero-retrace evidence in BENCH_hetero.json."""
    sched = make_schedule(tc)
    metric = gate_metric_fn(cfg.gate_metric)

    def make(node):
        def loss(payload, x, y):
            return bce_loss(node.apply(payload, x),
                            jax.nn.one_hot(y, n_classes))

        def train_step(payload, opt, batch, step):
            trace_log.append(node.family)
            x, y = batch
            l, g = jax.value_and_grad(loss)(payload, x, y)
            payload, opt = adamw_update(payload, g, opt, tc,
                                        sched(opt["count"]))
            return payload, opt, {"loss": l}

        def eval_fn(payload, v):
            x, y, m = v
            return metric(jax.nn.sigmoid(node.apply(payload, x)), y, m)

        return train_step, eval_fn

    return [make(n) for n in nodes]


def _batch_stream(trains, steps: int, batch_size: int, seed: int):
    """[steps, N, B, H, W, 3] / [steps, N, B] stacked minibatch stream
    (tiny shards resample with replacement — vmap needs one B)."""
    n = len(trains)
    bs = min(batch_size, max(len(y) for _, y in trains))
    rngs = [np.random.default_rng(seed * 100 + i) for i in range(n)]
    iters = [iter(()) for _ in range(n)]
    h = trains[0][0].shape[1]
    xs = np.empty((steps, n, bs, h, h, 3), np.float32)
    ys = np.empty((steps, n, bs), np.int32)
    for s in range(steps):
        for i, (x, y) in enumerate(trains):
            if len(y) < bs:
                idx = rngs[i].integers(0, len(y), bs)
                xs[s, i], ys[s, i] = augment(x[idx], rngs[i]), y[idx]
                continue
            try:
                b = next(iters[i])
            except StopIteration:
                iters[i] = batches(x, y, bs, rngs[i])
                b = next(iters[i])
            xs[s, i], ys[s, i] = b
    return jnp.asarray(xs), jnp.asarray(ys)


def _stack_vals(vals):
    """Pad per-node validation sets to one length + validity mask."""
    n = len(vals)
    vmax = max(len(y) for _, y in vals)
    h = vals[0][0].shape[1]
    vx = np.zeros((n, vmax, h, h, 3), np.float32)
    vy = np.zeros((n, vmax), np.int32)
    vm = np.zeros((n, vmax), bool)
    for i, (x, y) in enumerate(vals):
        vx[i, :len(y)], vy[i, :len(y)], vm[i, :len(y)] = x, y, True
    return jnp.asarray(vx), jnp.asarray(vy), jnp.asarray(vm)


def _full_payload_f32_bytes(nodes, cfg: SwarmConfig) -> float:
    """Counterfactual wire cost: the SAME schedule shape forced onto a
    full-payload f32 sync at the zoo's mean full param count."""
    full_cfg = SwarmConfig(
        n_nodes=cfg.n_nodes, sync_every=cfg.sync_every,
        topology=cfg.topology, merge=cfg.merge, lora_only=False,
        val_threshold=cfg.val_threshold, gate_metric=cfg.gate_metric)
    counts = [sum(int(x.size) for x in jax.tree.leaves(n.template))
              for n in nodes]
    p_full = int(np.mean(counts))
    return comms.pick_schedule(full_cfg, simulated=True).bytes_per_sync(p_full)


def run_scenario(scn: Scenario, rcfg: Optional[ScenarioRunConfig] = None) -> dict:
    """One grid cell end-to-end. Returns the BENCH_hetero row dict."""
    rcfg = rcfg or ScenarioRunConfig()
    cfg = rcfg.swarm
    n = cfg.n_nodes
    images, labels = make_histo_dataset(
        rcfg.n_train, size=rcfg.image_size, noise=rcfg.noise,
        class_probs=rcfg.class_probs, seed=rcfg.seed)
    test_x, test_y = make_histo_dataset(
        rcfg.n_test, size=rcfg.image_size, noise=rcfg.noise,
        class_probs=rcfg.class_probs, seed=rcfg.seed + 999)
    shards, n_synth = build_shards(scn, images, labels, n, seed=rcfg.seed,
                                   image_size=rcfg.image_size,
                                   noise=rcfg.noise)

    vals, trains = [], []
    for x, y in shards:
        n_val = max(4, int(len(y) * rcfg.val_frac))
        vals.append((x[:n_val], y[:n_val]))
        trains.append((x[n_val:], y[n_val:]))

    nodes = zoo.build_zoo(jax.random.PRNGKey(rcfg.seed), n,
                          image_size=rcfg.image_size, feat_dim=rcfg.feat_dim,
                          hidden=rcfg.hidden, rank=rcfg.lora_rank)
    tc = TrainConfig(lr=rcfg.lr, warmup_steps=4, max_steps=rcfg.steps,
                     weight_decay=1e-4, schedule="cosine")
    trace_log: list = []
    fns = _zoo_closures(nodes, cfg, tc, n_classes=3, trace_log=trace_log)
    payloads = [nd.payload() for nd in nodes]

    sess = SwarmSession(cfg, [f[0] for f in fns], [f[1] for f in fns],
                        params=payloads,
                        opt_state=[adamw_init(p) for p in payloads],
                        data_sizes=[len(y) for _, y in trains],
                        seed=rcfg.seed)
    xs, ys = _batch_stream(trains, rcfg.steps, rcfg.batch_size, rcfg.seed)
    val = _stack_vals(vals)

    t = cfg.sync_every
    rounds = max(1, rcfg.steps // t)
    logs = []
    traces_round1 = None
    for r in range(rounds):
        logs.append(sess.round((xs[r * t:(r + 1) * t], ys[r * t:(r + 1) * t]),
                               val))
        if r == 0:
            traces_round1 = len(trace_log)
    retraces = len(trace_log) - traces_round1  # identical shapes → 0

    # per-site test metrics: each site's committed payload row through its
    # OWN frozen backbone, on the shared held-out test set
    row_payloads = [
        {k: v[i] for k, v in sess.state.params.items()} for i in range(n)]
    per_site = []
    for nd, pl in zip(nodes, row_payloads):
        probs = np.asarray(jax.nn.sigmoid(nd.apply(pl, jnp.asarray(test_x))))
        rep = classify_report(probs, test_y)
        rep["family"] = nd.family
        per_site.append(rep)

    # centralized oracle: node 0's architecture on the pooled corpus with
    # the same step budget — the "no privacy constraint" upper bound
    oracle_fns = _zoo_closures(nodes[:1], cfg, tc, 3, trace_log=[])
    o_step = jax.jit(oracle_fns[0][0])
    p0, o0 = payloads[0], adamw_init(payloads[0])
    rng = np.random.default_rng(rcfg.seed)
    it = iter(())
    for step in range(rcfg.steps):
        try:
            b = next(it)
        except StopIteration:
            it = batches(images, labels, rcfg.batch_size, rng)
            b = next(it)
        p0, o0, _ = o_step(p0, o0, (jnp.asarray(b[0]), jnp.asarray(b[1])),
                           step)
    oprobs = np.asarray(jax.nn.sigmoid(nodes[0].apply(p0, jnp.asarray(test_x))))
    oracle = classify_report(oprobs, test_y)

    aucs = [r["auc"] for r in per_site]
    sens = [r["sensitivity"] for r in per_site]
    last = logs[-1]
    out = {
        "scenario": scn.name,
        "partition": scn.partition,
        "families": [nd.family for nd in nodes],
        "shard_sizes": [len(y) for _, y in shards],
        "n_synth": n_synth,
        "schedule": sess.sync_schedule.name,
        "payload_class": sess.sync_schedule.payload,
        "payload_params": int(sess.payload_params),
        "wire_bytes_per_sync": float(sess.predicted_sync_bytes),
        "full_f32_bytes_per_sync": _full_payload_f32_bytes(nodes, cfg),
        "retraces": int(retraces),
        "rounds": rounds,
        "per_site": per_site,
        "site_auc_spread": float(max(aucs) - min(aucs)),
        "site_sensitivity_spread": float(max(sens) - min(sens)),
        "worst_site_auc": float(min(aucs)),
        "oracle": oracle,
        "oracle_gap_auc": float(oracle["auc"] - float(np.mean(aucs))),
        "gates_last": np.asarray(last["gates"]).astype(int).tolist(),
    }
    out["wire_fraction_of_full"] = (out["wire_bytes_per_sync"]
                                    / max(out["full_f32_bytes_per_sync"], 1.0))
    if "fairness_ok" in last:
        out["fairness_ok_last"] = bool(np.asarray(last["fairness_ok"]))
        out["worst_site_gate_metric"] = float(np.asarray(last["worst_site"]))
    return out


def run_grid(rcfg: Optional[ScenarioRunConfig] = None,
             cells: Optional[List[Scenario]] = None) -> List[dict]:
    """Sweep the grid — the BENCH_hetero.json payload."""
    rcfg = rcfg or ScenarioRunConfig()
    return [run_scenario(s, rcfg) for s in (cells or scenario_grid())]
