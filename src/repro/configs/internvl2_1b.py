"""InternVL2-1B: InternViT-300M frontend (STUB per carve-out) + InternLM2-1.8B-
style language backbone [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm", source="arXiv:2404.16821",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151_655, head_dim=64, activation="swiglu", rope_theta=1e6,
    n_patches=256, frontend_dim=1024,  # InternViT hidden size
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
