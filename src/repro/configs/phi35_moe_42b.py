"""Phi-3.5-MoE 42B (6.6B active): 16 experts, top-2 routing
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32_064, head_dim=128, activation="swiglu",
    n_experts=16, top_k=2, d_ff_expert=6400, capacity_factor=1.25,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
