"""MiniCPM-2B: llama-like arch; signature WSD LR schedule [arXiv:2404.06395]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense", source="arXiv:2404.06395",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab_size=122_753, head_dim=64, activation="swiglu", tie_embeddings=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
# Use TrainConfig(schedule="wsd") with this arch — its signature schedule.
