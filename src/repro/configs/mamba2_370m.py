"""Mamba2-370M: attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", source="arXiv:2405.21060",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50_280, head_dim=64, tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256, ssm_groups=1,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
