"""Architecture config registry: the 10 assigned architectures + the paper's
own histopathology CNN. ``--arch <id>`` anywhere in launch/ resolves here."""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeConfig, SwarmConfig,
    TrainConfig,
)

from repro.configs.internvl2_1b import CONFIG as _internvl2
from repro.configs.command_r_plus_104b import CONFIG as _commandr
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.mamba2_370m import CONFIG as _mamba2
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.phi35_moe_42b import CONFIG as _phi35
from repro.configs.minicpm_2b import CONFIG as _minicpm
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.deepseek_coder_33b import CONFIG as _deepseek
from repro.configs.granite_moe_3b import CONFIG as _granite

ARCHS = {c.name: c for c in [
    _internvl2, _commandr, _hymba, _mamba2, _nemotron,
    _phi35, _minicpm, _seamless, _deepseek, _granite,
]}
ARCH_IDS = tuple(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant per assignment: ≤2 layers, d_model ≤ 512,
    ≤4 experts — runs a real forward/train step on CPU."""
    nh = max(2, min(4, cfg.n_heads))
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    nkv = max(1, nh // ratio)
    upd = dict(
        n_layers=2, d_model=256, n_heads=nh, n_kv_heads=nkv, head_dim=64,
        d_ff=0 if cfg.family == "ssm" else 512, vocab_size=512,
        max_seq_len=4096, param_dtype="float32", compute_dtype="float32",
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
    )
    if cfg.family == "moe":
        upd.update(n_experts=4, top_k=min(cfg.top_k, 2), d_ff_expert=128)
    if cfg.family in ("ssm", "hybrid"):
        upd.update(ssm_state=min(cfg.ssm_state, 16), ssm_chunk=16,
                   ssm_head_dim=64, ssm_expand=2)
    if cfg.is_encdec:
        upd.update(n_enc_layers=2, enc_seq_len=16, frontend_dim=32)
    if cfg.family == "vlm":
        upd.update(n_patches=8, frontend_dim=32)
    return cfg.replace(name=cfg.name + "-smoke", **upd)


def adapt_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Per-shape architecture adaptation (DESIGN.md §Arch-applicability):
    ``long_500k`` on full-attention archs switches on the sliding-window
    variant (window 4096, periodic global layers disabled) so the attention
    path is sub-quadratic; ssm/hybrid run natively."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.sliding_window == 0:
            return cfg.replace(sliding_window=4096, attn_every=0)
    return cfg
