"""Hymba-1.5B: hybrid heads — attention ∥ mamba(SSD) in every block, SWA with
periodic global-attention layers [arXiv:2411.13676]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32_001, head_dim=64, activation="swiglu",
    sliding_window=1024, attn_every=8,  # global attention every 8th layer
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
