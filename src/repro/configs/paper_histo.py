"""The paper's own model config: DenseNet-lite encoder (TorchXRayVision-style)
+ 3-class histopathology head (§3.3). Used by examples/ and benchmarks/."""
from dataclasses import dataclass


@dataclass(frozen=True)
class HistoCNNConfig:
    image_size: int = 32          # paper: 224; reduced for CPU experiments
    n_classes: int = 3
    growth: int = 8
    stem: int = 16
    feat_dim: int = 96            # paper: 1152 (scales with image size)
    hidden: int = 32              # paper: 512
    n_blocks: int = 4             # paper: four encoder modules
    layers_per_block: int = 4     # paper: four layers each


CONFIG = HistoCNNConfig()
PAPER_FULL = HistoCNNConfig(image_size=224, feat_dim=1152, hidden=512,
                            growth=32, stem=64)
