"""Nemotron-4 15B: GQA + squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense", source="arXiv:2402.16819",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24_576,
    vocab_size=256_000, head_dim=128, activation="sq_relu",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
