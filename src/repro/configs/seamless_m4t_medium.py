"""SeamlessM4T-medium: speech encoder-decoder; mel+conv frontend is a STUB per
the carve-out (the model consumes precomputed frame embeddings)
[arXiv:2308.11596]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio", source="arXiv:2308.11596",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256_206, head_dim=64, activation="gelu",
    enc_seq_len=1024, frontend_dim=512,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
