"""Config dataclasses for the repro framework.

Every assigned architecture instantiates :class:`ModelConfig`; the swarm layer
is configured by :class:`SwarmConfig`; training by :class:`TrainConfig`.
Configs are plain frozen dataclasses so they hash (usable as jit static args).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description, rich enough for all 6 assigned families."""

    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio (enc-dec)
    source: str = ""       # citation for the config numbers

    # transformer backbone
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1000
    head_dim: int = 0          # 0 -> d_model // n_heads
    activation: str = "swiglu"  # swiglu | sq_relu | gelu
    use_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    max_seq_len: int = 524_288
    logit_softcap: float = 0.0
    # attention variant
    sliding_window: int = 0     # 0 = full attention; >0 = window size
    attn_every: int = 0         # hybrid/SWA: full-attn every k-th layer (0=never)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0          # 0 -> derived: d_inner // ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    ssm_groups: int = 1

    # enc-dec (audio family)
    n_enc_layers: int = 0       # >0 enables encoder-decoder
    enc_seq_len: int = 0        # encoder (frame) length for dry-run specs

    # multimodal frontends (stubs per assignment carve-out)
    n_patches: int = 0          # vlm: number of image patch embeddings
    frontend_dim: int = 0       # raw embedding dim out of the stub frontend

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # dry-run: unroll scan-over-layers so cost_analysis counts every layer
    unroll_layers: bool = False
    # Megatron-style vocab padding so embedding/logits shard evenly
    vocab_pad_to: int = 256

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p if p else self.vocab_size

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * hd * (nh + 2 * nkv) + nh * hd * d
        if self.activation == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family in ("moe",):
            fe = self.d_ff_expert or f
            mlp = self.n_experts * (3 * d * fe) + d * self.n_experts
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, ns, nh_s = self.d_inner, self.ssm_state, self.n_ssm_heads
            g = self.ssm_groups
            zx = d * (2 * di + 2 * g * ns + nh_s)
            ssm = zx + self.conv_width * (di + 2 * g * ns) + nh_s * 2 + di * d + di
            if self.family == "ssm":
                attn, mlp = 0, 0
        block = attn + mlp + ssm + 2 * d
        n_blocks = self.n_layers + self.n_enc_layers
        cross = 0
        if self.is_encdec:
            cross = self.n_layers * (d * hd * (nh + 2 * nkv) + nh * hd * d + d)
        emb = v * d * (1 if self.tie_embeddings else 2)
        front = 0
        if self.frontend_dim:
            front = self.frontend_dim * d + d  # projector
        return emb + n_blocks * block + cross + front

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, fe = self.d_model, (self.d_ff_expert or self.d_ff)
        total = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * d * fe
        active = self.n_layers * self.top_k * 3 * d * fe
        return total - all_experts + active

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class SwarmConfig:
    """P2P-SL: the paper's technique as a first-class feature."""

    n_nodes: int = 4
    sync_every: int = 10          # steps between peer exchanges (paper: 3 epochs)
    topology: str = "ring"        # ring | full | dynamic
    merge: str = "fedavg"         # mean | fedavg | fisher | gradmatch
    lora_only: bool = True        # paper: exchange LoRA-adapter weights only
    # what SwarmState.params covers (docs/heterogeneous.md):
    #   "full" — the stacked state is every node's full param pytree;
    #            lora_only then selects the adapter SUBTREE at sync time.
    #   "lora" — heterogeneous swarm: the stacked state IS the shared wire
    #            payload (LoRA adapters + decoder head, one flat path-keyed
    #            dict per node via `core.lora.flatten_payload`); each node's
    #            frozen, architecture-specific backbone stays local inside
    #            its train/eval closure and never crosses the wire. Needs a
    #            compiled backend; per-node closure lists ("model zoo") are
    #            engine-backend only.
    payload: str = "full"
    lora_rank: int = 16
    lora_alpha: float = 32.0
    val_threshold: float = 0.8    # paper: validation-based acceptance at 80%
    gate_metric: str = "auc"      # traced gate: auc | accuracy | f1 | sensitivity
    self_weight: float = 0.5      # gossip self-mixing weight (ring)
    fisher_decay: float = 0.95    # EMA decay of in-graph importance stats
    overlap_sync: bool = False    # stale-by-one double-buffered round overlap
    # wire compression (core.comms): payload dtype on the sync wire.
    #   "f32"  — uncompressed (default; bit-identical to the pre-comms paths)
    #   "bf16" — payloads cast to bf16 on the wire, f32 accumulation
    #   "int8" — error-feedback quantized deltas with per-block scales; the
    #            EF state rides in SwarmState.wire on BOTH compiled backends:
    #            the θ̂ reference on "engine", the sharded per-shard residual
    #            pytree of the picked *_q8 collective schedule on "gossip"
    wire_dtype: str = "f32"
    wire_block: int = 512         # elements per int8 scale block (mult. of 128)
    # two-level mesh cost model (core.comms): relative per-byte cost of the
    # two link classes on a ("pod", "node") mesh. Intra-pod (ICI) links are
    # cheap and plentiful; cross-pod (DCN) links are the scarce resource —
    # real deployments sit around a 10:1 ratio. pick_schedule argmins
    # Σ bytes(class)·cost(class), so raising cross_pod_cost above ~5.4× the
    # intra cost flips a 2×2 int8 ring swarm onto the hierarchical
    # pod-delegate schedules. On flat (1-D) meshes only the ratio's sign
    # matters (all candidates ride one class) and defaults are neutral.
    intra_pod_cost: float = 1.0
    cross_pod_cost: float = 1.0
    # graceful degradation (repro.faults, docs/faults.md): minimum number
    # of active nodes for a sync to commit. Below quorum the round still
    # trains locally but every gate is held closed — nodes keep their
    # locals and the merge is skipped (0 disables the policy). Evaluated
    # in-graph on the post-quarantine membership mask, so membership
    # changes never retrace.
    quorum: int = 0
    # per-site fairness gate (docs/heterogeneous.md): minimum gate metric
    # (cfg.gate_metric — worst-site sensitivity/AUC in the paper's reading)
    # that every ACTIVE site's merged candidate must clear for the round to
    # commit. Below the floor every gate is held closed — like `quorum`, the
    # whole swarm keeps its locals rather than committing a merge that
    # degrades the worst site. 0.0 disables; evaluated in-graph on the
    # traced per-site metrics, so metric/membership swings never retrace.
    fairness_floor: float = 0.0
    seed: int = 0


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 32          # global
    seq_len: int = 128
    lr: float = 1e-4
    weight_decay: float = 1e-4    # paper: AdamW wd 1e-4
    schedule: str = "cosine"      # cosine | wsd | const
    warmup_steps: int = 100
    max_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    early_stop_patience: int = 5  # paper: patience of five
    remat: bool = True
    accum_steps: int = 1          # microbatch gradient accumulation
    seed: int = 0


@dataclass(frozen=True)
class ShapeConfig:
    """One of the 4 assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}
