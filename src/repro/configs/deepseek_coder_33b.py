"""DeepSeek-Coder-33B: llama-arch dense, GQA kv=8 [arXiv:2401.14196]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense", source="arXiv:2401.14196",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19_200,
    vocab_size=32_256, head_dim=128, activation="swiglu",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
