"""Granite-MoE 3B (800M active): fine-grained experts, top-8 of 40
[hf:ibm-granite/granite-3.0-1b-a400m-base].

NOTE: the assignment line reads "MoE 40e top-8 — 32 experts top-8"; the config
field (40 experts) wins, discrepancy recorded in DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49_155, head_dim=64, activation="swiglu",
    n_experts=40, top_k=8, d_ff_expert=512, capacity_factor=1.25,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
