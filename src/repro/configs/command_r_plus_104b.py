"""Command R+ 104B: GQA, no-bias dense transformer
[hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=64, d_model=12_288, n_heads=96, n_kv_heads=8, d_ff=33_792,
    vocab_size=256_000, head_dim=128, activation="swiglu", use_bias=False,
    rope_theta=75e6, param_dtype="bfloat16", compute_dtype="bfloat16",
)
