"""Logical-axis sharding rules.

Models annotate activations with *logical* axis names; a rules table maps the
logical names to physical mesh axes. Outside a mesh context (CPU smoke tests)
the annotations are no-ops, so the same model code runs everywhere.

Param shardings are derived from pytree paths by :func:`param_specs` — the
same table drives both the dry-run ``in_shardings`` and the activation
constraints, so they cannot drift apart.
"""
from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


# Logical axis -> mesh axis mapping. "data" may be a tuple ("pod","data") on
# the multi-pod mesh, "node" is the swarm-gossip axis on the swarm mesh.
DEFAULT_LOGICAL = {
    "batch": "data",
    "seq": None,
    "heads": "model",
    "kv_heads": "model",
    "attn_seq": "model",   # sequence-parallel attention (heads ∤ mesh)
    "head_dim": "model",   # decode-cache fallback when kv_heads ∤ mesh
    "res_seq": "model",    # Megatron-SP: residual stream sharded on seq —
                           # cuts remat-saved activations by the TP degree
    "ff": "model",
    "embed": None,
    "vocab": "model",
    "experts": "model",
    # MoE fallback when n_experts ∤ model: shard expert-buffer SLOTS over the
    # whole grid instead (experts replicated, compute still fully parallel)
    "moe_slots": ("pod", "data", "model"),
    "state": None,
}


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes the logical axis maps to (1 if inactive)."""
    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None:
        return 1
    ax = rules.get(logical)
    if ax is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        n *= sizes.get(a, 1)
    return n


@contextmanager
def sharding_rules(mesh: Mesh, logical: Optional[dict] = None, **overrides):
    """Activate logical->physical rules for model code executed inside."""
    table = dict(DEFAULT_LOGICAL if logical is None else logical)
    table.update(overrides)
    # drop axes the mesh doesn't have
    axis_names = set(mesh.axis_names)

    def ok(v):
        if v is None:
            return None
        if isinstance(v, (tuple, list)):
            kept = tuple(a for a in v if a in axis_names)
            return kept if kept else None
        return v if v in axis_names else None

    table = {k: ok(v) for k, v in table.items()}
    prev_r, prev_m = _rules(), _mesh()
    _state.rules, _state.mesh = table, mesh
    try:
        yield table
    finally:
        _state.rules, _state.mesh = prev_r, prev_m


def logical_shard(x, *logical_axes):
    """Constrain ``x`` (rank == len(logical_axes)) to the active rules.

    Axes whose size does not divide the mesh axis become UNCONSTRAINED (the
    compiler decides) — uneven GSPMD shardings (e.g. 36 heads over 16 chips)
    trigger halo-permute churn, while a hard `None` would force replication.
    """
    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(f"rank {x.ndim} != {len(logical_axes)} logical axes")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def resolve(dim, logical):
        if logical is None:
            return None
        ax = rules.get(logical)
        if ax is None:
            return None
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= sizes.get(a, 1)
        return ax if n and dim % n == 0 else P.UNCONSTRAINED

    spec = P(*(resolve(d, a) for d, a in zip(x.shape, logical_axes)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter partition specs (path-pattern table)
# ---------------------------------------------------------------------------

# Each rule: (path regex, PartitionSpec builder taking the rules table).
# Conventions: weight matrices are [in, out]. We shard the "wide" axis over
# `model` and (FSDP) the other over `data` where the dims are large.
_PARAM_RULES = [
    # tied embedding (lookup + unembed): vocab over model — logits stay
    # sharded; the lookup pays a table all-gather (small models only)
    (r"embed_tied.*table$", lambda t: P(t["vocab"], None)),
    # input-only embedding: shard d_model — the backward scatter-add becomes
    # LOCAL per model shard (an unsharded [V,d] f32 scatter temp otherwise)
    (r"embed.*table$", lambda t: P(None, t["heads"])),
    (r"(unembed|lm_head).*w$", lambda t: P(None, t["vocab"])),
    # attention projections
    (r"attn.*\b(q|k|v)\b.*w$", lambda t: P(t["fsdp"], t["heads"])),
    (r"attn.*\bo\b.*w$", lambda t: P(t["heads"], t["fsdp"])),
    (r"cross.*\b(q|k|v)\b.*w$", lambda t: P(t["fsdp"], t["heads"])),
    (r"cross.*\bo\b.*w$", lambda t: P(t["heads"], t["fsdp"])),
    # MLP
    (r"mlp.*(gate|up).*w$", lambda t: P(t["fsdp"], t["ff"])),
    (r"mlp.*down.*w$", lambda t: P(t["ff"], t["fsdp"])),
    # MoE experts: leading expert axis over model
    (r"experts.*(gate|up).*w$", lambda t: P(t["experts"], t["fsdp"], None)),
    (r"experts.*down.*w$", lambda t: P(t["experts"], None, t["fsdp"])),
    (r"router.*w$", lambda t: P(None, None)),
    # SSM
    (r"ssm.*in_proj.*w$", lambda t: P(t["fsdp"], t["ff"])),
    (r"ssm.*out_proj.*w$", lambda t: P(t["ff"], t["fsdp"])),
    (r"ssm.*conv.*", lambda t: P(None, t["ff"]) ),
    # LoRA adapters: small; replicate
    (r"lora.*", lambda t: P(None)),
    # frontend projector
    (r"projector.*w$", lambda t: P(None, None)),
]


def constrain_block_params(tree):
    """Constrain a (per-layer) param subtree to its rule shardings inside a
    scan body. with_sharding_constraint transposes onto cotangents, so the
    scan-stacked gradient accumulators inherit the param sharding instead of
    staying model-replicated (measured ~20 GiB/device f32 on 104B train)."""
    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None:
        return tree
    table = dict(rules)
    table.setdefault("fsdp", table.get("batch"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        spec = spec_for_path(_path_str(path), table)
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        fixed = []
        for dim, ax in zip(leaf.shape, entries):
            if ax is None:
                fixed.append(None)
                continue
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= sizes.get(a, 1)
            fixed.append(ax if n and dim % n == 0 else None)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, P(*fixed)))

    return jax.tree_util.tree_map_with_path(one, tree)


def spec_for_path(path: str, table: dict) -> P:
    for pat, builder in _PARAM_RULES:
        if re.search(pat, path):
            spec = builder(table)
            return spec
    return P()  # replicate scalars / norms / biases


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_shape, mesh: Mesh, *, fsdp: bool = True, logical=None):
    """PartitionSpec pytree for a param (shape-)pytree.

    ``fsdp=True`` additionally shards the non-model weight axis over `data`
    when divisible — ZeRO-3-style, needed to fit 100B-class configs.
    """
    table = dict(DEFAULT_LOGICAL if logical is None else logical)
    axis_names = set(mesh.axis_names)

    def ok(v):
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in axis_names)
            return kept or None
        return v if v in axis_names else None

    table = {k: ok(v) for k, v in table.items()}
    data_ax = "data" if "data" in axis_names else None
    if fsdp is True:
        table["fsdp"] = data_ax
    elif fsdp:
        table["fsdp"] = ok(tuple(fsdp) if not isinstance(fsdp, str) else fsdp)
    else:
        table["fsdp"] = None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        spec = spec_for_path(_path_str(path), table)
        # drop spec axes that don't divide the dim
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        fixed = []
        for dim, ax in zip(leaf.shape, entries):
            if ax is None:
                fixed.append(None)
            else:
                n = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    n *= sizes.get(a, 1)
                fixed.append(ax if n and dim % n == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def shardings_for(params_shape, mesh: Mesh, **kw):
    specs = param_specs(params_shape, mesh, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
