"""Production serving plane (PR 8): continuous-batching consensus inference
over swarm-trained ensembles with zero-downtime checkpoint hot-swap.

The N per-node variants in ``SwarmState.params`` are served directly as one
vmapped ensemble; ``core.session.load_checkpoint_params`` is the ingest
surface from a training swarm's ``session.save`` checkpoints. See
docs/serving.md for the request lifecycle, bucket policy, consensus modes
and the hot-swap protocol.
"""
from repro.serve.batcher import BucketPolicy
from repro.serve.engine import AGG_MODES, ServeEngine, aggregate_logits
from repro.serve.hot_swap import HotSwapSlot
from repro.serve.queue import Request, RequestQueue

__all__ = ["AGG_MODES", "BucketPolicy", "HotSwapSlot", "Request",
           "RequestQueue", "ServeEngine", "aggregate_logits"]
