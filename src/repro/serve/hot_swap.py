"""Zero-downtime hot-swap: an atomic double-buffered ensemble param slot.

Protocol (docs/serving.md#hot-swap-protocol):

1. ``ingest(path)`` reads ONLY the stacked per-node params out of a full
   ``SwarmSession.save`` checkpoint (``core.session.load_checkpoint_params``
   skips opt state / merge stats / wire state by template) and validates the
   tree structure, shapes and node count against the live ensemble.
2. ``publish`` stages the new buffer under a fresh version number FIRST and
   flips the live version pointer LAST — a single int store — so a reader
   always sees one complete buffer, never a mix of old and new leaves.
3. In-flight requests are pinned to the version they were admitted under
   (``Request.param_version``); the engine dispatches one decode per live
   version during the transition window, all through the same compiled step
   (params are an argument, so a swap never retraces).
4. Superseded buffers stay resident until ``retire`` observes that no live
   slot pins them; the engine calls it every tick, so the old ensemble is
   freed exactly when its last request drains.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Optional, Tuple

import jax

from repro.core.session import load_checkpoint_params


def _spec(params) -> Tuple:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return treedef, [(leaf.shape, leaf.dtype) for leaf in leaves]


class HotSwapSlot:
    """Double-buffered stacked-ensemble params with version pinning."""

    def __init__(self, params: Any):
        self._buffers: Dict[int, Any] = {0: params}
        self._version = 0

    @property
    def version(self) -> int:
        return self._version

    @property
    def versions(self) -> Tuple[int, ...]:
        return tuple(sorted(self._buffers))

    @property
    def live(self) -> Any:
        return self._buffers[self._version]

    def buffer(self, version: int) -> Any:
        return self._buffers[version]

    def publish(self, params: Any) -> int:
        """Atomically make ``params`` the live ensemble; returns its version."""
        if _spec(params) != _spec(self.live):
            raise ValueError(
                "published params do not match the live ensemble's "
                "tree structure / leaf shapes / dtypes")
        staged = self._version + 1
        self._buffers[staged] = params   # stage the complete buffer first ...
        self._version = staged           # ... flip the pointer last
        return staged

    def ingest(self, path: str, *, expect_nodes: Optional[int] = None) -> int:
        """Load the stacked params from a ``SwarmSession.save`` checkpoint
        and publish them as the new live version."""
        return self.publish(load_checkpoint_params(
            path, self.live, expect_nodes=expect_nodes))

    def retire(self, pinned: Iterable[int]) -> None:
        """Drop buffers no in-flight request pins (live always survives)."""
        keep = {int(v) for v in pinned} | {self._version}
        for version in [v for v in self._buffers if v not in keep]:
            del self._buffers[version]
