"""Request queue for the serving plane.

Single-threaded and tick-driven: :class:`~repro.serve.engine.ServeEngine`
pumps the queue from its scheduler loop, so admission order, param-version
pinning and completion are fully deterministic (and therefore testable —
the hot-swap invariants in tests/test_serve.py rely on this).
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import numpy as np


@dataclass
class Request:
    """One generation request and its full lifecycle record.

    ``node_tokens`` accumulates the per-node token vector emitted at each
    step (a list of ``[N]`` int32 arrays); ``tokens`` is the aggregated
    stream — identical across nodes for consensus/average/topk modes, node
    0's stream under ``per_node``. ``param_version`` is pinned at admission:
    every token of this request comes from exactly that version of the
    hot-swap slot, even if a newer checkpoint is published mid-request.
    """

    rid: int
    prompt: np.ndarray              # [prompt_len] int32
    max_new: int
    submit_t: float = 0.0
    admit_t: Optional[float] = None
    finish_t: Optional[float] = None
    param_version: Optional[int] = None
    node_tokens: List[np.ndarray] = field(default_factory=list)

    @property
    def tokens(self) -> List[int]:
        return [int(v[0]) for v in self.node_tokens]

    @property
    def done(self) -> bool:
        return self.finish_t is not None

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


class RequestQueue:
    """FIFO admission queue with monotonically increasing request ids."""

    def __init__(self, now=time.perf_counter):
        self._pending: Deque[Request] = deque()
        self._ids = itertools.count()
        self._now = now

    def submit(self, prompt, max_new: int) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        req = Request(rid=next(self._ids), prompt=prompt, max_new=int(max_new),
                      submit_t=self._now())
        self._pending.append(req)
        return req

    def pop(self) -> Request:
        return self._pending.popleft()

    def __len__(self) -> int:
        return len(self._pending)
