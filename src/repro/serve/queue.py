"""Request queue for the serving plane.

Single-threaded and tick-driven: :class:`~repro.serve.engine.ServeEngine`
pumps the queue from its scheduler loop, so admission order, param-version
pinning and completion are fully deterministic (and therefore testable —
the hot-swap invariants in tests/test_serve.py rely on this).

Degradation surface (docs/faults.md): the queue is BOUNDED when
``max_pending`` is set — a submit beyond the bound is rejected explicitly
(terminal ``status="rejected"``, never enqueued) instead of growing an
unbounded backlog; and every request can carry a ``deadline_s`` budget —
:meth:`RequestQueue.expire` sweeps pending requests past their deadline
(terminal ``status="deadline_exceeded"``) so stale work never occupies a
prefill dispatch.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

import numpy as np


@dataclass
class Request:
    """One generation request and its full lifecycle record.

    ``node_tokens`` accumulates the per-node token vector emitted at each
    step (a list of ``[N]`` int32 arrays); ``tokens`` is the aggregated
    stream — identical across nodes for consensus/average/topk modes, node
    0's stream under ``per_node``. ``param_version`` is pinned at admission:
    every token of this request comes from exactly that version of the
    hot-swap slot, even if a newer checkpoint is published mid-request.

    ``status`` is the lifecycle verdict: ``"pending"`` → ``"live"`` on
    admission → one terminal state — ``"done"`` (completed normally),
    ``"rejected"`` (bounded-queue backpressure: never admitted), or
    ``"deadline_exceeded"`` (its ``deadline_s`` budget ran out, queued or
    mid-decode; any already-emitted tokens are kept). Every terminal
    transition also stamps ``finish_t``, so ``done`` means "reached a
    terminal state", not "succeeded" — check ``status`` for the verdict.
    """

    rid: int
    prompt: np.ndarray              # [prompt_len] int32
    max_new: int
    submit_t: float = 0.0
    admit_t: Optional[float] = None
    finish_t: Optional[float] = None
    param_version: Optional[int] = None
    node_tokens: List[np.ndarray] = field(default_factory=list)
    deadline_s: Optional[float] = None
    status: str = "pending"

    @property
    def tokens(self) -> List[int]:
        return [int(v[0]) for v in self.node_tokens]

    @property
    def done(self) -> bool:
        return self.finish_t is not None

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


class RequestQueue:
    """FIFO admission queue with monotonically increasing request ids.

    ``max_pending`` bounds the backlog: ``None`` (default) keeps the
    historical unbounded behaviour; with a bound, an over-limit submit
    returns the request already in terminal ``status="rejected"`` — the
    caller observes explicit backpressure instead of unbounded growth.
    """

    def __init__(self, now=time.perf_counter,
                 max_pending: Optional[int] = None):
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self._pending: Deque[Request] = deque()
        self._ids = itertools.count()
        self._now = now
        self.max_pending = max_pending

    def submit(self, prompt, max_new: int,
               deadline_s: Optional[float] = None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        req = Request(rid=next(self._ids), prompt=prompt, max_new=int(max_new),
                      submit_t=self._now(), deadline_s=deadline_s)
        if (self.max_pending is not None
                and len(self._pending) >= self.max_pending):
            req.status = "rejected"
            req.finish_t = req.submit_t
            return req
        self._pending.append(req)
        return req

    def expire(self, now: Optional[float] = None) -> List[Request]:
        """Sweep pending requests whose ``deadline_s`` budget has elapsed;
        each is marked terminal ``deadline_exceeded`` and returned."""
        t = self._now() if now is None else now
        expired: List[Request] = []
        kept: Deque[Request] = deque()
        for req in self._pending:
            if (req.deadline_s is not None
                    and t - req.submit_t >= req.deadline_s):
                req.status = "deadline_exceeded"
                req.finish_t = t
                expired.append(req)
            else:
                kept.append(req)
        self._pending = kept
        return expired

    @property
    def pending(self) -> Tuple[Request, ...]:
        return tuple(self._pending)

    def pop(self) -> Request:
        return self._pending.popleft()

    def __len__(self) -> int:
        return len(self._pending)
