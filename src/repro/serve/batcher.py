"""Padded-bucket shape policy: the engine dispatches only a small fixed set
of (batch, seq) shapes so XLA's jit cache stays warm.

Prompts are right-padded up to the next seq bucket before the prefill
dispatch. For position-indexed caches (the attention families) this is
exact, not approximate: pad positions sit AFTER the real tokens, the causal
mask assigns them zero attention weight from every real query position, and
later decode steps overwrite them in place. Recurrent-state families (ssm)
consume pads into their state, so they need seq buckets matching their
prompt lengths exactly (docs/serving.md#bucket-policy).

The decode batch dimension is the live-slot table, which grows and shrinks
only across ``batch_buckets`` — each bucket compiles once, ever.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class BucketPolicy:
    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    seq_buckets: Tuple[int, ...] = (8, 16, 32, 64)

    def __post_init__(self):
        for name in ("batch_buckets", "seq_buckets"):
            b = tuple(getattr(self, name))
            if not b or list(b) != sorted(set(b)) or b[0] < 1:
                raise ValueError(
                    f"{name} must be a sorted tuple of unique positive ints, "
                    f"got {b!r}")

    def batch_bucket(self, n: int) -> int:
        """Smallest batch bucket holding ``n`` live slots."""
        for b in self.batch_buckets:
            if n <= b:
                return b
        raise ValueError(f"{n} slots exceed the largest batch bucket "
                         f"{self.batch_buckets[-1]}")

    def seq_bucket(self, n: int) -> int:
        """Smallest seq bucket holding an ``n``-token prompt."""
        for b in self.seq_buckets:
            if n <= b:
                return b
        raise ValueError(f"a {n}-token prompt exceeds the largest seq bucket "
                         f"{self.seq_buckets[-1]}")

    def pad_prompt(self, prompt: np.ndarray) -> Tuple[np.ndarray, int]:
        """Right-pad to the prompt's seq bucket; returns (padded, real_len)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        out = np.zeros(self.seq_bucket(prompt.size), np.int32)
        out[:prompt.size] = prompt
        return out, int(prompt.size)
