"""Continuous-batching consensus engine: the serving plane's core.

Architecture
------------
* **Slot model.** The engine owns a table of up to ``max_slots`` decode
  slots. Each live slot is one in-flight request: a lane in the stacked
  cache, a position counter, and a pinned param version. ``step()`` is one
  host-side scheduler tick: admit pending requests into free slots (one
  bucketed prefill dispatch each), then advance every live slot one token
  with a single batched decode dispatch. Requests at different depths
  coexist because every lane carries its own ``cache_pos``.
* **Bucketed shapes.** Dispatch shapes come from :class:`BucketPolicy`:
  prompts right-pad to a seq bucket, the slot table grows/shrinks across
  batch buckets — so each jitted entry compiles once per bucket, ever
  (``trace_counts`` is keyed by (kind, shape) and tests pin zero retraces
  across hot-swaps and steady-state serving).
* **Vmapped ensemble.** The N per-node variants in ``SwarmState.params``
  are served as one double-vmapped forward — outer vmap over nodes (params
  + cache axis 0), inner vmap over slots (cache axis 1, per-lane
  ``cache_pos``) — built from the ``launch.serve.make_logits_step``
  primitive, with traced aggregation (:func:`aggregate_logits`) choosing
  the token every node continues with.
* **Hot swap.** Params live in a :class:`~repro.serve.hot_swap.HotSwapSlot`.
  Each request decodes under the version it was admitted with; during a
  transition the tick issues one decode dispatch per live version (same
  compiled step — params are an argument), and superseded buffers are
  retired once their last request drains. No request is ever dropped or
  served a mix of versions.

Both jitted entries donate the cache table (arg 1): the slot caches are
mutated in place tick over tick, never copied (swarmlint SWL003's serve
scope pins this).
"""
from __future__ import annotations

import collections
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import make_logits_step
from repro.models import Model
from repro.serve.batcher import BucketPolicy
from repro.serve.hot_swap import HotSwapSlot
from repro.serve.queue import Request, RequestQueue

AGG_MODES = ("consensus", "average", "per_node", "topk")


def aggregate_logits(logits, mode: str, top_k: int = 2, node_mask=None):
    """Traced ensemble aggregation: per-node logits [N, B, V] -> the next
    token each node continues with, [N, B] int32.

    consensus
        Majority vote over per-node argmaxes; ties break toward the
        candidate with the highest mean probability (the fractional
        tie-break term is < 1 vote, so a strict majority always wins).
    average
        Argmax of the mean per-node softmax (probability-space averaging).
    topk
        Like ``average``, but only the ``top_k`` most confident nodes
        (highest max-probability) vote in each slot.
    per_node
        No aggregation: every node decodes its own stream — the per-site
        diversity view (N divergent sequences per request).

    ``node_mask`` ([N] bool, optional) drops crashed/quarantined ensemble
    lanes from the aggregate: masked nodes cast no vote, contribute no
    probability mass, and can never be selected by ``topk``. It is runtime
    DATA — flipping it between ticks re-aggregates over the survivors with
    zero retraces. ``None`` (the default) is the historical unmasked math,
    bit-for-bit.
    """
    n, b, v = logits.shape
    if mode == "per_node":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    probs = jax.nn.softmax(logits, axis=-1)                       # [N, B, V]
    if node_mask is None:
        if mode == "consensus":
            votes = jax.nn.one_hot(jnp.argmax(logits, -1), v)     # [N, B, V]
            score = votes.sum(0) + probs.mean(0) / (n + 1.0)
            winner = jnp.argmax(score, -1)                        # [B]
        elif mode == "average":
            winner = jnp.argmax(probs.mean(0), -1)
        elif mode == "topk":
            conf = probs.max(-1)                                  # [N, B]
            _, idx = jax.lax.top_k(conf.T, top_k)                 # [B, k]
            sel = jnp.take_along_axis(
                jnp.moveaxis(probs, 0, 1), idx[..., None], axis=1)  # [B,k,V]
            winner = jnp.argmax(sel.mean(1), -1)
        else:
            raise ValueError(f"unknown aggregation mode {mode!r}; "
                             f"expected one of {AGG_MODES}")
        return jnp.broadcast_to(winner[None], (n, b)).astype(jnp.int32)
    m = jnp.asarray(node_mask).astype(probs.dtype)                # [N]
    n_act = jnp.maximum(m.sum(), 1.0)
    if mode == "consensus":
        votes = jax.nn.one_hot(jnp.argmax(logits, -1), v) * m[:, None, None]
        pmean = (probs * m[:, None, None]).sum(0) / n_act
        score = votes.sum(0) + pmean / (n_act + 1.0)
        winner = jnp.argmax(score, -1)
    elif mode == "average":
        winner = jnp.argmax((probs * m[:, None, None]).sum(0) / n_act, -1)
    elif mode == "topk":
        # masked lanes sink below every real confidence, so top_k only
        # surfaces them when fewer than k survivors exist — and then their
        # zero ``valid`` weight still keeps them out of the average
        conf = jnp.where(m[:, None] > 0, probs.max(-1), -1.0)     # [N, B]
        _, idx = jax.lax.top_k(conf.T, top_k)                     # [B, k]
        valid = jnp.take(m, idx)                                  # [B, k]
        sel = jnp.take_along_axis(
            jnp.moveaxis(probs, 0, 1), idx[..., None], axis=1)    # [B, k, V]
        weighted = ((sel * valid[..., None]).sum(1)
                    / jnp.maximum(valid.sum(1), 1.0)[..., None])
        winner = jnp.argmax(weighted, -1)
    else:
        raise ValueError(f"unknown aggregation mode {mode!r}; "
                         f"expected one of {AGG_MODES}")
    return jnp.broadcast_to(winner[None], (n, b)).astype(jnp.int32)


class ServeEngine:
    """Continuous-batching ensemble server over stacked per-node params.

    Parameters
    ----------
    model : the (single-node) Model bundle; decode must be position-indexed
        (attention families) for padded prefill — see docs/serving.md.
    params : stacked params with leading node axis N (``SwarmState.params``
        layout), or a :class:`HotSwapSlot` already wrapping them.
    mode : aggregation mode, one of ``AGG_MODES`` (static per engine — each
        mode is its own compiled program).
    max_len : cache depth per slot; prompt_len + max_new must fit.
    max_slots : concurrency ceiling (≤ the largest batch bucket);
        ``max_slots=1`` with ``batch_buckets=(1,)`` is the naive
        one-request-at-a-time baseline the benchmarks compare against.
    """

    def __init__(self, model: Model, params, *, mode: str = "consensus",
                 top_k: int = 2, max_len: int = 64, max_slots: int = 8,
                 policy: Optional[BucketPolicy] = None,
                 max_pending: Optional[int] = None,
                 now=time.perf_counter):
        if mode not in AGG_MODES:
            raise ValueError(f"unknown mode {mode!r}; expected {AGG_MODES}")
        self.model = model
        self.mode = mode
        self.top_k = int(top_k)
        self.max_len = int(max_len)
        self.max_slots = int(max_slots)
        self.policy = policy if policy is not None else BucketPolicy()
        if self.max_slots > self.policy.batch_buckets[-1]:
            raise ValueError(
                f"max_slots={self.max_slots} exceeds the largest batch "
                f"bucket {self.policy.batch_buckets[-1]}")
        self.slot = params if isinstance(params, HotSwapSlot) \
            else HotSwapSlot(params)
        self.n_nodes = int(jax.tree_util.tree_leaves(self.slot.live)[0].shape[0])
        self._logits_step = make_logits_step(model)
        self._now = now
        self.queue = RequestQueue(now=now, max_pending=max_pending)
        self.completed: List[Request] = []
        # ensemble-lane health: a crashed node's lane is dropped from every
        # aggregation (runtime data — flips never retrace); per_node mode
        # keeps decoding all lanes (each stream is already independent)
        self._node_mask = np.ones(self.n_nodes, bool)
        # (kind, shape) -> number of traces; the python bodies below run only
        # at trace time, so steady-state serving and hot-swaps keep these flat
        self.trace_counts = collections.defaultdict(int)
        self._decode_commit = jax.jit(self._decode_commit_impl,
                                      donate_argnums=(1,))
        self._prefill_commit = jax.jit(self._prefill_commit_impl,
                                       donate_argnums=(1,))
        self._bucket = self.policy.batch_buckets[0]
        self._caches = self._init_caches(self._bucket)
        self._pos = np.zeros(self._bucket, np.int32)
        self._live = np.zeros(self._bucket, bool)
        self._pinned = np.zeros(self._bucket, np.int64)
        self._tokens = np.zeros((self.n_nodes, self._bucket), np.int32)
        self._reqs: List[Optional[Request]] = [None] * self._bucket

    # -- jitted cores -------------------------------------------------------

    def _decode_commit_impl(self, params, caches, tokens, pos, live,
                            node_mask):
        """One batched ensemble decode tick: tokens [N,B], pos [B], live [B]
        -> (aggregated next tokens [N,B], caches with live lanes advanced).
        ``node_mask`` [N] drops crashed lanes from the aggregate (data, not
        structure — consensus re-forms over survivors with zero retraces)."""
        self.trace_counts["decode", tokens.shape[1]] += 1

        def slot_step(p, tok, cache, q):
            logits, new = self._logits_step(p, tok[None, None], cache, q)
            return logits[0, -1], new

        def node_step(p, toks, node_caches):
            return jax.vmap(slot_step, in_axes=(None, 0, 0, 0))(
                p, toks, node_caches, pos)

        logits, new_caches = jax.vmap(node_step)(params, tokens, caches)
        nxt = aggregate_logits(logits, self.mode, self.top_k,
                               node_mask=node_mask)

        def commit(old, new):
            mask = live.reshape((1, live.shape[0]) + (1,) * (new.ndim - 2))
            return jnp.where(mask, new, old)

        return nxt, jax.tree.map(commit, caches, new_caches)

    def _prefill_commit_impl(self, params, caches, prompt, slot, length,
                             node_mask):
        """Ensemble prefill of ONE slot: padded prompt [S] -> per-node first
        tokens [N]; the slot's cache lane is replaced in place."""
        table = jax.tree_util.tree_leaves(caches)[0].shape[1]
        self.trace_counts["prefill", prompt.shape[0], table] += 1

        def node_prefill(p):
            fresh = self.model.init_cache(1, self.max_len)
            logits, cache = self._logits_step(p, prompt[None], fresh,
                                              jnp.int32(0))
            return logits[0, length - 1], cache

        logits, slot_cache = jax.vmap(node_prefill)(params)
        first = aggregate_logits(logits[:, None, :], self.mode,
                                 self.top_k, node_mask=node_mask)[:, 0]
        caches = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new, slot, axis=1),
            caches, slot_cache)
        return first, caches

    # -- slot-table plumbing ------------------------------------------------

    def _init_caches(self, b: int):
        """Stacked slot caches: leaves [N, b, *single-slot cache dims]."""
        one = self.model.init_cache(1, self.max_len)
        return jax.tree.map(
            lambda leaf: jnp.zeros((self.n_nodes, b) + leaf.shape, leaf.dtype),
            one)

    def _grow(self, nb: int) -> None:
        pad = nb - self._bucket
        self._caches = jax.tree.map(
            lambda c: jnp.concatenate(
                [c, jnp.zeros(c.shape[:1] + (pad,) + c.shape[2:], c.dtype)],
                axis=1),
            self._caches)
        self._pos = np.concatenate([self._pos, np.zeros(pad, np.int32)])
        self._live = np.concatenate([self._live, np.zeros(pad, bool)])
        self._pinned = np.concatenate([self._pinned, np.zeros(pad, np.int64)])
        self._tokens = np.concatenate(
            [self._tokens, np.zeros((self.n_nodes, pad), np.int32)], axis=1)
        self._reqs.extend([None] * pad)
        self._bucket = nb

    def _maybe_shrink(self) -> None:
        b0 = self.policy.batch_buckets[0]
        if self._bucket == b0 or self._live.any() or len(self.queue):
            return
        self._caches = jax.tree.map(lambda c: c[:, :b0], self._caches)
        self._pos = self._pos[:b0].copy()
        self._live = self._live[:b0].copy()
        self._pinned = self._pinned[:b0].copy()
        self._tokens = self._tokens[:, :b0].copy()
        self._reqs = self._reqs[:b0]
        self._bucket = b0

    # -- public API ---------------------------------------------------------

    @property
    def live_count(self) -> int:
        return int(self._live.sum())

    @property
    def total_traces(self) -> int:
        return sum(self.trace_counts.values())

    @property
    def node_mask(self) -> np.ndarray:
        return self._node_mask.copy()

    def fail_node(self, node: int) -> None:
        """Drop one ensemble lane from every aggregation, effective the very
        next dispatch — in-flight requests keep decoding, their consensus
        re-forms over the surviving lanes (no retrace, no drop)."""
        mask = self._node_mask.copy()
        mask[node] = False
        self.set_node_mask(mask)

    def restore_node(self, node: int) -> None:
        """Re-admit a recovered lane to the aggregate."""
        mask = self._node_mask.copy()
        mask[node] = True
        self.set_node_mask(mask)

    def set_node_mask(self, mask) -> None:
        mask = np.asarray(mask, bool).reshape(-1)
        if mask.shape[0] != self.n_nodes:
            raise ValueError(f"node mask has {mask.shape[0]} entries, the "
                             f"ensemble has {self.n_nodes} nodes")
        if not mask.any():
            raise ValueError("cannot fail every ensemble lane: at least one "
                             "node must survive to serve")
        self._node_mask = mask

    def submit(self, prompt, max_new: int,
               deadline_s: Optional[float] = None) -> Request:
        """Enqueue a request. ``deadline_s`` is a wall-clock budget from
        submission: once elapsed the request lands in terminal
        ``deadline_exceeded`` (queued or mid-decode; emitted tokens kept).
        A bounded queue (``max_pending``) may return the request already
        terminal ``rejected`` — explicit backpressure, never enqueued."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.policy.seq_bucket(prompt.size)   # must fit a bucket
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({max_new}) exceeds the "
                f"cache depth max_len={self.max_len}")
        req = self.queue.submit(prompt, max_new, deadline_s=deadline_s)
        if req.status == "rejected":
            self.completed.append(req)
        return req

    def swap(self, params) -> int:
        """Publish a new stacked ensemble; in-flight requests finish on the
        version they were admitted with."""
        return self.slot.publish(params)

    def ingest_checkpoint(self, path: str) -> int:
        """Hot-swap in the params of a ``SwarmSession.save`` checkpoint."""
        return self.slot.ingest(path, expect_nodes=self.n_nodes)

    def step(self) -> List[Request]:
        """One scheduler tick: expire -> admit -> decode -> harvest. Returns
        the requests that reached a terminal state this tick (``done`` OR
        ``deadline_exceeded`` — check ``status``)."""
        done: List[Request] = []
        done.extend(self.queue.expire())        # queued past-deadline sweeps
        self._expire_live(done)                 # mid-decode deadline sweeps
        self._admit(done)
        if self._live.any():
            self._decode_tick(done)
        self.slot.retire(self._pinned[self._live].tolist())
        self._maybe_shrink()
        self.completed.extend(done)
        return done

    def drain(self, max_ticks: int = 100_000) -> List[Request]:
        """Tick until the queue and all slots are empty.

        Raises ``TimeoutError`` naming the stuck work — live ``(slot,
        rid)`` pairs and still-queued rids — if the budget runs out."""
        done: List[Request] = []
        while len(self.queue) or self._live.any():
            if max_ticks <= 0:
                stuck = [(int(s), self._reqs[s].rid)
                         for s in np.flatnonzero(self._live)]
                queued = [r.rid for r in self.queue.pending]
                raise TimeoutError(
                    f"drain did not converge: live slots (slot, rid) "
                    f"{stuck}, queued rids {queued}")
            max_ticks -= 1
            done.extend(self.step())
        return done

    # -- scheduler ----------------------------------------------------------

    def _admit(self, done: List[Request]) -> None:
        while len(self.queue):
            if self.live_count >= self.max_slots:
                break
            free = np.flatnonzero(~self._live)
            if free.size == 0:
                self._grow(self.policy.batch_bucket(self.live_count + 1))
                free = np.flatnonzero(~self._live)
            self._start(self.queue.pop(), int(free[0]), done)

    def _start(self, req: Request, slot: int, done: List[Request]) -> None:
        padded, length = self.policy.pad_prompt(req.prompt)
        version = self.slot.version
        first, self._caches = self._prefill_commit(
            self.slot.buffer(version), self._caches, jnp.asarray(padded),
            jnp.int32(slot), jnp.int32(length), jnp.asarray(self._node_mask))
        first = np.asarray(first)                                 # [N]
        req.param_version = version
        req.admit_t = self._now()
        req.status = "live"
        req.node_tokens.append(first)
        self._reqs[slot] = req
        self._live[slot] = True
        self._pinned[slot] = version
        self._pos[slot] = length
        self._tokens[:, slot] = first
        if req.max_new == 1:
            done.append(self._finish(slot))

    def _decode_tick(self, done: List[Request]) -> None:
        # one dispatch per live param version (≥ 2 only mid-hot-swap), all
        # through the same compiled step; non-matching lanes are masked out
        # of the cache commit and their host state is left untouched
        for version in sorted(set(self._pinned[self._live].tolist())):
            mask = self._live & (self._pinned == version)
            nxt, self._caches = self._decode_commit(
                self.slot.buffer(version), self._caches,
                jnp.asarray(self._tokens), jnp.asarray(self._pos),
                jnp.asarray(mask), jnp.asarray(self._node_mask))
            nxt = np.asarray(nxt)                                 # [N, B]
            for slot in np.flatnonzero(mask):
                req = self._reqs[slot]
                req.node_tokens.append(nxt[:, slot].copy())
                self._tokens[:, slot] = nxt[:, slot]
                self._pos[slot] += 1
                if len(req.node_tokens) >= req.max_new:
                    done.append(self._finish(int(slot)))

    def _expire_live(self, done: List[Request]) -> None:
        """Finish live slots whose wall-clock deadline elapsed — the lane
        frees immediately; tokens already emitted stay on the request."""
        now = self._now()
        for slot in np.flatnonzero(self._live):
            req = self._reqs[slot]
            if (req.deadline_s is not None
                    and now - req.submit_t >= req.deadline_s):
                done.append(self._finish(int(slot),
                                         status="deadline_exceeded"))

    def _finish(self, slot: int, status: str = "done") -> Request:
        req = self._reqs[slot]
        req.finish_t = self._now()
        req.status = status
        self._live[slot] = False
        self._reqs[slot] = None
        return req
