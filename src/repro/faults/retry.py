"""Bounded retry with deterministic backoff and a timeout budget.

The single sanctioned home for host-side retry loops in ``src/repro``
(swarmlint SWL007): hand-rolled ``while: try/except + sleep`` loops hide
unbounded attempts and untestable pacing; :func:`with_retry` makes
attempts, backoff, the total time budget, and the clock/sleep functions
explicit and injectable, so fault tests can drive it with fake time.

Deliberately stdlib-only and jax-free — it wraps checkpoint I/O and
future orchestration hooks, both of which must work before any backend
exists.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Tuple, Type

__all__ = ["RetryError", "with_retry"]


class RetryError(RuntimeError):
    """All attempts failed (or the time budget ran out). The final
    underlying exception is chained (``__cause__``) and kept on
    ``last_exception``."""

    def __init__(self, message: str, last_exception: BaseException):
        super().__init__(message)
        self.last_exception = last_exception


def with_retry(fn: Callable[[], object], *, attempts: int = 3,
               base_delay: float = 0.02, backoff: float = 2.0,
               max_delay: float = 1.0, timeout: Optional[float] = None,
               retry_on: Tuple[Type[BaseException], ...] = (OSError,),
               raise_last: bool = False, describe: str = "",
               sleep: Callable[[float], None] = time.sleep,
               clock: Callable[[], float] = time.monotonic):
    """Call ``fn()`` with at most ``attempts`` tries.

    Only exceptions in ``retry_on`` are retried; anything else propagates
    immediately (a corrupt checkpoint must not be re-read three times).
    Between tries sleeps ``min(base_delay * backoff**k, max_delay)`` —
    deterministic, no jitter, so fault tests can pin the exact schedule.
    ``timeout`` bounds the total budget: no retry starts if the next sleep
    would overrun it. On exhaustion raises :class:`RetryError`, or the
    last underlying exception unchanged with ``raise_last=True`` (used by
    checkpoint I/O so callers keep seeing ``FileNotFoundError`` etc.).
    ``sleep``/``clock`` are injectable for tests.
    """
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    start = clock()
    last: Optional[BaseException] = None
    for attempt in range(attempts):
        try:
            return fn()
        except retry_on as exc:
            last = exc
            if attempt == attempts - 1:
                break
            delay = min(base_delay * (backoff ** attempt), max_delay)
            if timeout is not None and (clock() - start) + delay > timeout:
                break
            sleep(delay)
    if raise_last:
        raise last
    name = describe or getattr(fn, "__name__", "operation")
    raise RetryError(
        f"{name} failed after {attempt + 1} attempt(s): {last!r}",
        last) from last
