"""Chaos plane: deterministic fault injection + graceful degradation.

The paper's headline claim is *robust* decentralized diagnostics — node
dropout tolerance is the core advantage P2P sync has over a coordinator.
This package makes failures first-class and injectable:

  * :mod:`repro.faults.plan`    — seeded, declarative :class:`FaultPlan`
    (crash / straggle / drop / corrupt / preempt events) lowered to
    per-round membership masks and in-graph corruption signals;
  * :mod:`repro.faults.signals` — :class:`FaultSignals`, the pytree the
    compiled round consumes, and the deterministic bit-flip injector for
    the quantized wire;
  * :mod:`repro.faults.runner`  — drives a `SwarmSession` through a plan
    (active-mask updates, EF quarantine on rejoin, preempt + restore)
    without ever leaving the compiled round's trace;
  * :mod:`repro.faults.oracle`  — the fault-free / faulted numpy reference
    the parity tests compare committed params against;
  * :mod:`repro.faults.retry`   — bounded retry/backoff/timeout for
    host-side I/O (the single sanctioned home for retry loops —
    swarmlint SWL007).

See docs/faults.md for the plan grammar and the degradation policies.
"""
from repro.faults.plan import FaultEvent, FaultPlan, LoweredPlan
from repro.faults.retry import RetryError, with_retry
from repro.faults.runner import run_plan
from repro.faults.signals import FaultSignals, flip_payload_bits, idle_signals

__all__ = [
    "FaultEvent", "FaultPlan", "LoweredPlan", "FaultSignals",
    "flip_payload_bits", "idle_signals", "RetryError", "with_retry",
    "run_plan",
]
