"""In-graph fault signals for the compiled round.

:class:`FaultSignals` is the pytree `SwarmEngine.sync` consumes to inject
wire corruption *inside* the compiled program: both fields are runtime
data, so arming / disarming corruption between rounds never retraces —
the runner threads a (possibly all-False) signal every round and only the
array values change.

:func:`flip_payload_bits` is the deterministic corruptor: for every node
flagged in ``corrupt`` it XORs bit ``bit`` (a mid-mantissa f32 bit — a
~2⁻³ relative perturbation that stays finite, never NaN/Inf) into a
seeded pseudo-random ~``rate`` subset of the node's payload elements,
plus always the first element of every leaf so at least one bit flips
regardless of payload size. The per-payload checksum
(`repro.core.comms.payload_checksum`) must detect the flip and the sync
must quarantine the sender (reject-and-keep-local) — see docs/faults.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass
class FaultSignals:
    """Per-round corruption directive, as data.

    ``corrupt``: [N] bool — nodes whose *outgoing* wire payload arrives
    bit-flipped this round. ``key``: a (legacy uint32[2]) PRNG key fixing
    the flip pattern; derive it per round with :func:`plan_key` so a
    seeded plan replays bit-identically.
    """

    corrupt: Any
    key: Any


jax.tree_util.register_dataclass(
    FaultSignals, data_fields=["corrupt", "key"], meta_fields=[])


def plan_key(seed: int, round_index: int):
    """Deterministic per-round key: (plan seed, round) as raw key data."""
    return jnp.asarray([seed & 0xFFFFFFFF, round_index & 0xFFFFFFFF],
                       jnp.uint32)


def idle_signals(n_nodes: int) -> FaultSignals:
    """The no-fault signal (same pytree structure as an armed one, so a
    fault-free round through the faulted entry point shares its trace)."""
    return FaultSignals(corrupt=jnp.zeros((n_nodes,), bool),
                        key=jnp.zeros((2,), jnp.uint32))


def signals_for_round(plan, lowered, round_index: int) -> FaultSignals:
    """The round's :class:`FaultSignals` from a lowered plan."""
    return FaultSignals(
        corrupt=jnp.asarray(lowered.corrupt[round_index]),
        key=plan_key(plan.seed, round_index))


def flip_payload_bits(payload, corrupt, key, *, bit: int = 20,
                      rate: float = 1.0 / 16):
    """Deterministically bit-flip the payload rows of ``corrupt`` nodes.

    ``payload``: stacked pytree, leaves [N, ...] (None leaves pass
    through). Rows of nodes with ``corrupt[i] == False`` are returned
    bit-identical. Traceable; the flip pattern depends only on
    ``(key, leaf index, leaf shape)``.
    """
    cb = jnp.asarray(corrupt).astype(bool)
    leaves, treedef = jax.tree_util.tree_flatten(
        payload, is_leaf=lambda v: v is None)
    out = []
    for i, x in enumerate(leaves):
        if x is None:
            out.append(None)
            continue
        xf = jnp.asarray(x, jnp.float32)
        n = xf.shape[0]
        u = jax.lax.bitcast_convert_type(xf, jnp.uint32).reshape(n, -1)
        flips = jax.random.bernoulli(jax.random.fold_in(key, i), rate,
                                     u.shape)
        flips = flips.at[:, 0].set(True)   # ≥1 guaranteed flip per node row
        hit = (flips & cb[:, None]).astype(jnp.uint32) << bit
        out.append(jax.lax.bitcast_convert_type(
            (u ^ hit).reshape(xf.shape), jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, out)
