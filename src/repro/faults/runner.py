"""Drive a `SwarmSession` through a :class:`~repro.faults.plan.FaultPlan`.

The runner is the host-side choreography and nothing more: every fault
lands as *data* the session's compiled round already consumes —

  * membership windows (crash / straggle / drop) become
    ``session.set_active`` updates between rounds (the zero-retrace
    join/leave path);
  * in-graph corruption becomes a :class:`FaultSignals` pytree threaded
    through ``session.round(batches, val, faults=...)`` — armed on the
    engine backend's quantized wire, lowered to drops elsewhere;
  * a rejoin triggers the EF quarantine (``session.quarantine_wire``) so
    a returning node's stale wire reference cannot poison the telescoping
    residual;
  * a preempt checkpoint-cycles the whole session (save → fresh session
    via ``make_session`` → restore), which must be bit-identical to the
    uninterrupted run.

On the engine backend with a quantized wire the runner threads a
(possibly idle) ``FaultSignals`` every round so the round's trace
structure is constant — a whole plan replays against ONE compiled round.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.faults.plan import FaultPlan
from repro.faults.signals import idle_signals, signals_for_round


def _supports_in_graph_corrupt(session) -> bool:
    return (session.backend == "engine"
            and getattr(session, "_state", None) is not None
            and session._state.wire is not None)


def run_plan(session, plan: FaultPlan, batches, val, *,
             make_session: Optional[Callable[[], Any]] = None,
             checkpoint_path: Optional[str] = None,
             on_round: Optional[Callable[[int, dict], None]] = None
             ) -> Tuple[Any, List[Dict[str, Any]]]:
    """Replay ``plan`` against ``session``, one ``session.round`` per plan
    round. Returns ``(session, logs)`` — the session object can change
    identity across a preempt event, so callers must keep the returned
    one.

    ``batches`` is either a fixed per-round batch pytree (reused every
    round) or a callable ``round_index -> batches``. ``make_session`` /
    ``checkpoint_path`` are required iff the plan contains preempt events.
    ``on_round(r, log)`` is an optional per-round observer hook.
    """
    if plan.n_nodes != session.cfg.n_nodes:
        raise ValueError(f"plan is for {plan.n_nodes} nodes, session has "
                         f"{session.cfg.n_nodes}")
    in_graph = _supports_in_graph_corrupt(session)
    lowered = plan.lower(corrupt_in_graph=in_graph)
    has_preempt = bool(lowered.preempt.any())
    if has_preempt and (make_session is None or checkpoint_path is None):
        raise ValueError("plan contains preempt events: run_plan needs "
                         "make_session= and checkpoint_path=")
    logs: List[Dict[str, Any]] = []
    for r in range(plan.n_rounds):
        if lowered.preempt[r]:
            session.save(checkpoint_path)
            session = make_session()
            session.load(checkpoint_path)
        mask = lowered.active[r]
        prev = session.active
        if not np.array_equal(prev, mask):
            session.set_active(mask)
        for node in np.flatnonzero(mask & ~prev):
            # EF quarantine before the rejoined node's first sync
            session.quarantine_wire(int(node))
        faults = None
        if in_graph:
            faults = (signals_for_round(plan, lowered, r)
                      if lowered.corrupt[r].any()
                      else idle_signals(plan.n_nodes))
        round_batches = batches(r) if callable(batches) else batches
        out = session.round(round_batches, val, faults=faults)
        log = {"round": r, "active": mask.copy(),
               "preempted": bool(lowered.preempt[r]),
               "corrupt": lowered.corrupt[r].copy(),
               "gates": np.asarray(out["gates"]).astype(bool)}
        for key in ("wire_ok", "quorum_ok"):
            if key in out:
                log[key] = np.asarray(out[key])
        logs.append(log)
        if on_round is not None:
            on_round(r, log)
    return session, logs
