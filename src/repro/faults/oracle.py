"""Numpy oracle for fault-plan parity tests.

Replicates, in float64 numpy, the committed-params trajectory of the
compiled round for the canonical toy dynamics the fault tests drive:
single-leaf ``[N, D]`` params, per-node linear pull toward fixed targets
(``x ← x + lr·(t − x)``), Δθ² EMA importance accumulation, and the exact
merge formulas of `repro.core.merge_impl` / `repro.core.engine` under a
per-round membership mask:

  * mean/fedavg: the membership-masked mixing matrix
    (`topology.dynamic_matrix` over the normalized-weight base — the
    numpy twin of ``mixing_matrix_traced``);
  * fisher/gradmatch: mask-then-normalize Fisher mass
    (``finalize_mass``), the eps-floored ratio merge (`fisher_merge` /
    `gradmatch_merge` on full topology, `topo_weighted_merge` rows on
    ring/dynamic);
  * gating: an always-accepting eval (threshold 0) masked by membership,
    optionally held closed by the ``quorum`` policy;
  * corrupt quarantine: a checksum-rejected sender is excluded from the
    sync exactly like an absent node for that one round.

The oracle is exact f32-free math: engine parity holds to ~1e-6 on the
uncompressed wire and to the settled ≤1e-5 bound on the quantized (EF)
wire once the telescoping residual has converged (see docs/faults.md).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import repro.core.topology as topo


def mixing_matrix(topology: str, active, *, weights=None,
                  self_weight: float = 0.5) -> np.ndarray:
    """Numpy twin of ``topology.mixing_matrix_traced``: normalized-weight
    base matrix, then membership masking + row renormalization."""
    a = np.asarray(active, bool)
    n = a.shape[0]
    if topology in ("full", "dynamic"):
        if weights is None:
            w = np.full(n, 1.0 / n)
        else:
            w = np.asarray(weights, np.float64)
            w = w / max(w.sum(), 1e-30)
        base = np.tile(w[None, :], (n, 1))
    elif topology == "ring":
        base = topo.ring_matrix(n, self_weight)
    else:
        raise ValueError(f"unknown topology {topology!r}")
    return topo.dynamic_matrix(base, a)


def active_weights(data_sizes, active) -> np.ndarray:
    """Numpy twin of ``engine.active_weights_traced``."""
    w = np.asarray(data_sizes, np.float64) * np.asarray(active, np.float64)
    s = w.sum()
    if s <= 0:
        return np.full(len(w), 1.0 / len(w))
    return w / s


def finalize_mass(stats: np.ndarray, active) -> np.ndarray:
    """Mask-then-normalize (strategy ``finalize_mass``): zero departed
    nodes' mass, then scale the whole stack to a global mean of 1."""
    a = np.asarray(active, np.float64)
    masked = np.asarray(stats, np.float64) * a[:, None]
    mean = masked.sum() / masked.size
    scale = 1.0 / max(mean, 1e-30) if mean > 0 else 1.0
    return masked * scale


def merge_candidate(x: np.ndarray, active, *, merge: str, topology: str,
                    stats: Optional[np.ndarray] = None, data_sizes=None,
                    self_weight: float = 0.5, eps: float = 1e-8) -> np.ndarray:
    """The round's merge candidate for every node ([N, D] -> [N, D])."""
    x = np.asarray(x, np.float64)
    a = np.asarray(active, bool)
    n = x.shape[0]
    sizes = (np.ones(n) if data_sizes is None
             else np.asarray(data_sizes, np.float64))
    if merge in ("mean", "fedavg"):
        W = mixing_matrix(topology, a,
                          weights=sizes if merge == "fedavg" else None,
                          self_weight=self_weight)
        return W @ x
    if merge not in ("fisher", "gradmatch"):
        raise ValueError(f"unknown merge {merge!r}")
    mass = finalize_mass(np.zeros_like(x) if stats is None else stats, a)
    w = active_weights(sizes, a)
    ff = mass + eps
    if topology in ("ring", "dynamic"):
        # topology-restricted ratio over graph-neighbour rows
        W = mixing_matrix(topology, a, weights=None, self_weight=self_weight)
        rows = W if merge == "fisher" else W * w[None, :]
        num = rows @ (ff * x)
        den = rows @ ff
        return num / np.maximum(den, 1e-30)
    if merge == "fisher":
        merged = (ff * x).sum(0) / ff.sum(0)
        return np.broadcast_to(merged, x.shape).copy()
    # gradmatch, full topology: θ̄ + Σ w(F/F̄ − 1)(θ − θ̄)
    wb = w[:, None]
    mean = (wb * x).sum(0)
    fbar = (wb * ff).sum(0)
    corr = (wb * (ff / fbar - 1.0) * (x - mean)).sum(0)
    return np.broadcast_to(mean + corr, x.shape).copy()


def commit(x: np.ndarray, cand: np.ndarray, active, *,
           quorum: int = 0) -> np.ndarray:
    """Gated commit with an always-accepting eval: active nodes take the
    candidate unless the quorum policy holds the whole round's locals."""
    a = np.asarray(active, bool)
    gates = a.copy()
    if quorum > 0 and int(a.sum()) < quorum:
        gates[:] = False
    return np.where(gates[:, None], cand, np.asarray(x, np.float64))


def simulate(x0: np.ndarray, targets: np.ndarray, active_rounds: np.ndarray,
             *, merge: str, topology: str, lr: float = 0.0,
             steps_per_round: int = 0, data_sizes=None,
             self_weight: float = 0.5, fisher_decay: float = 0.95,
             eps: float = 1e-8, quorum: int = 0,
             corrupt_rounds: Optional[np.ndarray] = None) -> np.ndarray:
    """Full faulted trajectory: per round, ``steps_per_round`` linear local
    steps (Δθ² EMA accumulation), then the masked gated sync. Returns the
    committed params after every round, ``[R, N, D]``. ``corrupt_rounds``
    rows are quarantined senders — excluded from the sync like absences."""
    x = np.array(x0, np.float64)
    t = np.asarray(targets, np.float64)
    st = np.zeros_like(x)
    out = []
    for r in range(active_rounds.shape[0]):
        for _ in range(steps_per_round):
            d = lr * (t - x)
            st = fisher_decay * st + d * d
            x = x + d
        a = active_rounds[r].astype(bool).copy()
        if corrupt_rounds is not None:
            a &= ~corrupt_rounds[r].astype(bool)
        cand = merge_candidate(x, a, merge=merge, topology=topology,
                               stats=st if merge in ("fisher", "gradmatch")
                               else None,
                               data_sizes=data_sizes,
                               self_weight=self_weight, eps=eps)
        x = commit(x, cand, a, quorum=quorum)
        out.append(x.copy())
    return np.stack(out)
