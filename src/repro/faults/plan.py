"""Seeded, declarative fault plans.

A :class:`FaultPlan` is an immutable schedule of fault events against an
``n_nodes``-node swarm over ``n_rounds`` sync rounds. Events are appended
with the builder methods (each returns a NEW plan, so plans compose like
configs) and validated eagerly:

    plan = (FaultPlan(n_nodes=4, n_rounds=10, seed=0)
            .crash(1, at=2, rejoin=5)      # out for rounds [2, 5)
            .straggle(2, at=3, rounds=2)   # misses syncs 3 and 4
            .drop(3, at=6)                 # one dropped sync payload
            .corrupt(0, at=7)              # bit-flipped wire payload
            .preempt(at=8))                # save + rebuild + restore

``lower()`` compiles the event list into dense per-round directives
(:class:`LoweredPlan`) the runner replays against a live session. Every
fault kind lowers to **data the compiled round already consumes**:

  crash / straggle / drop
      windows of the ``[R, N]`` active mask — the masked merges (fedavg
      active-weight renormalization, zeroed Fisher mass, the traced
      mixing-matrix rebuild) absorb them with zero retraces.
  corrupt
      a ``[R, N]`` boolean feeding the in-graph bit-flip injector on the
      quantized engine wire (`repro.faults.signals`). When the target
      backend has no in-graph corruption path (gossip mesh schedules, or
      an uncompressed f32 wire), ``lower(corrupt_in_graph=False)`` folds
      the event into the active mask instead — the post-detection
      degraded behavior (reject-and-keep-local) without the detection.
  preempt
      a ``[R]`` boolean: before that round the runner checkpoints the
      session, constructs a fresh one, and restores — proving wire/EF
      state round-trips mid-plan (bit-identical to uninterrupted).

Determinism: the plan's ``seed`` keys every random choice downstream
(bit-flip patterns), so a (plan, session) pair replays identically.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

FAULT_KINDS = ("crash", "straggle", "drop", "corrupt", "preempt")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``until`` is the exclusive end round for
    windowed kinds (crash rejoin round / straggle end); None for a crash
    means the node never returns."""

    kind: str
    node: int = -1           # -1 for node-less events (preempt)
    round: int = 0           # first round the fault is visible
    until: Optional[int] = None


@dataclass(frozen=True)
class LoweredPlan:
    """Dense per-round directives (all numpy, host-side)."""

    active: np.ndarray       # [R, N] bool — sync membership per round
    corrupt: np.ndarray      # [R, N] bool — in-graph wire corruption
    rejoin: np.ndarray       # [R, N] bool — node returns at this round
    preempt: np.ndarray      # [R] bool — save+rebuild+restore BEFORE round


@dataclass(frozen=True)
class FaultPlan:
    n_nodes: int
    n_rounds: int
    seed: int = 0
    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        if self.n_nodes < 1 or self.n_rounds < 1:
            raise ValueError("FaultPlan needs n_nodes >= 1 and n_rounds >= 1")
        for event in self.events:   # directly-constructed plans validate too
            self._validate(event)

    # -- builders (each returns a new, validated plan) -----------------------

    def _validate(self, event: FaultEvent) -> None:
        if event.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {event.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if event.kind != "preempt" and not 0 <= event.node < self.n_nodes:
            raise ValueError(
                f"{event.kind}: node {event.node} out of range "
                f"[0, {self.n_nodes})")
        if not 0 <= event.round < self.n_rounds:
            raise ValueError(
                f"{event.kind}: round {event.round} out of range "
                f"[0, {self.n_rounds})")
        if event.until is not None and event.until <= event.round:
            raise ValueError(
                f"{event.kind}: until={event.until} must be > "
                f"round={event.round}")

    def _add(self, event: FaultEvent) -> "FaultPlan":
        self._validate(event)
        return dataclasses.replace(self, events=self.events + (event,))

    def crash(self, node: int, *, at: int,
              rejoin: Optional[int] = None) -> "FaultPlan":
        """Node dies before round ``at``; back at ``rejoin`` (None: never)."""
        return self._add(FaultEvent("crash", node, at, rejoin))

    def straggle(self, node: int, *, at: int, rounds: int = 1) -> "FaultPlan":
        """Node falls ``rounds`` sync rounds behind: it keeps training on
        whatever batches the caller feeds it but its updates miss the sync
        window, so it is excluded from merges for rounds [at, at+rounds)."""
        if rounds < 1:
            raise ValueError(f"straggle: rounds must be >= 1, got {rounds}")
        return self._add(FaultEvent("straggle", node, at, at + rounds))

    def drop(self, node: int, *, at: int) -> "FaultPlan":
        """Node's sync payload is lost for exactly one round."""
        return self._add(FaultEvent("drop", node, at))

    def corrupt(self, node: int, *, at: int) -> "FaultPlan":
        """Node's wire payload arrives bit-flipped at round ``at`` — the
        per-payload checksum must detect it and quarantine the sender."""
        return self._add(FaultEvent("corrupt", node, at))

    def preempt(self, *, at: int) -> "FaultPlan":
        """Kill-and-restore the whole session before round ``at`` via
        checkpoint round-trip (preemption mid-run)."""
        return self._add(FaultEvent("preempt", -1, at))

    # -- lowering ------------------------------------------------------------

    def lower(self, corrupt_in_graph: bool = True) -> LoweredPlan:
        """Compile events to per-round directives. With
        ``corrupt_in_graph=False`` corrupt events degrade to one-round
        drops (membership mask) instead of in-graph bit flips."""
        r, n = self.n_rounds, self.n_nodes
        active = np.ones((r, n), bool)
        corrupt = np.zeros((r, n), bool)
        preempt = np.zeros((r,), bool)
        for ev in self.events:
            if ev.kind == "preempt":
                preempt[ev.round] = True
            elif ev.kind == "corrupt":
                if corrupt_in_graph:
                    corrupt[ev.round, ev.node] = True
                else:
                    active[ev.round, ev.node] = False
            elif ev.kind == "drop":
                active[ev.round, ev.node] = False
            else:  # crash / straggle: a [round, until) absence window
                end = r if ev.until is None else min(ev.until, r)
                active[ev.round:end, ev.node] = False
        prev = np.vstack([np.ones((1, n), bool), active[:-1]])
        rejoin = active & ~prev
        return LoweredPlan(active=active, corrupt=corrupt, rejoin=rejoin,
                           preempt=preempt)
