"""Early stopping with patience (paper: patience of five epochs)."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class EarlyStopper:
    patience: int = 5
    mode: str = "max"  # max: metric is accuracy/AUC; min: loss
    best: float = field(default=None)  # type: ignore
    bad_rounds: int = 0
    stopped: bool = False

    def update(self, metric: float) -> bool:
        """Returns True if training should stop."""
        better = (self.best is None
                  or (metric > self.best if self.mode == "max" else metric < self.best))
        if better:
            self.best, self.bad_rounds = float(metric), 0
        else:
            self.bad_rounds += 1
            if self.bad_rounds >= self.patience:
                self.stopped = True
        return self.stopped
