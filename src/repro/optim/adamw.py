"""AdamW with decoupled weight decay + global-norm clipping (paper §4.1).

Functional, pytree-generic, no optax dependency (offline container). The
moment states mirror the param tree so the sharding rules apply unchanged
(ZeRO-style: moments inherit the param PartitionSpec).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.float32(0.0)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(params, grads, state, cfg: TrainConfig, lr):
    """Returns (new_params, new_state). `lr` may be a traced scalar."""
    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mhat = mu / bc1
        vhat = nu / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}
