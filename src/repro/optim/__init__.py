from repro.optim.adamw import adamw_init, adamw_update, global_norm, clip_by_global_norm  # noqa: F401
from repro.optim.schedules import make_schedule  # noqa: F401
from repro.optim.early_stop import EarlyStopper  # noqa: F401
