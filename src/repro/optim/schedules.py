"""LR schedules: cosine annealing (paper §4.1) and WSD (MiniCPM's signature
Warmup-Stable-Decay, arXiv:2404.06395 — required by the minicpm-2b config)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def cosine_schedule(step, base_lr, warmup, total, min_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def wsd_schedule(step, base_lr, warmup, total, decay_frac=0.1, min_frac=0.1):
    """Warmup → stable plateau → sharp final decay (last `decay_frac` steps)."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = total * (1.0 - decay_frac)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
    decay = base_lr * (1.0 - (1.0 - min_frac) * prog)
    lr = jnp.where(step < warmup, warm, jnp.where(step < decay_start, base_lr, decay))
    return lr


def make_schedule(cfg: TrainConfig):
    if cfg.schedule == "cosine":
        return lambda step: cosine_schedule(step, cfg.lr, cfg.warmup_steps, cfg.max_steps)
    if cfg.schedule == "wsd":
        return lambda step: wsd_schedule(step, cfg.lr, cfg.warmup_steps, cfg.max_steps)
    if cfg.schedule == "const":
        return lambda step: jnp.full((), cfg.lr, jnp.float32)
    raise ValueError(f"unknown schedule {cfg.schedule!r}")
