"""SPMD gossip: the paper's P2P exchange realized as TPU mesh collectives.

Two schedules, both operating on stacked pytrees whose leading node axis is
sharded over a mesh axis (the swarm axis — `node` single-pod, `pod` multi-pod):

  * ``fedavg_gossip``   — dense merge: one weighted ``psum`` over the swarm
    axis (every node ends with the same weighted average). Collective bytes
    per sync: ~2·P per link direction (reduce-scatter + all-gather lowering).
  * ``ring_gossip``     — sparse P2P merge: two ``ppermute`` shifts; each node
    mixes with its ring neighbours only. Collective bytes per sync: 2·P
    point-to-point, no global reduction — the TPU-native analogue of the
    paper's pairwise peer exchange, and the beyond-paper §Perf winner.
  * ``matrix_gossip``   — arbitrary (possibly dynamic-membership) mixing
    matrix via all_gather + local contraction; the faithful general form.

All three return a stacked pytree of the same structure. `None` leaves (the
non-payload part when lora_only sync is active) pass through untouched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax>=0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _mapped(fn, mesh, axis, stacked, *extra, inner_specs=None):
    """shard_map fn over the swarm axis, skipping None leaves.

    inner_specs: optional pytree of PartitionSpecs for the NON-node dims of
    each leaf. Without it the shard_map boundary implies replication on the
    other mesh axes, which forces a full all-gather of (data, model)-sharded
    params before every gossip round (measured: 12.6 GB/device of spurious
    all-gather on minicpm-2b). With it, gossip exchanges only local shards.
    """
    nones = lambda x: x is None

    def leaf_fn(x, spec):
        if x is None:
            return None
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        out = shard_map(fn, mesh,
                        in_specs=(in_spec,) + tuple(P() for _ in extra),
                        out_specs=in_spec)(x, *extra)
        return out

    if inner_specs is None:
        inner_specs = jax.tree.map(lambda x: None, stacked, is_leaf=nones)
    return jax.tree.map(leaf_fn, stacked, inner_specs, is_leaf=nones)


def fedavg_gossip(stacked, weights, mesh, axis: str, inner_specs=None):
    """Weighted global merge: θ_i ← Σ_j w_j θ_j for every node i."""
    n = mesh.shape[axis]

    def f(x, w):  # x: [N/n_shards, ...] local shard; w: [N]
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        wl = jax.lax.dynamic_slice_in_dim(w, idx * per, per, 0)
        contrib = x.astype(jnp.float32) * wl.reshape((per,) + (1,) * (x.ndim - 1))
        merged = jax.lax.psum(contrib.sum(0), axis)
        return jnp.broadcast_to(merged, x.shape).astype(x.dtype)

    w = jnp.asarray(weights, jnp.float32)
    assert w.shape == (n,) or w.size % n == 0
    return _mapped(f, mesh, axis, stacked, w, inner_specs=inner_specs)


def ring_gossip(stacked, mesh, axis: str, self_weight: float = 0.5,
                inner_specs=None):
    """Sparse P2P: θ_i ← s·θ_i + (1-s)/2·(θ_{i-1} + θ_{i+1})."""
    n = mesh.shape[axis]
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]

    def f(x):
        # wire dtype = param dtype (bf16): halves link bytes vs f32;
        # the mixing arithmetic still accumulates in f32
        left = jax.lax.ppermute(x, axis, fwd).astype(jnp.float32)
        right = jax.lax.ppermute(x, axis, bwd).astype(jnp.float32)
        side = (1.0 - self_weight) / 2.0
        return (self_weight * x.astype(jnp.float32)
                + side * (left + right)).astype(x.dtype)

    return _mapped(f, mesh, axis, stacked, inner_specs=inner_specs)


def fisher_gossip(stacked, fishers, mesh, axis: str, inner_specs=None,
                  eps: float = 1e-8):
    """Diagonal-Fisher-weighted merge over the swarm axis:
    θ* = Σ_i F_i⊙θ_i / Σ_i F_i  (two psums), broadcast to every node.

    The SPMD realization of `merge_impl.fisher_merge` — the principled
    aggregation the paper cites ([6]) but never builds.
    """
    def f(x, fsh):
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        num = jax.lax.psum((ff * xf).sum(0), axis)
        den = jax.lax.psum(ff.sum(0), axis)
        return jnp.broadcast_to(num / den, x.shape).astype(x.dtype)

    nones = lambda v: v is None

    def leaf_fn(x, fsh, spec):
        if x is None:
            return None
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        return shard_map(f, mesh, in_specs=(in_spec, in_spec),
                         out_specs=in_spec)(x, fsh)

    if inner_specs is None:
        inner_specs = jax.tree.map(lambda v: None, stacked, is_leaf=nones)
    return jax.tree.map(leaf_fn, stacked, fishers, inner_specs, is_leaf=nones)


def topo_fisher_gossip(stacked, fishers, rows, mesh, axis: str,
                       inner_specs=None, eps: float = 1e-8):
    """Topology-restricted importance-weighted merge over the swarm axis:

        θ*_i = Σ_j rows[i,j]·(F_j+eps)⊙θ_j / Σ_j rows[i,j]·(F_j+eps)

    The SPMD realization of `merge_impl.topo_weighted_merge` — ring/dynamic
    swarms merge only graph-neighbour contributions. Lowering: all_gather of
    the importance-weighted numerator and the mass, then a local per-row
    contraction (two `matrix_gossip` passes share the mixing machinery)."""
    nones = lambda v: v is None

    def wnum(x, f):
        if x is None:
            return None
        return (f.astype(jnp.float32) + eps) * x.astype(jnp.float32)

    def wden(x, f):
        if x is None:
            return None
        return jnp.broadcast_to(f.astype(jnp.float32) + eps, x.shape)

    num = matrix_gossip(jax.tree.map(wnum, stacked, fishers, is_leaf=nones),
                        rows, mesh, axis, inner_specs=inner_specs)
    den = matrix_gossip(jax.tree.map(wden, stacked, fishers, is_leaf=nones),
                        rows, mesh, axis, inner_specs=inner_specs)

    def ratio(x, n, d):
        if x is None:
            return None
        return (n / jnp.maximum(d, 1e-30)).astype(x.dtype)

    return jax.tree.map(ratio, stacked, num, den, is_leaf=nones)


def matrix_gossip(stacked, W, mesh, axis: str, inner_specs=None):
    """General mixing matrix (dynamic membership): all_gather + local row mix."""
    n = mesh.shape[axis]

    def f(x, Wm):  # x: [per, ...]; Wm: [N, N]
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        allx = jax.lax.all_gather(x.astype(jnp.float32), axis, tiled=True)  # [N, ...]
        rows = jax.lax.dynamic_slice_in_dim(Wm, idx * per, per, 0)          # [per, N]
        flat = allx.reshape(allx.shape[0], -1)
        out = rows @ flat
        return out.reshape((per,) + x.shape[1:]).astype(x.dtype)

    Wj = jnp.asarray(W, jnp.float32)
    assert Wj.shape[0] == Wj.shape[1]
    return _mapped(f, mesh, axis, stacked, Wj, inner_specs=inner_specs)
