"""SPMD gossip: the paper's P2P exchange realized as TPU mesh collectives.

Two schedules, both operating on stacked pytrees whose leading node axis is
sharded over a mesh axis (the swarm axis — `node` single-pod, `pod` multi-pod):

  * ``fedavg_gossip``   — dense merge: one weighted ``psum`` over the swarm
    axis (every node ends with the same weighted average). Collective bytes
    per sync: ~2·P per link direction (reduce-scatter + all-gather lowering).
  * ``ring_gossip``     — sparse P2P merge: two ``ppermute`` shifts; each node
    mixes with its ring neighbours only. Collective bytes per sync: 2·P
    point-to-point, no global reduction — the TPU-native analogue of the
    paper's pairwise peer exchange, and the beyond-paper §Perf winner.
  * ``matrix_gossip``   — arbitrary (possibly dynamic-membership) mixing
    matrix via all_gather + local contraction; the faithful general form.
  * ``ring_rows_gossip`` / ``ring_topo_fisher_gossip`` — ring-native
    schedules: two ``ppermute`` shifts honouring (possibly traced) ring
    mixing rows, 2·P / 4·P point-to-point values per sync instead of the
    gathered forms' N·P / 2·N·P. ``topo_fisher_gossip`` is the general-rows
    fallback — ONE all_gather of the fused ``(F⊙θ ⊕ F)`` stack.

Which schedule a given config lowers to is decided by the `core.comms` cost
model (`comms.pick_schedule`); ``wire_dtype`` compresses point-to-point
payloads (bf16 on the mesh; int8 error-feedback lives on the engine backend).

All schedules return a stacked pytree of the same structure. `None` leaves
(the non-payload part when lora_only sync is active) pass through untouched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax>=0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _mapped(fn, mesh, axis, stacked, *extra, inner_specs=None):
    """shard_map fn over the swarm axis, skipping None leaves.

    inner_specs: optional pytree of PartitionSpecs for the NON-node dims of
    each leaf. Without it the shard_map boundary implies replication on the
    other mesh axes, which forces a full all-gather of (data, model)-sharded
    params before every gossip round (measured: 12.6 GB/device of spurious
    all-gather on minicpm-2b). With it, gossip exchanges only local shards.
    """
    nones = lambda x: x is None

    def leaf_fn(x, spec):
        if x is None:
            return None
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        out = shard_map(fn, mesh,
                        in_specs=(in_spec,) + tuple(P() for _ in extra),
                        out_specs=in_spec)(x, *extra)
        return out

    if inner_specs is None:
        inner_specs = jax.tree.map(lambda x: None, stacked, is_leaf=nones)
    return jax.tree.map(leaf_fn, stacked, inner_specs, is_leaf=nones)


def _wire_cast(z, wire_dtype):
    """Cast a payload for the wire (point-to-point collectives only).

    bf16 halves link bytes; accumulation stays f32 after decode. int8 needs
    the engine backend's error-feedback state (`core.comms`) — a stateless
    int8 mesh wire would silently drop mass, so it is refused here.
    """
    if wire_dtype in (None, "f32"):
        return z
    if wire_dtype == "bf16":
        return z.astype(jnp.bfloat16)
    raise ValueError(f"wire_dtype {wire_dtype!r} is not supported on the "
                     "mesh gossip path (int8 needs error-feedback state; "
                     "use the engine backend)")


def fedavg_gossip(stacked, weights, mesh, axis: str, inner_specs=None):
    """Weighted global merge: θ_i ← Σ_j w_j θ_j for every node i."""
    n = mesh.shape[axis]

    def f(x, w):  # x: [N/n_shards, ...] local shard; w: [N]
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        wl = jax.lax.dynamic_slice_in_dim(w, idx * per, per, 0)
        contrib = x.astype(jnp.float32) * wl.reshape((per,) + (1,) * (x.ndim - 1))
        merged = jax.lax.psum(contrib.sum(0), axis)
        return jnp.broadcast_to(merged, x.shape).astype(x.dtype)

    w = jnp.asarray(weights, jnp.float32)
    assert w.shape == (n,) or w.size % n == 0
    return _mapped(f, mesh, axis, stacked, w, inner_specs=inner_specs)


def ring_gossip(stacked, mesh, axis: str, self_weight: float = 0.5,
                inner_specs=None):
    """Sparse P2P: θ_i ← s·θ_i + (1-s)/2·(θ_{i-1} + θ_{i+1})."""
    n = mesh.shape[axis]
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]

    def f(x):
        # wire dtype = param dtype (bf16): halves link bytes vs f32;
        # the mixing arithmetic still accumulates in f32
        left = jax.lax.ppermute(x, axis, fwd).astype(jnp.float32)
        right = jax.lax.ppermute(x, axis, bwd).astype(jnp.float32)
        side = (1.0 - self_weight) / 2.0
        return (self_weight * x.astype(jnp.float32)
                + side * (left + right)).astype(x.dtype)

    return _mapped(f, mesh, axis, stacked, inner_specs=inner_specs)


def fisher_gossip(stacked, fishers, mesh, axis: str, inner_specs=None,
                  eps: float = 1e-8):
    """Diagonal-Fisher-weighted merge over the swarm axis:
    θ* = Σ_i F_i⊙θ_i / Σ_i F_i  (two psums), broadcast to every node.

    The SPMD realization of `merge_impl.fisher_merge` — the principled
    aggregation the paper cites ([6]) but never builds.
    """
    def f(x, fsh):
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        num = jax.lax.psum((ff * xf).sum(0), axis)
        den = jax.lax.psum(ff.sum(0), axis)
        return jnp.broadcast_to(num / den, x.shape).astype(x.dtype)

    nones = lambda v: v is None

    def leaf_fn(x, fsh, spec):
        if x is None:
            return None
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        return shard_map(f, mesh, in_specs=(in_spec, in_spec),
                         out_specs=in_spec)(x, fsh)

    if inner_specs is None:
        inner_specs = jax.tree.map(lambda v: None, stacked, is_leaf=nones)
    return jax.tree.map(leaf_fn, stacked, fishers, inner_specs, is_leaf=nones)


def _fisher_pair_map(fn, mesh, axis, stacked, fishers, extra, inner_specs):
    """shard_map fn(x, fisher, *extra) leaf-wise over (params, mass) pairs;
    extras are replicated (P()); None leaves pass through."""
    nones = lambda v: v is None

    def leaf_fn(x, fsh, spec):
        if x is None:
            return None
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        return shard_map(fn, mesh,
                         in_specs=(in_spec, in_spec)
                         + tuple(P() for _ in extra),
                         out_specs=in_spec)(x, fsh, *extra)

    if inner_specs is None:
        inner_specs = jax.tree.map(lambda v: None, stacked, is_leaf=nones)
    return jax.tree.map(leaf_fn, stacked, fishers, inner_specs, is_leaf=nones)


def topo_fisher_gossip(stacked, fishers, rows, mesh, axis: str,
                       inner_specs=None, eps: float = 1e-8, wire_dtype=None):
    """Topology-restricted importance-weighted merge over the swarm axis:

        θ*_i = Σ_j rows[i,j]·(F_j+eps)⊙θ_j / Σ_j rows[i,j]·(F_j+eps)

    The SPMD realization of `merge_impl.topo_weighted_merge` — ring/dynamic
    swarms merge only graph-neighbour contributions. Lowering: the
    importance-weighted numerator and the mass are stacked into ONE
    ``(num ⊕ mass)`` payload and moved by a SINGLE ``all_gather`` per leaf
    (2·N·P values at the wire dtype), then contracted locally per row —
    the general-rows form; ring rows take the 4·P two-``ppermute`` schedule
    (:func:`ring_topo_fisher_gossip`) instead."""
    n = mesh.shape[axis]

    def f(x, fsh, Wm):  # x/fsh: [per, ...] local shard; Wm: [N, N]
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        z = jnp.concatenate([ff * xf, ff], axis=0)          # [2·per, ...]
        allz = jax.lax.all_gather(_wire_cast(z, wire_dtype), axis,
                                  tiled=True).astype(jnp.float32)
        pair = allz.reshape(n, 2, per, -1)                   # shard-major
        num_all = pair[:, 0].reshape(n * per, -1)            # [N, D]
        den_all = pair[:, 1].reshape(n * per, -1)
        r = jax.lax.dynamic_slice_in_dim(Wm, idx * per, per, 0)  # [per, N]
        num = r @ num_all
        den = r @ den_all
        out = num / jnp.maximum(den, 1e-30)
        return out.reshape((per,) + x.shape[1:]).astype(x.dtype)

    Wj = jnp.asarray(rows, jnp.float32)
    return _fisher_pair_map(f, mesh, axis, stacked, fishers, (Wj,),
                            inner_specs)


def _ring_perms(n: int):
    """(receive-from-left, receive-from-right) ppermute pairs."""
    fwd = [(i, (i + 1) % n) for i in range(n)]   # data flows i -> i+1
    bwd = [(i, (i - 1) % n) for i in range(n)]   # data flows i -> i-1
    return fwd, bwd


def _check_one_node_per_shard(stacked, mesh, axis, what: str):
    n = mesh.shape[axis]
    lead = jax.tree.leaves(stacked)[0].shape[0]
    if lead != n:
        raise ValueError(
            f"{what} needs one node per mesh shard (leading axis {lead} vs "
            f"mesh axis {axis}={n}); use the gathered fallback for per>1")
    if n < 3:
        raise ValueError(f"{what} needs N >= 3 (an N=2 ring folds both "
                         f"neighbour edges onto one peer); got N={n}")


def ring_rows_gossip(stacked, W, mesh, axis: str, inner_specs=None,
                     wire_dtype=None):
    """Ring-native mixing-row gossip (mean/fedavg on a ring):

        θ*_i = W[i,i]·θ_i + W[i,i−1]·θ_{i−1} + W[i,i+1]·θ_{i+1}

    Two ``ppermute`` shifts move 2·P point-to-point values per device — no
    global collective — while honouring a (possibly traced, membership-
    masked) ring mixing matrix, unlike :func:`ring_gossip`'s fixed
    self-weight. Only neighbour payloads are wire-cast; the self term stays
    exact local precision. Requires one node per shard and N ≥ 3."""
    _check_one_node_per_shard(stacked, mesh, axis, "ring_rows_gossip")
    n = mesh.shape[axis]
    fwd, bwd = _ring_perms(n)

    def f(x, Wm):  # x: [1, ...] this node's shard; Wm: [N, N]
        idx = jax.lax.axis_index(axis)
        z = _wire_cast(x, wire_dtype)
        left = jax.lax.ppermute(z, axis, fwd).astype(jnp.float32)
        right = jax.lax.ppermute(z, axis, bwd).astype(jnp.float32)
        w_self = Wm[idx, idx]
        w_left = Wm[idx, (idx - 1) % n]
        w_right = Wm[idx, (idx + 1) % n]
        out = (w_self * x.astype(jnp.float32) + w_left * left
               + w_right * right)
        return out.astype(x.dtype)

    return _mapped(f, mesh, axis, stacked, jnp.asarray(W, jnp.float32),
                   inner_specs=inner_specs)


def ring_topo_fisher_gossip(stacked, fishers, rows, mesh, axis: str,
                            inner_specs=None, eps: float = 1e-8,
                            wire_dtype=None):
    """Ring-native topology-restricted weighted merge — the wire-optimal
    form of :func:`topo_fisher_gossip` for ring mixing rows:

        θ*_i = Σ_{j∈{i−1,i,i+1}} rows[i,j]·(F_j+eps)⊙θ_j
             / Σ_{j∈{i−1,i,i+1}} rows[i,j]·(F_j+eps)

    Each node fuses its importance-weighted numerator and mass into one
    ``(F⊙θ ⊕ F)`` side-channel payload and ppermutes it to both ring
    neighbours: ~4·P point-to-point values per sync instead of the gathered
    form's 2·N·P. Self contributions never touch the wire (exact f32).
    Requires one node per shard and N ≥ 3 (ring rows only have the three
    per-row entries this schedule exchanges)."""
    _check_one_node_per_shard(stacked, mesh, axis, "ring_topo_fisher_gossip")
    n = mesh.shape[axis]
    fwd, bwd = _ring_perms(n)

    def f(x, fsh, Wm):  # x/fsh: [1, ...]; Wm: [N, N] ring-structured rows
        idx = jax.lax.axis_index(axis)
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        y = ff * xf                                   # numerator payload
        z = _wire_cast(jnp.concatenate([y, ff], axis=0), wire_dtype)  # [2,...]
        left = jax.lax.ppermute(z, axis, fwd).astype(jnp.float32)
        right = jax.lax.ppermute(z, axis, bwd).astype(jnp.float32)
        r_self = Wm[idx, idx]
        r_left = Wm[idx, (idx - 1) % n]
        r_right = Wm[idx, (idx + 1) % n]
        num = r_self * y + r_left * left[0:1] + r_right * right[0:1]
        den = r_self * ff + r_left * left[1:2] + r_right * right[1:2]
        return (num / jnp.maximum(den, 1e-30)).astype(x.dtype)

    Wj = jnp.asarray(rows, jnp.float32)
    return _fisher_pair_map(f, mesh, axis, stacked, fishers, (Wj,),
                            inner_specs)


def matrix_gossip(stacked, W, mesh, axis: str, inner_specs=None,
                  wire_dtype=None):
    """General mixing matrix (dynamic membership): all_gather + local row mix."""
    n = mesh.shape[axis]

    def f(x, Wm):  # x: [per, ...]; Wm: [N, N]
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        allx = jax.lax.all_gather(
            _wire_cast(x.astype(jnp.float32), wire_dtype), axis,
            tiled=True).astype(jnp.float32)                             # [N, ...]
        rows = jax.lax.dynamic_slice_in_dim(Wm, idx * per, per, 0)          # [per, N]
        flat = allx.reshape(allx.shape[0], -1)
        out = rows @ flat
        return out.reshape((per,) + x.shape[1:]).astype(x.dtype)

    Wj = jnp.asarray(W, jnp.float32)
    assert Wj.shape[0] == Wj.shape[1]
    return _mapped(f, mesh, axis, stacked, Wj, inner_specs=inner_specs)
