"""SPMD gossip: the paper's P2P exchange realized as TPU mesh collectives.

Two schedules, both operating on stacked pytrees whose leading node axis is
sharded over a mesh axis (the swarm axis — `node` single-pod, `pod` multi-pod):

  * ``fedavg_gossip``   — dense merge: one weighted ``psum`` over the swarm
    axis (every node ends with the same weighted average). Collective bytes
    per sync: ~2·P per link direction (reduce-scatter + all-gather lowering).
  * ``ring_gossip``     — sparse P2P merge: two ``ppermute`` shifts; each node
    mixes with its ring neighbours only. Collective bytes per sync: 2·P
    point-to-point, no global reduction — the TPU-native analogue of the
    paper's pairwise peer exchange, and the beyond-paper §Perf winner.
  * ``matrix_gossip``   — arbitrary (possibly dynamic-membership) mixing
    matrix via all_gather + local contraction; the faithful general form.
  * ``ring_rows_gossip`` / ``ring_topo_fisher_gossip`` — ring-native
    schedules: two ``ppermute`` shifts honouring (possibly traced) ring
    mixing rows, 2·P / 4·P point-to-point values per sync instead of the
    gathered forms' N·P / 2·N·P. ``topo_fisher_gossip`` is the general-rows
    fallback — ONE all_gather of the fused ``(F⊙θ ⊕ F)`` stack.

Which schedule a given config lowers to is decided by the `core.comms` cost
model (`comms.pick_schedule`); ``wire_dtype`` compresses point-to-point
payloads: bf16 is a stateless cast, and int8 rides the **mesh error-feedback
wire** — the ``*_q8`` schedule forms below carry a sharded EF reference
(per-shard residual pytree in the SPMD gossip state) so the collectives move
int8 payloads + per-block f32 scales instead of f32/bf16 values:

  * ``ring_rows_gossip_q8`` / ``ring_topo_fisher_gossip_q8`` — the ppermute
    schedules with int8 deltas against per-node references; each device also
    tracks its two ring neighbours' references (updated from the same delta
    stream the senders apply, so replicas never diverge).
  * ``matrix_gossip_q8`` / ``topo_fisher_gossip_q8`` — the gathered forms
    with ONE int8 all_gather of every node's delta; every device carries the
    full reconstruction table (replicated — all devices receive the same
    deltas, so the table stays bit-identical across the mesh).
  * ``fedavg_psum_q8`` / ``fisher_psum_q8`` — the psum family rebuilt as a
    compression-aware reduction: quantized-chunk reduce-scatter (all_to_all
    of int8 chunks + local dequant-and-sum at the chunk owner, with a
    second-stage EF residual per chunk) followed by a quantized all_gather
    of the reduced chunks into a replicated consensus accumulator.
  * ``hier_fedavg_ring_q8`` / ``hier_fisher_ring_q8`` — two-level
    ``("pod", "node")`` meshes: the flat schedules above also run over the
    joint axis tuple unchanged, but these keep the f32 bulk on intra-pod
    links — a weighted intra-pod psum reduce, then each device delegates a
    1/per_pod chunk of its pod's reduction onto a cross-pod int8 EF ring
    (per-pod residual + neighbour-pod replicas riding ``SwarmState.wire``),
    then an intra-pod all_gather broadcast. Cross-pod (DCN) traffic drops
    to k·P/per_pod int8 values per device (k = 1 at two pods, else 2).

All quantization goes through the shared `core.comms` quant core, so the
mesh wire can never diverge from the engine-backend EF contract. Every EF
residual telescopes: on settling inputs the reconstructions converge to the
exact f32 payloads and the merges to their uncompressed oracles.

All schedules return a stacked pytree of the same structure. `None` leaves
(the non-payload part when lora_only sync is active) pass through untouched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import comms

try:  # jax>=0.6
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, mesh, in_specs, out_specs, check_rep=True):
    # check_rep=False: the q8 schedules return replicated state (the
    # reconstruction table / consensus accumulator) that IS identical on
    # every device — each applies the same all_gathered deltas — but the
    # static replication checker can't see through the axis_index arithmetic
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check_rep is True:
        return _shard_map(f, **kw)
    try:
        return _shard_map(f, check_rep=False, **kw)
    except TypeError:  # pragma: no cover — kwarg renamed in newer jax
        return _shard_map(f, check_vma=False, **kw)


def axis_size(mesh, axis) -> int:
    """Total shard count along the swarm axis — a single mesh axis name or
    a tuple of names (two-level meshes gossip over the joint axis)."""
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def _pod_axes(axis):
    """The (pod, node) axis names of a two-level swarm axis."""
    if not (isinstance(axis, tuple) and len(axis) == 2):
        raise ValueError("hierarchical schedules need a two-level swarm axis "
                         f"(pod, node); got {axis!r}")
    return axis[0], axis[1]


def _mapped(fn, mesh, axis, stacked, *extra, inner_specs=None):
    """shard_map fn over the swarm axis, skipping None leaves.

    inner_specs: optional pytree of PartitionSpecs for the NON-node dims of
    each leaf. Without it the shard_map boundary implies replication on the
    other mesh axes, which forces a full all-gather of (data, model)-sharded
    params before every gossip round (measured: 12.6 GB/device of spurious
    all-gather on minicpm-2b). With it, gossip exchanges only local shards.
    """
    nones = lambda x: x is None

    def leaf_fn(x, spec):
        if x is None:
            return None
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        out = shard_map(fn, mesh,
                        in_specs=(in_spec,) + tuple(P() for _ in extra),
                        out_specs=in_spec)(x, *extra)
        return out

    if inner_specs is None:
        inner_specs = jax.tree.map(lambda x: None, stacked, is_leaf=nones)
    return jax.tree.map(leaf_fn, stacked, inner_specs, is_leaf=nones)


def _wire_cast(z, wire_dtype):
    """STATELESS cast of a payload for the wire (point-to-point collectives
    only). bf16 halves link bytes; accumulation stays f32 after decode.
    int8 is refused here because a stateless int8 wire would silently drop
    mass — it rides the ``*_q8`` error-feedback schedule forms below, which
    carry the sharded mesh EF state instead.
    """
    if wire_dtype in (None, "f32"):
        return z
    if wire_dtype == "bf16":
        return z.astype(jnp.bfloat16)
    raise ValueError(f"wire_dtype {wire_dtype!r} has no stateless mesh cast "
                     "(int8 needs error-feedback state — the *_q8 schedule "
                     "forms carry it)")


def fedavg_gossip(stacked, weights, mesh, axis: str, inner_specs=None):
    """Weighted global merge: θ_i ← Σ_j w_j θ_j for every node i."""
    n = axis_size(mesh, axis)

    def f(x, w):  # x: [N/n_shards, ...] local shard; w: [N]
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        wl = jax.lax.dynamic_slice_in_dim(w, idx * per, per, 0)
        contrib = x.astype(jnp.float32) * wl.reshape((per,) + (1,) * (x.ndim - 1))
        merged = jax.lax.psum(contrib.sum(0), axis)
        return jnp.broadcast_to(merged, x.shape).astype(x.dtype)

    w = jnp.asarray(weights, jnp.float32)
    assert w.shape == (n,) or w.size % n == 0
    return _mapped(f, mesh, axis, stacked, w, inner_specs=inner_specs)


def ring_gossip(stacked, mesh, axis: str, self_weight: float = 0.5,
                inner_specs=None):
    """Sparse P2P: θ_i ← s·θ_i + (1-s)/2·(θ_{i-1} + θ_{i+1})."""
    n = axis_size(mesh, axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]

    def f(x):
        # wire dtype = param dtype (bf16): halves link bytes vs f32;
        # the mixing arithmetic still accumulates in f32
        left = jax.lax.ppermute(x, axis, fwd).astype(jnp.float32)
        right = jax.lax.ppermute(x, axis, bwd).astype(jnp.float32)
        side = (1.0 - self_weight) / 2.0
        return (self_weight * x.astype(jnp.float32)
                + side * (left + right)).astype(x.dtype)

    return _mapped(f, mesh, axis, stacked, inner_specs=inner_specs)


def fisher_gossip(stacked, fishers, mesh, axis: str, inner_specs=None,
                  eps: float = 1e-8):
    """Diagonal-Fisher-weighted merge over the swarm axis:
    θ* = Σ_i F_i⊙θ_i / Σ_i F_i  (two psums), broadcast to every node.

    The SPMD realization of `merge_impl.fisher_merge` — the principled
    aggregation the paper cites ([6]) but never builds.
    """
    def f(x, fsh):
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        num = jax.lax.psum((ff * xf).sum(0), axis)
        den = jax.lax.psum(ff.sum(0), axis)
        return jnp.broadcast_to(num / den, x.shape).astype(x.dtype)

    nones = lambda v: v is None

    def leaf_fn(x, fsh, spec):
        if x is None:
            return None
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        return shard_map(f, mesh, in_specs=(in_spec, in_spec),
                         out_specs=in_spec)(x, fsh)

    if inner_specs is None:
        inner_specs = jax.tree.map(lambda v: None, stacked, is_leaf=nones)
    return jax.tree.map(leaf_fn, stacked, fishers, inner_specs, is_leaf=nones)


def _fisher_pair_map(fn, mesh, axis, stacked, fishers, extra, inner_specs):
    """shard_map fn(x, fisher, *extra) leaf-wise over (params, mass) pairs;
    extras are replicated (P()); None leaves pass through."""
    nones = lambda v: v is None

    def leaf_fn(x, fsh, spec):
        if x is None:
            return None
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        return shard_map(fn, mesh,
                         in_specs=(in_spec, in_spec)
                         + tuple(P() for _ in extra),
                         out_specs=in_spec)(x, fsh, *extra)

    if inner_specs is None:
        inner_specs = jax.tree.map(lambda v: None, stacked, is_leaf=nones)
    return jax.tree.map(leaf_fn, stacked, fishers, inner_specs, is_leaf=nones)


def topo_fisher_gossip(stacked, fishers, rows, mesh, axis: str,
                       inner_specs=None, eps: float = 1e-8, wire_dtype=None):
    """Topology-restricted importance-weighted merge over the swarm axis:

        θ*_i = Σ_j rows[i,j]·(F_j+eps)⊙θ_j / Σ_j rows[i,j]·(F_j+eps)

    The SPMD realization of `merge_impl.topo_weighted_merge` — ring/dynamic
    swarms merge only graph-neighbour contributions. Lowering: the
    importance-weighted numerator and the mass are stacked into ONE
    ``(num ⊕ mass)`` payload and moved by a SINGLE ``all_gather`` per leaf
    (2·N·P values at the wire dtype), then contracted locally per row —
    the general-rows form; ring rows take the 4·P two-``ppermute`` schedule
    (:func:`ring_topo_fisher_gossip`) instead."""
    n = axis_size(mesh, axis)

    def f(x, fsh, Wm):  # x/fsh: [per, ...] local shard; Wm: [N, N]
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        z = jnp.concatenate([ff * xf, ff], axis=0)          # [2·per, ...]
        allz = jax.lax.all_gather(_wire_cast(z, wire_dtype), axis,
                                  tiled=True).astype(jnp.float32)
        pair = allz.reshape(n, 2, per, -1)                   # shard-major
        num_all = pair[:, 0].reshape(n * per, -1)            # [N, D]
        den_all = pair[:, 1].reshape(n * per, -1)
        r = jax.lax.dynamic_slice_in_dim(Wm, idx * per, per, 0)  # [per, N]
        num = r @ num_all
        den = r @ den_all
        out = num / jnp.maximum(den, 1e-30)
        return out.reshape((per,) + x.shape[1:]).astype(x.dtype)

    Wj = jnp.asarray(rows, jnp.float32)
    return _fisher_pair_map(f, mesh, axis, stacked, fishers, (Wj,),
                            inner_specs)


def _ring_perms(n: int):
    """(receive-from-left, receive-from-right) ppermute pairs."""
    fwd = [(i, (i + 1) % n) for i in range(n)]   # data flows i -> i+1
    bwd = [(i, (i - 1) % n) for i in range(n)]   # data flows i -> i-1
    return fwd, bwd


def _check_one_node_per_shard(stacked, mesh, axis, what: str):
    n = axis_size(mesh, axis)
    lead = jax.tree.leaves(stacked)[0].shape[0]
    if lead != n:
        raise ValueError(
            f"{what} needs one node per mesh shard (leading axis {lead} vs "
            f"mesh axis {axis}={n}); use the gathered fallback for per>1")
    if n < 3:
        raise ValueError(f"{what} needs N >= 3 (an N=2 ring folds both "
                         f"neighbour edges onto one peer); got N={n}")


def ring_rows_gossip(stacked, W, mesh, axis: str, inner_specs=None,
                     wire_dtype=None):
    """Ring-native mixing-row gossip (mean/fedavg on a ring):

        θ*_i = W[i,i]·θ_i + W[i,i−1]·θ_{i−1} + W[i,i+1]·θ_{i+1}

    Two ``ppermute`` shifts move 2·P point-to-point values per device — no
    global collective — while honouring a (possibly traced, membership-
    masked) ring mixing matrix, unlike :func:`ring_gossip`'s fixed
    self-weight. Only neighbour payloads are wire-cast; the self term stays
    exact local precision. Requires one node per shard and N ≥ 3."""
    _check_one_node_per_shard(stacked, mesh, axis, "ring_rows_gossip")
    n = axis_size(mesh, axis)
    fwd, bwd = _ring_perms(n)

    def f(x, Wm):  # x: [1, ...] this node's shard; Wm: [N, N]
        idx = jax.lax.axis_index(axis)
        z = _wire_cast(x, wire_dtype)
        left = jax.lax.ppermute(z, axis, fwd).astype(jnp.float32)
        right = jax.lax.ppermute(z, axis, bwd).astype(jnp.float32)
        w_self = Wm[idx, idx]
        w_left = Wm[idx, (idx - 1) % n]
        w_right = Wm[idx, (idx + 1) % n]
        out = (w_self * x.astype(jnp.float32) + w_left * left
               + w_right * right)
        return out.astype(x.dtype)

    return _mapped(f, mesh, axis, stacked, jnp.asarray(W, jnp.float32),
                   inner_specs=inner_specs)


def ring_topo_fisher_gossip(stacked, fishers, rows, mesh, axis: str,
                            inner_specs=None, eps: float = 1e-8,
                            wire_dtype=None):
    """Ring-native topology-restricted weighted merge — the wire-optimal
    form of :func:`topo_fisher_gossip` for ring mixing rows:

        θ*_i = Σ_{j∈{i−1,i,i+1}} rows[i,j]·(F_j+eps)⊙θ_j
             / Σ_{j∈{i−1,i,i+1}} rows[i,j]·(F_j+eps)

    Each node fuses its importance-weighted numerator and mass into one
    ``(F⊙θ ⊕ F)`` side-channel payload and ppermutes it to both ring
    neighbours: ~4·P point-to-point values per sync instead of the gathered
    form's 2·N·P. Self contributions never touch the wire (exact f32).
    Requires one node per shard and N ≥ 3 (ring rows only have the three
    per-row entries this schedule exchanges)."""
    _check_one_node_per_shard(stacked, mesh, axis, "ring_topo_fisher_gossip")
    n = axis_size(mesh, axis)
    fwd, bwd = _ring_perms(n)

    def f(x, fsh, Wm):  # x/fsh: [1, ...]; Wm: [N, N] ring-structured rows
        idx = jax.lax.axis_index(axis)
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        y = ff * xf                                   # numerator payload
        z = _wire_cast(jnp.concatenate([y, ff], axis=0), wire_dtype)  # [2,...]
        left = jax.lax.ppermute(z, axis, fwd).astype(jnp.float32)
        right = jax.lax.ppermute(z, axis, bwd).astype(jnp.float32)
        r_self = Wm[idx, idx]
        r_left = Wm[idx, (idx - 1) % n]
        r_right = Wm[idx, (idx + 1) % n]
        num = r_self * y + r_left * left[0:1] + r_right * right[0:1]
        den = r_self * ff + r_left * left[1:2] + r_right * right[1:2]
        return (num / jnp.maximum(den, 1e-30)).astype(x.dtype)

    Wj = jnp.asarray(rows, jnp.float32)
    return _fisher_pair_map(f, mesh, axis, stacked, fishers, (Wj,),
                            inner_specs)


# ---------------------------------------------------------------------------
# mesh int8 error-feedback wire: the *_q8 schedule forms
# ---------------------------------------------------------------------------
# Per-leaf EF codec (runs INSIDE shard_map, on local shards). The payload is
# flattened per row, zero-padded to the wire-block grid, and delta-encoded
# against a same-shaped reference through the shared `core.comms` quant core;
# the padded tail stays exactly zero on both sides, so references can be
# stored in payload shape and re-padded every round without drift.

def _ef_encode(z, ref, wire_block: int, pad_to: int = 0):
    """(z, ref) local [rows, ...] → (q int8 [rows, Dp], scales f32
    [rows, Dp/wb], ref' [rows, ...]) with ref' = ref + dequant(q·s)."""
    rows = z.shape[0]
    flat = z.astype(jnp.float32).reshape(rows, -1)
    rflat = ref.astype(jnp.float32).reshape(rows, -1)
    d = flat.shape[1]
    grid = max(wire_block, pad_to)
    pad = (-d) % grid
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
        rflat = jnp.pad(rflat, ((0, 0), (0, pad)))
    q, s = comms.quant_encode(flat - rflat, wire_block)
    ref2 = rflat + comms.quant_decode(q, s, wire_block)
    return q, s, ref2[:, :d].reshape(ref.shape)


def _ef_apply(ref, q, s, wire_block: int):
    """Receiver side: advance a reference replica with a received (q, s)
    payload — bit-identical to the sender's own `_ef_encode` advance."""
    rows = ref.shape[0]
    rflat = ref.astype(jnp.float32).reshape(rows, -1)
    d = rflat.shape[1]
    deq = comms.quant_decode(q, s, wire_block)[:, :d]
    return (rflat + deq).reshape(ref.shape)


def _leafwise(fn, trees, n_out: int):
    """Apply ``fn(*leaves) -> n_out-tuple`` leaf-wise over parallel pytrees
    (explicit flatten, so structural tuples in params can't be confused with
    the output tuples); None payload leaves map to None in every output."""
    nones = lambda v: v is None
    flats = [jax.tree_util.tree_flatten(t, is_leaf=nones)[0] for t in trees]
    treedef = jax.tree_util.tree_flatten(trees[0], is_leaf=nones)[1]
    outs = [[] for _ in range(n_out)]
    for leaves in zip(*flats):
        res = (None,) * n_out if leaves[0] is None else fn(*leaves)
        for acc, r in zip(outs, res):
            acc.append(r)
    return tuple(jax.tree_util.tree_unflatten(treedef, acc) for acc in outs)


def _inner_spec_tree(stacked, inner_specs):
    if inner_specs is None:
        return jax.tree.map(lambda x: None, stacked,
                            is_leaf=lambda v: v is None)
    return inner_specs


def _padded_chunk(shape, n: int, wire_block: int) -> int:
    """Per-shard chunk length of a leaf row flattened and padded to the
    n·wire_block grid (the psum-q8 reduce-scatter layout)."""
    d = 1
    for s in shape[1:]:
        d *= s
    grid = n * wire_block
    return (-(-d // grid) * grid) // n


def init_mesh_wire(schedule: str, payload, *, n_shards: int,
                   wire_block: int = 512, mesh_shape=None):
    """Zero EF wire state for a ``*_q8`` mesh schedule over a stacked payload
    pytree ([N, ...] leaves; None leaves mirror as None). The returned pytree
    rides ``SwarmState.wire`` next to the params:

      ring:      {"ref", "left", "right"} — own + neighbour-replica
                 references, payload-shaped, sharded by node
                 (weighted forms: each a {"num", "mass"} pair of trees)
      gathered:  {"table"} — the full reconstruction table, replicated
      psum q8:   {"ref"} per-shard contribution reference (one row/shard),
                 {"cons"} replicated consensus row, {"cres"} second-stage
                 chunk residual (one chunk per shard)
      hier q8:   {"ref", "left"[, "right"]} — per-device delegate-chunk
                 references ([N, chunk] rows, sharded over the joint
                 ("pod", "node") axis) for own pod + neighbour pods; needs
                 ``mesh_shape=(n_pods, per_pod)``, and "right" exists only
                 for n_pods > 2 (a two-pod ring folds onto one peer)
    """
    nones = lambda v: v is None
    zlike = lambda x: (None if x is None
                       else jnp.zeros(x.shape, jnp.float32))
    zrow = lambda x: (None if x is None
                      else jnp.zeros((1,) + x.shape[1:], jnp.float32))
    zshard = lambda x: (None if x is None
                        else jnp.zeros((n_shards,) + x.shape[1:], jnp.float32))
    zchunk = lambda x: (None if x is None else jnp.zeros(
        (n_shards, _padded_chunk(x.shape, n_shards, wire_block)), jnp.float32))
    tmap = lambda f: jax.tree.map(f, payload, is_leaf=nones)
    pair = lambda f: {"num": tmap(f), "mass": tmap(f)}
    if schedule == "ring_ppermute":
        return {"ref": tmap(zlike), "left": tmap(zlike), "right": tmap(zlike)}
    if schedule == "ring_topo_ppermute":
        return {"ref": pair(zlike), "left": pair(zlike), "right": pair(zlike)}
    if schedule == "gathered_rows":
        return {"table": tmap(zlike)}
    if schedule == "gathered_topo_stack":
        return {"table": pair(zlike)}
    if schedule == "fedavg_psum_q8":
        return {"ref": tmap(zshard), "cons": tmap(zrow), "cres": tmap(zchunk)}
    if schedule == "fisher_psum_q8":
        return {"ref": pair(zshard), "cons": pair(zrow), "cres": pair(zchunk)}
    if schedule in ("hier_fedavg_ring_q8", "hier_fisher_ring_q8"):
        if mesh_shape is None:
            raise ValueError(f"{schedule} needs mesh_shape=(n_pods, per_pod)")
        k_pods, per_pod = mesh_shape
        zhier = lambda x: (None if x is None else jnp.zeros(
            (k_pods * per_pod, _padded_chunk(x.shape, per_pod, wire_block)),
            jnp.float32))
        leaf = tmap if schedule == "hier_fedavg_ring_q8" else pair
        out = {"ref": leaf(zhier), "left": leaf(zhier)}
        if k_pods > 2:
            out["right"] = leaf(zhier)
        return out
    raise ValueError(f"no mesh wire state for schedule {schedule!r}")


def reset_mesh_wire(wire):
    """Quarantine the WHOLE mesh EF wire state (crash→rejoin recovery).

    Per-node row surgery is unsafe here: the q8 ring/hier schedules carry
    neighbour replicas ("left"/"right") that must track the sender's "ref"
    bit-exactly — zeroing one node's reference without zeroing every
    replica of it (sharded on other devices) would desynchronize the
    telescoping residual and the divergence would be committed as if it
    were quantization error. A full reset keeps every replica trivially
    consistent: the next sync retransmits full quantized payloads
    everywhere and EF re-settles within a few rounds (see docs/faults.md).

    ``x * 0`` (not ``zeros_like``) so shardings and replication of the
    schedule-shaped pytree are preserved leaf-by-leaf.
    """
    return jax.tree.map(lambda x: None if x is None else x * 0,
                        wire, is_leaf=lambda v: v is None)


def ring_rows_gossip_q8(stacked, W, wire, mesh, axis: str, inner_specs=None,
                        wire_block: int = 512):
    """int8-EF form of :func:`ring_rows_gossip`: the two ppermutes move int8
    deltas + per-block scales (~2·P bytes + 8·P/wire_block per sync instead
    of 8·P f32 bytes). Each device advances its own reference and its two
    neighbour replicas from the identical delta stream, so reconstructions
    match the senders bit-for-bit; the self term stays exact local f32.
    Returns ``(merged, new_wire)``."""
    _check_one_node_per_shard(stacked, mesh, axis, "ring_rows_gossip_q8")
    n = axis_size(mesh, axis)
    fwd, bwd = _ring_perms(n)
    Wj = jnp.asarray(W, jnp.float32)

    def f(x, ref, lft, rgt, Wm):
        idx = jax.lax.axis_index(axis)
        q, s, ref2 = _ef_encode(x, ref, wire_block)
        ql = jax.lax.ppermute(q, axis, fwd)
        sl = jax.lax.ppermute(s, axis, fwd)
        qr = jax.lax.ppermute(q, axis, bwd)
        sr = jax.lax.ppermute(s, axis, bwd)
        lft2 = _ef_apply(lft, ql, sl, wire_block)
        rgt2 = _ef_apply(rgt, qr, sr, wire_block)
        w_self = Wm[idx, idx]
        w_left = Wm[idx, (idx - 1) % n]
        w_right = Wm[idx, (idx + 1) % n]
        out = (w_self * x.astype(jnp.float32) + w_left * lft2
               + w_right * rgt2)
        return out.astype(x.dtype), ref2, lft2, rgt2

    def leaf(x, ref, lft, rgt, spec):
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        sm = shard_map(f, mesh, in_specs=(in_spec,) * 4 + (P(),),
                       out_specs=(in_spec,) * 4)
        return sm(x, ref, lft, rgt, Wj)

    specs = _inner_spec_tree(stacked, inner_specs)
    merged, ref2, lft2, rgt2 = _leafwise(
        leaf, (stacked, wire["ref"], wire["left"], wire["right"], specs), 4)
    return merged, {"ref": ref2, "left": lft2, "right": rgt2}


def ring_topo_fisher_gossip_q8(stacked, fishers, rows, wire, mesh, axis: str,
                               inner_specs=None, eps: float = 1e-8,
                               wire_block: int = 512):
    """int8-EF form of :func:`ring_topo_fisher_gossip`: the fused
    ``(F⊙θ ⊕ F)`` side-channel rides the wire as two delta-encoded streams
    (numerator and mass, each int8 + scales) against per-node references
    with neighbour replicas — ~4·P wire bytes per sync instead of 16·P.
    Self contributions never touch the wire (exact f32).
    Returns ``(merged, new_wire)``."""
    _check_one_node_per_shard(stacked, mesh, axis,
                              "ring_topo_fisher_gossip_q8")
    n = axis_size(mesh, axis)
    fwd, bwd = _ring_perms(n)
    Wj = jnp.asarray(rows, jnp.float32)

    def f(x, fsh, rn, rm, ln, lm, rgn, rgm, Wm):
        idx = jax.lax.axis_index(axis)
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        y = ff * xf
        # the num and mass streams ride as ONE stacked (F⊙θ ⊕ F) payload —
        # per-row quantization blocks are unchanged, but each direction
        # launches one int8 ppermute + one scale ppermute instead of four
        z = jnp.concatenate([y, ff], axis=0)              # [2, ...]
        refs = jnp.concatenate([rn, rm], axis=0)
        q, s, ref2 = _ef_encode(z, refs, wire_block)
        ql = jax.lax.ppermute(q, axis, fwd)
        sl = jax.lax.ppermute(s, axis, fwd)
        qr = jax.lax.ppermute(q, axis, bwd)
        sr = jax.lax.ppermute(s, axis, bwd)
        lft2 = _ef_apply(jnp.concatenate([ln, lm], axis=0), ql, sl,
                         wire_block)
        rgt2 = _ef_apply(jnp.concatenate([rgn, rgm], axis=0), qr, sr,
                         wire_block)
        r_self = Wm[idx, idx]
        r_left = Wm[idx, (idx - 1) % n]
        r_right = Wm[idx, (idx + 1) % n]
        num = r_self * y + r_left * lft2[0:1] + r_right * rgt2[0:1]
        den = r_self * ff + r_left * lft2[1:2] + r_right * rgt2[1:2]
        return ((num / jnp.maximum(den, 1e-30)).astype(x.dtype),
                ref2[0:1], ref2[1:2], lft2[0:1], lft2[1:2],
                rgt2[0:1], rgt2[1:2])

    def leaf(x, fsh, rn, rm, ln, lm, rgn, rgm, spec):
        in_spec = P(axis, *(tuple(spec) if spec is not None else ()))
        sm = shard_map(f, mesh, in_specs=(in_spec,) * 8 + (P(),),
                       out_specs=(in_spec,) * 7)
        return sm(x, fsh, rn, rm, ln, lm, rgn, rgm, Wj)

    specs = _inner_spec_tree(stacked, inner_specs)
    ref, lft, rgt = wire["ref"], wire["left"], wire["right"]
    merged, rn2, rm2, ln2, lm2, rgn2, rgm2 = _leafwise(
        leaf, (stacked, fishers, ref["num"], ref["mass"], lft["num"],
               lft["mass"], rgt["num"], rgt["mass"], specs), 7)
    return merged, {"ref": {"num": rn2, "mass": rm2},
                    "left": {"num": ln2, "mass": lm2},
                    "right": {"num": rgn2, "mass": rgm2}}


def matrix_gossip_q8(stacked, W, wire, mesh, axis: str, inner_specs=None,
                     wire_block: int = 512):
    """int8-EF form of :func:`matrix_gossip` (the ``gathered_rows`` q8
    schedule): ONE int8 all_gather of every node's delta + scales; each
    device advances the full replicated reconstruction table (all devices
    see the same deltas, so the table stays bit-identical across the mesh)
    and contracts its mixing rows against the reconstructions.
    Returns ``(merged, new_wire)``."""
    n = axis_size(mesh, axis)
    Wj = jnp.asarray(W, jnp.float32)

    def f(x, table, Wm):  # x: [per, ...] local; table: [N, ...] replicated
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        myref = jax.lax.dynamic_slice_in_dim(table, idx * per, per, 0)
        q, s, _ = _ef_encode(x.astype(jnp.float32), myref, wire_block)
        allq = jax.lax.all_gather(q, axis, tiled=True)    # [N, Dp] int8
        alls = jax.lax.all_gather(s, axis, tiled=True)    # [N, Dp/wb] f32
        table2 = _ef_apply(table, allq, alls, wire_block)
        rows = jax.lax.dynamic_slice_in_dim(Wm, idx * per, per, 0)  # [per, N]
        out = rows @ table2.reshape(table2.shape[0], -1)
        return out.reshape(x.shape).astype(x.dtype), table2

    def leaf(x, table, spec):
        inner = tuple(spec) if spec is not None else ()
        in_spec = P(axis, *inner)
        tab_spec = P(None, *inner)
        sm = shard_map(f, mesh, in_specs=(in_spec, tab_spec, P()),
                       out_specs=(in_spec, tab_spec), check_rep=False)
        return sm(x, table, Wj)

    specs = _inner_spec_tree(stacked, inner_specs)
    merged, table2 = _leafwise(leaf, (stacked, wire["table"], specs), 2)
    return merged, {"table": table2}


def topo_fisher_gossip_q8(stacked, fishers, rows, wire, mesh, axis: str,
                          inner_specs=None, eps: float = 1e-8,
                          wire_block: int = 512):
    """int8-EF form of :func:`topo_fisher_gossip` (the
    ``gathered_topo_stack`` q8 schedule): the importance-weighted numerator
    and mass streams are delta-encoded against a replicated reconstruction
    table and moved by ONE stacked int8 all_gather plus one scale gather
    (PR 4's fused-gather invariant, kept at the q8 byte cost), then
    contracted per mixing row. Returns ``(merged, new_wire)``."""
    n = axis_size(mesh, axis)
    Wj = jnp.asarray(rows, jnp.float32)

    def f(x, fsh, tn, tm, Wm):
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        y = ff * xf
        refn = jax.lax.dynamic_slice_in_dim(tn, idx * per, per, 0)
        refm = jax.lax.dynamic_slice_in_dim(tm, idx * per, per, 0)
        z = jnp.concatenate([y, ff], axis=0)              # [2·per, ...]
        refs = jnp.concatenate([refn, refm], axis=0)
        q, s, _ = _ef_encode(z, refs, wire_block)
        allq = jax.lax.all_gather(q, axis, tiled=True)    # [N·2·per, Dp]
        alls = jax.lax.all_gather(s, axis, tiled=True)
        gq = allq.reshape(n, 2, per, allq.shape[-1])      # shard-major
        gs = alls.reshape(n, 2, per, alls.shape[-1])
        tn2 = _ef_apply(tn, gq[:, 0].reshape(n * per, -1),
                        gs[:, 0].reshape(n * per, -1), wire_block)
        tm2 = _ef_apply(tm, gq[:, 1].reshape(n * per, -1),
                        gs[:, 1].reshape(n * per, -1), wire_block)
        r = jax.lax.dynamic_slice_in_dim(Wm, idx * per, per, 0)   # [per, N]
        num = r @ tn2.reshape(tn2.shape[0], -1)
        den = r @ tm2.reshape(tm2.shape[0], -1)
        out = num / jnp.maximum(den, 1e-30)
        return out.reshape(x.shape).astype(x.dtype), tn2, tm2

    def leaf(x, fsh, tn, tm, spec):
        inner = tuple(spec) if spec is not None else ()
        in_spec = P(axis, *inner)
        tab_spec = P(None, *inner)
        sm = shard_map(f, mesh,
                       in_specs=(in_spec, in_spec, tab_spec, tab_spec, P()),
                       out_specs=(in_spec, tab_spec, tab_spec),
                       check_rep=False)
        return sm(x, fsh, tn, tm, Wj)

    specs = _inner_spec_tree(stacked, inner_specs)
    tab = wire["table"]
    merged, tn2, tm2 = _leafwise(
        leaf, (stacked, fishers, tab["num"], tab["mass"], specs), 3)
    return merged, {"table": {"num": tn2, "mass": tm2}}


def _psum_q8_stream(z, ref, cons, cres, axis, n: int, wire_block: int):
    """One delta-consensus EF stream of the compression-aware psum:

      1. delta-encode the local contribution z against its per-shard
         reference (int8 + scales; reference advances locally),
      2. quantized-chunk reduce-scatter: all_to_all of int8 chunks, local
         dequant + sum at each chunk owner (f32 accumulation),
      3. second-stage EF: the owner re-quantizes its reduced chunk against
         a per-chunk residual, and the int8 chunks are all_gathered into
         the replicated consensus accumulator.

    Returns ``(consensus_row', ref', cons', cres')`` — all errors live in
    EF residuals, so the consensus telescopes to Σ_j z_j exactly as inputs
    settle. Runs INSIDE shard_map: z/ref/cons [1, ...row], cres [1, chunk].
    """
    q, s, ref2 = _ef_encode(z, ref, wire_block, pad_to=n * wire_block)
    dp = q.shape[1]
    chunk = dp // n
    qc = q.reshape(n, chunk)
    sc = s.reshape(n, chunk // wire_block)
    qx = jax.lax.all_to_all(qc, axis, split_axis=0, concat_axis=0)
    sx = jax.lax.all_to_all(sc, axis, split_axis=0, concat_axis=0)
    deq = comms.quant_decode(qx, sx, wire_block)          # [n, chunk] f32
    u = deq.sum(0, keepdims=True) + cres                  # [1, chunk]
    q2, s2 = comms.quant_encode(u, wire_block)
    cres2 = u - comms.quant_decode(q2, s2, wire_block)
    aq = jax.lax.all_gather(q2, axis, tiled=True)         # [n, chunk] int8
    as_ = jax.lax.all_gather(s2, axis, tiled=True)
    dhat = comms.quant_decode(aq, as_, wire_block).reshape(1, dp)
    cflat = cons.astype(jnp.float32).reshape(1, -1)
    d = cflat.shape[1]
    cons2 = (cflat + dhat[:, :d]).reshape(cons.shape)
    return cons2, ref2, cons2, cres2


def fedavg_psum_q8(stacked, weights, wire, mesh, axis: str, inner_specs=None,
                   wire_block: int = 512):
    """Compression-aware weighted global merge (the ``fedavg_psum_q8``
    schedule): every node ends with the replicated consensus reconstruction
    of Σ_j w_j θ_j, built from int8 wire traffic only (see
    :func:`_psum_q8_stream`). Weights may be traced (runtime membership).
    Returns ``(merged, new_wire)``."""
    n = axis_size(mesh, axis)
    if inner_specs is not None and any(
            s is not None for s in jax.tree.leaves(inner_specs)):
        raise ValueError("fedavg_psum_q8 does not support model-sharded "
                         "payloads (inner_specs); use a ring/gathered "
                         "schedule or wire_dtype='bf16'")
    w = jnp.asarray(weights, jnp.float32)

    def f(x, ref, cons, cres, wv):
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        wl = jax.lax.dynamic_slice_in_dim(wv, idx * per, per, 0)
        z = (x.astype(jnp.float32)
             * wl.reshape((per,) + (1,) * (x.ndim - 1))).sum(0, keepdims=True)
        cons_row, ref2, cons2, cres2 = _psum_q8_stream(
            z, ref, cons, cres, axis, n, wire_block)
        merged = jnp.broadcast_to(cons_row, x.shape).astype(x.dtype)
        return merged, ref2, cons2, cres2

    def leaf(x, ref, cons, cres, spec):
        in_spec = P(axis)
        sm = shard_map(f, mesh,
                       in_specs=(in_spec, in_spec, P(), in_spec, P()),
                       out_specs=(in_spec, in_spec, P(), in_spec),
                       check_rep=False)
        return sm(x, ref, cons, cres, w)

    specs = _inner_spec_tree(stacked, inner_specs)
    merged, ref2, cons2, cres2 = _leafwise(
        leaf, (stacked, wire["ref"], wire["cons"], wire["cres"], specs), 4)
    return merged, {"ref": ref2, "cons": cons2, "cres": cres2}


def fisher_psum_q8(stacked, fishers, wire, mesh, axis: str, inner_specs=None,
                   eps: float = 1e-8, wire_block: int = 512):
    """Compression-aware importance-weighted global merge (the
    ``fisher_psum_q8`` schedule): numerator Σ (F+eps)⊙θ and mass Σ (F+eps)
    each ride one delta-consensus EF stream (int8 reduce-scatter +
    all_gather); the merge is the ratio of the two replicated consensus
    reconstructions. Any weight folding (gradmatch) happens in the mass
    before the call, exactly like :func:`fisher_gossip`.
    Returns ``(merged, new_wire)``."""
    n = axis_size(mesh, axis)
    if inner_specs is not None and any(
            s is not None for s in jax.tree.leaves(inner_specs)):
        raise ValueError("fisher_psum_q8 does not support model-sharded "
                         "payloads (inner_specs); use a ring/gathered "
                         "schedule or wire_dtype='bf16'")

    def f(x, fsh, rn, rm, cn, cm, qn_res, qm_res):
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        zn = (ff * xf).sum(0, keepdims=True)
        zm = ff.sum(0, keepdims=True)
        num_row, rn2, cn2, qn2 = _psum_q8_stream(
            zn, rn, cn, qn_res, axis, n, wire_block)
        den_row, rm2, cm2, qm2 = _psum_q8_stream(
            zm, rm, cm, qm_res, axis, n, wire_block)
        merged = num_row / jnp.maximum(den_row, 1e-30)
        return (jnp.broadcast_to(merged, x.shape).astype(x.dtype),
                rn2, rm2, cn2, cm2, qn2, qm2)

    def leaf(x, fsh, rn, rm, cn, cm, qn_res, qm_res, spec):
        in_spec = P(axis)
        sm = shard_map(
            f, mesh,
            in_specs=(in_spec, in_spec, in_spec, in_spec, P(), P(),
                      in_spec, in_spec),
            out_specs=(in_spec, in_spec, in_spec, P(), P(), in_spec,
                       in_spec),
            check_rep=False)
        return sm(x, fsh, rn, rm, cn, cm, qn_res, qm_res)

    specs = _inner_spec_tree(stacked, inner_specs)
    ref, cons, cres = wire["ref"], wire["cons"], wire["cres"]
    merged, rn2, rm2, cn2, cm2, qn2, qm2 = _leafwise(
        leaf, (stacked, fishers, ref["num"], ref["mass"], cons["num"],
               cons["mass"], cres["num"], cres["mass"], specs), 7)
    return merged, {"ref": {"num": rn2, "mass": rm2},
                    "cons": {"num": cn2, "mass": cm2},
                    "cres": {"num": qn2, "mass": qm2}}


# ---------------------------------------------------------------------------
# hierarchical two-level schedules: intra-pod reduce → pod-delegate int8 EF
# ring → intra-pod broadcast
# ---------------------------------------------------------------------------

def _hier_shapes(mesh, axis, stacked):
    """Validate a hierarchical call and return (pod_ax, node_ax, K, per)."""
    pod_ax, node_ax = _pod_axes(axis)
    k_pods = mesh.shape[pod_ax]
    per_pod = mesh.shape[node_ax]
    lead = jax.tree.leaves(stacked)[0].shape[0]
    if lead != k_pods * per_pod:
        raise ValueError(
            f"hierarchical schedules need one node per device (leading axis "
            f"{lead} vs mesh {pod_ax}×{node_ax}={k_pods}×{per_pod})")
    if k_pods < 2 or per_pod < 2:
        raise ValueError(f"hierarchical schedules need ≥2 pods and ≥2 nodes "
                         f"per pod; got {k_pods}×{per_pod}")
    return pod_ax, node_ax, k_pods, per_pod


def _refuse_inner_sharding(inner_specs, what: str):
    if inner_specs is not None and any(
            s is not None for s in jax.tree.leaves(inner_specs)):
        raise ValueError(f"{what} does not support model-sharded payloads "
                         "(inner_specs): delegate chunks slice the "
                         "globally-flattened payload")


def hier_fedavg_ring_q8(stacked, weights, pod_rows, wire, mesh, axis,
                        inner_specs=None, wire_block: int = 512):
    """Hierarchical weighted merge on a two-level ``("pod", "node")`` mesh
    (the ``hier_fedavg_ring_q8`` schedule):

      1. **intra-pod reduce** — a weighted f32 psum over the node axis gives
         every device its pod's average  ā_q = Σ_{i∈q} w_i θ_i / Σ_{i∈q} w_i
         (2·(per−1)/per values of intra-pod ring-allreduce traffic);
      2. **pod-delegate int8 EF ring** — each device owns the 1/per_pod
         chunk of the flattened ā_q matching its node index and ppermutes it
         across pods as an int8 delta + per-block scales against a per-pod
         EF residual (neighbour-pod replicas advance from the identical
         stream). Only this leg crosses the DCN: k·P/per_pod int8 values
         per device, k = 1 at two pods (the pair ring folds both edges onto
         one peer and "right" drops out of the wire), else 2;
      3. **intra-pod broadcast** — a node-axis all_gather reassembles the
         pod-row-mixed chunks (P f32 values, intra-pod).

    The self-pod term mixes at exact f32; neighbour pods telescope through
    the EF wire, so on settling inputs every node converges to the pod-ring
    mix  Σ_q pod_rows[pod(i), q] · ā_q . Weights may be traced (runtime
    membership) but every pod needs ≥1 active node for its average to be
    meaningful. Returns ``(merged, new_wire)``."""
    pod_ax, node_ax, k_pods, per_pod = _hier_shapes(mesh, axis, stacked)
    _refuse_inner_sharding(inner_specs, "hier_fedavg_ring_q8")
    fwd, bwd = _ring_perms(k_pods)
    two_sided = k_pods > 2
    Wp = jnp.asarray(pod_rows, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)

    def f(x, ref, lft, rgt, wv, Wpm):  # x/ref/lft/rgt: [1, ...] per device
        p = jax.lax.axis_index(pod_ax)
        j = jax.lax.axis_index(node_ax)
        wl = jax.lax.dynamic_slice_in_dim(wv, p * per_pod + j, 1, 0)  # [1]
        xf = x.astype(jnp.float32)
        ones = (1,) + (1,) * (xf.ndim - 1)
        num = jax.lax.psum(xf * wl.reshape(ones), node_ax)
        mass = jax.lax.psum(wl, node_ax)
        avg = num / jnp.maximum(mass, 1e-30).reshape(ones)
        flat = avg.reshape(1, -1)
        d = flat.shape[1]
        pad = (-d) % (per_pod * wire_block)
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        clen = flat.shape[1] // per_pod
        chunk = jax.lax.dynamic_slice_in_dim(flat, j * clen, clen, 1)
        q, s, ref2 = _ef_encode(chunk, ref, wire_block)
        ql = jax.lax.ppermute(q, pod_ax, fwd)
        sl = jax.lax.ppermute(s, pod_ax, fwd)
        lft2 = _ef_apply(lft, ql, sl, wire_block)
        mixed = Wpm[p, p] * chunk + Wpm[p, (p - 1) % k_pods] * lft2
        if two_sided:
            qr = jax.lax.ppermute(q, pod_ax, bwd)
            sr = jax.lax.ppermute(s, pod_ax, bwd)
            rgt2 = _ef_apply(rgt, qr, sr, wire_block)
            mixed = mixed + Wpm[p, (p + 1) % k_pods] * rgt2
        full = jax.lax.all_gather(mixed, node_ax, tiled=True)  # [per, clen]
        out = full.reshape(1, per_pod * clen)[:, :d].reshape(xf.shape)
        if two_sided:
            return out.astype(x.dtype), ref2, lft2, rgt2
        return out.astype(x.dtype), ref2, lft2

    n_out = 4 if two_sided else 3

    def leaf(x, ref, lft, rgt, spec):
        in_spec = P(axis)
        sm = shard_map(f, mesh, in_specs=(in_spec,) * 4 + (P(), P()),
                       out_specs=(in_spec,) * n_out, check_rep=False)
        return sm(x, ref, lft, rgt, w, Wp)

    specs = _inner_spec_tree(stacked, inner_specs)
    rgt_in = wire["right"] if two_sided else wire["left"]  # dummy at K=2
    outs = _leafwise(leaf, (stacked, wire["ref"], wire["left"], rgt_in,
                            specs), n_out)
    if two_sided:
        merged, ref2, lft2, rgt2 = outs
        return merged, {"ref": ref2, "left": lft2, "right": rgt2}
    merged, ref2, lft2 = outs
    return merged, {"ref": ref2, "left": lft2}


def hier_fisher_ring_q8(stacked, fishers, pod_rows, wire, mesh, axis,
                        inner_specs=None, eps: float = 1e-8,
                        wire_block: int = 512):
    """Hierarchical importance-weighted merge on a two-level mesh (the
    ``hier_fisher_ring_q8`` schedule) — :func:`hier_fedavg_ring_q8` with the
    fused ``(F⊙θ ⊕ F)`` side channel of the ring fisher forms: the intra-pod
    psums reduce the pod numerator Σ (F+eps)⊙θ and mass Σ (F+eps), both ride
    the cross-pod delegate ring as ONE stacked two-stream EF payload
    (2·k·P/per_pod int8 values per device), and the merge is the ratio of
    the pod-row-mixed streams. Any weight folding (gradmatch) happens in the
    mass before the call, exactly like :func:`fisher_psum_q8`.
    Returns ``(merged, new_wire)``."""
    pod_ax, node_ax, k_pods, per_pod = _hier_shapes(mesh, axis, stacked)
    _refuse_inner_sharding(inner_specs, "hier_fisher_ring_q8")
    fwd, bwd = _ring_perms(k_pods)
    two_sided = k_pods > 2
    Wp = jnp.asarray(pod_rows, jnp.float32)

    def f(x, fsh, rn, rm, ln, lm, rgn, rgm, Wpm):
        p = jax.lax.axis_index(pod_ax)
        j = jax.lax.axis_index(node_ax)
        xf = x.astype(jnp.float32)
        ff = fsh.astype(jnp.float32) + eps
        num = jax.lax.psum(ff * xf, node_ax)          # [1, ...] pod Σ F⊙θ
        den = jax.lax.psum(ff, node_ax)               # [1, ...] pod Σ F
        zn = num.reshape(1, -1)
        zm = den.reshape(1, -1)
        d = zn.shape[1]
        pad = (-d) % (per_pod * wire_block)
        if pad:
            zn = jnp.pad(zn, ((0, 0), (0, pad)))
            zm = jnp.pad(zm, ((0, 0), (0, pad)))
        clen = zn.shape[1] // per_pod
        z = jnp.concatenate([zn, zm], axis=0)         # [2, Dp]
        chunk = jax.lax.dynamic_slice_in_dim(z, j * clen, clen, 1)  # [2, ·]
        refs = jnp.concatenate([rn, rm], axis=0)
        q, s, ref2 = _ef_encode(chunk, refs, wire_block)
        ql = jax.lax.ppermute(q, pod_ax, fwd)
        sl = jax.lax.ppermute(s, pod_ax, fwd)
        lft2 = _ef_apply(jnp.concatenate([ln, lm], axis=0), ql, sl,
                         wire_block)
        r_self = Wpm[p, p]
        r_left = Wpm[p, (p - 1) % k_pods]
        num_mix = r_self * chunk[0:1] + r_left * lft2[0:1]
        den_mix = r_self * chunk[1:2] + r_left * lft2[1:2]
        if two_sided:
            qr = jax.lax.ppermute(q, pod_ax, bwd)
            sr = jax.lax.ppermute(s, pod_ax, bwd)
            rgt2 = _ef_apply(jnp.concatenate([rgn, rgm], axis=0), qr, sr,
                             wire_block)
            r_right = Wpm[p, (p + 1) % k_pods]
            num_mix = num_mix + r_right * rgt2[0:1]
            den_mix = den_mix + r_right * rgt2[1:2]
        mixed = num_mix / jnp.maximum(den_mix, 1e-30)  # [1, clen]
        full = jax.lax.all_gather(mixed, node_ax, tiled=True)
        out = full.reshape(1, per_pod * clen)[:, :d].reshape(xf.shape)
        if two_sided:
            return (out.astype(x.dtype), ref2[0:1], ref2[1:2],
                    lft2[0:1], lft2[1:2], rgt2[0:1], rgt2[1:2])
        return (out.astype(x.dtype), ref2[0:1], ref2[1:2],
                lft2[0:1], lft2[1:2])

    n_out = 7 if two_sided else 5

    def leaf(x, fsh, rn, rm, ln, lm, rgn, rgm, spec):
        in_spec = P(axis)
        sm = shard_map(f, mesh, in_specs=(in_spec,) * 8 + (P(),),
                       out_specs=(in_spec,) * n_out, check_rep=False)
        return sm(x, fsh, rn, rm, ln, lm, rgn, rgm, Wp)

    specs = _inner_spec_tree(stacked, inner_specs)
    ref, lft = wire["ref"], wire["left"]
    rgt = wire["right"] if two_sided else wire["left"]  # dummy at K=2
    outs = _leafwise(
        leaf, (stacked, fishers, ref["num"], ref["mass"], lft["num"],
               lft["mass"], rgt["num"], rgt["mass"], specs), n_out)
    if two_sided:
        merged, rn2, rm2, ln2, lm2, rgn2, rgm2 = outs
        return merged, {"ref": {"num": rn2, "mass": rm2},
                        "left": {"num": ln2, "mass": lm2},
                        "right": {"num": rgn2, "mass": rgm2}}
    merged, rn2, rm2, ln2, lm2 = outs
    return merged, {"ref": {"num": rn2, "mass": rm2},
                    "left": {"num": ln2, "mass": lm2}}


def matrix_gossip(stacked, W, mesh, axis: str, inner_specs=None,
                  wire_dtype=None):
    """General mixing matrix (dynamic membership): all_gather + local row mix."""
    n = axis_size(mesh, axis)

    def f(x, Wm):  # x: [per, ...]; Wm: [N, N]
        idx = jax.lax.axis_index(axis)
        per = x.shape[0]
        allx = jax.lax.all_gather(
            _wire_cast(x.astype(jnp.float32), wire_dtype), axis,
            tiled=True).astype(jnp.float32)                             # [N, ...]
        rows = jax.lax.dynamic_slice_in_dim(Wm, idx * per, per, 0)          # [per, N]
        flat = allx.reshape(allx.shape[0], -1)
        out = rows @ flat
        return out.reshape((per,) + x.shape[1:]).astype(x.dtype)

    Wj = jnp.asarray(W, jnp.float32)
    assert Wj.shape[0] == Wj.shape[1]
    return _mapped(f, mesh, axis, stacked, Wj, inner_specs=inner_specs)
