"""Host-simulated P2P-SL loop — the `SwarmSession` compatibility backend.

The paper's loop (§3.1):
  1. nodes train locally for `sync_every` steps,
  2. exchange payloads (LoRA adapters, or full params) with peers,
  3. each node merges locally (weighted averaging),
  4. each node ACCEPTS the merge only if a local validation check clears the
     80% threshold; otherwise it keeps its own params (autonomy).

**The public entry point is `repro.core.session.SwarmSession`** — one API
over a single `SwarmState` pytree for every backend, with runtime
join/leave membership and checkpoint/resume:

    session = SwarmSession(cfg, train_step, eval_fn, params=p,
                           backend="host")   # this module's loop underneath
    session.round(batches, val); session.leave(2); session.save(path)

``SwarmLearner`` (below) is the machinery that backend wraps: a host-driven
N-node swarm accepting **arbitrary Python** ``train_step_fn``/``eval_fn``
callables (non-traceable models, multi-arch examples, tests). Constructing it
directly still works but is a deprecated spelling of
``SwarmSession(..., backend="host")``. Its merge math delegates to
`repro.core.engine` and the configured `merge_impl.MergeStrategy`: propose
runs as one jitted program, Fisher mass for fisher/gradmatch merges
accumulates automatically during ``local_steps`` (no caller-side estimation
loop; a ``train_step_fn`` returning the opt-in 4-tuple
``(params, opt_state, metrics, grads)`` feeds exact squared gradients
instead of the Δθ² proxy), ring/dynamic fisher merges are restricted to
graph-neighbour contributions, and every commit goes through the fused
Pallas merge kernel — only the user eval calls stay on the host.

Fully-traceable workloads should use the session's default ``"engine"``
backend (or ``"gossip"`` on a mesh): the whole round — local steps, in-graph
validation, gate, fused commit — compiles into a single `jax.jit` with
donated buffers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig
import repro.core.topology as topo
from repro.core import engine as engine_lib
from repro.core import merge_impl as merge_lib
from repro.core.engine import (  # noqa: F401  (re-exported public API)
    active_weights, gate_decisions, gated_commit, mixing_matrix, propose_merge,
)


# ---------------------------------------------------------------------------
# host-simulated swarm (arbitrary-callable path)
# ---------------------------------------------------------------------------

@dataclass
class NodeState:
    params: Any
    opt_state: Any
    data_size: int
    fisher: Any = None        # explicit importance estimate; never mutated
    fisher_stats: Any = None  # strategy-accumulated Δθ² mass (local_steps)
    active: bool = True
    history: list = field(default_factory=list)


@dataclass
class SwarmLearner:
    """N independent learners + periodic gated P2P merge (the paper's system).

    train_step_fn(params, opt_state, batch, step) -> (params, opt_state, metrics)
    eval_fn(params, val_data) -> scalar metric in [0,1]
    """

    cfg: SwarmConfig
    train_step_fn: Callable
    eval_fn: Callable
    nodes: List[NodeState]
    step: int = 0
    sync_log: list = field(default_factory=list)

    @property
    def n(self) -> int:
        return len(self.nodes)

    @property
    def strategy(self):
        return merge_lib.get_strategy(self.cfg)

    def local_steps(self, batches_per_node: Sequence[Any]):
        """One local step on every node with a batch (pass ``None`` to skip a
        node). Data availability gates local training; MEMBERSHIP gates merge
        participation only — a departed node keeps training on its own shard
        if its stream still supplies batches, matching the engine backend's
        semantics. For fisher/gradmatch merges the strategy accumulates each
        node's importance mass here (into ``node.fisher_stats``) — callers no
        longer estimate Fishers themselves. An explicitly set ``node.fisher``
        (true squared-gradient estimates) is never touched and takes
        precedence at sync."""
        strategy = self.strategy
        for node, batch in zip(self.nodes, batches_per_node):
            if batch is None:
                continue
            old_params = node.params
            out = self.train_step_fn(
                node.params, node.opt_state, batch, self.step)
            grads = None
            if len(out) == 4:  # opt-in true-Fisher hook: per-step grads
                node.params, node.opt_state, metrics, grads = out
            else:
                node.params, node.opt_state, metrics = out
            if strategy.uses_stats:
                if node.fisher_stats is None:
                    node.fisher_stats = strategy.init_stats(old_params)
                if grads is not None:
                    node.fisher_stats = strategy.accumulate_grads(
                        node.fisher_stats, grads, self.step)
                else:
                    node.fisher_stats = strategy.accumulate(
                        node.fisher_stats, old_params, node.params, self.step)
            node.history.append({k: float(v) for k, v in metrics.items()})
        self.step += 1

    def maybe_sync(self, val_data_per_node: Sequence[Any], force: bool = False):
        if not force and (self.step == 0 or self.step % self.cfg.sync_every != 0):
            return None
        return self.sync(val_data_per_node)

    def sync(self, val_data_per_node: Sequence[Any]):
        """One full propose→validate→commit round. Returns the round log."""
        active = [n.active for n in self.nodes]
        sizes = [n.data_size for n in self.nodes]
        W = mixing_matrix(self.cfg, sizes, active=active)
        stacked = merge_lib.stack_params([n.params for n in self.nodes])
        strategy = self.strategy
        fishers = None
        if strategy.uses_stats:
            # explicit node.fisher wins over accumulated stats; a node with
            # neither gets ZERO mass (≈ excluded) — a ones_like default
            # would dwarf the lr²-scaled Δθ² mass of the trained nodes and
            # hand the merge to the untrained node
            masses = [
                n.fisher if n.fisher is not None
                else (n.fisher_stats if n.fisher_stats is not None
                      else jax.tree.map(jnp.zeros_like, n.params))
                for n in self.nodes]
            has_explicit = [n.fisher is not None for n in self.nodes]
            if any(has_explicit) and not all(has_explicit):
                # mixed sources: explicit squared-grad Fishers (~O(1)) and
                # the Δθ² proxy (~lr²) are on incomparable scales — one
                # explicit node would swallow the merge. Normalize each
                # node's mass to mean 1 first; per-element relative
                # importance survives, the source-scale mismatch doesn't.
                masses = [strategy.fishers(m) for m in masses]
            fishers = merge_lib.stack_params(masses)
            fishers = strategy.finalize_mass(fishers, np.asarray(active))
        weights = active_weights(sizes, active)
        rows = None
        if strategy.uses_stats and self.cfg.topology in ("ring", "dynamic"):
            # topology-restricted weighted merge: graph-neighbour rows only
            rows = strategy.topo_rows(jnp.asarray(W, jnp.float32),
                                      jnp.asarray(weights, jnp.float32))
        candidate, W_eff, imp = engine_lib.propose_host(
            stacked, self.cfg, W, fishers=fishers, weights=weights, rows=rows)
        cand_nodes = merge_lib.unstack_params(candidate, self.n)

        metric_local, metric_merged = [], []
        for node, cand, val in zip(self.nodes, cand_nodes, val_data_per_node):
            if node.active and val is not None:
                metric_local.append(float(self.eval_fn(node.params, val)))
                metric_merged.append(float(self.eval_fn(cand, val)))
            else:
                metric_local.append(1.0)
                metric_merged.append(0.0)  # inactive nodes never accept
        gates = np.array(gate_decisions(
            jnp.asarray(metric_merged), jnp.asarray(metric_local),
            self.cfg.val_threshold, mode="relative"))
        gates &= np.asarray(active)
        quorum = int(getattr(self.cfg, "quorum", 0) or 0)
        quorum_ok = True
        if quorum > 0:
            # same degradation policy as the compiled backends: below
            # quorum the round holds every node's locals (gates all closed)
            quorum_ok = int(np.asarray(active).sum()) >= quorum
            if not quorum_ok:
                gates[:] = False

        committed = engine_lib.commit_host(stacked, candidate, W_eff, gates,
                                           self.cfg, imp=imp)
        for i, node in enumerate(self.nodes):
            node.params = jax.tree.map(lambda x, i=i: x[i], committed)
        log = {"step": self.step, "gates": gates.tolist(),
               "metric_local": metric_local, "metric_merged": metric_merged,
               "spectral_gap": topo.spectral_gap(W)}
        if quorum > 0:
            log["quorum_ok"] = bool(quorum_ok)
        self.sync_log.append(log)
        return log

    def set_active(self, idx: int, active: bool):
        """Dynamic membership: node joins/leaves the swarm."""
        self.nodes[idx].active = active
