"""P2P-SL orchestration: propose → validate → gated commit.

The paper's loop (§3.1):
  1. nodes train locally for `sync_every` steps,
  2. exchange payloads (LoRA adapters, or full params) with peers,
  3. each node merges locally (weighted averaging),
  4. each node ACCEPTS the merge only if a local validation check clears the
     80% threshold; otherwise it keeps its own params (autonomy).

``SwarmLearner`` is the host-simulated N-node swarm used by the paper
reproduction (CNN, 4 nodes) and by the multi-arch examples on CPU.
The SPMD production path uses the same ``propose_merge``/``gated_commit``
pure functions with `repro.core.gossip` collectives (see launch/train.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig
import repro.core.topology as topo
from repro.core import merge_impl as merge_lib
from repro.core.lora import combine, split_adapters


# ---------------------------------------------------------------------------
# pure building blocks (shared by host-sim and SPMD paths)
# ---------------------------------------------------------------------------

def mixing_matrix(cfg: SwarmConfig, data_sizes: Sequence[float],
                  active: Optional[Sequence[bool]] = None) -> np.ndarray:
    weights = topo.fedavg_weights(data_sizes) if cfg.merge == "fedavg" else None
    return topo.build_matrix(cfg.topology, cfg.n_nodes,
                             weights=weights, self_weight=cfg.self_weight,
                             active=active)


def propose_merge(stacked, cfg: SwarmConfig, W, *, fishers=None, weights=None):
    """Merge candidate for every node. Honors lora_only payload selection."""
    if cfg.lora_only:
        adapters, base = split_adapters(stacked)
        merged_adapters = merge_lib.merge(
            adapters, cfg.merge if cfg.merge in ("fisher", "gradmatch") else "fedavg",
            W=W, fishers=split_adapters(fishers)[0] if fishers is not None else None,
            weights=weights)
        return combine(merged_adapters, base)
    method = cfg.merge if cfg.merge in ("fisher", "gradmatch") else "fedavg"
    return merge_lib.merge(stacked, method, W=W, fishers=fishers, weights=weights)


def gate_decisions(metric_merged, metric_local, threshold: float,
                   mode: str = "relative"):
    """Per-node accept bits. `relative`: merged ≥ thr × local (robust default);
    `absolute`: merged ≥ thr (the paper's literal 80% reading)."""
    m, l = jnp.asarray(metric_merged), jnp.asarray(metric_local)
    if mode == "relative":
        return m >= threshold * l
    return m >= threshold


def gated_commit(candidate, local, gates):
    """θ_i ← gate_i ? merged_i : local_i (leading node axis)."""
    g = jnp.asarray(gates)

    def one(c, l):
        if c is None or l is None:
            return c if l is None else l
        gb = g.reshape((g.shape[0],) + (1,) * (c.ndim - 1))
        return jnp.where(gb, c, l)

    return jax.tree.map(one, candidate, local, is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# host-simulated swarm (paper reproduction path)
# ---------------------------------------------------------------------------

@dataclass
class NodeState:
    params: Any
    opt_state: Any
    data_size: int
    fisher: Any = None
    active: bool = True
    history: list = field(default_factory=list)


@dataclass
class SwarmLearner:
    """N independent learners + periodic gated P2P merge (the paper's system).

    train_step_fn(params, opt_state, batch, step) -> (params, opt_state, metrics)
    eval_fn(params, val_data) -> scalar metric in [0,1]
    """

    cfg: SwarmConfig
    train_step_fn: Callable
    eval_fn: Callable
    nodes: List[NodeState]
    step: int = 0
    sync_log: list = field(default_factory=list)

    @property
    def n(self) -> int:
        return len(self.nodes)

    def local_steps(self, batches_per_node: Sequence[Any]):
        """One local step on every active node."""
        for node, batch in zip(self.nodes, batches_per_node):
            if not node.active or batch is None:
                continue
            node.params, node.opt_state, metrics = self.train_step_fn(
                node.params, node.opt_state, batch, self.step)
            node.history.append({k: float(v) for k, v in metrics.items()})
        self.step += 1

    def maybe_sync(self, val_data_per_node: Sequence[Any], force: bool = False):
        if not force and (self.step == 0 or self.step % self.cfg.sync_every != 0):
            return None
        return self.sync(val_data_per_node)

    def sync(self, val_data_per_node: Sequence[Any]):
        """One full propose→validate→commit round. Returns the round log."""
        active = [n.active for n in self.nodes]
        sizes = [n.data_size for n in self.nodes]
        W = mixing_matrix(self.cfg, sizes, active=active)
        stacked = merge_lib.stack_params([n.params for n in self.nodes])
        fishers = None
        if self.cfg.merge in ("fisher", "gradmatch"):
            fishers = merge_lib.stack_params(
                [n.fisher if n.fisher is not None else
                 jax.tree.map(jnp.ones_like, n.params) for n in self.nodes])
        weights = topo.fedavg_weights(sizes)
        candidate = propose_merge(stacked, self.cfg, W,
                                  fishers=fishers, weights=weights)
        cand_nodes = merge_lib.unstack_params(candidate, self.n)

        metric_local, metric_merged = [], []
        for node, cand, val in zip(self.nodes, cand_nodes, val_data_per_node):
            if node.active and val is not None:
                metric_local.append(float(self.eval_fn(node.params, val)))
                metric_merged.append(float(self.eval_fn(cand, val)))
            else:
                metric_local.append(1.0)
                metric_merged.append(0.0)  # inactive nodes never accept
        gates = np.array(gate_decisions(
            jnp.asarray(metric_merged), jnp.asarray(metric_local),
            self.cfg.val_threshold, mode="relative"))
        gates &= np.asarray(active)

        committed = gated_commit(candidate, stacked, gates)
        for i, node in enumerate(self.nodes):
            node.params = jax.tree.map(lambda x, i=i: x[i], committed)
        log = {"step": self.step, "gates": gates.tolist(),
               "metric_local": metric_local, "metric_merged": metric_merged,
               "spectral_gap": topo.spectral_gap(W)}
        self.sync_log.append(log)
        return log

    def set_active(self, idx: int, active: bool):
        """Dynamic membership: node joins/leaves the swarm."""
        self.nodes[idx].active = active
