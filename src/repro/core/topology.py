"""Swarm peer topologies as mixing matrices — host (numpy) AND traced (jax).

The paper's "dynamic networking" (§3.1) — nodes discover, join and leave the
swarm — is modeled as a time-varying row-stochastic **mixing matrix** W_t:
one gossip round maps node i's params to  θ_i ← Σ_j W_t[i,j] θ_j.

  full + FedAvg weights  → classic FedAvg (one-round consensus)
  ring                   → true peer-to-peer: each node touches only its two
                           graph neighbours per round (maps to collective_permute)
  dynamic                → membership-masked matrix; absent nodes are isolated
                           (W[i,i]=1) and contribute nothing — the paper's
                           join/leave semantics

Two families of builders:

  * ``build_matrix`` / ``full_matrix`` / ``ring_matrix`` / ``dynamic_matrix``
    — host-side numpy, for host-driven loops and analysis (spectral gap).
  * ``mixing_matrix_traced`` — the SAME construction fully in-graph from a
    **runtime** ``active`` mask plus the static topology kind, so a compiled
    swarm round handles join/leave/failure mid-run with zero retraces: the
    membership mask is data, not a compile-time constant.

Consensus rate is governed by the spectral gap 1-|λ₂(W)|; exposed here so
tests can assert the gossip contraction property.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


def fedavg_weights(data_sizes: Sequence[float]) -> np.ndarray:
    """Dataset-size-proportional weights (McMahan et al.)."""
    w = np.asarray(data_sizes, np.float64)
    if (w < 0).any() or w.sum() <= 0:
        raise ValueError("data sizes must be non-negative with positive sum")
    return w / w.sum()


def full_matrix(n: int, weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """Fully-connected merge: every node averages everyone (FedAvg if weighted)."""
    w = fedavg_weights(weights) if weights is not None else np.full(n, 1.0 / n)
    return np.tile(w[None, :], (n, 1))


def ring_matrix(n: int, self_weight: float = 0.5) -> np.ndarray:
    """Symmetric ring gossip: self + two neighbours. Doubly stochastic."""
    if not 0.0 < self_weight <= 1.0:
        raise ValueError("self_weight in (0,1]")
    side = (1.0 - self_weight) / 2.0
    W = np.zeros((n, n))  # noqa: SWL002 — n is a static python int; builds a trace-time constant consumed via jnp.asarray (mixing_matrix_traced)
    for i in range(n):
        W[i, i] = self_weight
        W[i, (i - 1) % n] += side
        W[i, (i + 1) % n] += side
    return W


def dynamic_matrix(base: np.ndarray, active: Sequence[bool]) -> np.ndarray:
    """Mask out absent nodes and renormalize rows; absent rows become identity.

    This is the paper's dynamic join/leave: an absent node neither sends nor
    receives; remaining nodes redistribute its weight proportionally.
    """
    n = base.shape[0]
    a = np.asarray(active, bool)
    W = base * a[None, :]                       # drop absent senders
    rows = W.sum(axis=1, keepdims=True)
    W = np.divide(W, rows, out=np.zeros_like(W), where=rows > 0)
    W[~a] = 0.0
    W[~a, ~a] = 1.0                              # absent nodes keep their params
    # a fully-isolated active row (all its peers absent) also keeps its params
    dead = (~a[None, :] | np.eye(n, dtype=bool))  # noqa: F841 (doc)
    for i in range(n):
        if a[i] and W[i].sum() == 0:
            W[i, i] = 1.0
    return W


# ---------------------------------------------------------------------------
# traced builders: W from a runtime active mask, inside jit/scan
# ---------------------------------------------------------------------------

def dynamic_matrix_traced(base, active):
    """In-graph :func:`dynamic_matrix`: mask absent senders, renormalize rows;
    absent/isolated rows fall back to identity (keep own params). ``active``
    may be a traced array — membership changes reuse the compiled round."""
    import jax.numpy as jnp

    base = jnp.asarray(base, jnp.float32)
    n = base.shape[0]
    a = jnp.asarray(active).astype(jnp.float32)
    W = base * a[None, :]
    rows = W.sum(1, keepdims=True)
    W = jnp.where(rows > 0, W / jnp.where(rows > 0, rows, 1.0), 0.0)
    eye = jnp.eye(n, dtype=jnp.float32)
    W = jnp.where(a[:, None] > 0, W, eye)   # absent nodes keep their params
    rows = W.sum(1, keepdims=True)
    return jnp.where(rows > 0, W, eye)      # fully-isolated active rows too


def mixing_matrix_traced(topology: str, active, *, weights=None,
                         self_weight: float = 0.5):
    """Mixing matrix built fully in-graph from a runtime ``active`` mask.

    ``topology`` is static (it fixes the graph family and therefore the
    program); ``active`` and ``weights`` are runtime data. Equivalent to
    ``dynamic_matrix(build_matrix(topology, n, ...), active)`` but traceable,
    so one compiled round serves every membership configuration.
    """
    import jax.numpy as jnp

    a = jnp.asarray(active).astype(jnp.float32)
    n = a.shape[0]
    if topology in ("full", "dynamic"):
        if weights is None:
            w = jnp.full((n,), 1.0 / n, jnp.float32)
        else:
            w = jnp.asarray(weights, jnp.float32)
            w = w / jnp.maximum(w.sum(), 1e-30)
        base = jnp.broadcast_to(w[None, :], (n, n))
    elif topology == "ring":
        base = jnp.asarray(ring_matrix(n, self_weight), jnp.float32)
    else:
        raise ValueError(f"unknown topology {topology!r}")
    return dynamic_matrix_traced(base, a)


def ring_structured(W: np.ndarray) -> bool:
    """True iff W only couples ring neighbours: W[i,j] == 0 whenever j is
    neither i nor i±1 (mod N). The invariant the ring-native two-``ppermute``
    gossip schedules (`core.gossip.ring_rows_gossip` /
    ``ring_topo_fisher_gossip``) rely on — membership masking
    (:func:`dynamic_matrix`) preserves it, because masking only zeroes and
    renormalizes entries. Host-side check for tests and debugging."""
    W = np.asarray(W)
    n = W.shape[0]
    idx = np.arange(n)
    allowed = np.zeros((n, n), bool)
    for off in (-1, 0, 1):
        allowed[idx, (idx + off) % n] = True
    return bool(np.all(W[~allowed] == 0.0))


def spectral_gap(W: np.ndarray) -> float:
    """1 - |λ₂|: per-round contraction rate of disagreement under gossip."""
    eig = np.linalg.eigvals(W)
    mags = np.sort(np.abs(eig))[::-1]
    return float(1.0 - (mags[1] if len(mags) > 1 else 0.0))


def build_matrix(topology: str, n: int, *, weights=None, self_weight=0.5,
                 active=None) -> np.ndarray:
    if topology == "full":
        W = full_matrix(n, weights)
    elif topology == "ring":
        W = ring_matrix(n, self_weight)
    elif topology == "dynamic":
        W = full_matrix(n, weights)
    else:
        raise ValueError(f"unknown topology {topology!r}")
    if active is not None:
        W = dynamic_matrix(W, active)
    return W
