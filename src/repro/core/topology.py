"""Swarm peer topologies as mixing matrices.

The paper's "dynamic networking" (§3.1) — nodes discover, join and leave the
swarm — is modeled as a time-varying row-stochastic **mixing matrix** W_t:
one gossip round maps node i's params to  θ_i ← Σ_j W_t[i,j] θ_j.

  full + FedAvg weights  → classic FedAvg (one-round consensus)
  ring                   → true peer-to-peer: each node touches only its two
                           graph neighbours per round (maps to collective_permute)
  dynamic                → membership-masked matrix; absent nodes are isolated
                           (W[i,i]=1) and contribute nothing — the paper's
                           join/leave semantics

Consensus rate is governed by the spectral gap 1-|λ₂(W)|; exposed here so
tests can assert the gossip contraction property.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


def fedavg_weights(data_sizes: Sequence[float]) -> np.ndarray:
    """Dataset-size-proportional weights (McMahan et al.)."""
    w = np.asarray(data_sizes, np.float64)
    if (w < 0).any() or w.sum() <= 0:
        raise ValueError("data sizes must be non-negative with positive sum")
    return w / w.sum()


def full_matrix(n: int, weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """Fully-connected merge: every node averages everyone (FedAvg if weighted)."""
    w = fedavg_weights(weights) if weights is not None else np.full(n, 1.0 / n)
    return np.tile(w[None, :], (n, 1))


def ring_matrix(n: int, self_weight: float = 0.5) -> np.ndarray:
    """Symmetric ring gossip: self + two neighbours. Doubly stochastic."""
    if not 0.0 < self_weight <= 1.0:
        raise ValueError("self_weight in (0,1]")
    side = (1.0 - self_weight) / 2.0
    W = np.zeros((n, n))
    for i in range(n):
        W[i, i] = self_weight
        W[i, (i - 1) % n] += side
        W[i, (i + 1) % n] += side
    return W


def dynamic_matrix(base: np.ndarray, active: Sequence[bool]) -> np.ndarray:
    """Mask out absent nodes and renormalize rows; absent rows become identity.

    This is the paper's dynamic join/leave: an absent node neither sends nor
    receives; remaining nodes redistribute its weight proportionally.
    """
    n = base.shape[0]
    a = np.asarray(active, bool)
    W = base * a[None, :]                       # drop absent senders
    rows = W.sum(axis=1, keepdims=True)
    W = np.divide(W, rows, out=np.zeros_like(W), where=rows > 0)
    W[~a] = 0.0
    W[~a, ~a] = 1.0                              # absent nodes keep their params
    # a fully-isolated active row (all its peers absent) also keeps its params
    dead = (~a[None, :] | np.eye(n, dtype=bool))  # noqa: F841 (doc)
    for i in range(n):
        if a[i] and W[i].sum() == 0:
            W[i, i] = 1.0
    return W


def spectral_gap(W: np.ndarray) -> float:
    """1 - |λ₂|: per-round contraction rate of disagreement under gossip."""
    eig = np.linalg.eigvals(W)
    mags = np.sort(np.abs(eig))[::-1]
    return float(1.0 - (mags[1] if len(mags) > 1 else 0.0))


def build_matrix(topology: str, n: int, *, weights=None, self_weight=0.5,
                 active=None) -> np.ndarray:
    if topology == "full":
        W = full_matrix(n, weights)
    elif topology == "ring":
        W = ring_matrix(n, self_weight)
    elif topology == "dynamic":
        W = full_matrix(n, weights)
    else:
        raise ValueError(f"unknown topology {topology!r}")
    if active is not None:
        W = dynamic_matrix(W, active)
    return W
