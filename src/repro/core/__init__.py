"""P2P Swarm Learning core — the paper's contribution as a composable module.

`SwarmSession` is the public entry point (one API over a `SwarmState` pytree
for the engine, gossip, and host backends); everything else is machinery it
composes and the function-form ground truths the tests pin down.
"""
from repro.core.engine import (  # noqa: F401
    SwarmEngine, active_weights, host_commit, strategy_propose,
)
from repro.core.merge_impl import (  # noqa: F401
    FisherStrategy, GradMatchStrategy, MergeStrategy, MixStrategy,
    fisher_merge, get_strategy, gradmatch_merge, merge, mix, stack_params,
    topo_weighted_merge, unstack_params,
)
from repro.core.session import SwarmSession, SwarmState  # noqa: F401
from repro.core.swarm import (  # noqa: F401
    NodeState, SwarmLearner, gate_decisions, gated_commit, mixing_matrix,
    propose_merge,
)
from repro.core.topology import (  # noqa: F401
    build_matrix, dynamic_matrix, fedavg_weights, full_matrix,
    mixing_matrix_traced, ring_matrix, spectral_gap,
)
