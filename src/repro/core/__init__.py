"""P2P Swarm Learning core — the paper's contribution as a composable module."""
from repro.core.engine import (  # noqa: F401
    SwarmEngine, active_weights, host_commit, strategy_propose,
)
from repro.core.merge_impl import (  # noqa: F401
    FisherStrategy, GradMatchStrategy, MergeStrategy, MixStrategy,
    fisher_merge, get_strategy, gradmatch_merge, merge, mix, stack_params,
    unstack_params,
)
from repro.core.swarm import (  # noqa: F401
    NodeState, SwarmLearner, gate_decisions, gated_commit, mixing_matrix,
    propose_merge,
)
from repro.core.topology import (  # noqa: F401
    build_matrix, dynamic_matrix, fedavg_weights, full_matrix, ring_matrix,
    spectral_gap,
)
