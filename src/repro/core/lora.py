"""LoRA adapters as the swarm exchange payload.

The paper's nodes exchange **LoRA-adapter weights only** (every 3 epochs,
gRPC/TLS). Here adapters are injected directly into the param pytree: any
2-D (or stacked 3-D, scan-over-layers) projection matrix named ``w`` under a
matching module gains ``lora_A``/``lora_B``/``lora_scale`` siblings, which
``repro.models.layers.linear`` applies transparently — zero model changes.

``split_adapters`` partitions a pytree into (adapters, base); the swarm sync
then merges only the adapter subtree, shrinking the gossip payload by ~99%
(see EXPERIMENTS.md §Perf for the measured collective-byte effect).
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
import jax.numpy as jnp

DEFAULT_TARGETS = r"(attn|cross|mlp|experts|in_proj|out_proj|lm_head|head)"


def inject_lora(params, key, rank: int = 16, alpha: float = 32.0,
                targets: str = DEFAULT_TARGETS):
    """Returns a new pytree with LoRA params added to matching linears."""
    keys = iter(jax.random.split(key, 4096))

    def rec(node, path):
        if not isinstance(node, dict):
            if isinstance(node, list):
                return [rec(v, f"{path}/{i}") for i, v in enumerate(node)]
            return node
        out = {k: rec(v, f"{path}/{k}") for k, v in node.items()}
        w = node.get("w")
        if (
            w is not None
            and hasattr(w, "ndim")
            and w.ndim in (2, 3)
            and re.search(targets, path)
            and "lora_A" not in node
        ):
            if w.ndim == 2:
                i, o = w.shape
                a_shape, b_shape = (i, rank), (rank, o)
                scale = jnp.asarray(alpha / rank, jnp.float32)
            else:  # stacked over layers: [L, in, out] — scale must scan too
                l, i, o = w.shape
                a_shape, b_shape = (l, i, rank), (l, rank, o)
                scale = jnp.full((l,), alpha / rank, jnp.float32)
            out["lora_A"] = (jax.random.normal(next(keys), a_shape)
                             / jnp.sqrt(rank)).astype(w.dtype)
            out["lora_B"] = jnp.zeros(b_shape, w.dtype)
            out["lora_scale"] = scale
        return out

    return rec(params, "")


def is_adapter_path(path: str) -> bool:
    return "lora_" in path


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return flat


def payload_path_str(path) -> str:
    """Canonical "/"-joined path string for a tree_*_with_path key tuple."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def flatten_payload(params, select=None):
    """Flatten the wire-payload subtree to ONE flat ``{path: leaf}`` dict.

    ``select(path_str) -> bool`` picks the leaves that cross the wire;
    the default is :func:`is_adapter_path` (``lora_`` leaves). The result is
    sorted by path, so every node with the same payload interface — whatever
    its backbone architecture — produces a structurally identical pytree
    that stacks along a node axis. :func:`unflatten_payload` is the inverse
    against a full-params template.

    This is THE single adapter flatten implementation (swarmlint SWL004
    sole-impl ``adapter_flatten``): engine, gossip, and kernel paths all
    share it, so payload membership can never silently diverge between what
    is merged, what is quantized, and what is checkpointed.
    """
    if select is None:
        select = is_adapter_path
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = {payload_path_str(p): x for p, x in flat
           if select(payload_path_str(p))}
    if not out:
        raise ValueError("flatten_payload: no leaf matched the payload "
                         "selector (nothing would cross the wire)")
    return dict(sorted(out.items()))


def unflatten_payload(flat, template):
    """Inverse of :func:`flatten_payload`: write the flat payload leaves
    back into a full-params ``template`` pytree (the frozen local backbone
    plus payload placeholders). Leaves whose path is not in ``flat`` pass
    through from the template untouched; gradients flow through the payload
    leaves only — exactly the frozen-backbone fine-tuning contract."""
    used = set()

    def sub(p, x):
        s = payload_path_str(p)
        if s in flat:
            used.add(s)
            return flat[s]
        return x

    out = jax.tree_util.tree_map_with_path(sub, template)
    missing = set(flat) - used
    if missing:
        raise ValueError("unflatten_payload: payload paths not present in "
                         f"the template: {sorted(missing)[:4]}")
    return out


def split_adapters(params, is_leaf=None) -> Tuple[dict, dict]:
    """(adapters, base) — same treedef, non-matching leaves replaced by None.

    is_leaf: forwarded to tree_map_with_path (needed when leaves are
    PartitionSpecs, which are tuple subclasses jax would recurse into).
    """
    def path_str(p):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)

    def select(pred):
        return jax.tree_util.tree_map_with_path(
            lambda p, x: x if pred(path_str(p)) else None, params,
            is_leaf=is_leaf)

    return select(is_adapter_path), select(lambda s: not is_adapter_path(s))


def combine(adapters, base):
    """Inverse of split_adapters."""
    return jax.tree.map(
        lambda a, b: a if b is None else b, adapters, base,
        is_leaf=lambda x: x is None)


def adapter_only(params):
    """Pytree with ONLY adapter leaves (others None) — the sync payload."""
    return split_adapters(params)[0]


def merge_lora_into_base(params):
    """Fold A@B into w and drop adapters (deployment export)."""
    def rec(node):
        if isinstance(node, list):
            return [rec(v) for v in node]
        if not isinstance(node, dict):
            return node
        out = {k: rec(v) for k, v in node.items() if not k.startswith("lora_")}
        if "lora_A" in node:
            a, b = node["lora_A"], node["lora_B"]
            scale = node["lora_scale"].astype(jnp.float32)
            delta = jnp.einsum("...ir,...ro->...io",
                               a.astype(jnp.float32), b.astype(jnp.float32))
            if scale.ndim == 1:  # stacked-over-layers scale [L]
                scale = scale[:, None, None]
            out["w"] = (node["w"].astype(jnp.float32) + scale * delta).astype(node["w"].dtype)
        return out

    return rec(params)


def payload_bytes(params, lora_only: bool) -> int:
    """Sync payload size — the paper's communication-efficiency claim."""
    tree = adapter_only(params) if lora_only else params
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(tree) if x is not None))
