"""Wire-efficient sync layer: cost model, schedule picker, quantized wire.

The paper's pitch is P2P sync cheap enough for resource-constrained clinics,
yet the merge machinery alone doesn't decide what actually crosses the wire:
the same topology × merge-strategy pair can lower to an ``all_gather`` that
moves N·P values per sync or to a two-``ppermute`` ring schedule that moves
4·P — and every payload can ride the wire compressed. This module is the one
place those decisions live; every backend routes through it:

  * **Cost model** — :func:`candidate_schedules` enumerates the collective
    schedules that are *correct* for a ``SwarmConfig`` (topology × merge ×
    shard layout), each with an analytic per-device bytes/sync formula
    (:class:`SyncSchedule`). :func:`pick_schedule` argmins the model — the
    engine's gossip backend dispatches on the winner at trace time, and the
    engine/host backends surface the equivalent schedule (``simulated=True``)
    so logs and benchmarks always report predicted wire cost.
  * **Quantized error-feedback wire** (``SwarmConfig.wire_dtype``) — peers
    exchange int8/bf16-quantized parameter *deltas* against a shared
    reference copy θ̂ (what the wire has already delivered), with per-block
    scales and the residual θ−θ̂ carried across rounds in ``SwarmState.wire``
    (f32 accumulation everywhere; only the wire payload is low-precision).
    :func:`wire_effective` is the XLA ground truth; the fused Pallas
    quantize→merge→dequantize commit (`kernels.fused_merge.
    fused_quant_merge_all`) re-derives the same values in one VMEM pass.

Schedule table (values moved per device per sync, P = payload params/node,
N = swarm size; wire dtype scales the point-to-point entries):

  topology   merge            schedule              values/sync   collective
  full       mean/fedavg      fedavg_psum           2P·(N−1)/N    psum
  ring       mean/fedavg      ring_ppermute         2P            ppermute
  dynamic    mean/fedavg      gathered_rows         N·P           all_gather
  full       fisher/gradmatch fisher_psum           4P·(N−1)/N    psum
  ring       fisher/gradmatch ring_topo_ppermute    4P            ppermute
  dynamic    fisher/gradmatch gathered_topo_stack   2N·P          all_gather

Ring schedules need one node per mesh shard (``per == 1``) and N ≥ 3 (an
N = 2 ring folds both neighbour edges onto one peer); otherwise the gathered
forms are the fallback. psum schedules allreduce in f32 (wire compression
does not commute with the reduction), so int8/bf16 wire can flip the argmin
toward a gathered/ppermute schedule — that is the point of the model.

Error-feedback contract: v_t = θ_t − θ̂_{t−1} is quantized per block of
``wire_block`` elements (scale = max|v|/127, round-half-even — fully
deterministic), θ̂_t = θ̂_{t−1} + dequant(v_t), so the residual θ_t − θ̂_t is
exactly the quantization error and telescopes: on constant inputs
‖residual‖ contracts by ≥ 127× per round toward zero.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp

WIRE_BYTES = {"f32": 4, "bf16": 2, "int8": 1}

#: nominal payload used to rank schedules when the real count isn't known yet
_NOMINAL_P = 1 << 20


def validate_wire_dtype(wire_dtype: str) -> str:
    wd = wire_dtype or "f32"
    if wd not in WIRE_BYTES:
        raise ValueError(f"unknown wire_dtype {wire_dtype!r} "
                         f"(choose from {sorted(WIRE_BYTES)})")
    return wd


def validate_wire_block(wire_block: int) -> int:
    if wire_block <= 0 or wire_block % 128:
        raise ValueError(f"wire_block must be a positive multiple of 128 "
                         f"(lane width), got {wire_block}")
    return wire_block


# ---------------------------------------------------------------------------
# cost model + schedule picker
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SyncSchedule:
    """One collective schedule with its analytic wire cost.

    ``payload_factor`` is the number of values moved per device per sync in
    units of P (the per-node payload param count) — the HLO result-shape
    convention `launch.hlo_stats` measures (all_gather counts its gathered
    result, psum its ring-allreduce traffic, ppermute each permuted payload).
    """

    name: str
    collective: str          # "psum" | "ppermute" | "all_gather" | "none"
    payload_factor: float
    wire_dtype: str = "f32"
    wire_block: int = 512
    simulated: bool = False  # engine/host backend: the SPMD-equivalent cost

    def bytes_per_sync(self, payload_params: int) -> float:
        """Predicted per-device wire bytes for one sync of P payload values."""
        vals = self.payload_factor * float(payload_params)
        out = vals * WIRE_BYTES[self.wire_dtype]
        if self.wire_dtype == "int8":  # one f32 scale per wire block
            out += vals / self.wire_block * 4.0
        return out

    def describe(self, payload_params: Optional[int] = None) -> str:
        p = _NOMINAL_P if payload_params is None else payload_params
        tag = " (simulated)" if self.simulated else ""
        return (f"{self.name}[{self.collective}/{self.wire_dtype}]{tag}: "
                f"{self.payload_factor:g}·P values, "
                f"{self.bytes_per_sync(p) / 1e6:.3f} MB/sync at P={p}")


def candidate_schedules(cfg, *, per: int = 1) -> List[SyncSchedule]:
    """Every schedule that is CORRECT for this config's sync semantics.

    ``per`` = stacked nodes per mesh shard (N // mesh axis size); ppermute
    schedules map one node to one shard, so they need ``per == 1``.
    """
    n = cfg.n_nodes
    wd = validate_wire_dtype(getattr(cfg, "wire_dtype", "f32"))
    wb = validate_wire_block(getattr(cfg, "wire_block", 512))
    weighted = cfg.merge in ("fisher", "gradmatch")
    ring_ok = cfg.topology == "ring" and per == 1 and n >= 3
    mk = lambda name, coll, factor, wdt: SyncSchedule(
        name, coll, factor, wire_dtype=wdt, wire_block=wb)

    out: List[SyncSchedule] = []
    if weighted:
        if cfg.topology == "full":
            # psums reduce in f32: compression doesn't commute with the sum
            out.append(mk("fisher_psum", "psum", 4.0 * (n - 1) / n, "f32"))
        out.append(mk("gathered_topo_stack", "all_gather", 2.0 * n, wd))
        if ring_ok:
            out.append(mk("ring_topo_ppermute", "ppermute", 4.0, wd))
    else:
        if cfg.topology == "full":
            out.append(mk("fedavg_psum", "psum", 2.0 * (n - 1) / n, "f32"))
        out.append(mk("gathered_rows", "all_gather", 1.0 * n, wd))
        if ring_ok:
            out.append(mk("ring_ppermute", "ppermute", 2.0, wd))
    return out


def pick_schedule(cfg, *, per: int = 1, payload_params: Optional[int] = None,
                  simulated: bool = False) -> SyncSchedule:
    """Cheapest correct schedule under the cost model (trace-time static:
    everything it consumes — topology, merge, wire dtype, N, shard layout —
    is config/mesh data, so the choice never retraces a compiled round)."""
    p = _NOMINAL_P if payload_params is None else payload_params
    cands = candidate_schedules(cfg, per=per)
    best = min(cands, key=lambda s: s.bytes_per_sync(p))
    if simulated:
        best = dataclasses.replace(best, simulated=True)
    return best


def payload_param_count(stacked, lora_only: bool, n_nodes: int) -> int:
    """Per-node payload values P for a stacked params pytree."""
    tree = stacked
    if lora_only:
        from repro.core.lora import split_adapters
        tree = split_adapters(stacked)[0]
    total = sum(x.size for x in jax.tree.leaves(tree) if x is not None)
    return int(total // max(n_nodes, 1))


# ---------------------------------------------------------------------------
# quantized wire: stateless per-block quant→dequant + error-feedback advance
# ---------------------------------------------------------------------------

def _leaf_quant_dequant(x, wire_dtype: str, wire_block: int):
    """Per-leaf quantize→dequantize of a stacked [N, ...] leaf (f32 out).

    int8: per-(node, block-of-``wire_block``-elements) max-abs scales,
    deterministic round-half-even — the exact arithmetic the fused Pallas
    commit kernel re-derives in its VMEM pass (same block grid from 0).
    """
    xf = jnp.asarray(x, jnp.float32)
    if wire_dtype == "f32":
        return xf
    if wire_dtype == "bf16":
        return xf.astype(jnp.bfloat16).astype(jnp.float32)
    n = xf.shape[0]
    flat = xf.reshape(n, -1)
    d = flat.shape[1]
    pad = (-d) % wire_block
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    blocks = flat.reshape(n, -1, wire_block)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.where(scale > 0, scale, 1.0)),
                 -127.0, 127.0)
    deq = (q * scale).reshape(n, -1)[:, :d]
    return deq.reshape(xf.shape)


def quant_dequant_tree(tree, wire_dtype: str, wire_block: int = 512):
    """Stateless wire round-trip of a stacked pytree (None leaves pass)."""
    wire_dtype = validate_wire_dtype(wire_dtype)
    if wire_dtype == "f32":
        return jax.tree.map(
            lambda x: None if x is None else jnp.asarray(x, jnp.float32),
            tree, is_leaf=lambda v: v is None)
    wire_block = validate_wire_block(wire_block)
    return jax.tree.map(
        lambda x: (None if x is None
                   else _leaf_quant_dequant(x, wire_dtype, wire_block)),
        tree, is_leaf=lambda v: v is None)


def init_wire(payload):
    """Zero wire reference θ̂ matching a stacked payload pytree (f32)."""
    return jax.tree.map(
        lambda x: None if x is None else jnp.zeros(x.shape, jnp.float32),
        payload, is_leaf=lambda v: v is None)


def wire_effective(payload, wire, wire_dtype: str, wire_block: int = 512):
    """Error-feedback wire advance: θ̂' = θ̂ + dequant(quant(θ − θ̂)).

    Returns the NEW reference θ̂' — simultaneously the effective params every
    peer reconstructs this round and the state to carry into the next one
    (the residual θ − θ̂' is exactly this round's quantization error, so
    untransmitted mass is never dropped, only delayed)."""
    wire_dtype = validate_wire_dtype(wire_dtype)
    wire_block = validate_wire_block(wire_block)

    def one(p, w):
        if p is None:
            return None
        v = jnp.asarray(p, jnp.float32) - w
        return w + _leaf_quant_dequant(v, wire_dtype, wire_block)

    return jax.tree.map(one, payload, wire, is_leaf=lambda v: v is None)


def wire_residual(payload, wire):
    """θ − θ̂: the untransmitted (error-feedback) mass per leaf."""
    return jax.tree.map(
        lambda p, w: None if p is None else jnp.asarray(p, jnp.float32) - w,
        payload, wire, is_leaf=lambda v: v is None)
