"""Wire-efficient sync layer: cost model, schedule picker, quantized wire.

The paper's pitch is P2P sync cheap enough for resource-constrained clinics,
yet the merge machinery alone doesn't decide what actually crosses the wire:
the same topology × merge-strategy pair can lower to an ``all_gather`` that
moves N·P values per sync or to a two-``ppermute`` ring schedule that moves
4·P — and every payload can ride the wire compressed. This module is the one
place those decisions live; every backend routes through it:

  * **Cost model** — :func:`candidate_schedules` enumerates the collective
    schedules that are *correct* for a ``SwarmConfig`` (topology × merge ×
    shard layout), each with an analytic per-device bytes/sync formula
    (:class:`SyncSchedule`). :func:`pick_schedule` argmins the model — the
    engine's gossip backend dispatches on the winner at trace time, and the
    engine/host backends surface the equivalent schedule (``simulated=True``)
    so logs and benchmarks always report predicted wire cost.
  * **Quantized error-feedback wire** (``SwarmConfig.wire_dtype``) — peers
    exchange int8/bf16-quantized parameter *deltas* against a shared
    reference copy θ̂ (what the wire has already delivered), with per-block
    scales and the residual θ−θ̂ carried across rounds in ``SwarmState.wire``
    (f32 accumulation everywhere; only the wire payload is low-precision).
    :func:`wire_effective` is the XLA ground truth; the fused Pallas
    quantize→merge→dequantize commit (`kernels.fused_merge.
    fused_quant_merge_all`) re-derives the same values in one VMEM pass.

Schedule table (values moved per device per sync, P = payload params/node,
N = swarm size; wire dtype scales the point-to-point entries, and int8 adds
4/wire_block bytes per value of scale overhead):

  topology   merge            schedule              values/sync   collective
  full       mean/fedavg      fedavg_psum           2P·(N−1)/N    psum
  full       mean/fedavg      fedavg_psum_q8        2P            reduce_scatter
  ring       mean/fedavg      ring_ppermute         2P            ppermute
  dynamic    mean/fedavg      gathered_rows         N·P           all_gather
  full       fisher/gradmatch fisher_psum           4P·(N−1)/N    psum
  full       fisher/gradmatch fisher_psum_q8        4P            reduce_scatter
  ring       fisher/gradmatch ring_topo_ppermute    4P            ppermute
  dynamic    fisher/gradmatch gathered_topo_stack   2N·P          all_gather

Ring schedules need one node per mesh shard (``per == 1``) and N ≥ 3 (an
N = 2 ring folds both neighbour edges onto one peer); otherwise the gathered
forms are the fallback. The plain psum schedules allreduce in f32 (wire
compression does not commute with the sum); an int8 wire adds the ``*_q8``
compression-aware reductions (`core.gossip`: quantized-chunk reduce-scatter
+ local dequant + quantized all_gather) whose payloads ride the wire at one
byte per value — the picker follows the bytes, not the table.

**Adapter-only (lora) payload class.** The factor formulas above are per
payload value, so they hold unchanged when only the LoRA adapters + decoder
head cross the wire (``cfg.lora_only`` carving the adapter subtree out of a
full state, or the heterogeneous ``cfg.payload = "lora"`` mode where the
stacked state IS the flat adapter payload — docs/heterogeneous.md). What
changes is P: the adapter count, orders of magnitude below the full model,
which compounds multiplicatively with the int8 wire (1 byte/value + scale
overhead vs 4). Every candidate carries its payload class in
``SyncSchedule.payload`` and CHANGES.md keeps a per-class values/sync table
that the drift gate re-derives from :func:`pick_schedule` in CI.

**Two-level (pod, node) meshes.** A swarm spanning pods has two link
classes: cheap intra-pod (ICI) links and the scarce cross-pod (DCN) hop.
On a 2-D mesh every schedule prices its traffic per class
(:meth:`SyncSchedule.bytes_by_link_class`): the flat schedules above run
over the joint ``("pod", "node")`` axis, so their collectives span pods and
the whole payload is classed *cross*; the hierarchical schedules
(``hier_fedavg_ring_q8`` / ``hier_fisher_ring_q8``, K pods × ``per_pod``
nodes, ring topology, int8 wire) keep the f32 bulk intra-pod — a weighted
intra-pod psum reduce, then each device carries a 1/per_pod chunk of its
pod's average onto a cross-pod int8 error-feedback ring (one delegate
chunk per device; k = 1 hop at K = 2 since the pair ring folds, else 2),
then an intra-pod all_gather broadcast. :func:`pick_schedule` argmins
Σ bytes(class) · ``cfg.{intra,cross}_pod_cost`` — with neutral costs the
flat forms win (they move fewer total bytes); once the DCN hop costs ≳5.4×
the ICI link, the hierarchical forms win. On 1-D meshes everything rides
one class and the picker reduces exactly to the PR 4/5 bytes argmin.

Error-feedback contract: v_t = θ_t − θ̂_{t−1} is quantized per block of
``wire_block`` elements (scale = max|v|/127, round-half-even — fully
deterministic), θ̂_t = θ̂_{t−1} + dequant(v_t), so the residual θ_t − θ̂_t is
exactly the quantization error and telescopes: on constant inputs
‖residual‖ contracts by ≥ 127× per round toward zero.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp

WIRE_BYTES = {"f32": 4, "bf16": 2, "int8": 1}

#: nominal payload used to rank schedules when the real count isn't known yet
_NOMINAL_P = 1 << 20


def validate_wire_dtype(wire_dtype: str) -> str:
    wd = wire_dtype or "f32"
    if wd not in WIRE_BYTES:
        raise ValueError(f"unknown wire_dtype {wire_dtype!r} "
                         f"(choose from {sorted(WIRE_BYTES)})")
    return wd


def validate_wire_block(wire_block: int) -> int:
    if wire_block <= 0 or wire_block % 128:
        raise ValueError(f"wire_block must be a positive multiple of 128 "
                         f"(lane width), got {wire_block}")
    return wire_block


PAYLOAD_MODES = ("full", "lora")


def payload_mode(cfg) -> str:
    """``cfg.payload`` with validation — what the stacked state covers.

    ``"full"`` (default): SwarmState.params is every node's full pytree and
    ``cfg.lora_only`` selects the adapter subtree at sync time. ``"lora"``:
    the heterogeneous-swarm mode — the state IS the wire payload (one flat
    path-keyed adapter dict per node, `core.lora.flatten_payload`) and each
    node's frozen backbone lives inside its closures (docs/heterogeneous.md).
    """
    mode = getattr(cfg, "payload", "full") or "full"
    if mode not in PAYLOAD_MODES:
        raise ValueError(f"unknown payload mode {mode!r} "
                         f"(choose from {PAYLOAD_MODES})")
    return mode


def split_payload_at_sync(cfg) -> bool:
    """True when sync must carve the adapter subtree out of a full state.

    In ``payload="lora"`` mode there is nothing to carve — the state already
    is the payload — so ``lora_only`` is satisfied structurally and the
    engine/host split-at-sync paths turn off."""
    if not getattr(cfg, "lora_only", False):
        return False
    return payload_mode(cfg) != "lora"


# ---------------------------------------------------------------------------
# cost model + schedule picker
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SyncSchedule:
    """One collective schedule with its analytic wire cost.

    ``payload_factor`` is the number of values moved per device per sync in
    units of P (the per-node payload param count) — the HLO result-shape
    convention `launch.hlo_stats` measures (all_gather counts its gathered
    result, psum its ring-allreduce traffic, ppermute each permuted payload).
    """

    name: str
    collective: str          # "psum" | "ppermute" | "all_gather" | "none"
    payload_factor: float
    wire_dtype: str = "f32"
    wire_block: int = 512
    simulated: bool = False  # engine/host backend: the SPMD-equivalent cost
    # Two-level (pod, node) split of payload_factor. cross_factor is None on
    # a flat mesh (one bandwidth domain — everything counts as intra). On a
    # 2-D mesh: cross_factor·P values cross pods at wire_dtype and
    # intra_factor·P values stay on intra-pod links at intra_dtype; flat
    # schedules built for a 2-D mesh set cross_factor = payload_factor
    # (their global collectives span the pod axis on every hop).
    cross_factor: Optional[float] = None
    intra_factor: float = 0.0
    intra_dtype: str = "f32"
    # payload class: "full" = whole param pytree crosses the wire; "lora" =
    # only the adapter subtree / adapter-only state does (P is then the
    # adapter count — orders of magnitude smaller, and it compounds with the
    # int8 wire). Purely descriptive for the factor formulas (identical per
    # class) but load-bearing for the CHANGES.md drift gate, which re-derives
    # the lora rows per class from pick_schedule.
    payload: str = "full"

    def _leg_bytes(self, vals: float, dtype: str) -> float:
        out = vals * WIRE_BYTES[dtype]
        if dtype == "int8":  # one f32 scale per wire block
            out += vals / self.wire_block * 4.0
        return out

    def bytes_by_link_class(self, payload_params: int) -> dict:
        """Predicted per-device wire bytes per link class for one sync.

        ``{"intra": ..., "cross": ...}`` — on a flat mesh everything is
        intra (there is no second class to cross)."""
        p = float(payload_params)
        if self.cross_factor is None:
            return {"intra": self._leg_bytes(self.payload_factor * p,
                                             self.wire_dtype),
                    "cross": 0.0}
        return {"intra": self._leg_bytes(self.intra_factor * p,
                                         self.intra_dtype),
                "cross": self._leg_bytes(self.cross_factor * p,
                                         self.wire_dtype)}

    def bytes_per_sync(self, payload_params: int) -> float:
        """Predicted per-device wire bytes for one sync of P payload values."""
        b = self.bytes_by_link_class(payload_params)
        return b["intra"] + b["cross"]

    def cost_per_sync(self, payload_params: int, intra_cost: float = 1.0,
                      cross_cost: float = 1.0) -> float:
        """Σ bytes(class) · cost(class): what :func:`pick_schedule` argmins.

        With equal per-byte costs this is plain bytes_per_sync, so flat-mesh
        picks are unchanged from the PR 4/5 bytes argmin."""
        b = self.bytes_by_link_class(payload_params)
        return b["intra"] * intra_cost + b["cross"] * cross_cost

    def describe(self, payload_params: Optional[int] = None) -> str:
        p = _NOMINAL_P if payload_params is None else payload_params
        tag = " (simulated)" if self.simulated else ""
        if self.payload != "full":
            tag = f"/{self.payload}{tag}"
        out = (f"{self.name}[{self.collective}/{self.wire_dtype}]{tag}: "
               f"{self.payload_factor:g}·P values, "
               f"{self.bytes_per_sync(p) / 1e6:.3f} MB/sync at P={p}")
        if self.cross_factor is not None:
            b = self.bytes_by_link_class(p)
            out += (f" [intra {b['intra'] / 1e6:.3f} MB + "
                    f"cross {b['cross'] / 1e6:.3f} MB]")
        return out


def candidate_schedules(cfg, *, per: int = 1, model_sharded: bool = False,
                        mesh_shape=None) -> List[SyncSchedule]:
    """Every schedule that is CORRECT for this config's sync semantics.

    ``per`` = stacked nodes per mesh shard (N // mesh axis size); ppermute
    schedules map one node to one shard, so they need ``per == 1``.
    ``model_sharded`` = payload leaves carry non-trivial inner (model-axis)
    PartitionSpecs; the q8 psum reductions chunk the globally-flattened
    payload and don't support that layout, so they drop out of the
    candidate set (the ring/gathered q8 forms handle inner specs).
    ``mesh_shape`` = (n_pods, per_pod) on a two-level ("pod", "node") mesh;
    flat candidates are then priced 100% cross-pod (their collectives span
    the pod axis) and the hierarchical pod-delegate candidates join the set.
    """
    n = cfg.n_nodes
    wd = validate_wire_dtype(getattr(cfg, "wire_dtype", "f32"))
    wb = validate_wire_block(getattr(cfg, "wire_block", 512))
    weighted = cfg.merge in ("fisher", "gradmatch")
    ring_ok = cfg.topology == "ring" and per == 1 and n >= 3
    psum_q8_ok = wd == "int8" and not model_sharded
    two_level = mesh_shape is not None
    # flat schedules on a 2-D mesh run over the joint axis: every hop of the
    # collective may cross pods, so the whole payload prices as cross-pod
    flat_kw = lambda factor: (
        {"cross_factor": factor, "intra_factor": 0.0} if two_level else {})
    pcls = ("lora" if (payload_mode(cfg) == "lora"
                       or getattr(cfg, "lora_only", False)) else "full")
    mk = lambda name, coll, factor, wdt: SyncSchedule(
        name, coll, factor, wire_dtype=wdt, wire_block=wb,
        payload=pcls, **flat_kw(factor))

    out: List[SyncSchedule] = []
    if weighted:
        if cfg.topology == "full":
            # psums reduce in f32: compression doesn't commute with the sum
            out.append(mk("fisher_psum", "psum", 4.0 * (n - 1) / n, "f32"))
            if psum_q8_ok:
                # compression-aware reduction: int8 reduce-scatter chunks
                # (all_to_all, P values/stream) + int8 all_gather of the
                # reduced chunks (P values/stream), two (num ⊕ mass) streams
                out.append(mk("fisher_psum_q8", "reduce_scatter", 4.0, wd))
        out.append(mk("gathered_topo_stack", "all_gather", 2.0 * n, wd))
        if ring_ok:
            out.append(mk("ring_topo_ppermute", "ppermute", 4.0, wd))
    else:
        if cfg.topology == "full":
            out.append(mk("fedavg_psum", "psum", 2.0 * (n - 1) / n, "f32"))
            if psum_q8_ok:
                out.append(mk("fedavg_psum_q8", "reduce_scatter", 2.0, wd))
        out.append(mk("gathered_rows", "all_gather", 1.0 * n, wd))
        if ring_ok:
            out.append(mk("ring_ppermute", "ppermute", 2.0, wd))

    if two_level:
        k_pods, per_pod = mesh_shape
        # hierarchical pod-delegate forms: intra-pod f32 psum reduce (
        # 2(per−1)/per values of ring-allreduce traffic) + cross-pod int8 EF
        # ring over per_pod-sharded delegate chunks (k·P/per_pod values,
        # k = 1 at K = 2 since the pair ring folds both edges onto one peer)
        # + intra-pod f32 all_gather broadcast (P values). Ring topology +
        # int8 wire + one node per device only — same constraints as the
        # flat ring q8 forms, minus the N ≥ 3 floor (the pod ring handles
        # K = 2 as a single chunk swap).
        hier_ok = (k_pods >= 2 and per_pod >= 2 and per == 1
                   and n == k_pods * per_pod and wd == "int8"
                   and not model_sharded and cfg.topology == "ring")
        if hier_ok:
            k_hops = 1.0 if k_pods == 2 else 2.0
            cross = k_hops / per_pod
            intra = 2.0 * (per_pod - 1) / per_pod + 1.0
            if weighted:
                out.append(SyncSchedule(
                    "hier_fisher_ring_q8", "hier_ring",
                    2.0 * (cross + intra), wire_dtype=wd, wire_block=wb,
                    cross_factor=2.0 * cross, intra_factor=2.0 * intra,
                    payload=pcls))
            else:
                out.append(SyncSchedule(
                    "hier_fedavg_ring_q8", "hier_ring", cross + intra,
                    wire_dtype=wd, wire_block=wb,
                    cross_factor=cross, intra_factor=intra, payload=pcls))
    return out


def has_inner_sharding(param_specs) -> bool:
    """True when a param-specs pytree names any non-trivial inner (model)
    axis — the layout the q8 psum reductions can't chunk."""
    if param_specs is None:
        return False
    from jax.sharding import PartitionSpec as PSpec
    leaves = jax.tree.leaves(param_specs,
                             is_leaf=lambda x: isinstance(x, PSpec))
    return any(any(d is not None for d in tuple(s))
               for s in leaves if isinstance(s, PSpec))


def pick_schedule(cfg, *, per: int = 1, payload_params: Optional[int] = None,
                  simulated: bool = False, model_sharded: bool = False,
                  mesh_shape=None) -> SyncSchedule:
    """Cheapest correct schedule under the cost model (trace-time static:
    everything it consumes — topology, merge, wire dtype, N, shard layout,
    mesh shape, link costs — is config/mesh data, so the choice never
    retraces a compiled round). On a two-level mesh the objective is
    Σ bytes(link class) · per-byte cost (``cfg.intra_pod_cost`` /
    ``cfg.cross_pod_cost``); on a flat mesh it reduces to the bytes argmin."""
    p = _NOMINAL_P if payload_params is None else payload_params
    cands = candidate_schedules(cfg, per=per, model_sharded=model_sharded,
                                mesh_shape=mesh_shape)
    intra_cost = float(getattr(cfg, "intra_pod_cost", 1.0))
    cross_cost = float(getattr(cfg, "cross_pod_cost", 1.0))
    best = min(cands, key=lambda s: s.cost_per_sync(p, intra_cost, cross_cost))
    if simulated:
        best = dataclasses.replace(best, simulated=True)
    return best


def payload_param_count(stacked, lora_only: bool, n_nodes: int) -> int:
    """Per-node payload values P for a stacked params pytree."""
    tree = stacked
    if lora_only:
        from repro.core.lora import split_adapters
        tree = split_adapters(stacked)[0]
    total = sum(x.size for x in jax.tree.leaves(tree) if x is not None)
    return int(total // max(n_nodes, 1))


# ---------------------------------------------------------------------------
# shared quantization core: THE per-block int8/bf16 round-trip implementation
# ---------------------------------------------------------------------------
# Every path that quantizes — the stateless XLA wire (`_leaf_quant_dequant`),
# the fused Pallas commit kernel (`kernels.fused_merge`), and the mesh gossip
# q8 schedules (`core.gossip`) — goes through these three functions, so the
# EF contract (scale = max|block|/127, round-half-even, clip ±127) has exactly
# one home and can never silently diverge between the gate candidate and the
# committed params.

def _block_quantize(v):
    """[..., n_blocks, wire_block] f32 → (q f32 int-valued, scale f32).

    scale = max|block|/127 (zero blocks keep scale 0 and quantize to 0);
    q = clip(round(v / scale), ±127) — deterministic round-half-even."""
    scale = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(v / jnp.where(scale > 0, scale, 1.0)),
                 -127.0, 127.0)
    return q, scale


def quant_dequant_block(v, wire_dtype: str, wire_block: int):
    """The single int8/bf16 round-trip over a [..., B] array (B a multiple of
    ``wire_block``; f32 out). Safe inside a Pallas kernel body (pure jnp on
    the tile) and equal bit-for-bit to ``quant_decode(*quant_encode(v))``."""
    vf = jnp.asarray(v, jnp.float32)
    if wire_dtype == "f32":
        return vf
    if wire_dtype == "bf16":
        return vf.astype(jnp.bfloat16).astype(jnp.float32)
    shape = vf.shape
    blocks = vf.reshape(shape[:-1] + (shape[-1] // wire_block, wire_block))
    q, scale = _block_quantize(blocks)
    return (q * scale).reshape(shape)


def quant_encode(v, wire_block: int):
    """[..., B] f32 (B a multiple of ``wire_block``) → the int8 wire payload
    ``(q int8 [..., B], scales f32 [..., B // wire_block])`` — what actually
    crosses a mesh collective on the q8 schedules."""
    vf = jnp.asarray(v, jnp.float32)
    shape = vf.shape
    blocks = vf.reshape(shape[:-1] + (shape[-1] // wire_block, wire_block))
    q, scale = _block_quantize(blocks)
    return q.astype(jnp.int8).reshape(shape), scale[..., 0]


def quant_decode(q, scales, wire_block: int):
    """Inverse of :func:`quant_encode`: (int8 payload, per-block scales) →
    the dequantized f32 values (== the sender's round-trip, bit-exact)."""
    qf = q.astype(jnp.float32)
    shape = qf.shape
    blocks = qf.reshape(shape[:-1] + (shape[-1] // wire_block, wire_block))
    return (blocks * scales[..., None]).reshape(shape)


# ---------------------------------------------------------------------------
# quantized wire: stateless per-block quant→dequant + error-feedback advance
# ---------------------------------------------------------------------------

def _leaf_quant_dequant(x, wire_dtype: str, wire_block: int):
    """Per-leaf quantize→dequantize of a stacked [N, ...] leaf (f32 out).

    Pads the flattened per-node payload to the ``wire_block`` grid and runs
    the shared :func:`quant_dequant_block` core — the exact arithmetic the
    fused Pallas commit kernel applies in its VMEM pass (same block grid
    from 0)."""
    xf = jnp.asarray(x, jnp.float32)
    if wire_dtype == "f32":
        return xf
    if wire_dtype == "bf16":
        return xf.astype(jnp.bfloat16).astype(jnp.float32)
    n = xf.shape[0]
    flat = xf.reshape(n, -1)
    d = flat.shape[1]
    pad = (-d) % wire_block
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    deq = quant_dequant_block(flat, wire_dtype, wire_block)
    return deq[:, :d].reshape(xf.shape)


def quant_dequant_tree(tree, wire_dtype: str, wire_block: int = 512):
    """Stateless wire round-trip of a stacked pytree (None leaves pass)."""
    wire_dtype = validate_wire_dtype(wire_dtype)
    if wire_dtype == "f32":
        return jax.tree.map(
            lambda x: None if x is None else jnp.asarray(x, jnp.float32),
            tree, is_leaf=lambda v: v is None)
    wire_block = validate_wire_block(wire_block)
    return jax.tree.map(
        lambda x: (None if x is None
                   else _leaf_quant_dequant(x, wire_dtype, wire_block)),
        tree, is_leaf=lambda v: v is None)


def init_wire(payload):
    """Zero wire reference θ̂ matching a stacked payload pytree (f32)."""
    return jax.tree.map(
        lambda x: None if x is None else jnp.zeros(x.shape, jnp.float32),
        payload, is_leaf=lambda v: v is None)


def wire_effective(payload, wire, wire_dtype: str, wire_block: int = 512):
    """Error-feedback wire advance: θ̂' = θ̂ + dequant(quant(θ − θ̂)).

    Returns the NEW reference θ̂' — simultaneously the effective params every
    peer reconstructs this round and the state to carry into the next one
    (the residual θ − θ̂' is exactly this round's quantization error, so
    untransmitted mass is never dropped, only delayed)."""
    wire_dtype = validate_wire_dtype(wire_dtype)
    wire_block = validate_wire_block(wire_block)

    def one(p, w):
        if p is None:
            return None
        v = jnp.asarray(p, jnp.float32) - w
        return w + _leaf_quant_dequant(v, wire_dtype, wire_block)

    return jax.tree.map(one, payload, wire, is_leaf=lambda v: v is None)


def wire_residual(payload, wire):
    """θ − θ̂: the untransmitted (error-feedback) mass per leaf."""
    return jax.tree.map(
        lambda p, w: None if p is None else jnp.asarray(p, jnp.float32) - w,
        payload, wire, is_leaf=lambda v: v is None)


def _mix32(v):
    """murmur3 fmix32: bijective avalanche on uint32 (each input bit flips
    ~half the output bits)."""
    v = v ^ (v >> jnp.uint32(16))
    v = v * jnp.uint32(0x85EBCA6B)
    v = v ^ (v >> jnp.uint32(13))
    v = v * jnp.uint32(0xC2B2AE35)
    v = v ^ (v >> jnp.uint32(16))
    return v


def payload_checksum(payload):
    """Per-node uint32 checksum of a stacked payload pytree ([N] uint32).

    Bit-level: every leaf row is bitcast to uint32, each element is
    position-salted (a Weyl sequence keyed on the flattened index and the
    leaf's position in the pytree) and avalanche-mixed before the mod-2³²
    per-node sum. The mixing matters: a plain sum lets symmetric multi-bit
    corruption cancel — k elements with the SAME bit toggled shift the sum
    by (#zeros−#ones)·2^bit, which is zero whenever the toggles balance
    (≈1/√k odds for random data). After mixing, every single-bit flip
    perturbs its element's contribution pseudorandomly, so collisions need
    a ~2⁻³² coincidence. Computed by sender and receiver of the quantized
    wire; a mismatch quarantines the sender for the round
    (reject-and-keep-local — see `SwarmEngine.sync` and docs/faults.md).
    Traceable and cheap: elementwise bitcast + mix + a per-node reduction.
    """
    leaves = [x for x in jax.tree.leaves(payload,
                                         is_leaf=lambda v: v is None)
              if x is not None]
    if not leaves:
        raise ValueError("payload_checksum: empty payload pytree")
    n = leaves[0].shape[0]
    total = jnp.zeros((n,), jnp.uint32)
    for i, x in enumerate(leaves):
        u = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32),
                                         jnp.uint32).reshape(n, -1)
        salt = (jnp.arange(u.shape[1], dtype=jnp.uint32)
                + jnp.uint32(i)) * jnp.uint32(0x9E3779B9)
        total = total + jnp.sum(_mix32(u ^ salt[None, :]), axis=1,
                                dtype=jnp.uint32)
    return total
