"""Wire-efficient sync layer: cost model, schedule picker, quantized wire.

The paper's pitch is P2P sync cheap enough for resource-constrained clinics,
yet the merge machinery alone doesn't decide what actually crosses the wire:
the same topology × merge-strategy pair can lower to an ``all_gather`` that
moves N·P values per sync or to a two-``ppermute`` ring schedule that moves
4·P — and every payload can ride the wire compressed. This module is the one
place those decisions live; every backend routes through it:

  * **Cost model** — :func:`candidate_schedules` enumerates the collective
    schedules that are *correct* for a ``SwarmConfig`` (topology × merge ×
    shard layout), each with an analytic per-device bytes/sync formula
    (:class:`SyncSchedule`). :func:`pick_schedule` argmins the model — the
    engine's gossip backend dispatches on the winner at trace time, and the
    engine/host backends surface the equivalent schedule (``simulated=True``)
    so logs and benchmarks always report predicted wire cost.
  * **Quantized error-feedback wire** (``SwarmConfig.wire_dtype``) — peers
    exchange int8/bf16-quantized parameter *deltas* against a shared
    reference copy θ̂ (what the wire has already delivered), with per-block
    scales and the residual θ−θ̂ carried across rounds in ``SwarmState.wire``
    (f32 accumulation everywhere; only the wire payload is low-precision).
    :func:`wire_effective` is the XLA ground truth; the fused Pallas
    quantize→merge→dequantize commit (`kernels.fused_merge.
    fused_quant_merge_all`) re-derives the same values in one VMEM pass.

Schedule table (values moved per device per sync, P = payload params/node,
N = swarm size; wire dtype scales the point-to-point entries, and int8 adds
4/wire_block bytes per value of scale overhead):

  topology   merge            schedule              values/sync   collective
  full       mean/fedavg      fedavg_psum           2P·(N−1)/N    psum
  full       mean/fedavg      fedavg_psum_q8        2P            reduce_scatter
  ring       mean/fedavg      ring_ppermute         2P            ppermute
  dynamic    mean/fedavg      gathered_rows         N·P           all_gather
  full       fisher/gradmatch fisher_psum           4P·(N−1)/N    psum
  full       fisher/gradmatch fisher_psum_q8        4P            reduce_scatter
  ring       fisher/gradmatch ring_topo_ppermute    4P            ppermute
  dynamic    fisher/gradmatch gathered_topo_stack   2N·P          all_gather

Ring schedules need one node per mesh shard (``per == 1``) and N ≥ 3 (an
N = 2 ring folds both neighbour edges onto one peer); otherwise the gathered
forms are the fallback. The plain psum schedules allreduce in f32 (wire
compression does not commute with the sum); an int8 wire adds the ``*_q8``
compression-aware reductions (`core.gossip`: quantized-chunk reduce-scatter
+ local dequant + quantized all_gather) whose payloads ride the wire at one
byte per value — the picker follows the bytes, not the table.

Error-feedback contract: v_t = θ_t − θ̂_{t−1} is quantized per block of
``wire_block`` elements (scale = max|v|/127, round-half-even — fully
deterministic), θ̂_t = θ̂_{t−1} + dequant(v_t), so the residual θ_t − θ̂_t is
exactly the quantization error and telescopes: on constant inputs
‖residual‖ contracts by ≥ 127× per round toward zero.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp

WIRE_BYTES = {"f32": 4, "bf16": 2, "int8": 1}

#: nominal payload used to rank schedules when the real count isn't known yet
_NOMINAL_P = 1 << 20


def validate_wire_dtype(wire_dtype: str) -> str:
    wd = wire_dtype or "f32"
    if wd not in WIRE_BYTES:
        raise ValueError(f"unknown wire_dtype {wire_dtype!r} "
                         f"(choose from {sorted(WIRE_BYTES)})")
    return wd


def validate_wire_block(wire_block: int) -> int:
    if wire_block <= 0 or wire_block % 128:
        raise ValueError(f"wire_block must be a positive multiple of 128 "
                         f"(lane width), got {wire_block}")
    return wire_block


# ---------------------------------------------------------------------------
# cost model + schedule picker
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SyncSchedule:
    """One collective schedule with its analytic wire cost.

    ``payload_factor`` is the number of values moved per device per sync in
    units of P (the per-node payload param count) — the HLO result-shape
    convention `launch.hlo_stats` measures (all_gather counts its gathered
    result, psum its ring-allreduce traffic, ppermute each permuted payload).
    """

    name: str
    collective: str          # "psum" | "ppermute" | "all_gather" | "none"
    payload_factor: float
    wire_dtype: str = "f32"
    wire_block: int = 512
    simulated: bool = False  # engine/host backend: the SPMD-equivalent cost

    def bytes_per_sync(self, payload_params: int) -> float:
        """Predicted per-device wire bytes for one sync of P payload values."""
        vals = self.payload_factor * float(payload_params)
        out = vals * WIRE_BYTES[self.wire_dtype]
        if self.wire_dtype == "int8":  # one f32 scale per wire block
            out += vals / self.wire_block * 4.0
        return out

    def describe(self, payload_params: Optional[int] = None) -> str:
        p = _NOMINAL_P if payload_params is None else payload_params
        tag = " (simulated)" if self.simulated else ""
        return (f"{self.name}[{self.collective}/{self.wire_dtype}]{tag}: "
                f"{self.payload_factor:g}·P values, "
                f"{self.bytes_per_sync(p) / 1e6:.3f} MB/sync at P={p}")


def candidate_schedules(cfg, *, per: int = 1,
                        model_sharded: bool = False) -> List[SyncSchedule]:
    """Every schedule that is CORRECT for this config's sync semantics.

    ``per`` = stacked nodes per mesh shard (N // mesh axis size); ppermute
    schedules map one node to one shard, so they need ``per == 1``.
    ``model_sharded`` = payload leaves carry non-trivial inner (model-axis)
    PartitionSpecs; the q8 psum reductions chunk the globally-flattened
    payload and don't support that layout, so they drop out of the
    candidate set (the ring/gathered q8 forms handle inner specs).
    """
    n = cfg.n_nodes
    wd = validate_wire_dtype(getattr(cfg, "wire_dtype", "f32"))
    wb = validate_wire_block(getattr(cfg, "wire_block", 512))
    weighted = cfg.merge in ("fisher", "gradmatch")
    ring_ok = cfg.topology == "ring" and per == 1 and n >= 3
    psum_q8_ok = wd == "int8" and not model_sharded
    mk = lambda name, coll, factor, wdt: SyncSchedule(
        name, coll, factor, wire_dtype=wdt, wire_block=wb)

    out: List[SyncSchedule] = []
    if weighted:
        if cfg.topology == "full":
            # psums reduce in f32: compression doesn't commute with the sum
            out.append(mk("fisher_psum", "psum", 4.0 * (n - 1) / n, "f32"))
            if psum_q8_ok:
                # compression-aware reduction: int8 reduce-scatter chunks
                # (all_to_all, P values/stream) + int8 all_gather of the
                # reduced chunks (P values/stream), two (num ⊕ mass) streams
                out.append(mk("fisher_psum_q8", "reduce_scatter", 4.0, wd))
        out.append(mk("gathered_topo_stack", "all_gather", 2.0 * n, wd))
        if ring_ok:
            out.append(mk("ring_topo_ppermute", "ppermute", 4.0, wd))
    else:
        if cfg.topology == "full":
            out.append(mk("fedavg_psum", "psum", 2.0 * (n - 1) / n, "f32"))
            if psum_q8_ok:
                out.append(mk("fedavg_psum_q8", "reduce_scatter", 2.0, wd))
        out.append(mk("gathered_rows", "all_gather", 1.0 * n, wd))
        if ring_ok:
            out.append(mk("ring_ppermute", "ppermute", 2.0, wd))
    return out


def has_inner_sharding(param_specs) -> bool:
    """True when a param-specs pytree names any non-trivial inner (model)
    axis — the layout the q8 psum reductions can't chunk."""
    if param_specs is None:
        return False
    from jax.sharding import PartitionSpec as PSpec
    leaves = jax.tree.leaves(param_specs,
                             is_leaf=lambda x: isinstance(x, PSpec))
    return any(any(d is not None for d in tuple(s))
               for s in leaves if isinstance(s, PSpec))


def pick_schedule(cfg, *, per: int = 1, payload_params: Optional[int] = None,
                  simulated: bool = False,
                  model_sharded: bool = False) -> SyncSchedule:
    """Cheapest correct schedule under the cost model (trace-time static:
    everything it consumes — topology, merge, wire dtype, N, shard layout —
    is config/mesh data, so the choice never retraces a compiled round)."""
    p = _NOMINAL_P if payload_params is None else payload_params
    cands = candidate_schedules(cfg, per=per, model_sharded=model_sharded)
    best = min(cands, key=lambda s: s.bytes_per_sync(p))
    if simulated:
        best = dataclasses.replace(best, simulated=True)
    return best


def payload_param_count(stacked, lora_only: bool, n_nodes: int) -> int:
    """Per-node payload values P for a stacked params pytree."""
    tree = stacked
    if lora_only:
        from repro.core.lora import split_adapters
        tree = split_adapters(stacked)[0]
    total = sum(x.size for x in jax.tree.leaves(tree) if x is not None)
    return int(total // max(n_nodes, 1))


# ---------------------------------------------------------------------------
# shared quantization core: THE per-block int8/bf16 round-trip implementation
# ---------------------------------------------------------------------------
# Every path that quantizes — the stateless XLA wire (`_leaf_quant_dequant`),
# the fused Pallas commit kernel (`kernels.fused_merge`), and the mesh gossip
# q8 schedules (`core.gossip`) — goes through these three functions, so the
# EF contract (scale = max|block|/127, round-half-even, clip ±127) has exactly
# one home and can never silently diverge between the gate candidate and the
# committed params.

def _block_quantize(v):
    """[..., n_blocks, wire_block] f32 → (q f32 int-valued, scale f32).

    scale = max|block|/127 (zero blocks keep scale 0 and quantize to 0);
    q = clip(round(v / scale), ±127) — deterministic round-half-even."""
    scale = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(v / jnp.where(scale > 0, scale, 1.0)),
                 -127.0, 127.0)
    return q, scale


def quant_dequant_block(v, wire_dtype: str, wire_block: int):
    """The single int8/bf16 round-trip over a [..., B] array (B a multiple of
    ``wire_block``; f32 out). Safe inside a Pallas kernel body (pure jnp on
    the tile) and equal bit-for-bit to ``quant_decode(*quant_encode(v))``."""
    vf = jnp.asarray(v, jnp.float32)
    if wire_dtype == "f32":
        return vf
    if wire_dtype == "bf16":
        return vf.astype(jnp.bfloat16).astype(jnp.float32)
    shape = vf.shape
    blocks = vf.reshape(shape[:-1] + (shape[-1] // wire_block, wire_block))
    q, scale = _block_quantize(blocks)
    return (q * scale).reshape(shape)


def quant_encode(v, wire_block: int):
    """[..., B] f32 (B a multiple of ``wire_block``) → the int8 wire payload
    ``(q int8 [..., B], scales f32 [..., B // wire_block])`` — what actually
    crosses a mesh collective on the q8 schedules."""
    vf = jnp.asarray(v, jnp.float32)
    shape = vf.shape
    blocks = vf.reshape(shape[:-1] + (shape[-1] // wire_block, wire_block))
    q, scale = _block_quantize(blocks)
    return q.astype(jnp.int8).reshape(shape), scale[..., 0]


def quant_decode(q, scales, wire_block: int):
    """Inverse of :func:`quant_encode`: (int8 payload, per-block scales) →
    the dequantized f32 values (== the sender's round-trip, bit-exact)."""
    qf = q.astype(jnp.float32)
    shape = qf.shape
    blocks = qf.reshape(shape[:-1] + (shape[-1] // wire_block, wire_block))
    return (blocks * scales[..., None]).reshape(shape)


# ---------------------------------------------------------------------------
# quantized wire: stateless per-block quant→dequant + error-feedback advance
# ---------------------------------------------------------------------------

def _leaf_quant_dequant(x, wire_dtype: str, wire_block: int):
    """Per-leaf quantize→dequantize of a stacked [N, ...] leaf (f32 out).

    Pads the flattened per-node payload to the ``wire_block`` grid and runs
    the shared :func:`quant_dequant_block` core — the exact arithmetic the
    fused Pallas commit kernel applies in its VMEM pass (same block grid
    from 0)."""
    xf = jnp.asarray(x, jnp.float32)
    if wire_dtype == "f32":
        return xf
    if wire_dtype == "bf16":
        return xf.astype(jnp.bfloat16).astype(jnp.float32)
    n = xf.shape[0]
    flat = xf.reshape(n, -1)
    d = flat.shape[1]
    pad = (-d) % wire_block
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    deq = quant_dequant_block(flat, wire_dtype, wire_block)
    return deq[:, :d].reshape(xf.shape)


def quant_dequant_tree(tree, wire_dtype: str, wire_block: int = 512):
    """Stateless wire round-trip of a stacked pytree (None leaves pass)."""
    wire_dtype = validate_wire_dtype(wire_dtype)
    if wire_dtype == "f32":
        return jax.tree.map(
            lambda x: None if x is None else jnp.asarray(x, jnp.float32),
            tree, is_leaf=lambda v: v is None)
    wire_block = validate_wire_block(wire_block)
    return jax.tree.map(
        lambda x: (None if x is None
                   else _leaf_quant_dequant(x, wire_dtype, wire_block)),
        tree, is_leaf=lambda v: v is None)


def init_wire(payload):
    """Zero wire reference θ̂ matching a stacked payload pytree (f32)."""
    return jax.tree.map(
        lambda x: None if x is None else jnp.zeros(x.shape, jnp.float32),
        payload, is_leaf=lambda v: v is None)


def wire_effective(payload, wire, wire_dtype: str, wire_block: int = 512):
    """Error-feedback wire advance: θ̂' = θ̂ + dequant(quant(θ − θ̂)).

    Returns the NEW reference θ̂' — simultaneously the effective params every
    peer reconstructs this round and the state to carry into the next one
    (the residual θ − θ̂' is exactly this round's quantization error, so
    untransmitted mass is never dropped, only delayed)."""
    wire_dtype = validate_wire_dtype(wire_dtype)
    wire_block = validate_wire_block(wire_block)

    def one(p, w):
        if p is None:
            return None
        v = jnp.asarray(p, jnp.float32) - w
        return w + _leaf_quant_dequant(v, wire_dtype, wire_block)

    return jax.tree.map(one, payload, wire, is_leaf=lambda v: v is None)


def wire_residual(payload, wire):
    """θ − θ̂: the untransmitted (error-feedback) mass per leaf."""
    return jax.tree.map(
        lambda p, w: None if p is None else jnp.asarray(p, jnp.float32) - w,
        payload, wire, is_leaf=lambda v: v is None)
