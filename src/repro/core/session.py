"""SwarmSession: ONE backend-agnostic entry point for P2P swarm learning.

The paper ships three ways to run the same algorithm — the host-simulated
`SwarmLearner` loop, the compiled `SwarmEngine`, and the SPMD gossip path in
`launch.train` — each with its own constructor, state threading, and
checkpoint story. `SwarmSession` collapses them behind a single API driven by
one pytree, :class:`SwarmState`:

    session = SwarmSession(cfg, train_step, eval_fn, params=params,
                           data_sizes=sizes)          # backend="engine"
    log = session.round(batches, val)                 # T steps + gated sync
    session.leave(3); session.round(batches, val)     # zero retraces
    session.join(3)
    session.save("ckpt.msgpack")
    session = SwarmSession.restore("ckpt.msgpack", cfg, train_step, eval_fn,
                                   params=params, data_sizes=sizes)

Backends (construction-time choice; the API is identical):

  * ``"engine"``  — the compiled stacked round (N param copies on one
    device): vmapped local steps, in-graph gate, fused Pallas commit.
  * ``"gossip"``  — the same round with the merge realized as mesh
    collectives (leading node axis sharded over ``axis``).
  * ``"host"``    — arbitrary (non-traceable) Python ``train_step_fn`` /
    ``eval_fn`` callables via the `SwarmLearner` loop; the compatibility
    path. Batches are ``[T][N]`` nested lists of per-node batch objects and
    ``val`` is an ``[N]`` list, instead of stacked arrays.

Dynamic membership is **runtime state**: ``session.join(i)`` / ``leave(i)``
flip one element of ``SwarmState.active`` — a device array consumed by the
traced topology builder (`topology.mixing_matrix_traced`), so a join→leave→
rejoin schedule mid-``run_rounds`` reuses the same compiled round with zero
retraces. Checkpoints round-trip the FULL state — params, opt state, merge-
strategy importance accumulators, membership mask, rng, and round/step
counters — through `checkpointing.io`.
"""
from __future__ import annotations

import dataclasses
import logging
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import load_metadata, load_pytree, save_pytree
from repro.configs.base import SwarmConfig
from repro.core import comms
from repro.core import merge_impl as merge_lib
from repro.core.engine import SwarmEngine
from repro.kernels.fused_merge import DEFAULT_BLOCK

logger = logging.getLogger(__name__)


@dataclass
class SwarmState:
    """The whole swarm as one pytree (every backend consumes and returns it).

    params / opt_state / stats are **stacked** pytrees (leading node axis N);
    ``stats`` carries the merge strategy's importance accumulators (None for
    mean/fedavg). ``wire`` is the quantized-sync error-feedback state: the
    θ̂ reference on the engine backend (`core.comms`), the schedule-specific
    sharded mesh EF pytree on the gossip backend (`core.gossip`), and None
    unless ``cfg.wire_dtype`` enables stateful wire compression.
    ``active`` is the runtime membership mask, ``rng`` a (legacy uint32)
    PRNG key folded once per round, ``round``/``step`` the global counters.
    All fields are data — membership changes, resumed counters, and reseeded
    rngs never trigger a recompile.
    """

    params: Any
    opt_state: Any = None
    stats: Any = None
    wire: Any = None
    active: Any = None
    rng: Any = None
    round: Any = 0
    step: Any = 0


jax.tree_util.register_dataclass(
    SwarmState,
    data_fields=["params", "opt_state", "stats", "wire", "active", "rng",
                 "round", "step"],
    meta_fields=[])


def _stack_per_node(value, n: int):
    """list/tuple of N per-node pytrees -> stacked; single pytree -> tiled.

    A TOP-LEVEL list/tuple is always read as "one entry per node". Params
    whose own pytree root is a list/tuple (e.g. a plain list of per-layer
    arrays) must therefore be wrapped — ``params=[p] * cfg.n_nodes`` — or
    passed pre-stacked via ``stacked=True``; they cannot be disambiguated
    from a per-node list by inspection.
    """
    if value is None:
        return None
    if isinstance(value, (list, tuple)):
        if len(value) != n:
            raise ValueError(
                f"expected {n} per-node pytrees, got a length-{len(value)} "
                "list/tuple. A top-level list/tuple is interpreted as one "
                "entry per node — wrap a list-rooted params pytree as "
                "[params] * n_nodes, or pass it pre-stacked (stacked=True)")
        return merge_lib.stack_params(list(value))
    return merge_lib.stack_params([value] * n)


class SwarmSession:
    """Backend-agnostic swarm driver over a single :class:`SwarmState`.

    Parameters
    ----------
    cfg : SwarmConfig
    train_step_fn : ``(params, opt_state, batch, step) -> (params, opt_state,
        metrics)`` — or the opt-in true-Fisher 4-tuple form that additionally
        returns per-step grads. Must be traceable for the engine/gossip
        backends; arbitrary Python for ``backend="host"``.
    eval_fn : ``(params, val) -> scalar in [0, 1]`` (same traceability rule).
        Both fns may instead be a LIST of ``n_nodes`` per-node closures
        (model zoo: heterogeneous frozen backbones captured per closure,
        shared adapter payload as the state — ``cfg.payload="lora"``,
        engine backend only; see docs/heterogeneous.md).
    params / opt_state : a single per-node pytree (replicated N times), a
        list of N pytrees, or — with ``stacked=True`` — an already-stacked
        pytree with leading node axis.
    data_sizes : per-node dataset sizes (fedavg / weighted-merge weights).
    backend : ``"engine"`` (default) | ``"gossip"`` | ``"host"``.
    mesh / axis / param_specs : gossip backend placement; ``axis`` is a mesh
        axis name, or a 2-tuple ``("pod", "node")`` on a two-level mesh —
        gossip then runs over the joint axis and the per-link-class cost
        model may pick the hierarchical pod-delegate schedules.
    seed : session rng seed (defaults to ``cfg.seed``).
    """

    def __init__(self, cfg: SwarmConfig, train_step_fn: Optional[Callable],
                 eval_fn: Optional[Callable], *, params=None, opt_state=None,
                 data_sizes: Optional[Sequence[float]] = None,
                 backend: str = "engine", mesh=None, axis: Optional[str] = None,
                 param_specs=None, block: int = DEFAULT_BLOCK,
                 interpret: Optional[bool] = None, strategy=None,
                 seed: Optional[int] = None, stacked: bool = False):
        if backend not in ("engine", "gossip", "host"):
            raise ValueError(f"unknown backend {backend!r}")
        self.cfg = cfg
        self.backend = backend
        self.train_step_fn = train_step_fn
        self.eval_fn = eval_fn
        n = cfg.n_nodes
        if stacked:
            stacked_params, stacked_opt = params, opt_state
        else:
            stacked_params = _stack_per_node(params, n)
            stacked_opt = _stack_per_node(opt_state, n)
        if stacked_params is None:
            raise ValueError("SwarmSession needs initial params")
        rng = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        wire_dtype = comms.validate_wire_dtype(
            getattr(cfg, "wire_dtype", "f32"))
        if wire_dtype != "f32" and backend == "host":
            raise ValueError(
                "wire_dtype compression needs a compiled backend "
                '(backend="engine" carries the error-feedback reference; '
                '"gossip" carries the sharded mesh EF state for int8 and '
                "casts bf16); the host loop is uncompressed")
        if comms.payload_mode(cfg) == "lora" and backend == "host":
            raise ValueError(
                'payload="lora" (adapter-only state, heterogeneous '
                "backbones in per-node closures) needs a compiled backend; "
                "the host loop threads full per-node param pytrees")
        if (backend == "host"
                and (isinstance(train_step_fn, (list, tuple))
                     or isinstance(eval_fn, (list, tuple)))):
            raise ValueError(
                "per-node closure lists (model zoo) are engine-backend "
                "only; the host loop applies one callable to every node")

        if backend == "host":
            from repro.core.swarm import NodeState, SwarmLearner
            sizes = (np.ones(n) if data_sizes is None
                     else np.asarray(data_sizes, np.float64))
            nodes = [NodeState(params=p, opt_state=o, data_size=float(s))
                     for p, o, s in zip(
                         merge_lib.unstack_params(stacked_params, n),
                         (merge_lib.unstack_params(stacked_opt, n)
                          if stacked_opt is not None else [None] * n),
                         sizes)]
            self._learner = SwarmLearner(cfg, train_step_fn, eval_fn, nodes)
            self._rng = rng
            self._round_ct = 0
            self.engine = None
            self.sync_schedule = comms.pick_schedule(cfg, simulated=True)
            self.payload_params = comms.payload_param_count(
                stacked_params, comms.split_payload_at_sync(cfg), n)
            self.predicted_sync_bytes = self.sync_schedule.bytes_per_sync(
                self.payload_params)
            self.predicted_link_bytes = self.sync_schedule.bytes_by_link_class(
                self.payload_params)
            return

        self.engine = SwarmEngine(
            cfg, train_step_fn, eval_fn, data_sizes=data_sizes,
            backend="gossip" if backend == "gossip" else "host",
            mesh=mesh, axis=axis, param_specs=param_specs, block=block,
            interpret=interpret, strategy=strategy)
        # error-feedback wire state for the quantized sync — the engine
        # backend carries the θ̂ reference (shaped like the sync payload,
        # adapters only under lora_only); the gossip backend carries the
        # schedule-specific sharded mesh EF pytree; bf16-on-mesh is a
        # stateless cast (no state)
        wire = self.engine._auto_wire(stacked_params, None)
        self._state = SwarmState(
            params=stacked_params, opt_state=stacked_opt,
            stats=self.engine.init_stats(stacked_params), wire=wire,
            active=jnp.ones((n,), bool), rng=rng,
            round=jnp.asarray(0, jnp.int32), step=jnp.asarray(0, jnp.int32))
        # cost-model-driven schedule choice, surfaced for logs/benchmarks;
        # predicted_link_bytes splits the prediction per link class on a
        # two-level ("pod", "node") mesh ({"intra": ..., "cross": ...})
        self.sync_schedule = self.engine.sync_schedule
        self.payload_params = comms.payload_param_count(
            stacked_params, comms.split_payload_at_sync(cfg), n)
        self.predicted_sync_bytes = self.sync_schedule.bytes_per_sync(
            self.payload_params)
        self.predicted_link_bytes = self.sync_schedule.bytes_by_link_class(
            self.payload_params)
        logger.info("sync schedule: %s",
                    self.sync_schedule.describe(self.payload_params))
        # the three compiled drivers; the state buffer is donated, so every
        # call consumes self._state and replaces it with the result
        self._round_jit = jax.jit(self._round_impl, donate_argnums=(0,))
        self._rounds_jit = jax.jit(self._rounds_impl, donate_argnums=(0,))
        self._local_jit = jax.jit(self._local_impl, donate_argnums=(0,))

    # -- state ---------------------------------------------------------------

    @property
    def state(self) -> SwarmState:
        if self.backend != "host":
            return self._state
        lr = self._learner
        strategy = lr.strategy
        stats = None
        if strategy.uses_stats:
            stats = merge_lib.stack_params([
                nd.fisher_stats if nd.fisher_stats is not None
                else strategy.init_stats(nd.params)
                for nd in lr.nodes])
        opt = (None if all(nd.opt_state is None for nd in lr.nodes)
               else merge_lib.stack_params([nd.opt_state for nd in lr.nodes]))
        return SwarmState(
            params=merge_lib.stack_params([nd.params for nd in lr.nodes]),
            opt_state=opt, stats=stats,
            active=jnp.asarray([nd.active for nd in lr.nodes]),
            rng=self._rng, round=jnp.asarray(self._round_ct, jnp.int32),
            step=jnp.asarray(lr.step, jnp.int32))

    def load_state(self, state: SwarmState) -> None:
        """Replace the session's state (all backends)."""
        if self.backend != "host":
            self._state = state
            return
        lr = self._learner
        n = self.cfg.n_nodes
        ps = merge_lib.unstack_params(state.params, n)
        os_ = (merge_lib.unstack_params(state.opt_state, n)
               if state.opt_state is not None else [None] * n)
        sts = (merge_lib.unstack_params(state.stats, n)
               if state.stats is not None else [None] * n)
        active = np.asarray(state.active)
        for i, nd in enumerate(lr.nodes):
            nd.params, nd.opt_state, nd.fisher_stats = ps[i], os_[i], sts[i]
            nd.active = bool(active[i])
        self._rng = jnp.asarray(state.rng)
        self._round_ct = int(state.round)
        lr.step = int(state.step)

    @property
    def node_params(self):
        """Per-node (unstacked) parameter pytrees."""
        if self.backend == "host":
            return [nd.params for nd in self._learner.nodes]
        return merge_lib.unstack_params(self._state.params, self.cfg.n_nodes)

    @property
    def active(self) -> np.ndarray:
        if self.backend == "host":
            return np.asarray([nd.active for nd in self._learner.nodes])
        return np.asarray(self.state.active)

    # -- dynamic membership (runtime data; never recompiles) -----------------

    def join(self, node: int) -> None:
        """Node (re-)joins the swarm: flips one element of the active mask."""
        self._set_active_index(node, True)

    def leave(self, node: int) -> None:
        """Node leaves the swarm: excluded from every merge (its params and
        importance mass enter nobody's candidate, its own params pass through
        commits untouched). Local training is governed by DATA, not
        membership — on every backend a departed node keeps training on
        whatever batches the caller still supplies; feed it ``None`` (host)
        or padding it can ignore (engine) to pause it entirely."""
        self._set_active_index(node, False)

    def set_active(self, mask) -> None:
        if self.backend == "host":
            for i, v in enumerate(np.asarray(mask)):
                self._learner.nodes[i].active = bool(v)
            return
        self._state = dataclasses.replace(
            self._state, active=jnp.asarray(mask).astype(bool))

    def _set_active_index(self, node: int, value: bool) -> None:
        if self.backend == "host":
            self._learner.nodes[node].active = value
            return
        self._state = dataclasses.replace(
            self._state, active=self._state.active.at[node].set(value))

    def quarantine_wire(self, node: Optional[int] = None) -> None:
        """Reset the error-feedback wire state for a crash→rejoin.

        A node that left and came back holds a θ̂ reference the survivors
        kept advancing without it — telescoping against the stale reference
        would commit the divergence as if it were quantization error, so
        the rejoiner's EF state must be quarantined before its first sync.

        engine backend: zero ONE node's rows of the θ̂ reference (the next
        sync retransmits that node's full payload; everyone else's EF
        residual is untouched). gossip backend: the mesh EF pytree is a
        schedule-shaped sharded structure whose neighbour replicas must
        track the reference bit-exactly, so per-node surgery is unsafe —
        the whole mesh wire resets (`gossip.reset_mesh_wire`) and EF
        re-settles for everyone. No-op without wire state or on the host
        backend (uncompressed). Pure data update: never retraces.
        """
        if self.backend == "host" or self._state.wire is None:
            return
        wire = self._state.wire
        if self.backend == "engine" and node is not None:
            new_wire = jax.tree.map(
                lambda x: None if x is None else x.at[node].set(0),
                wire, is_leaf=lambda v: v is None)
        else:
            from repro.core import gossip
            new_wire = gossip.reset_mesh_wire(wire)
        self._state = dataclasses.replace(self._state, wire=new_wire)

    # -- compiled round bodies (engine / gossip backends) --------------------
    # Thin SwarmState adapters over the engine's round implementations — the
    # serial and stale-by-one overlap scan bodies have exactly one home
    # (`SwarmEngine._round` / `_run_rounds` / `_run_local`).

    def _round_impl(self, state: SwarmState, batches, val, faults=None):
        t = jax.tree.leaves(batches)[0].shape[0]
        p, o, out = self.engine._round(state.params, state.opt_state, batches,
                                       val, state.active, state.step,
                                       state.stats, state.wire, faults)
        st = out.pop("stats", None)
        wr = out.pop("wire", state.wire)
        new = SwarmState(
            params=p, opt_state=o, stats=st, wire=wr, active=state.active,
            rng=jax.random.fold_in(state.rng, state.round),
            round=state.round + 1, step=state.step + t)
        return new, out

    def _rounds_impl(self, state: SwarmState, batches, val):
        shape = jax.tree.leaves(batches)[0].shape
        r, t = shape[0], shape[1]
        p, o, tm, logs = self.engine._run_rounds(
            state.params, state.opt_state, batches, val, state.active,
            state.step, state.stats, state.wire)
        st = logs.pop("stats", None)
        wr = logs.pop("wire", state.wire)
        rng = state.rng
        for i in range(r):  # same per-round folds as r successive round()s
            rng = jax.random.fold_in(rng, state.round + i)
        new = SwarmState(
            params=p, opt_state=o, stats=st, wire=wr, active=state.active,
            rng=rng, round=state.round + r, step=state.step + r * t)
        return new, tm, logs

    def _local_impl(self, state: SwarmState, batches):
        s_count = jax.tree.leaves(batches)[0].shape[0]
        p, o, tm, st = self.engine._run_local(
            state.params, state.opt_state, batches, state.step, state.stats)
        new = dataclasses.replace(state, params=p, opt_state=o, stats=st,
                                  step=state.step + s_count)
        return new, tm

    # -- drivers -------------------------------------------------------------

    def round(self, batches, val, faults=None):
        """One full round: ``sync_every`` local steps + gated sync.

        engine/gossip: ``batches`` is a stacked ``[T, N, ...]`` pytree, the
        whole round runs as one compiled call, and the log holds device
        arrays ``gates`` / ``metric_local`` / ``metric_merged`` (each [N])
        plus ``train`` ([T, N] per-step metrics). host: ``batches`` is a
        ``[T][N]`` nested list of per-node batch objects, ``val`` an ``[N]``
        list, and the log is the `SwarmLearner` sync record — same
        ``gates``/``metric_local``/``metric_merged`` keys as Python lists,
        plus ``step``/``spectral_gap``; per-step train metrics live in each
        node's ``history`` instead of a ``train`` key.

        ``faults``: optional `repro.faults.signals.FaultSignals` for
        in-graph corrupt-wire injection (engine backend with a quantized
        wire only — see `SwarmEngine.sync`). Thread a signal (possibly
        `faults.idle_signals`) every round to keep one compiled trace.
        """
        if self.backend == "host":
            if faults is not None:
                raise ValueError(
                    "in-graph fault injection (faults=) needs a compiled "
                    "backend; lower corrupt events to drops on the host loop")
            return self._host_round(batches, val)
        self._state, out = self._round_jit(self._state, batches, val, faults)
        return out

    def run_rounds(self, batches, val):
        """R rounds over ``[R, T, N, ...]`` batches, scanned on-device
        (engine/gossip) or looped (host). Returns per-round logs — stacked
        [R, ...] arrays with a ``train`` key on engine/gossip; per-key lists
        of the R host round logs (see :meth:`round`) on host."""
        if self.backend == "host":
            logs = [self._host_round(rb, val) for rb in batches]
            return {k: [lg[k] for lg in logs] for k in logs[0]}
        self._state, tm, logs = self._rounds_jit(self._state, batches, val)
        return dict(logs, train=tm)

    def run_local(self, batches):
        """Sync-free local training ([S, N, ...] stacked, or [S][N] host)."""
        if self.backend == "host":
            for step_batches in batches:
                self._learner.local_steps(step_batches)
            return None
        self._state, tm = self._local_jit(self._state, batches)
        return tm

    def _host_round(self, batches, val):
        lr = self._learner
        for step_batches in batches:
            lr.local_steps(step_batches)
        log = lr.sync(val)
        self._round_ct += 1
        self._rng = jax.random.fold_in(self._rng, self._round_ct - 1)
        return log

    # -- checkpoint / resume -------------------------------------------------

    def save(self, path: str) -> None:
        """Checkpoint the FULL session state (params, opt state, strategy
        stats, active mask, rng, counters) as one msgpack pytree."""
        state = self.state
        meta = {"cfg": dataclasses.asdict(self.cfg), "backend": self.backend,
                "round": int(state.round), "step": int(state.step),
                "format": 1}
        save_pytree(path, state, metadata=meta)

    def load(self, path: str) -> "SwarmSession":
        """Restore a checkpoint into this session (same cfg/param shapes)."""
        meta = load_metadata(path)
        saved_cfg = meta.get("cfg", {})
        for key in ("n_nodes", "merge", "topology", "lora_only",
                    "payload", "wire_dtype"):
            if key in saved_cfg and saved_cfg[key] != getattr(self.cfg, key):
                raise ValueError(
                    f"checkpoint cfg mismatch: {key}={saved_cfg[key]!r} "
                    f"saved vs {getattr(self.cfg, key)!r} in session")
        self.load_state(load_pytree(path, self.state))
        return self

    @classmethod
    def restore(cls, path: str, cfg: SwarmConfig, train_step_fn, eval_fn,
                **kwargs) -> "SwarmSession":
        """Build a session (constructor kwargs supply the param template)
        and restore the checkpointed state into it."""
        return cls(cfg, train_step_fn, eval_fn, **kwargs).load(path)


def load_checkpoint_params(path: str, params_template, *,
                           expect_nodes: Optional[int] = None):
    """Serving-plane ingest surface: read ONLY the stacked per-node params
    out of a full :meth:`SwarmSession.save` checkpoint.

    ``params_template`` is a stacked params pytree (leading node axis N)
    with the target shapes/dtypes/shardings — normally the serving
    ensemble's current live params. ``load_pytree`` restores by flattened
    key, so a params-only ``SwarmState`` template skips the checkpoint's
    opt state, merge stats, wire state and counters without materializing
    them. ``expect_nodes`` cross-checks the checkpoint cfg's ``n_nodes``
    so a serving ensemble can't silently ingest a differently-sized swarm.
    """
    meta = load_metadata(path)
    saved_cfg = meta.get("cfg", {})
    if (expect_nodes is not None and "n_nodes" in saved_cfg
            and saved_cfg["n_nodes"] != expect_nodes):
        raise ValueError(
            f"checkpoint has n_nodes={saved_cfg['n_nodes']}, the serving "
            f"ensemble expects {expect_nodes}")
    template = SwarmState(params=params_template, opt_state=None, stats=None,
                          wire=None, active=None, rng=None, round=None,
                          step=None)
    return load_pytree(path, template).params
