"""Jitted stacked swarm engine: the whole P2P-SL round as ONE compiled program.

The paper's loop (§3.1) — `sync_every` local steps, peer exchange, 80 %-
validation gated commit — was previously host-simulated as a Python loop over
nodes: every sync unstacked N param copies, ran per-node ``eval_fn`` with
``float(...)`` device round-trips, and merged through an unfused mix + where.
This module compiles the round end-to-end over **stacked pytrees** (leading
node axis N):

  local steps   ``jax.vmap`` of the user train step over the node axis,
                ``jax.lax.scan`` over the ``sync_every`` time axis; the
                configured `merge_impl.MergeStrategy` accumulates per-node
                importance statistics (Fisher mass) in the same scan;
  propose       strategy-driven: mixing-matrix contraction or Fisher-
                weighted merge (host backend) / mesh collectives (gossip
                backend, `core.gossip`) — every merge method in-graph;
  gate          in-graph validation metrics for local AND merged params
                (``jax.vmap`` of a traceable ``eval_fn``) → per-node accept
                bits — no host scalar sync anywhere in the round;
  commit        `kernels.fused_merge.fused_merge_tree`: the Pallas kernel
                fuses contraction-over-nodes (W rows, optionally importance-
                weighted for fisher/gradmatch) and gating into one VMEM pass
                per leaf (interpret-mode on CPU).

API
---
**The public entry point is `repro.core.session.SwarmSession`**, which wraps
this engine behind a single `SwarmState` pytree (params, opt state, strategy
stats, runtime active mask, rng, counters) shared with the host and gossip
backends, and adds the lifecycle layer: ``join``/``leave`` as pure state
updates (zero retraces — the mixing matrix is built in-graph by
`topology.mixing_matrix_traced` from the runtime mask) and
``save``/``restore`` checkpointing. Constructing ``SwarmEngine`` directly
still works but is a deprecated spelling of ``SwarmSession(...)``.

``SwarmEngine(cfg, train_step_fn, eval_fn, *, data_sizes, backend, ...)``

  * ``engine.round(params, opt_state, batches, val, active, step0, stats)``
      one jitted round: ``[T, N, ...]`` batches → T vmapped local steps +
      propose + gate + fused commit. ``(params, opt_state, stats)`` are
      donated, so the round updates buffers in place. ``out["stats"]``
      carries the updated importance accumulators for weighted merges.
  * ``engine.run_rounds(params, opt_state, batches, val, active, step0)``
      ``jax.lax.scan`` driver over ``[R, T, N, ...]`` batches: R full rounds
      with zero host round-trips between them (fisher/gradmatch statistics
      live inside the scan carry). Returns per-round train metrics and sync
      logs (gates / metric_local / metric_merged, ``[R, N]``). With
      ``cfg.overlap_sync`` the commit of round k is produced as a *side
      value* and folded in after round k+1's local steps (stale-by-one,
      double-buffered params) so the collective/merge overlaps compute.
  * ``engine.run_local(params, opt_state, batches, step0, stats)``
      sync-free local training over ``[S, N, ...]`` batches (isolated
      baselines, remainder steps) → ``(params, opt_state, metrics, stats)``;
      stats stays None unless accumulators are threaded in.
  * ``engine.propose(stacked, active, fishers)`` / ``engine.sync(...)``
      the pure pieces, reused by `SwarmLearner` (host) and
      `launch.train.make_swarm_sync_step` (SPMD gossip backend).

``train_step_fn(params, opt_state, batch, step) -> (params, opt_state,
metrics)`` — or the opt-in true-Fisher 4-tuple form that additionally
returns per-step ``grads`` (consumed as exact squared gradients by
fisher/gradmatch accumulation) — and ``eval_fn(params, val) -> scalar in
[0, 1]`` must be jax-traceable; arbitrary host callables stay on the
`SwarmLearner` slow path, which still shares `strategy_propose` /
`host_commit` below.

Roofline
--------
The fused commit is memory-bound. For P stacked parameters the mean/fedavg
kernel moves 2N·P·4 bytes (read the [N, BLOCK] tile once per column block,
write N rows) — on TPU v5e (819 GB/s) that is ~9.8 µs per 10⁶ f32 params at
N = 4. The weighted (fisher/gradmatch) commit streams a second [N, BLOCK]
importance tile alongside the params, so it moves 3N·P·4 bytes — ~14.7 µs
per 10⁶ params at N = 4 — and fuses the numerator contraction, denominator
reduction, normalization, and gate select into that single pass; the unfused
XLA chain materializes numerator, denominator, candidate, and select as
separate HBM round-trips (~6N·P moved). Note the gate forces the candidate
to be materialized anyway (its validation metric is part of the round), so
the fused commit re-contracts W·θ (or ΣFθ/ΣF) rather than re-reading
candidate+local. Everything else in the round (vmapped train steps; the
squared-delta Fisher accumulation is one extra elementwise FMA per step) is
compute-bound, so a round's wall time approaches T × (single-node step time)
on hardware with N-way parallelism along the node axis. In
``overlap_sync`` mode the commit additionally leaves the critical path:
round k+1's local steps depend only on round k's *local* params, and the
merge/collective output is consumed one round late — on hardware with async
collectives the sync cost hides entirely behind the next T local steps.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig
import repro.core.topology as topo
from repro.core import comms
from repro.core import merge_impl as merge_lib
from repro.core.lora import combine, split_adapters
from repro.faults.signals import flip_payload_bits
from repro.kernels.fused_merge import (DEFAULT_BLOCK, fused_merge_tree,
                                       fused_quant_merge_tree)


def default_interpret() -> bool:
    """Pallas interpret mode when no TPU is attached (validation mode)."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# model-zoo dispatch: per-node closures over a shared stacked payload
# ---------------------------------------------------------------------------
# In the heterogeneous payload="lora" mode every node's frozen backbone lives
# inside its own train/eval closure and only the shared adapter payload is
# stacked. A single vmap can't dispatch to N different programs, so zoo
# closure lists lower to an unrolled per-node call whose outputs restack —
# same (stacked in, stacked out) contract as the vmapped homogeneous path.
# Engine backend only: on gossip the node axis is sharded, and per-node
# indexing would lower to cross-shard gathers.

def _index_node(tree, i: int):
    """Row ``i`` of every stacked leaf (None subtrees pass through)."""
    return jax.tree.map(lambda x: x[i], tree)


def _stack_nodes(trees):
    """Inverse of :func:`_index_node` over a list of per-node pytrees."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def zoo_vstep(step_fns: Sequence[Callable]) -> Callable:
    """Stacked train-step dispatcher over per-node closures.

    Each ``step_fns[i]`` sees node i's (payload, opt_state, batch) rows and
    must return the same 3-tuple ``(params, opt_state, metrics)`` — or the
    true-Fisher 4-tuple — with structurally identical payload/metrics
    pytrees across nodes (the stacked-state contract; backbones may differ
    arbitrarily inside the closures)."""
    step_fns = list(step_fns)
    n = len(step_fns)

    def vstep(p, o, b, s):
        outs = [step_fns[i](_index_node(p, i), _index_node(o, i),
                            _index_node(b, i), s) for i in range(n)]
        k = len(outs[0])
        if any(len(out) != k for out in outs):
            raise ValueError("zoo train steps must agree on the 3-tuple vs "
                             "true-Fisher 4-tuple return form")
        return tuple(_stack_nodes([out[j] for out in outs])
                     for j in range(k))

    return vstep


def zoo_veval(eval_fns: Sequence[Callable]) -> Callable:
    """Stacked eval dispatcher: node i's closure scores its own payload row
    on its own validation rows → ``[N]`` metric vector."""
    eval_fns = list(eval_fns)

    def veval(p, val):
        return jnp.stack([fn(_index_node(p, i), _index_node(val, i))
                          for i, fn in enumerate(eval_fns)])

    return veval


# ---------------------------------------------------------------------------
# pure building blocks (shared by engine, SwarmLearner, and SPMD paths)
# ---------------------------------------------------------------------------

def mixing_matrix(cfg: SwarmConfig, data_sizes: Sequence[float],
                  active: Optional[Sequence[bool]] = None) -> np.ndarray:
    """Host-side (numpy) mixing matrix for the configured topology."""
    weights = topo.fedavg_weights(data_sizes) if cfg.merge == "fedavg" else None
    return topo.build_matrix(cfg.topology, cfg.n_nodes,
                             weights=weights, self_weight=cfg.self_weight,
                             active=active)


def active_weights(data_sizes, active=None) -> np.ndarray:
    """FedAvg weights zeroed + renormalized over the active membership.

    Departed nodes must not leak into fisher/gradmatch merges with full
    dataset weight — their mass is redistributed over the survivors.
    """
    w = np.asarray(data_sizes, np.float64)
    if active is not None:
        w = w * np.asarray(active, np.float64)
    s = w.sum()
    if s <= 0:  # nobody active: uniform (downstream gates reject everything)
        return np.full(len(w), 1.0 / len(w))
    return w / s


def active_weights_traced(data_sizes, active) -> jnp.ndarray:
    """In-graph version of :func:`active_weights` (active may be traced)."""
    w = jnp.asarray(data_sizes, jnp.float32) * active.astype(jnp.float32)
    s = w.sum()
    n = w.shape[0]
    return jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0), jnp.full((n,), 1.0 / n))


# the mask-departed-nodes invariant lives in merge_impl; re-exported here for
# existing importers
mask_fishers = merge_lib.mask_fishers


# in-graph topology construction now lives in `core.topology`; re-exported
# here for existing importers
dynamic_matrix_traced = topo.dynamic_matrix_traced


def strategy_propose(stacked, cfg: SwarmConfig, W, *, fishers=None,
                     weights=None, strategy=None, rows=None):
    """Merge candidate for every node via the configured `MergeStrategy`.

    Honors lora_only payload selection. Returns ``(candidate, W_commit,
    imp)``: the candidate pytree plus the row-weight matrix / optional
    importance pytree (payload subtree when lora_only) that `host_commit`
    re-contracts through the fused Pallas kernel. ``rows`` (optional [N, N])
    switches fisher/gradmatch to the topology-restricted per-row merge —
    only graph-neighbour contributions enter each node's candidate.
    """
    strategy = strategy or merge_lib.get_strategy(cfg)
    if comms.split_payload_at_sync(cfg):
        adapters, base = split_adapters(stacked)
        f_payload = (split_adapters(fishers)[0] if fishers is not None
                     else None)
        cand, W_eff, imp = strategy.propose(adapters, W, weights=weights,
                                            fishers=f_payload, rows=rows)
        return combine(cand, base), W_eff, imp
    return strategy.propose(stacked, W, weights=weights, fishers=fishers,
                            rows=rows)


def propose_merge(stacked, cfg: SwarmConfig, W, *, fishers=None, weights=None):
    """Merge candidate for every node (candidate-only view of
    :func:`strategy_propose`, kept for existing callers)."""
    return strategy_propose(stacked, cfg, W, fishers=fishers,
                            weights=weights)[0]


def gate_decisions(metric_merged, metric_local, threshold: float,
                   mode: str = "relative"):
    """Per-node accept bits. `relative`: merged ≥ thr × local (robust default);
    `absolute`: merged ≥ thr (the paper's literal 80% reading)."""
    m, l = jnp.asarray(metric_merged), jnp.asarray(metric_local)
    if mode == "relative":
        return m >= threshold * l
    return m >= threshold


def gated_commit(candidate, local, gates):
    """θ_i ← gate_i ? merged_i : local_i (leading node axis) — the unfused
    where-select, used when the candidate is not a W-row mix (fisher/gradmatch)."""
    g = jnp.asarray(gates)

    def one(c, l):
        if c is None or l is None:
            return c if l is None else l
        gb = g.reshape((g.shape[0],) + (1,) * (c.ndim - 1))
        return jnp.where(gb, c, l)

    return jax.tree.map(one, candidate, local, is_leaf=lambda x: x is None)


def host_commit(stacked, candidate, W, gates, cfg: SwarmConfig, *, imp=None,
                block: int = DEFAULT_BLOCK, interpret: bool = False):
    """Commit via the fused Pallas kernel: mean/fedavg re-contract the W rows;
    fisher/gradmatch pass their per-leaf importance weights (``imp``) so the
    normalized weighted merge also runs in the single VMEM pass. Only a
    candidate with no kernel form (gossip backend) falls back to where-select.

    lora_only: only adapter leaves are re-merged; base leaves pass through
    local params bit-exactly (candidate base == local base by construction).
    """
    if cfg.merge in ("mean", "fedavg") or imp is not None:
        kw = dict(block=block, interpret=interpret)
        if comms.split_payload_at_sync(cfg):
            adapters, base = split_adapters(stacked)
            merged = fused_merge_tree(adapters, W, None, gates, imp=imp, **kw)
            return combine(merged, base)
        return fused_merge_tree(stacked, W, None, gates, imp=imp, **kw)
    return gated_commit(candidate, stacked, gates)


# jitted wrappers for the SwarmLearner host path (cfg hashes — frozen dataclass)

@functools.partial(jax.jit, static_argnames=("cfg",))
def _propose_jit(stacked, W, fishers, weights, rows, cfg):
    return strategy_propose(stacked, cfg, W, fishers=fishers, weights=weights,
                            rows=rows)


def propose_host(stacked, cfg: SwarmConfig, W, *, fishers=None, weights=None,
                 rows=None):
    """One-call jitted propose (stack→mix fused by XLA; no eager dispatch).

    Returns ``(candidate, W_commit, imp)`` — see :func:`strategy_propose`.
    """
    w = None if weights is None else jnp.asarray(weights, jnp.float32)
    return _propose_jit(stacked, jnp.asarray(W, jnp.float32), fishers, w,
                        rows, cfg)


@functools.partial(jax.jit, static_argnames=("cfg", "block", "interpret"))
def _commit_jit(stacked, candidate, W, gates, imp, cfg, block, interpret):
    return host_commit(stacked, candidate, W, gates, cfg, imp=imp,
                       block=block, interpret=interpret)


def commit_host(stacked, candidate, W, gates, cfg: SwarmConfig, *, imp=None,
                block: int = DEFAULT_BLOCK, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    return _commit_jit(stacked, candidate, jnp.asarray(W, jnp.float32),
                       jnp.asarray(gates).astype(bool), imp, cfg, block,
                       interpret)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class SwarmEngine:
    """Compiled stacked swarm: vmapped local steps + in-graph gated sync.

    backend="host"    merge via mixing-matrix contraction, commit via the
                      fused Pallas kernel (N param copies on one device —
                      the paper-repro and benchmark path).
    backend="gossip"  merge via `core.gossip` mesh collectives (leading node
                      axis sharded over ``axis``); commit stays the in-graph
                      where-select, since the merged payload already lives on
                      each node's shard.
    """

    def __init__(self, cfg: SwarmConfig, train_step_fn: Optional[Callable],
                 eval_fn: Optional[Callable], *,
                 data_sizes: Optional[Sequence[float]] = None,
                 backend: str = "host", mesh=None, axis: Optional[str] = None,
                 param_specs=None, block: int = DEFAULT_BLOCK,
                 interpret: Optional[bool] = None,
                 strategy: Optional[merge_lib.MergeStrategy] = None):
        if backend not in ("host", "gossip"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "gossip" and (mesh is None or axis is None):
            raise ValueError("gossip backend needs mesh and axis")
        self.cfg = cfg
        self.backend = backend
        self.mesh, self.axis, self.param_specs = mesh, axis, param_specs
        self.block = block
        self.interpret = default_interpret() if interpret is None else interpret
        self.data_sizes = (np.ones(cfg.n_nodes) if data_sizes is None
                           else np.asarray(data_sizes, np.float64))
        self.strategy = strategy or merge_lib.get_strategy(cfg)
        self.wire_dtype = comms.validate_wire_dtype(
            getattr(cfg, "wire_dtype", "f32"))
        self.wire_block = comms.validate_wire_block(
            getattr(cfg, "wire_block", 512))
        # static degradation policy (resolved here, not inside the traced
        # sync): minimum active membership for any commit — docs/faults.md
        self.quorum = int(getattr(cfg, "quorum", 0) or 0)
        if self.quorum > cfg.n_nodes:
            raise ValueError(f"quorum={self.quorum} can never be met with "
                             f"n_nodes={cfg.n_nodes}")
        # what the stacked state covers (full pytree vs adapter-only flat
        # payload) and whether sync still needs to carve the adapter subtree
        # out of it — docs/heterogeneous.md
        self.payload_mode = comms.payload_mode(cfg)
        self._split_lora = comms.split_payload_at_sync(cfg)
        # per-site fairness floor, ANDed into the commit gate like quorum
        self.fairness_floor = float(getattr(cfg, "fairness_floor", 0.0) or 0.0)
        if not 0.0 <= self.fairness_floor <= 1.0:
            raise ValueError("fairness_floor must be a gate-metric value in "
                             f"[0, 1], got {self.fairness_floor}")
        # the comms cost model picks the sync schedule at trace time: for
        # the gossip backend this decides which collectives propose lowers
        # to; for host it reports the SPMD-equivalent wire cost (simulated).
        # model-sharded payloads (inner param specs) drop the q8 psum
        # reductions from the candidate set — they chunk the globally-
        # flattened payload, which a model axis would scramble.
        # The swarm axis may be a 2-tuple of mesh axis names — a two-level
        # ("pod", "node") mesh: flat schedules then run over the joint axis
        # and the per-link-class cost model decides whether the hierarchical
        # pod-delegate forms win (cfg.intra_pod_cost / cfg.cross_pod_cost).
        self._axis_size = None
        self.mesh_shape = None
        if backend == "gossip":
            if isinstance(axis, tuple):
                size = 1
                for a in axis:
                    size *= mesh.shape[a]
                self._axis_size = size
                if len(axis) == 2:
                    self.mesh_shape = (mesh.shape[axis[0]],
                                       mesh.shape[axis[1]])
            else:
                self._axis_size = mesh.shape[axis]
        per = 1 if backend != "gossip" else max(
            1, cfg.n_nodes // self._axis_size)
        self.sync_schedule = comms.pick_schedule(
            cfg, per=per, simulated=(backend != "gossip"),
            model_sharded=(backend == "gossip"
                           and comms.has_inner_sharding(param_specs)),
            mesh_shape=self.mesh_shape)
        # per-node closure lists ("model zoo", heterogeneous backbones)
        # dispatch through the unrolled zoo_vstep/zoo_veval instead of vmap
        zoo = (isinstance(train_step_fn, (list, tuple))
               or isinstance(eval_fn, (list, tuple)))
        if zoo and backend == "gossip":
            raise ValueError(
                "per-node closure lists (model zoo) are engine-backend only: "
                "the gossip backend shards the node axis and per-node "
                "dispatch would lower to cross-shard gathers")

        def _fn_list(fn, what):
            fns = list(fn)
            if len(fns) != cfg.n_nodes:
                raise ValueError(f"{what} zoo must list one closure per node "
                                 f"(got {len(fns)}, n_nodes={cfg.n_nodes})")
            return fns

        if isinstance(train_step_fn, (list, tuple)):
            self._vstep = zoo_vstep(_fn_list(train_step_fn, "train_step_fn"))
        else:
            self._vstep = (None if train_step_fn is None
                           else jax.vmap(train_step_fn,
                                         in_axes=(0, 0, 0, None)))
        if isinstance(eval_fn, (list, tuple)):
            self._veval = zoo_veval(_fn_list(eval_fn, "eval_fn"))
        else:
            self._veval = None if eval_fn is None else jax.vmap(eval_fn)
        self._base_W = mixing_matrix(cfg, self.data_sizes)
        self.spectral_gap = topo.spectral_gap(self._base_W)

        # jitted entry points; (params, opt_state, stats) buffers are donated
        # so a round updates in place — callers must not reuse the inputs.
        self.round = jax.jit(self._round, donate_argnums=(0, 1, 6))
        self.run_rounds = jax.jit(self._run_rounds, donate_argnums=(0, 1))
        self.run_local = jax.jit(self._run_local, donate_argnums=(0, 1, 4))

    def init_stats(self, stacked):
        """Strategy importance accumulators (None for mean/fedavg)."""
        return (self.strategy.init_stats(stacked)
                if self.strategy.uses_stats else None)

    # -- local training ------------------------------------------------------

    def local_steps(self, params, opt_state, batches, step0, stats=None):
        """scan over the leading [T] time axis of vmapped local steps; the
        strategy's importance accumulation rides in the same scan.

        ``train_step_fn`` may opt into the true-Fisher hook by returning a
        4-tuple ``(params, opt_state, metrics, grads)``: the per-step grads
        feed ``strategy.accumulate_grads`` (exact squared gradients) instead
        of the Δθ² proxy.
        """
        def body(carry, batch):
            p, o, st, s = carry
            out = self._vstep(p, o, batch, s)
            if len(out) == 4:
                p2, o2, m, grads = out
                if st is not None:
                    st = self.strategy.accumulate_grads(st, grads, s)
            else:
                p2, o2, m = out
                if st is not None:
                    st = self.strategy.accumulate(st, p, p2, s)
            return (p2, o2, st, s + 1), m

        init = (params, opt_state, stats, jnp.asarray(step0, jnp.int32))
        (p, o, st, _), metrics = jax.lax.scan(body, init, batches)
        return p, o, st, metrics

    # -- propose -------------------------------------------------------------

    def propose(self, stacked, active=None, fishers=None, stats=None):
        """Merge candidate for every node.

        Returns ``(candidate, W_commit, imp)`` — ``W_commit``/``imp`` are
        None on the gossip backend (commit is the in-graph where-select).
        """
        if fishers is None and stats is not None:
            fishers = stats
        if self.backend == "gossip":
            return self._propose_gossip(stacked, active, fishers)[0], None, None
        n = self.cfg.n_nodes
        a = (jnp.ones((n,), bool) if active is None
             else jnp.asarray(active).astype(bool))
        W = self._traced_W(a)
        w = active_weights_traced(self.data_sizes, a)
        if self.strategy.uses_stats and fishers is None:
            # no evidence for any node -> zero mass everywhere, which the
            # eps floor turns into a uniform mean (= SwarmLearner default)
            fishers = jax.tree.map(jnp.zeros_like, stacked)
        fishers = self.strategy.finalize_mass(fishers, a)
        rows = None
        if self.strategy.uses_stats and self.cfg.topology in ("ring",
                                                              "dynamic"):
            # topology-restricted weighted merge: only graph-neighbour
            # contributions enter each node's fisher/gradmatch candidate
            rows = self.strategy.topo_rows(W, w)
        return strategy_propose(stacked, self.cfg, W, fishers=fishers,
                                weights=w, strategy=self.strategy, rows=rows)

    def _pod_rows(self):
        """Pod-level ring mixing matrix for the hierarchical schedules
        ([K, K], K = number of pods). `topo.ring_matrix` folds both
        neighbour edges onto the single peer at K = 2, so the pair mesh
        mixes s·ā_self + (1−s)·ā_peer."""
        return jnp.asarray(
            topo.ring_matrix(self.mesh_shape[0], self.cfg.self_weight),
            jnp.float32)

    def _traced_W(self, active):
        """The round's mixing matrix, built in-graph from the runtime active
        mask (join/leave/failure never retraces the compiled round)."""
        weights = self.data_sizes if self.cfg.merge == "fedavg" else None
        return topo.mixing_matrix_traced(self.cfg.topology, active,
                                         weights=weights,
                                         self_weight=self.cfg.self_weight)

    def _propose_gossip(self, stacked, active, fishers, wire=None):
        """Merge on the mesh, lowered to the collective schedule the comms
        cost model picked at construction (`self.sync_schedule`):

          fedavg_psum / fisher_psum       — global weighted psum(s), f32
          *_psum_q8                       — compression-aware reduction:
                                            int8 reduce-scatter + all_gather
          ring_ppermute / ring_topo_...   — two point-to-point ppermutes
          gathered_rows / gathered_topo_… — one all_gather + row contraction
          hier_*_ring_q8                  — two-level ("pod", "node") mesh:
                                            intra-pod psum reduce → cross-pod
                                            delegate int8 EF ring → intra-pod
                                            all_gather broadcast

        Point-to-point schedules wire-cast their payloads per
        ``cfg.wire_dtype``; with ``wire_dtype="int8"`` every schedule runs
        its error-feedback q8 form against the sharded mesh wire state
        (``wire``; auto-initialized to zero when not threaded).

        Returns ``(merged, new_wire)`` — ``new_wire`` is None unless the
        int8 mesh wire is active."""
        from repro.core import gossip
        from jax.sharding import PartitionSpec as P

        cfg, specs = self.cfg, self.param_specs
        sched = self.sync_schedule.name
        q8 = self.wire_dtype == "int8"
        wire_cast = None if self.wire_dtype == "f32" or q8 else self.wire_dtype
        if q8 and wire is None:
            wire = self._auto_wire(stacked, None)
        # merge="mean" averages uniformly (host W is uniform); only fedavg
        # folds dataset sizes into the psum weights
        sizes = (jnp.asarray(self.data_sizes, jnp.float32)
                 if cfg.merge == "fedavg"
                 else jnp.ones(cfg.n_nodes, jnp.float32))
        weights = sizes / sizes.sum()
        if self._split_lora:
            payload, base = split_adapters(stacked)
            if specs is not None:
                specs = split_adapters(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]
            if fishers is not None:
                fishers = split_adapters(fishers)[0]
        else:
            payload, base = stacked, None

        new_wire = None
        qkw = dict(wire_block=self.wire_block)
        if cfg.merge in ("fisher", "gradmatch"):
            if fishers is None:
                if not self.strategy.uses_stats:
                    raise ValueError(f"{cfg.merge} merge needs fisher "
                                     "estimates or strategy stats")
                fishers = jax.tree.map(jnp.zeros_like, payload)
            a = (jnp.ones((cfg.n_nodes,), bool) if active is None
                 else jnp.asarray(active).astype(bool))
            fishers = self.strategy.finalize_mass(fishers, a)
            w = active_weights_traced(self.data_sizes, a)
            if sched == "hier_fisher_ring_q8":
                # two-level mesh: intra-pod psums reduce the (num ⊕ mass)
                # side channel, the pod-ring mixing matrix plays the role of
                # the flat forms' topo rows (membership within a pod rides
                # the finalized mass; a fully-absent pod is out of scope)
                fishers = self.strategy.gossip_mass(fishers, w)
                merged, new_wire = gossip.hier_fisher_ring_q8(
                    payload, fishers, self._pod_rows(), wire, self.mesh,
                    self.axis, inner_specs=specs, eps=self.strategy.eps,
                    **qkw)
            elif sched in ("fisher_psum", "fisher_psum_q8"):
                # the strategy owns any weight-folding identity (gradmatch ≡
                # w-weighted fisher ratio) — the two psums / the two EF
                # delta-consensus streams do the rest
                fishers = self.strategy.gossip_mass(fishers, w)
                if sched == "fisher_psum_q8":
                    merged, new_wire = gossip.fisher_psum_q8(
                        payload, fishers, wire, self.mesh, self.axis,
                        inner_specs=specs, eps=self.strategy.eps, **qkw)
                else:
                    merged = gossip.fisher_gossip(payload, fishers, self.mesh,
                                                  self.axis, inner_specs=specs)
            else:
                # topology-restricted weighted merge on the mesh: per-row
                # ratio over graph-neighbour contributions only, matching
                # the host backend's `topo_weighted_merge` oracle
                rows = self.strategy.topo_rows(self._traced_W(a), w)
                if q8:
                    fn = (gossip.ring_topo_fisher_gossip_q8
                          if sched == "ring_topo_ppermute"
                          else gossip.topo_fisher_gossip_q8)
                    merged, new_wire = fn(payload, fishers, rows, wire,
                                          self.mesh, self.axis,
                                          inner_specs=specs,
                                          eps=self.strategy.eps, **qkw)
                else:
                    fn = (gossip.ring_topo_fisher_gossip
                          if sched == "ring_topo_ppermute"
                          else gossip.topo_fisher_gossip)
                    merged = fn(payload, fishers, rows, self.mesh, self.axis,
                                inner_specs=specs, eps=self.strategy.eps,
                                wire_dtype=wire_cast)
        elif sched in ("fedavg_psum", "fedavg_psum_q8",
                       "hier_fedavg_ring_q8"):
            a = (None if active is None
                 else jnp.asarray(active).astype(bool))
            # runtime membership stays on the psum schedule: weights are
            # active-masked + renormalized in-graph, and absent nodes keep
            # their own params in the candidate (same semantics as the
            # masked mixing rows, at psum instead of gather cost)
            w_eff = (jnp.asarray(weights, jnp.float32) if a is None
                     else active_weights_traced(sizes, a))
            if sched == "hier_fedavg_ring_q8":
                # intra-pod weighted reduce normalizes per pod (the pod
                # average is invariant to the global renormalization), then
                # pod averages mix over the pod ring
                merged, new_wire = gossip.hier_fedavg_ring_q8(
                    payload, w_eff, self._pod_rows(), wire, self.mesh,
                    self.axis, inner_specs=specs, **qkw)
            elif sched == "fedavg_psum_q8":
                merged, new_wire = gossip.fedavg_psum_q8(
                    payload, w_eff, wire, self.mesh, self.axis,
                    inner_specs=specs, **qkw)
            else:
                merged = gossip.fedavg_gossip(payload, w_eff, self.mesh,
                                              self.axis, inner_specs=specs)
            if a is not None:
                def keep_absent(m, x):
                    if m is None:
                        return None
                    ab = a.reshape((a.shape[0],) + (1,) * (m.ndim - 1))
                    return jnp.where(ab, m, x)

                merged = jax.tree.map(keep_absent, merged, payload,
                                      is_leaf=lambda v: v is None)
        else:
            # in-graph masking so a traced active mask works under jit too
            a = (jnp.ones((cfg.n_nodes,), bool) if active is None
                 else jnp.asarray(active).astype(bool))
            W = self._traced_W(a)
            if sched == "ring_ppermute":
                if q8:
                    merged, new_wire = gossip.ring_rows_gossip_q8(
                        payload, W, wire, self.mesh, self.axis,
                        inner_specs=specs, **qkw)
                else:
                    merged = gossip.ring_rows_gossip(payload, W, self.mesh,
                                                     self.axis,
                                                     inner_specs=specs,
                                                     wire_dtype=wire_cast)
            elif q8:
                merged, new_wire = gossip.matrix_gossip_q8(
                    payload, W, wire, self.mesh, self.axis,
                    inner_specs=specs, **qkw)
            else:
                merged = gossip.matrix_gossip(payload, W, self.mesh,
                                              self.axis, inner_specs=specs,
                                              wire_dtype=wire_cast)

        return (combine(merged, base) if self._split_lora else merged), new_wire

    # -- gated sync ----------------------------------------------------------

    def _auto_wire(self, params, wire):
        """Default EF wire reference when ``cfg.wire_dtype`` enables
        compression but the caller didn't thread state (the direct engine
        tuple API): a zero reference per call — stateless quantization, so
        the knob is honoured (never a silent f32 no-op) even without the
        session's carried ``SwarmState.wire``. On the gossip backend the
        int8 wire state is the schedule-specific sharded mesh EF pytree
        (`gossip.init_mesh_wire`); bf16 stays a stateless cast (no state)."""
        if wire is not None or self.wire_dtype == "f32":
            return wire
        payload = (split_adapters(params)[0] if self._split_lora
                   else params)
        if self.backend == "host":
            return comms.init_wire(payload)
        if self.wire_dtype != "int8":
            return None
        from repro.core import gossip
        return gossip.init_mesh_wire(self.sync_schedule.name, payload,
                                     n_shards=self._axis_size,
                                     wire_block=self.wire_block,
                                     mesh_shape=self.mesh_shape)

    def sync(self, params, val, active=None, stats=None, wire=None,
             faults=None):
        """propose → in-graph validate → gate → fused commit. Pure/traceable.

        ``wire``: the error-feedback wire state from `core.comms` /
        `core.gossip` — peers merge the int8/bf16 wire reconstruction θ̂'
        instead of the exact params and rejected nodes keep exact f32
        locals. On the host backend the commit runs through the fused Pallas
        quantize→merge→dequantize kernel; on the gossip backend the q8
        collective schedules advance the sharded mesh EF state in-graph.
        The advanced state is returned in the log under ``"wire"``.

        ``faults``: optional `repro.faults.signals.FaultSignals` — in-graph
        corrupt-wire injection. Flagged nodes' effective payloads arrive
        bit-flipped; the per-payload checksum (`comms.payload_checksum`)
        detects the damage and the sender is quarantined for the round
        (reject-and-keep-local: excluded from the merge AND gated off, so
        nobody — including the sender — commits corrupted bytes). Only the
        wire-carrying host/engine path supports injection; pass drops
        (membership masking) elsewhere. Both ``faults`` fields are runtime
        data, so arming/disarming never retraces.
        """
        n = self.cfg.n_nodes
        a = (jnp.ones((n,), bool) if active is None
             else jnp.asarray(active).astype(bool))
        wire = self._auto_wire(params, wire)
        use_wire = wire is not None and self.backend == "host"
        use_mesh_wire = wire is not None and self.backend == "gossip"
        if faults is not None and not use_wire:
            raise ValueError(
                "in-graph corrupt-wire injection (faults=) requires the "
                "engine backend with a quantized/EF wire (SwarmState.wire); "
                "lower corrupt events to drops instead "
                "(FaultPlan.lower(corrupt_in_graph=False))")
        log = {}
        if use_wire:
            if self._split_lora:
                payload, base = split_adapters(params)
            else:
                payload, base = params, None
            # θ̂' — what every peer reconstructs from this round's wire
            # traffic; also next round's reference (EF: the residual θ−θ̂'
            # is exactly this round's quantization error)
            eff_payload = comms.wire_effective(payload, wire, self.wire_dtype,
                                               self.wire_block)
            if faults is not None:
                # sender-side checksum of the honest reconstruction, then
                # the (deterministic, seeded) wire damage, then the
                # receiver-side checksum: a mismatch quarantines the sender
                # for this round exactly like an absence.
                sent = comms.payload_checksum(eff_payload)
                eff_payload = flip_payload_bits(eff_payload, faults.corrupt,
                                                faults.key)
                wire_ok = jnp.equal(sent, comms.payload_checksum(eff_payload))
                a = a & wire_ok
                log["wire_ok"] = wire_ok
            eff = (combine(eff_payload, base) if base is not None
                   else eff_payload)
            fishers = None
            if self.strategy.uses_stats:
                f = (stats if stats is not None
                     else jax.tree.map(jnp.zeros_like, params))
                f = self.strategy.finalize_mass(f, a)
                if self._split_lora:
                    # only the payload's mass crosses the wire — don't burn
                    # a full-model quantize pass on base leaves propose will
                    # immediately discard
                    f = split_adapters(f)[0]
                # importance mass crosses the wire too (stateless round-trip:
                # mass errors cancel in the merge ratio, no EF state needed;
                # propose re-finalizes, which only rescales — the merge
                # ratio is scale-free)
                fishers = comms.quant_dequant_tree(f, self.wire_dtype,
                                                   self.wire_block)
            candidate, W, imp = self.propose(eff, a, fishers=fishers,
                                             stats=None)
        elif use_mesh_wire:
            # sharded mesh EF wire: the q8 collective schedule quantizes,
            # exchanges, and reconstructs in-graph; stats are the raw
            # importance accumulators (finalized inside _propose_gossip)
            candidate, new_mesh_wire = self._propose_gossip(
                params, active, stats, wire)
            W = imp = None
            log["wire"] = new_mesh_wire
        else:
            candidate, W, imp = self.propose(params, active, stats=stats)
        metric_local = jnp.where(a, self._veval(params, val), 1.0)
        metric_merged = jnp.where(a, self._veval(candidate, val), 0.0)
        gates = gate_decisions(metric_merged, metric_local,
                               self.cfg.val_threshold) & a
        q = self.quorum
        if q > 0:
            # degradation policy: below quorum the whole round holds locals
            # — every gate closes and the sync is a no-op commit. In-graph
            # on the runtime mask, so membership swings never retrace.
            quorum_ok = jnp.sum(a.astype(jnp.int32)) >= q
            gates = gates & quorum_ok
            log["quorum_ok"] = quorum_ok
        if self.fairness_floor > 0.0:
            # per-site fairness floor (docs/heterogeneous.md): the merged
            # candidate must clear cfg.gate_metric at EVERY active site or
            # the whole swarm holds its locals — a commit that helps the
            # average while degrading the worst site never lands. Inactive
            # sites read as 1.0 so they never drag the min; in-graph on the
            # traced metrics, so metric/membership swings never retrace.
            worst = jnp.min(jnp.where(a, metric_merged, 1.0))
            fair_ok = worst >= self.fairness_floor
            gates = gates & fair_ok
            log["fairness_ok"] = fair_ok
            log["worst_site"] = worst
        if use_wire:
            committed_payload, new_wire = fused_quant_merge_tree(
                payload, wire, W, gates, imp=imp,
                wire_dtype=self.wire_dtype, wire_block=self.wire_block,
                block=self.block, interpret=self.interpret)
            committed = (combine(committed_payload, base)
                         if base is not None else committed_payload)
            log["wire"] = new_wire
        elif self.backend == "host":
            committed = host_commit(params, candidate, W, gates, self.cfg,
                                    imp=imp, block=self.block,
                                    interpret=self.interpret)
        else:
            committed = gated_commit(candidate, params, gates)
        return committed, dict(log, gates=gates, metric_local=metric_local,
                               metric_merged=metric_merged)

    # -- jitted drivers ------------------------------------------------------

    def _round(self, params, opt_state, batches, val, active=None, step0=0,
               stats=None, wire=None, faults=None):
        """T local steps + one gated sync — a single compiled program."""
        if stats is None:
            stats = self.init_stats(params)
        params, opt_state, stats, train_metrics = self.local_steps(
            params, opt_state, batches, step0, stats)
        params, log = self.sync(params, val, active, stats=stats, wire=wire,
                                faults=faults)
        out = dict(log, train=train_metrics)
        if stats is not None:
            out["stats"] = stats
        return params, opt_state, out

    def _run_rounds(self, params, opt_state, batches, val, active=None,
                    step0=0, stats=None, wire=None):
        """scan over R rounds of [R, T, N, ...] batches; no host round-trips.

        Fisher/gradmatch importance accumulators live inside the scan carry,
        so weighted merges run across all R rounds without ever leaving the
        device. ``cfg.overlap_sync`` switches to the double-buffered
        stale-by-one schedule: round k's commit delta is a side value folded
        in after round k+1's local steps, taking the merge (collective on the
        gossip backend) off the critical path at the cost of one round of
        staleness in the consensus signal.
        """
        t = jax.tree.leaves(batches)[0].shape[1]
        if stats is None:
            stats = self.init_stats(params)
        # init the wire ref OUTSIDE the scan so the carry structure is
        # round-invariant (and EF state actually accumulates across rounds)
        wire = self._auto_wire(params, wire)
        step0 = jnp.asarray(step0, jnp.int32)

        if not self.cfg.overlap_sync:
            def body(carry, round_batches):
                p, o, st, wr, s = carry
                p, o, st, tm = self.local_steps(p, o, round_batches, s, st)
                p, log = self.sync(p, val, active, stats=st, wire=wr)
                wr = log.pop("wire", wr)   # wire ref rides the carry, not
                return (p, o, st, wr, s + t), (tm, log)  # the stacked logs

            init = (params, opt_state, stats, wire, step0)
            (p, o, st, wr, _), (train_metrics, logs) = jax.lax.scan(
                body, init, batches)
            if st is not None:   # final accumulators, for chunked callers
                logs = dict(logs, stats=st)
            if wr is not None:
                logs = dict(logs, wire=wr)
            return p, o, train_metrics, logs

        def body(carry, round_batches):
            p, o, st, wr, s, pending = carry
            # local steps depend on the previous round's LOCAL params (plus
            # the already-available stale delta) — never on the in-flight
            # merge, so the sync below can overlap them on hardware.
            p_loc, o, st, tm = self.local_steps(p, o, round_batches, s, st)
            committed, log = self.sync(p_loc, val, active, stats=st, wire=wr)
            wr = log.pop("wire", wr)
            delta = jax.tree.map(lambda c, l: c - l, committed, p_loc)
            p_next = jax.tree.map(lambda l, d: l + d, p_loc, pending)
            return (p_next, o, st, wr, s + t, delta), (tm, log)

        zeros = jax.tree.map(jnp.zeros_like, params)
        init = (params, opt_state, stats, wire, step0, zeros)
        (p, o, st, wr, _, pending), (train_metrics, logs) = jax.lax.scan(
            body, init, batches)
        # fold in the last round's commit so no accepted merge is dropped
        p = jax.tree.map(lambda l, d: l + d, p, pending)
        if st is not None:       # final accumulators, for chunked callers
            logs = dict(logs, stats=st)
        if wr is not None:
            logs = dict(logs, wire=wr)
        return p, o, train_metrics, logs

    def _run_local(self, params, opt_state, batches, step0=0, stats=None):
        """Sync-free local training over [S, N, ...] batches. Returns
        ``(params, opt_state, metrics, stats)`` — stats is None unless
        importance accumulators were passed in (accumulation only runs when
        the caller threads them)."""
        p, o, st, metrics = self.local_steps(params, opt_state, batches,
                                             step0, stats)
        return p, o, metrics, st
